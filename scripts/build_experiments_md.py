"""Compose EXPERIMENTS.md from experiment artifacts:
  experiments/dryrun/*.json   (dry-run records, incl. variants)
  experiments/paper/results_*.json
  experiments/perf_log.json   (hand-maintained hypothesis->result log)

    PYTHONPATH=src python scripts/build_experiments_md.py
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.configs import base as cfgbase  # noqa: E402
from repro.roofline import analysis as RA  # noqa: E402

cfgbase.load_all()


def _fmt_b(x):
    return f"{x/2**30:.2f}"


def dryrun_section() -> str:
    lines = ["## §Dry-run — 40 cells x {16x16, 2x16x16} meshes",
             "",
             "Every (architecture x input-shape) cell lowered + compiled with "
             "`jax.jit(step).lower(...).compile()` on 512 forced host "
             "devices. `args` = parameters + caches per device; `temp` = XLA "
             "temp allocation per device (v5e budget: 16 GiB). Collective "
             "bytes are scan-aware (loop-scope x layer repeats).",
             ""]
    for tag, title in (("sp", "single-pod 16x16 (256 chips)"),
                       ("mp", "multi-pod 2x16x16 (512 chips)")):
        recs = RA.load_records(ROOT / "experiments/dryrun", tag)
        recs = [r for r in recs if r.get("variant", "base") == "base"]
        lines += [f"### {title}", "",
                  "| arch | shape | status | compile s | args GiB/dev | "
                  "temp GiB/dev | collective GiB (scan-aware) |",
                  "|---|---|---|---|---|---|---|"]
        for r in recs:
            if r["status"] != "ok":
                lines.append(f"| {r['arch']} | {r['shape']} | SKIP "
                             f"(sub-quadratic rule) | — | — | — | — |")
                continue
            m = r["memory_analysis"]
            cb = RA.collective_bytes_from_record(r)
            lines.append(
                f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
                f"{_fmt_b(m['argument_size_in_bytes'])} | "
                f"{_fmt_b(m['temp_size_in_bytes'])} | {cb/2**30:.2f} |")
        lines.append("")
    return "\n".join(lines)


def roofline_section() -> str:
    recs = [r for r in RA.load_records(ROOT / "experiments/dryrun", "sp")
            if r.get("variant", "base") == "base"]
    rows, skips = [], []
    for r in recs:
        if r["status"] != "ok":
            skips.append(r)
            continue
        rows.append(RA.analyze_cell(r))
    out = ["## §Roofline — single-pod (256 chips), per (arch x shape)",
           "",
           "Methodology (see `roofline/analysis.py` docstring): XLA's "
           "`cost_analysis` counts `lax.scan` bodies ONCE (verified: a scan "
           "of 8 matmuls reports 1/8 the unrolled FLOPs), so compute/memory "
           "terms use an exact analytic enumerator over the architecture's "
           "tensor ops *as implemented* (full-square masked attention, MoE "
           "capacity buffers, remat re-forward, absorbed-MLA decode, int8 "
           "domains), cross-checked against per-body `cost_analysis`; "
           "collective bytes come from compiled HLO with loop-scope ops "
           "multiplied by the layer-scan trip count. Constants: 197 TFLOP/s "
           "bf16, 819 GB/s HBM, 50 GB/s/link ICI (v5e).",
           "",
           RA.markdown_table(rows)]
    if skips:
        out += ["", "Skipped cells (long_500k on quadratic-attention archs, "
                "DESIGN.md §4): " +
                ", ".join(f"{r['arch']}" for r in skips)]
    return "\n".join(out)


def paper_section() -> str:
    lines = ["## §Paper — faithful reproduction (ODiMO on DIANA cost models)",
             ""]
    for preset in ("medium", "quick"):
        f = ROOT / "experiments/paper" / f"results_{preset}.json"
        if not f.exists():
            continue
        res = json.loads(f.read_text())
        lines += [f"### preset `{preset}`", ""]
        if preset == "medium":
            lines += [
                "Full ResNet20 geometry, noise-0.8 task. CAVEAT read before "
                "the headline row: the fixed-mapping baselines train for "
                "300 steps from scratch directly in quantized mode, and "
                "All-8bit UNDER-TRAINS at this budget (acc 0.26 vs ODiMO's "
                "0.95-1.0, which includes an fp pretrain phase) — so the "
                "headline-vs-All-8bit row is vacuous here; use the `quick` "
                "preset (equal-footing budgets) for the baseline "
                "comparison. What medium DOES show cleanly is the paper's "
                "central accuracy-vs-cost trade on the real geometry: the "
                "λ-sweep spans 28x in modeled latency with accuracy moving "
                "1.000 -> 0.955, and every heuristic baseline is "
                "accuracy-dominated by an ODiMO point of comparable cost "
                "(e.g. All-Ternary 0.774 @1.53e4 cyc vs ODiMO-lat λ=1e-5 "
                "0.979 @1.68e4 cyc — the paper's Min-Cost-vs-ODiMO-Small-En "
                "phenomenon, Table I).", ""]
        lines += [
                  "| record | accuracy | modeled latency (cyc) | modeled "
                  "energy | AIMC ch. % |", "|---|---|---|---|---|"]
        for r in res:
            if r["kind"] == "baseline":
                lines.append(f"| baseline {r['model']}/{r['name']} | "
                             f"{r['accuracy']:.4f} | {r['latency']:.3e} | "
                             f"{r['energy']:.3e} | {r['aimc_ch']:.1%} |")
            elif r["kind"].startswith("odimo"):
                lines.append(f"| {r['kind']} {r['model']} {r['objective']} "
                             f"λ={r['lam']:.0e} | {r['accuracy']:.4f} | "
                             f"{r['latency']:.3e} | {r['energy']:.3e} | "
                             f"{r['aimc_ch']:.1%} |")
        # headline claims
        base8 = [r for r in res if r["kind"] == "baseline"
                 and r["name"] == "all_8bit"]
        od = [r for r in res if r["kind"] == "odimo_diana"]
        if base8 and od:
            a8 = base8[0]
            for obj in ("latency", "energy"):
                cands = [r for r in od if r["objective"] == obj and
                         r["accuracy"] >= a8["accuracy"] - 0.01]
                if cands:
                    b = min(cands, key=lambda r: r[obj])
                    lines.append(
                        f"| **headline: {obj} vs All-8bit** | "
                        f"Δacc {b['accuracy']-a8['accuracy']:+.4f} | "
                        f"**-{1-b[obj]/a8[obj]:.0%} {obj}** | | "
                        f"{b['aimc_ch']:.1%} |")
        lines.append("")
    return "\n".join(lines)


def fleet_section() -> str:
    """int8 precision domains (kvwq8) across every decode cell — the
    paper's technique as a fleet-wide serving feature."""
    base = {(r["arch"], r["shape"]): r
            for r in RA.load_records(ROOT / "experiments/dryrun", "sp")
            if r.get("status") == "ok"}
    var = {(r["arch"], r["shape"]): r
           for r in RA.load_records(ROOT / "experiments/dryrun", "sp-kvwq8")
           if r.get("status") == "ok"}
    if not var:
        return ""
    lines = [
        "## §Perf-fleet — ODiMO int8 domains on every decode cell",
        "",
        "`kv_cache_dtype=int8 + serve_weight_dtype=int8` (the TPU "
        "precision-domain deployment of the paper's technique) applied "
        "fleet-wide; memory term per cell, baseline vs int8 domains:",
        "",
        "| arch | shape | memory term bf16 | int8 domains | gain | dominant after |",
        "|---|---|---|---|---|---|"]
    for key in sorted(var):
        if key not in base:
            continue
        r0 = RA.analyze_cell(base[key])
        r1 = RA.analyze_cell(var[key])
        lines.append(
            f"| {key[0]} | {key[1]} | {r0.t_memory:.3e} s | "
            f"{r1.t_memory:.3e} s | **{r0.t_memory/r1.t_memory:.2f}x** | "
            f"{r1.dominant} |")
    lines += ["",
              "Every decode cell is memory-dominant at baseline; the int8 "
              "domains buy ~2x on the binding term across the fleet except "
              "xlstm-1.3b (1.02x): its decode traffic is dominated by the "
              "f32 mLSTM matrix memory (128 x 4 x 1024^2 x 4B x 42 layers "
              "~ 90 GB/step), which the KV-cache domain does not touch — "
              "the next domain to add is a quantized recurrent state, the "
              "natural ODiMO extension for matrix-memory archs."]
    return "\n".join(lines)


def podaxis_section() -> str:
    """sp vs mp: show the pod axis sharding (proof the 512-chip mesh
    distributes, not just compiles)."""
    sp = {(r["arch"], r["shape"]): r
          for r in RA.load_records(ROOT / "experiments/dryrun", "sp")
          if r.get("status") == "ok"}
    mp = {(r["arch"], r["shape"]): r
          for r in RA.load_records(ROOT / "experiments/dryrun", "mp")
          if r.get("status") == "ok"}
    lines = [
        "## §Pod-axis — single-pod vs 2-pod scaling (from the same records)",
        "",
        "The multi-pod mesh extends data parallelism across pods: per-device "
        "argument+temp memory drops ~2x on train cells (FSDP denominator "
        "doubles) while the collective schedule gains the cross-pod "
        "gradient reduction:",
        "",
        "| arch (train_4k) | args GiB/dev sp -> mp | temp GiB/dev sp -> mp |",
        "|---|---|---|"]
    for (arch, shape) in sorted(sp):
        if shape != "train_4k" or (arch, shape) not in mp:
            continue
        a0 = sp[(arch, shape)]["memory_analysis"]
        a1 = mp[(arch, shape)]["memory_analysis"]
        lines.append(
            f"| {arch} | {a0['argument_size_in_bytes']/2**30:.2f} -> "
            f"{a1['argument_size_in_bytes']/2**30:.2f} | "
            f"{a0['temp_size_in_bytes']/2**30:.2f} -> "
            f"{a1['temp_size_in_bytes']/2**30:.2f} |")
    return "\n".join(lines)


def perf_section() -> str:
    f = ROOT / "experiments/perf_log.json"
    if not f.exists():
        return "## §Perf\n\n(perf log not yet recorded)"
    log = json.loads(f.read_text())
    lines = [
        "## §Perf — hillclimb log (hypothesis -> change -> before -> "
        "after -> verdict)", "",
        "**Paper-faithful baseline vs beyond-paper optimized, separated:** "
        "the §Paper section above is the faithful ODiMO reproduction "
        "(DIANA cost models, Eq. 1-5, Fig. 3 reorg — validated against the "
        "paper's own claims: rich λ-monotone Pareto fronts, baselines "
        "dominated, -96%/-99% modeled latency/energy vs All-8bit at zero "
        "accuracy drop on the synthetic task). Everything below is the "
        "BEYOND-PAPER work: the paper's precision-domain idea applied to "
        "TPU serving (int8 weight/KV-cache domains) plus sharding/algorithm "
        "changes the paper never considered, each logged as "
        "hypothesis -> measure.", "",
        "Scoreboard (dominant roofline term, baseline -> final):", "",
        "| cell | dominant term before | after | gain |",
        "|---|---|---|---|",
        "| yi-9b decode_32k | memory 2.054e-3 s | 1.028e-3 s | **2.0x** |",
        "| deepseek-v2-lite decode_32k | compute 9.902e-3 s | "
        "9.295e-5 s (memory 3.873e-4 s now binds) | **106x** (25x vs "
        "memory bound) |",
        "| arctic-480b decode_32k | collective 9.314e-3 s | 1.742e-4 s "
        "(memory 3.712e-3 s now binds) | **53x** (2.5x vs memory bound) |",
        ""]
    for cell in log["cells"]:
        lines += [f"### {cell['cell']}  —  {cell['why']}", ""]
        for it in cell["iterations"]:
            lines += [f"**{it['name']}**",
                      f"- hypothesis: {it['hypothesis']}",
                      f"- change: {it['change']}",
                      f"- before: {it['before']}",
                      f"- after: {it['after']}",
                      f"- verdict: **{it['verdict']}**", ""]
        if cell.get("stop"):
            lines += [f"_Stop condition: {cell['stop']}_", ""]
    if log.get("notes"):
        lines += ["### Cross-cutting notes", ""]
        lines += [f"- {n}" for n in log["notes"]]
    return "\n".join(lines)


def examples_section() -> str:
    f = ROOT / "experiments/examples_log.json"
    if not f.exists():
        return ""
    log = json.loads(f.read_text())
    lines = ["## §Examples — end-to-end driver runs", ""]
    for e in log:
        lines.append(f"- `{e['cmd']}` → {e['result']}")
    return "\n".join(lines)


def main():
    header = (
        "# EXPERIMENTS\n\n"
        "Artifacts: `experiments/dryrun/*.json` (per-cell compiled dry-run "
        "records), `experiments/paper/results_*.json` (paper reproduction), "
        "`experiments/perf_log.json` (hillclimb). Regenerate this file with "
        "`PYTHONPATH=src python scripts/build_experiments_md.py`.\n")
    parts = [header, paper_section(), dryrun_section(), roofline_section(),
             podaxis_section(), perf_section(), fleet_section(),
             examples_section()]
    (ROOT / "EXPERIMENTS.md").write_text("\n\n".join(p for p in parts if p))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
