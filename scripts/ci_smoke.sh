#!/usr/bin/env bash
# One-command reproducible green/red state for the repo:
#   1. install test deps (skip with SKIP_INSTALL=1 for hermetic containers)
#   2. tier-1 test suite (ROADMAP.md verify command)
#   3. quickstart example in fast mode (exercises the repro.api pipeline,
#      mapping artifact, and the fused split-precision kernel end-to-end)
#   4. the full LM artifact pipeline: train --emit-mapping (schema-v2
#      artifact, scan-stacked layers as name@r entries) -> repro.runtime
#      lowering (ExecutionPlan) -> serve --mapping (per-layer planned kernel
#      execution under jax.jit, full coverage REQUIRED — scan-stacked
#      weights must bind, not silently fall back to fp)
#   5. the CNN artifact pipeline: train --arch cnn:resnet20_tiny
#      --emit-mapping -> lower -> serve --arch cnn:resnet20_tiny --mapping
#      (conv layers execute through the im2col'd planned kernels, full
#      coverage required)
#   6. engine robustness: a deadline-policy open-loop overload run (bounded
#      queue sheds, a high-priority arrival preempts mid-decode, token
#      parity replay), fault injection (detected + requeued + completed),
#      and fail-closed exit 2 on missing/malformed traces
#   7. the runtime bench in quick mode (benchmarks/bench_runtime.py):
#      asserts BENCH_runtime.json is emitted with the zamba2 + cnn legs,
#      zero capability fallbacks on the diana zamba2 leg, and the open-loop
#      leg's shed + degradation gates
#
# Usage:  bash scripts/ci_smoke.sh            # installs requirements-dev.txt
#         SKIP_INSTALL=1 bash scripts/ci_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${SKIP_INSTALL:-0}" != "1" ]]; then
    python -m pip install -r requirements-dev.txt
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== quickstart (fast) =="
python examples/quickstart.py --fast

echo "== LM mapping runtime loop (train --emit-mapping -> lower -> serve --mapping) =="
MAPDIR=$(mktemp -d)
trap 'rm -rf "$MAPDIR"' EXIT
# diana platform: mixed ternary+int8 layers MUST lower to the fused
# split_ternary kernel (they fell back to fp before PR 4) — full coverage
# below proves none of them run unplanned
python -m repro.launch.train --arch zamba2-1.2b --reduce --steps 2 \
    --batch 2 --seq 32 --platform diana \
    --emit-mapping "$MAPDIR/mapping.json"
python -m repro.runtime "$MAPDIR/mapping.json" --arch zamba2-1.2b --reduce \
    --out "$MAPDIR/plan.json"
test -s "$MAPDIR/plan.json"
# scan-stacked layers are in the artifact as name@r entries
grep -q '@0' "$MAPDIR/mapping.json"
python -m repro.launch.serve --arch zamba2-1.2b --reduce --requests 2 \
    --prompt-len 16 --gen-len 4 --mapping "$MAPDIR/mapping.json" \
    --require-full-coverage | tee "$MAPDIR/serve.log"
grep -q "per-layer planned execution" "$MAPDIR/serve.log"
grep -q ", 0 unbound" "$MAPDIR/serve.log"
# the per-kernel histogram is printed and shows the fused ternary+int8
# kernel serving the mixed layers
grep -q "kernel histogram:" "$MAPDIR/serve.log"
grep -q "split_ternary" "$MAPDIR/serve.log"

echo "== serving engine (continuous batching, paged KV, mixed-length trace, diana) =="
# the SAME artifact served through the continuous-batching engine: slot
# admission/retirement over mixed-length prompts, paged KV with chunked
# prefill (the default layout), full planned-kernel coverage still REQUIRED
python -m repro.launch.serve --arch zamba2-1.2b --reduce --engine \
    --requests 4 --prompt-len 12 --gen-len 4 --max-batch 2 \
    --mapping "$MAPDIR/mapping.json" --require-full-coverage \
    | tee "$MAPDIR/engine.log"
grep -q "engine\[continuous\]" "$MAPDIR/engine.log"
grep -q "ttft p50" "$MAPDIR/engine.log"
grep -q "paged kv:" "$MAPDIR/engine.log"

echo "== paged prefix cache (yi-9b, shared-prefix trace) =="
# two requests sharing a 24-token system prefix, served sequentially
# (max-batch 1): the second request must MAP the first one's prefix pages —
# a nonzero prefix-hit count is the smoke gate for the prefix cache
python -m repro.launch.serve --arch yi-9b --reduce --engine \
    --requests 2 --prompt-len 8 --gen-len 4 --max-batch 1 \
    --shared-prefix 24 --page-size 8 | tee "$MAPDIR/prefix.log"
grep -q "paged kv:" "$MAPDIR/prefix.log"
grep -Eq "prefix_hit_tokens=[1-9]" "$MAPDIR/prefix.log"

echo "== self-speculative serving (zamba2 diana draft+target precision bank) =="
# two mapping artifacts of the SAME weights: an all-int8 "target" and a
# 5%-ternary "draft" (train --mapping-bias), bound as one PlanSet bank and
# served with speculative decoding — the gates are (a) the engine's own
# token-identity replay vs target-only serving and (b) a NONZERO
# acceptance rate (the draft must actually agree with the target sometimes)
python -m repro.launch.train --arch zamba2-1.2b --reduce --steps 2 \
    --batch 2 --seq 32 --platform diana \
    --emit-mapping "$MAPDIR/spec_target.json" \
    --mapping-bias digital --mapping-act-scale 2.0
python -m repro.launch.train --arch zamba2-1.2b --reduce --steps 2 \
    --batch 2 --seq 32 --platform diana \
    --emit-mapping "$MAPDIR/spec_draft.json" \
    --mapping-bias aimc:0.05 --mapping-act-scale 2.0
python -m repro.launch.serve --arch zamba2-1.2b --reduce --engine \
    --requests 4 --prompt-len 12 --gen-len 8 --max-batch 2 \
    --mapping "$MAPDIR/spec_target.json" \
    --speculate "$MAPDIR/spec_draft.json" --draft-k 4 \
    --check-spec-parity --require-full-coverage | tee "$MAPDIR/spec.log"
grep -q "planset bank:" "$MAPDIR/spec.log"
grep -q "spec tokens identical to target-only: True" "$MAPDIR/spec.log"
# nonzero acceptance: the rate prints as acceptance=0.xxxx — require a
# nonzero digit after the point
grep -Eq "acceptance=0\.[0-9]*[1-9]" "$MAPDIR/spec.log"

echo "== robustness (deadline preemption + open-loop overload, yi-9b) =="
# a short open-loop overload trace against a 2-slot engine: Poisson
# arrivals outrun service, the bounded queue SHEDS (structured, never
# blocks), and a late high-priority deadline request PREEMPTS a running
# one — the parity replay proves the preempted tokens are identical to
# an unpreempted FCFS run (exit 2 otherwise)
python -m repro.launch.serve --arch yi-9b --reduce --engine \
    --requests 6 --prompt-len 8 --gen-len 8 --max-batch 2 \
    --policy deadline --priorities 0,0,5 --deadlines-ms none,none,20 \
    --poisson 1.5 --max-queue-depth 2 --page-size 8 \
    --check-preempt-parity | tee "$MAPDIR/robust.log"
grep -Eq "robustness: preemptions=[1-9]" "$MAPDIR/robust.log"
grep -Eq " sheds=[1-9]" "$MAPDIR/robust.log"
grep -Eq "preemption token parity .*: True" "$MAPDIR/robust.log"
# fault containment on the same engine: an injected non-finite logit is
# detected, the slot quarantined, the request requeued — and still
# completes (no hang, zero shed, detection count in the summary line)
python -m repro.launch.serve --arch yi-9b --reduce --engine \
    --requests 2 --prompt-len 8 --gen-len 6 --max-batch 2 \
    --fault-spec nonfinite_logits@3:0 --page-size 8 \
    | tee "$MAPDIR/faults.log"
grep -Eq "faults_injected=1 faults_detected=1" "$MAPDIR/faults.log"
grep -Eq "robustness: preemptions=0 resumes=1" "$MAPDIR/faults.log"
# trace loading fails CLOSED: a missing or malformed trace is exit 2,
# not a crash or a silently empty run
set +e
python -m repro.launch.serve --arch yi-9b --reduce --engine \
    --trace "$MAPDIR/missing.jsonl" >/dev/null 2>&1
[[ $? -eq 2 ]] || { echo "missing trace did not exit 2"; exit 1; }
echo 'not json' > "$MAPDIR/bad.jsonl"
python -m repro.launch.serve --arch yi-9b --reduce --engine \
    --trace "$MAPDIR/bad.jsonl" >/dev/null 2>&1
[[ $? -eq 2 ]] || { echo "malformed trace did not exit 2"; exit 1; }
set -e

echo "== CNN mapping runtime loop (train cnn: -> lower -> serve cnn:) =="
python -m repro.launch.train --arch cnn:resnet20_tiny --steps 2 --batch 8 \
    --platform tpu_v5e --emit-mapping "$MAPDIR/cnn_mapping.json"
python -m repro.runtime "$MAPDIR/cnn_mapping.json" \
    --out "$MAPDIR/cnn_plan.json"
test -s "$MAPDIR/cnn_plan.json"
python -m repro.launch.serve --arch cnn:resnet20_tiny --requests 4 \
    --mapping "$MAPDIR/cnn_mapping.json" \
    --require-full-coverage | tee "$MAPDIR/cnn_serve.log"
grep -q "per-layer planned execution" "$MAPDIR/cnn_serve.log"
grep -q ", 0 unbound" "$MAPDIR/cnn_serve.log"

echo "== runtime bench (quick) =="
python benchmarks/bench_runtime.py --quick \
    --legs zamba2,cnn,engine,paged,spec,openloop \
    --out "$MAPDIR/BENCH_runtime.json"
test -s "$MAPDIR/BENCH_runtime.json"
python - "$MAPDIR/BENCH_runtime.json" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
legs = {l["leg"]: l for l in doc["legs"]}
assert "lm:zamba2" in legs and "cnn:resnet20_tiny" in legs, legs.keys()
assert legs["lm:zamba2"]["modes"]["grouped"]["decode_total_tok_s"] > 0
assert not legs["lm:zamba2"]["fallbacks"], legs["lm:zamba2"]["fallbacks"]
eng = legs["engine:yi9b_trace"]
assert eng["policies"]["continuous"]["total_tok_s"] > 0
assert eng["continuous_vs_static_total"] >= 0.9, eng  # machine-drift slack
# paged leg: token parity is asserted INSIDE the bench; re-check the flag
# landed in the doc plus a nonzero prefix-cache hit on the shared trace
pg = legs["engine:yi9b_paged"]
assert pg["paged_token_parity"] is True, pg
assert pg["prefix"]["cold"]["prefix_hit_tokens"] > 0, pg["prefix"]
# speculative leg: token identity + nonzero acceptance are asserted INSIDE
# the bench; re-check both landed in the doc, plus the bank's dedup
sp = legs["engine:yi9b_spec"]
assert sp["spec_token_parity"] is True, sp
assert sp["modes"]["speculative"]["spec_acceptance"] > 0, sp
assert sp["planset_memory"]["dedup_saved_bytes"] > 0, sp["planset_memory"]
# open-loop leg: the overload point sheds and graceful degradation bounds
# the p95 TTFT (both asserted INSIDE the bench; re-check they landed)
ol = legs["engine:yi9b_openloop"]
assert ol["load_sweep"][-1]["shed"] > 0, ol["load_sweep"][-1]
assert ol["degradation"]["p95_ttft_ratio"] <= 1.0, ol["degradation"]
assert ol["degradation"]["degrade"]["degraded"] > 0, ol["degradation"]
print("[ci] BENCH_runtime.json ok:",
      {k: v.get("kernel_histogram") for k, v in legs.items()},
      "engine x%s vs static" % eng["continuous_vs_static_total"],
      "paged peak kv x%s below dense" % pg["dense_vs_paged_peak_kv"])
EOF

echo "ci_smoke OK"
