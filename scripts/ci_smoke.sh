#!/usr/bin/env bash
# One-command reproducible green/red state for the repo:
#   1. install test deps (skip with SKIP_INSTALL=1 for hermetic containers)
#   2. tier-1 test suite (ROADMAP.md verify command)
#   3. quickstart example in fast mode (exercises the repro.api pipeline,
#      mapping artifact, and the fused split-precision kernel end-to-end)
#
# Usage:  bash scripts/ci_smoke.sh            # installs requirements-dev.txt
#         SKIP_INSTALL=1 bash scripts/ci_smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${SKIP_INSTALL:-0}" != "1" ]]; then
    python -m pip install -r requirements-dev.txt
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== quickstart (fast) =="
python examples/quickstart.py --fast

echo "ci_smoke OK"
