"""Request traces: JSONL load/save + seeded synthetic mixed-length traffic.

Trace format (one JSON object per line)::

    {"id": "r0", "prompt": [3, 17, ...], "max_new_tokens": 12,
     "arrival_step": 0, "eos_id": null}

``prompt`` may be replaced by ``"prompt_len": N`` — the loader then draws N
tokens deterministically from the request id (useful for shipping
shape-only traces); that requires a ``vocab``.  `synthetic_trace` builds the
mixed-length trace the engine benchmarks/CI replay when no file is given.
"""
from __future__ import annotations

import json
import zlib
from pathlib import Path
from typing import List, Optional

import numpy as np

from repro.serving.scheduler import Request


def synthetic_trace(n: int, *, vocab: int, min_prompt: int = 4,
                    max_prompt: int = 32, min_new: int = 4,
                    max_new: int = 16, seed: int = 0,
                    arrival_every: int = 0, shared_prefix: int = 0,
                    long_every: int = 0,
                    long_prompt: Optional[int] = None,
                    slo_classes: Optional[List[str]] = None) -> List[Request]:
    """``n`` mixed-length requests with deterministic prompts.  With
    ``arrival_every`` > 0, request i only becomes visible at decode step
    ``i * arrival_every`` (a paced open-loop trace); 0 means everything is
    queued up front (closed-loop, the worst case for static batching).

    ``shared_prefix`` > 0 prepends the SAME deterministic
    ``shared_prefix``-token system prefix to every prompt (the prefix-cache
    workload).  ``long_every`` k > 0 makes every k-th request draw a
    ``long_prompt``-token prompt (default ``4 * max_prompt``) — the
    skewed-length workload where a dense B x max_len pool pays the long
    tail for every slot.  ``slo_classes`` tags request i with class
    ``slo_classes[i % len(slo_classes)]`` (round-robin — the SLO-routing
    workload; tags don't consume rng draws).  Defaults leave the token
    stream byte-identical to traces generated before these knobs existed."""
    rng = np.random.default_rng(seed)
    prefix = None
    if shared_prefix > 0:
        # separate stream: the main rng draws are unchanged by the prefix
        prefix = np.random.default_rng(seed + 1_000_003).integers(
            0, vocab, size=shared_prefix).astype(np.int32)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        gen = int(rng.integers(min_new, max_new + 1))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        if long_every and i % long_every == 0:
            lp = long_prompt if long_prompt is not None else 4 * max_prompt
            prompt = np.random.default_rng(seed + 7 * i + 13).integers(
                0, vocab, size=int(lp)).astype(np.int32)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        reqs.append(Request(
            rid=f"r{i}",
            prompt=prompt,
            max_new_tokens=gen,
            arrival_step=i * arrival_every,
            slo=(slo_classes[i % len(slo_classes)] if slo_classes
                 else None)))
    return reqs


def load_trace(path, vocab: Optional[int] = None) -> List[Request]:
    """Parse a JSONL trace file (see module docstring)."""
    reqs = []
    for ln, line in enumerate(Path(path).read_text().splitlines()):
        line = line.strip()
        if not line:
            continue
        doc = json.loads(line)
        rid = doc.get("id", f"r{ln}")
        if "prompt" in doc:
            prompt = np.asarray(doc["prompt"], dtype=np.int32)
        elif "prompt_len" in doc:
            if vocab is None:
                raise ValueError(f"{path}:{ln + 1}: shape-only trace entry "
                                 f"(prompt_len) needs a vocab to draw tokens")
            # crc32, not hash(): str hashes are salted per process, which
            # would make "deterministic" prompts differ run to run
            rng = np.random.default_rng(zlib.crc32(str(rid).encode()))
            prompt = rng.integers(0, vocab,
                                  size=int(doc["prompt_len"])).astype(np.int32)
        else:
            raise ValueError(f"{path}:{ln + 1}: trace entry needs 'prompt' "
                             f"or 'prompt_len'")
        reqs.append(Request(
            rid=rid, prompt=prompt,
            max_new_tokens=int(doc.get("max_new_tokens", 16)),
            eos_id=doc.get("eos_id"),
            arrival_step=int(doc.get("arrival_step", 0)),
            slo=doc.get("slo")))
    return reqs


def save_trace(path, requests: List[Request]) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    lines = []
    for r in requests:
        doc = {"id": r.rid, "prompt": [int(t) for t in r.prompt],
               "max_new_tokens": r.max_new_tokens, "eos_id": r.eos_id,
               "arrival_step": r.arrival_step}
        if r.slo is not None:
            doc["slo"] = r.slo
        lines.append(json.dumps(doc))
    p.write_text("\n".join(lines) + "\n")
    return p
