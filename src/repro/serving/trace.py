"""Request traces: JSONL load/save + seeded synthetic mixed-length traffic.

Trace format (one JSON object per line)::

    {"id": "r0", "prompt": [3, 17, ...], "max_new_tokens": 12,
     "arrival_step": 0, "eos_id": null}

``prompt`` may be replaced by ``"prompt_len": N`` — the loader then draws N
tokens deterministically from the request id (useful for shipping
shape-only traces); that requires a ``vocab``.  Optional per-request fields
``priority`` (int, higher = more urgent) and ``deadline_ms`` (float) feed
the deadline scheduler and round-trip through `save_trace`/`load_trace`.
`synthetic_trace` builds the mixed-length trace the engine benchmarks/CI
replay when no file is given; `poisson_arrivals` restamps a trace with
seeded open-loop arrival steps at a given offered load.
"""
from __future__ import annotations

import json
import zlib
from pathlib import Path
from typing import List, Optional

import numpy as np

from repro.serving.scheduler import Request


def synthetic_trace(n: int, *, vocab: int, min_prompt: int = 4,
                    max_prompt: int = 32, min_new: int = 4,
                    max_new: int = 16, seed: int = 0,
                    arrival_every: int = 0, shared_prefix: int = 0,
                    long_every: int = 0,
                    long_prompt: Optional[int] = None,
                    slo_classes: Optional[List[str]] = None,
                    priorities: Optional[List[int]] = None,
                    deadlines_ms: Optional[List[Optional[float]]] = None
                    ) -> List[Request]:
    """``n`` mixed-length requests with deterministic prompts.  With
    ``arrival_every`` > 0, request i only becomes visible at decode step
    ``i * arrival_every`` (a paced open-loop trace); 0 means everything is
    queued up front (closed-loop, the worst case for static batching).

    ``shared_prefix`` > 0 prepends the SAME deterministic
    ``shared_prefix``-token system prefix to every prompt (the prefix-cache
    workload).  ``long_every`` k > 0 makes every k-th request draw a
    ``long_prompt``-token prompt (default ``4 * max_prompt``) — the
    skewed-length workload where a dense B x max_len pool pays the long
    tail for every slot.  ``slo_classes`` tags request i with class
    ``slo_classes[i % len(slo_classes)]`` (round-robin — the SLO-routing
    workload; tags don't consume rng draws).  ``priorities`` /
    ``deadlines_ms`` assign scheduling urgency the same round-robin way
    (the deadline-policy workload).  Defaults leave the token stream
    byte-identical to traces generated before these knobs existed."""
    rng = np.random.default_rng(seed)
    prefix = None
    if shared_prefix > 0:
        # separate stream: the main rng draws are unchanged by the prefix
        prefix = np.random.default_rng(seed + 1_000_003).integers(
            0, vocab, size=shared_prefix).astype(np.int32)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(min_prompt, max_prompt + 1))
        gen = int(rng.integers(min_new, max_new + 1))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        if long_every and i % long_every == 0:
            lp = long_prompt if long_prompt is not None else 4 * max_prompt
            prompt = np.random.default_rng(seed + 7 * i + 13).integers(
                0, vocab, size=int(lp)).astype(np.int32)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        reqs.append(Request(
            rid=f"r{i}",
            prompt=prompt,
            max_new_tokens=gen,
            arrival_step=i * arrival_every,
            slo=(slo_classes[i % len(slo_classes)] if slo_classes
                 else None),
            priority=(priorities[i % len(priorities)] if priorities else 0),
            deadline_ms=(deadlines_ms[i % len(deadlines_ms)]
                         if deadlines_ms else None)))
    return reqs


def poisson_arrivals(requests: List[Request], rate: float, *,
                     seed: int = 0) -> List[Request]:
    """Restamp ``requests`` with Poisson-process arrival steps at ``rate``
    requests per engine step (exponential inter-arrival gaps drawn from a
    seeded stream, cumulated and floored to integer steps).  This is the
    open-loop load generator: the offered load is fixed by ``rate``
    regardless of how fast the engine drains, so overload shows up as
    queue growth rather than back-pressured arrivals.  Returns new
    `Request` objects; the inputs are not mutated."""
    if rate <= 0:
        raise ValueError(f"offered load must be > 0 req/step, got {rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / rate, size=len(requests))
    t = 0.0
    out = []
    for req, gap in zip(requests, gaps):
        t += gap
        out.append(Request(
            rid=req.rid, prompt=req.prompt,
            max_new_tokens=req.max_new_tokens, eos_id=req.eos_id,
            arrival_step=int(t), slo=req.slo, priority=req.priority,
            deadline_ms=req.deadline_ms))
    return out


def load_trace(path, vocab: Optional[int] = None) -> List[Request]:
    """Parse a JSONL trace file (see module docstring).

    Raises ValueError naming ``path:line`` for malformed JSON, non-object
    lines, or entries missing both ``prompt`` and ``prompt_len`` — callers
    (the serve CLI) turn that into a clean exit instead of a traceback."""
    reqs = []
    for ln, line in enumerate(Path(path).read_text().splitlines()):
        line = line.strip()
        if not line:
            continue
        try:
            doc = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"{path}:{ln + 1}: malformed trace line ({e})") from None
        if not isinstance(doc, dict):
            raise ValueError(f"{path}:{ln + 1}: trace line must be a JSON "
                             f"object, got {type(doc).__name__}")
        rid = doc.get("id", f"r{ln}")
        if "prompt" in doc:
            prompt = np.asarray(doc["prompt"], dtype=np.int32)
        elif "prompt_len" in doc:
            if vocab is None:
                raise ValueError(f"{path}:{ln + 1}: shape-only trace entry "
                                 f"(prompt_len) needs a vocab to draw tokens")
            # crc32, not hash(): str hashes are salted per process, which
            # would make "deterministic" prompts differ run to run
            rng = np.random.default_rng(zlib.crc32(str(rid).encode()))
            prompt = rng.integers(0, vocab,
                                  size=int(doc["prompt_len"])).astype(np.int32)
        else:
            raise ValueError(f"{path}:{ln + 1}: trace entry needs 'prompt' "
                             f"or 'prompt_len'")
        try:
            reqs.append(Request(
                rid=rid, prompt=prompt,
                max_new_tokens=int(doc.get("max_new_tokens", 16)),
                eos_id=doc.get("eos_id"),
                arrival_step=int(doc.get("arrival_step", 0)),
                slo=doc.get("slo"),
                priority=int(doc.get("priority", 0)),
                deadline_ms=doc.get("deadline_ms")))
        except (TypeError, ValueError) as e:
            raise ValueError(f"{path}:{ln + 1}: bad trace entry: {e}") \
                from None
    return reqs


def save_trace(path, requests: List[Request]) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    lines = []
    for r in requests:
        doc = {"id": r.rid, "prompt": [int(t) for t in r.prompt],
               "max_new_tokens": r.max_new_tokens, "eos_id": r.eos_id,
               "arrival_step": r.arrival_step}
        if r.slo is not None:
            doc["slo"] = r.slo
        if r.priority:
            doc["priority"] = r.priority
        if r.deadline_ms is not None:
            doc["deadline_ms"] = r.deadline_ms
        lines.append(json.dumps(doc))
    p.write_text("\n".join(lines) + "\n")
    return p
