"""repro.serving — continuous-batching inference engine over planned
execution.

The mapper (repro.api -> repro.runtime) answers "which kernel executes each
layer"; this package answers "what latency do real requests see".  It turns
the old fixed-shape serve loop into a reusable engine subsystem so the
planned split-precision kernels are exercised under realistic mixed-length
traffic, and "latency" means request-level TTFT and tokens/s — not a
same-length batch's wall time.

KV lives in a PAGED cache by default: a shared pool of ``num_pages``
fixed-size pages (page_size tokens each, 16 by default — big enough to
amortize gather indexing, small enough that a short request wastes less
than one page per slot), with each slot mapping logical positions through a
``(W,)`` int32 page-table row.  Peak KV memory therefore tracks tokens in
flight rather than B x worst-case ``max_len``; prompts stream in CHUNKS
interleaved with other slots' decode steps; and prompt pages are
content-hashed so requests sharing a system prefix map the SAME pages
(copy-on-write for a partially covered tail page) instead of recomputing
it.  ``kv_layout="dense"`` keeps the PR-5 B x max_len layout as the parity
oracle.

Architecture
    `Engine` (engine.py)        the serving loop: chunked prefill + one
                                jitted page-table decode step over the
                                shared page pool (dense: ragged prefill +
                                per-slot-masked decode over B fixed slots);
                                optional `repro.runtime.PlannedBackend` so
                                every covered projection runs its mapped
                                kernel.
    `PagePool` (paged.py)       host-side refcounted page allocator +
                                exact-token-prefix hash index with LRU
                                parking of retired-but-cached pages and
                                copy-on-write sharing.
    `Scheduler` / `RequestQueue` (scheduler.py)
                                FCFS admission into freed slots between
                                decode steps ("continuous", default) or
                                gang-batched ("static", the baseline the
                                benchmarks compare against).  Paged
                                admission is "fits in free pages" (with
                                head-of-line blocking), not
                                ``prompt_len < max_len``.
    `BatchState` (batch.py)     the B slots: per-slot sequence lengths
                                (= KV positions), active/prefilling flags,
                                page-table rows, last tokens, retire
                                predicate mirrors, and the device cache
                                pool.
    `RequestResult` / `summarize` (metrics.py)
                                per-request TTFT + decode tok/s, p50/p95
                                aggregation (per-SLO-class breakdown when
                                requests carry class tags).
    `SamplingParams` (sampling.py)
                                jit-safe temperature / top-p sampling as
                                per-slot PRNG state; OFF by default.
    traces (trace.py)           JSONL request traces + seeded synthetic
                                mixed-length / skewed-length /
                                shared-prefix / SLO-tagged traffic.

Multi-plan serving (PlanSet precision bank)
    Binding a `repro.runtime.PlanSet` — several precision variants of ONE
    params pytree, prepared buffers deduplicated where layers coincide —
    as the engine ``backend`` unlocks serving-time precision choices:

    * SELF-SPECULATIVE DECODING: ``Engine(..., speculate=("draft",
      "target"), draft_k=4)`` drafts ``draft_k`` greedy tokens per slot
      per round under the cheap draft variant (a `lax.scan` over the paged
      decode step), verifies all of them in ONE fixed-shape target-variant
      `prefill_chunk` (full logits recover the per-position argmax), and
      commits the longest agreeing prefix plus one bonus target token.
      The verify chunk overwrites every draft-written KV position with
      target numerics, and hybrid archs get a replay chunk that rewinds
      partially-accepting slots' recurrent state to the round snapshot and
      re-advances it over the committed tokens — output is TOKEN-IDENTICAL
      to target-only greedy serving (pinned in tests, asserted in the
      bench leg; requires static activation scales).  Acceptance /
      tokens-per-round land in ``engine.stats``.
    * SLO ROUTING: ``Engine(..., slo_routes={"interactive": "draft"})``
      routes each request's SLO class to a plan variant; decode and
      chunked prefill run once per ACTIVE variant group with other groups
      masked (paged masked writes land in the trash page, so groups cannot
      corrupt each other), keeping every request's numerics identical to
      serving it alone under its variant.  `summarize` reports per-class
      TTFT / decode-rate tails.
    Both are PAGED-ONLY: the dense layout writes garbage KV at masked
    slots' live positions, so variant-grouped masked stepping would
    corrupt co-batched requests there.

Robustness (deadline scheduling, overload, faults)
    `Scheduler("deadline")` orders admission by ``(priority, slack)`` —
    slack is time-to-deadline — and PREEMPTS a running slot for a more
    urgent arrival: the victim's committed tokens are recorded, its hashed
    pages PARK in the `PagePool` LRU (still matchable), and it re-enters
    the queue front; resumption prefills ``original prompt + committed
    tokens``, which the prefix cache serves mostly from the parked pages,
    and the token stream is IDENTICAL to an unpreempted run (pinned in
    tests — preemption is a scheduling decision, invisible in the output).
    Overload never blocks forever: queued requests past
    ``max_queue_depth`` / below the free-page ``page_watermark`` /
    over the ``request_timeout_s`` wall-clock budget are SHED with a
    structured `ShedResult` (running requests time out with their partial
    tokens).  A breached p95-TTFT target (``ttft_target_s``) degrades NEW
    admissions to a cheaper PlanSet variant (``degrade_to``) and recovers
    with hysteresis; transitions land in ``engine.degrade_log``.  A seeded
    `FaultInjector` (faults.py) drives the containment machinery: a
    ``jnp.isfinite`` screen over committed logits, slot quarantine,
    corrupted-page purge from the prefix cache, requeue-once recovery
    (token-identical — committed tokens are always clean), and a
    `repro.distributed.fault_tolerance.HeartbeatMonitor` on the engine's
    step clock that catches silently stuck slots.

Request lifecycle (paged)
    submitted -> (arrival_step reached) ready -> fits in free pages ->
    pages reserved (prefix-cache hits map shared pages; only the unique
    suffix needs compute) -> chunked prefill, ``prefill_chunk`` tokens per
    engine step interleaved with decode of other slots -> first token,
    TTFT clock stops -> per-slot decode steps -> retired on eos_id /
    max_new_tokens / page-capacity cap -> pages released (hashed prefix
    pages park in an LRU and stay matchable; the rest return to the free
    list).

Prefix caching is enabled automatically only where sharing is exact:
attention-only, non-MoE, frontend-free archs.  Recurrent (SSM/xLSTM)
per-slot state is not page-resident, and MoE capacity dispatch depends on
batch composition, so their prompts are always recomputed — chunked
prefill still applies (masked chunk steps are exact identities, so
recurrent state carries across chunk boundaries).

Example::

    from repro.serving import Engine, synthetic_trace
    eng = Engine(cfg, params, max_batch=4, max_len=64, backend=planned)
    results = eng.run(synthetic_trace(16, vocab=cfg.vocab))
    print(summarize(results, eng.stats["wall_s"]))

Migration note — ``serve_batch``
    `repro.launch.serve.serve_batch` (and ``serve --mapping``) are now thin
    clients of this engine: a same-length batch is submitted as B requests
    with a shared generation budget, admitted into B slots at once, and
    decoded to completion — token-identical to the old fixed-shape loop.
    Call the engine directly for anything beyond that (mixed lengths,
    queueing, early EOS, paced arrivals, TTFT accounting).

Exactness
    Per-slot masking is exact: for non-MoE archs the engine's greedy tokens
    are identical to serving each request alone (tests pin this), provided
    activation quantization is STATIC when a planned backend is bound —
    dynamic max-abs activation scales are computed over the pooled batch
    and depend on batch composition.  Capacity-style MoE dispatch is
    batch-composition-dependent by design (tokens of co-scheduled requests
    compete for expert capacity), so MoE archs only guarantee parity for
    identical batches.
"""
from repro.serving.batch import BatchState, SlotState
from repro.serving.engine import KV_LAYOUTS, Engine, EngineResult
from repro.serving.faults import FAULT_KINDS, FaultEvent, FaultInjector
from repro.serving.metrics import (RequestResult, Result, ShedResult,
                                   percentile, summarize)
from repro.serving.paged import PagePool
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (POLICIES, Request, RequestQueue,
                                     Scheduler, urgency)
from repro.serving.trace import (load_trace, poisson_arrivals, save_trace,
                                 synthetic_trace)

__all__ = [
    "BatchState", "Engine", "EngineResult", "FAULT_KINDS", "FaultEvent",
    "FaultInjector", "KV_LAYOUTS", "PagePool", "POLICIES", "Request",
    "RequestQueue", "RequestResult", "Result", "SamplingParams",
    "Scheduler", "ShedResult", "SlotState", "load_trace",
    "percentile", "poisson_arrivals", "save_trace", "summarize",
    "synthetic_trace", "urgency",
]
