"""repro.serving — continuous-batching inference engine over planned
execution.

The mapper (repro.api -> repro.runtime) answers "which kernel executes each
layer"; this package answers "what latency do real requests see".  It turns
the old fixed-shape serve loop into a reusable engine subsystem so the
planned split-precision kernels are exercised under realistic mixed-length
traffic, and "latency" means request-level TTFT and tokens/s — not a
same-length batch's wall time.

Architecture
    `Engine` (engine.py)        the serving loop: jitted ragged prefill +
                                one jitted per-slot-masked decode step over
                                a fixed B-slot cache pool; optional
                                `repro.runtime.PlannedBackend` so every
                                covered projection runs its mapped kernel.
    `Scheduler` / `RequestQueue` (scheduler.py)
                                FCFS admission into freed slots between
                                decode steps ("continuous", default) or
                                gang-batched ("static", the baseline the
                                benchmarks compare against).
    `BatchState` (batch.py)     the B slots: per-slot sequence lengths
                                (= KV-cache positions), active flags, last
                                tokens, and the device cache pool.
    `RequestResult` / `summarize` (metrics.py)
                                per-request TTFT + decode tok/s, p50/p95
                                aggregation.
    traces (trace.py)           JSONL request traces + seeded synthetic
                                mixed-length traffic.

Request lifecycle
    submitted -> (arrival_step reached) ready -> admitted into a free slot
    [ragged prefill -> first token, TTFT clock stops] -> per-slot decode
    steps -> retired on eos_id / max_new_tokens / pool length cap -> slot
    freed for the next admission (no drain barrier).

Example::

    from repro.serving import Engine, synthetic_trace
    eng = Engine(cfg, params, max_batch=4, max_len=64, backend=planned)
    results = eng.run(synthetic_trace(16, vocab=cfg.vocab))
    print(summarize(results, eng.stats["wall_s"]))

Migration note — ``serve_batch``
    `repro.launch.serve.serve_batch` (and ``serve --mapping``) are now thin
    clients of this engine: a same-length batch is submitted as B requests
    with a shared generation budget, admitted into B slots at once, and
    decoded to completion — token-identical to the old fixed-shape loop.
    Call the engine directly for anything beyond that (mixed lengths,
    queueing, early EOS, paced arrivals, TTFT accounting).

Exactness
    Per-slot masking is exact: for non-MoE archs the engine's greedy tokens
    are identical to serving each request alone (tests pin this), provided
    activation quantization is STATIC when a planned backend is bound —
    dynamic max-abs activation scales are computed over the pooled batch
    and depend on batch composition.  Capacity-style MoE dispatch is
    batch-composition-dependent by design (tokens of co-scheduled requests
    compete for expert capacity), so MoE archs only guarantee parity for
    identical batches.
"""
from repro.serving.batch import BatchState, SlotState
from repro.serving.engine import Engine
from repro.serving.metrics import RequestResult, percentile, summarize
from repro.serving.scheduler import (POLICIES, Request, RequestQueue,
                                     Scheduler)
from repro.serving.trace import load_trace, save_trace, synthetic_trace

__all__ = [
    "BatchState", "Engine", "POLICIES", "Request", "RequestQueue",
    "RequestResult", "Scheduler", "SlotState", "load_trace", "percentile",
    "save_trace", "summarize", "synthetic_trace",
]
