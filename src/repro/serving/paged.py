"""Host-side page allocator + hash-based prefix cache for the paged KV pool.

The device side of the paged cache is a shared pool of fixed-size KV pages
(`transformer.init_paged_cache`: attention leaves are ``(num_pages+1,
page_size, ...)`` with row 0 reserved as the TRASH page that masked writes
and unmapped page-table entries point at).  This module owns everything the
device never sees: which pages are free, which slot references which pages,
how many requests share a page, and which token prefixes are already
resident.

`PagePool` is a refcounted allocator with content-addressed reuse:

  * ``alloc(n)`` hands out ``n`` fresh pages, evicting least-recently-used
    CACHED pages (refcount 0 but content still valid and hash-indexed) when
    the free list runs dry.
  * ``register(page, key)`` publishes a page's content under a token-prefix
    key once the page is fully written; ``match(prompt)`` walks the longest
    chain of already-resident prefix pages for a new prompt and increfs the
    hits — the caller prefills only the unique suffix.
  * keys are EXACT token bytes (no lossy hashing): ``("f", tokens[:k*ps])``
    for the k-th full page of a prefix, ``("p", tokens)`` for a partial
    tail page holding the end of a full prompt.
  * copy-on-write: full prefix pages are only ever read by sharers (decode
    writes land at positions >= prompt_len, i.e. in later pages), so they
    are shared in place.  A matched PARTIAL tail page will be written by
    the new request's own recompute/decode, so `match` returns it as
    ``cow_src`` — the caller device-copies it into a freshly allocated page
    and drops the pin (`release_cow`).

A retired request decrefs its pages; hashed pages park in the LRU cache
(still allocated, still matchable) instead of returning to the free list,
which is what makes a shared system prompt survive across requests that
never overlap in time.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

import numpy as np

TRASH_PAGE = 0  # device row 0: masked writes + unmapped table entries


class PagePool:
    """Refcounted page allocator with prefix-cache reuse (see module doc).

    Page ids run ``1..num_pages`` (0 is the device trash row).  ``in_use``
    counts pages with refcount >= 1 — the peak of that is the paged
    engine's peak KV footprint."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 1 or page_size < 1:
            raise ValueError(f"need num_pages/page_size >= 1, got "
                             f"{num_pages}/{page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.free: deque = deque(range(1, self.num_pages + 1))
        self.ref = np.zeros(self.num_pages + 1, np.int32)
        self.by_hash: Dict[tuple, int] = {}       # content key -> page id
        self.keys_of: Dict[int, List[tuple]] = {}  # page id -> its keys
        self.lru: "OrderedDict[int, None]" = OrderedDict()  # cached, ref 0
        self.in_use = 0
        self.stats = {"lookups": 0, "hit_requests": 0, "hit_tokens": 0,
                      "hit_pages": 0, "cow_copies": 0, "evictions": 0,
                      "peak_pages": 0}

    # ---- capacity ---------------------------------------------------------

    def available(self) -> int:
        """Pages allocatable right now (free + evictable cached)."""
        return len(self.free) + len(self.lru)

    def pages_for(self, tokens: int) -> int:
        return -(-int(tokens) // self.page_size)

    # ---- refcounting ------------------------------------------------------

    def _claim(self, page: int) -> None:
        if self.ref[page] == 0:
            self.lru.pop(page, None)
            self.in_use += 1
            self.stats["peak_pages"] = max(self.stats["peak_pages"],
                                           self.in_use)
        self.ref[page] += 1

    def incref(self, page: int) -> None:
        self._claim(page)

    def decref(self, page: int) -> None:
        if self.ref[page] <= 0:
            raise RuntimeError(f"decref of unreferenced page {page}")
        self.ref[page] -= 1
        if self.ref[page] == 0:
            self.in_use -= 1
            if page in self.keys_of:      # content stays matchable (cached)
                self.lru[page] = None
            else:
                self.free.append(page)

    # ---- allocation -------------------------------------------------------

    def _evict_one(self) -> int:
        page, _ = self.lru.popitem(last=False)           # least recently used
        for key in self.keys_of.pop(page, []):
            if self.by_hash.get(key) == page:
                del self.by_hash[key]
        self.stats["evictions"] += 1
        return page

    def alloc(self, n: int) -> List[int]:
        """``n`` fresh pages with refcount 1; raises RuntimeError when the
        pool cannot supply them (callers gate admission on `available`)."""
        if n > self.available():
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {self.available()} "
                f"({self.in_use}/{self.num_pages} in use)")
        out = []
        for _ in range(n):
            page = self.free.popleft() if self.free else self._evict_one()
            self.ref[page] = 0
            self._claim(page)
            out.append(page)
        return out

    # ---- prefix cache -----------------------------------------------------

    def register(self, page: int, key: tuple) -> None:
        """Publish ``page``'s content under ``key`` (first writer wins —
        re-registering resident content is a no-op)."""
        if key in self.by_hash:
            return
        self.by_hash[key] = page
        self.keys_of.setdefault(page, []).append(key)

    def prompt_keys(self, prompt: np.ndarray) -> List[Tuple[tuple, int]]:
        """``[(key, end_position), ...]`` for every page of ``prompt`` that
        is fully determined by the prompt itself: each full page, plus the
        partial tail page when the prompt is not page-aligned."""
        ps = self.page_size
        plen = len(prompt)
        keys = [(("f", prompt[:(i + 1) * ps].tobytes()), (i + 1) * ps)
                for i in range(plen // ps)]
        if plen % ps:
            keys.append((("p", prompt.tobytes()), plen))
        return keys

    def match(self, prompt: np.ndarray
              ) -> Tuple[int, List[int], Optional[int]]:
        """Longest resident prefix of ``prompt``.

        Returns ``(hit_len, shared_pages, cow_src)``: ``hit_len`` tokens of
        KV (capped at ``prompt_len - 1`` so at least one token is always
        recomputed to produce first-token logits) are already resident —
        ``shared_pages`` are the fully covered pages (increfed here), and
        ``cow_src`` (increfed: pinned against eviction until the caller's
        `release_cow`) is the page holding the partially covered tail, to
        be device-copied into a page the new request owns."""
        ps = self.page_size
        plen = len(prompt)
        self.stats["lookups"] += 1
        chain: List[int] = []
        while (len(chain) + 1) * ps <= plen:
            page = self.by_hash.get(
                ("f", prompt[:(len(chain) + 1) * ps].tobytes()))
            if page is None:
                break
            chain.append(page)
        matched = len(chain) * ps
        partial = None
        if matched < plen:
            partial = self.by_hash.get(("p", prompt.tobytes()))
            if partial is not None:
                matched = plen
        hit_len = min(matched, plen - 1)
        shared = chain[:hit_len // ps]
        cow_src = None
        if hit_len % ps:
            q = hit_len // ps
            cow_src = chain[q] if q < len(chain) else partial
        for page in shared:
            self._claim(page)
        if cow_src is not None:
            self._claim(cow_src)
            self.stats["cow_copies"] += 1
        if hit_len > 0:
            self.stats["hit_requests"] += 1
            self.stats["hit_tokens"] += hit_len
            self.stats["hit_pages"] += len(shared)
        return hit_len, shared, cow_src

    def release_cow(self, page: int) -> None:
        """Drop the pin `match` took on a copy-on-write source page."""
        self.decref(page)

    def release(self, pages: List[int]) -> None:
        """Retire a request's page list (shared prefix pages survive in the
        LRU cache; unhashed pages return to the free list)."""
        for page in pages:
            self.decref(page)

    def purge(self, pages: List[int]) -> None:
        """Unpublish ``pages`` from the prefix cache (fault containment: a
        corrupted page must never be matched by a later prompt, and must
        return to the FREE list — not the LRU — once its refcount drops).
        Safe on pages that were never hashed; does not touch refcounts, so
        call it before `release`."""
        for page in pages:
            for key in self.keys_of.pop(page, []):
                if self.by_hash.get(key) == page:
                    del self.by_hash[key]
            if self.ref[page] == 0 and page in self.lru:
                del self.lru[page]
                self.free.append(page)
