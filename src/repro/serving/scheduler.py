"""Request queue + slot scheduler for the continuous-batching engine.

`Request` is the unit of work (one prompt, one generation budget); the
`RequestQueue` holds submitted requests in arrival order, optionally gated
by an ``arrival_step`` (trace replay: a request only becomes visible once
the engine's decode-step clock reaches it).  The `Scheduler` decides which
queued requests enter which free slots between decode steps:

  * ``policy="continuous"`` (the engine default) admits ready requests into
    EVERY free slot, every step — slots freed by retired requests are
    refilled immediately while the rest of the batch keeps decoding.  This
    is what makes mixed-length traffic cheap: a short request never holds
    the batch hostage to the longest one.
  * ``policy="static"`` is the classic static-batching baseline: requests
    are admitted in gangs of up to ``max_batch`` and the next gang waits
    until EVERY slot has retired.  `benchmarks/bench_runtime.py` runs both
    policies over the same trace to measure what continuous batching buys.

Both policies are FCFS.  Admission capacity is layout-dependent: the dense
engine rejects ``prompt_len >= max_len`` at submission time, while the paged
engine admits anything that FITS IN FREE PAGES — `admissions` takes an
optional ``fits(request)`` callback (the engine's page-reservation check)
and blocks head-of-line when the oldest visible request does not fit, so
FCFS order is preserved instead of starving large requests.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, List, Optional, Tuple

import numpy as np

POLICIES = ("continuous", "static")


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is a 1-D int32 token array; ``max_new_tokens`` caps the
    generation (the first token — sampled from the prefill logits — counts);
    ``eos_id`` retires the request early when sampled.  ``arrival_step``
    hides the request from the scheduler until the engine's decode-step
    clock reaches it (trace replay).  ``frontend`` optionally carries a
    per-request cross-attention source row (vision/audio archs).  ``slo``
    optionally names the request's SLO class — engines built on a
    multi-plan `repro.runtime.PlanSet` route each class to a bound plan
    variant (``Engine(slo_routes=...)``), making the paper's
    accuracy/latency trade-off per-request instead of per-deployment."""
    rid: Any
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_step: int = 0
    frontend: Optional[np.ndarray] = None
    slo: Optional[str] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, dtype=np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid!r}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid!r}: max_new_tokens must be "
                             f">= 1, got {self.max_new_tokens}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)


class RequestQueue:
    """FCFS queue with arrival-step visibility."""

    def __init__(self):
        self._q: deque[Request] = deque()

    def push(self, req: Request) -> None:
        self._q.append(req)

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self):
        return iter(self._q)

    def ready(self, step: int) -> int:
        """How many queued requests are visible at decode step ``step``."""
        return sum(1 for r in self._q if r.arrival_step <= step)

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival_step still queued (None when empty)."""
        return min((r.arrival_step for r in self._q), default=None)

    def pop_ready(self, step: int, k: int, fits=None) -> List[Request]:
        """Up to ``k`` visible requests, FCFS (non-visible ones keep their
        relative order).  ``fits(request) -> bool`` gates admission on
        resources (free KV pages); the first visible request that does NOT
        fit blocks everything behind it — head-of-line blocking keeps FCFS
        fairness instead of starving large requests."""
        out: List[Request] = []
        rest: deque[Request] = deque()
        blocked = False
        while self._q and len(out) < k:
            r = self._q.popleft()
            if r.arrival_step <= step and not blocked:
                if fits is None or fits(r):
                    out.append(r)
                    continue
                blocked = True
            rest.append(r)
        rest.extend(self._q)
        self._q = rest
        return out


class Scheduler:
    """Slot-admission policy over a `RequestQueue` (see module docstring)."""

    def __init__(self, policy: str = "continuous"):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {policy!r}")
        self.policy = policy

    def admissions(self, queue: RequestQueue, free_slots: List[int],
                   n_active: int, step: int,
                   fits=None) -> List[Tuple[int, Request]]:
        """``[(slot, request), ...]`` to admit before the next decode step.
        ``fits`` is forwarded to `RequestQueue.pop_ready` (page-aware
        admission, head-of-line blocking)."""
        if not free_slots:
            return []
        if self.policy == "static" and n_active > 0:
            return []  # gang scheduling: wait for the whole batch to drain
        reqs = queue.pop_ready(step, len(free_slots), fits=fits)
        return list(zip(free_slots, reqs))
