"""Request queue + slot scheduler for the continuous-batching engine.

`Request` is the unit of work (one prompt, one generation budget); the
`RequestQueue` holds submitted requests in arrival order, optionally gated
by an ``arrival_step`` (trace replay: a request only becomes visible once
the engine's decode-step clock reaches it).  The `Scheduler` decides which
queued requests enter which free slots between decode steps:

  * ``policy="continuous"`` (the engine default) admits ready requests into
    EVERY free slot, every step — slots freed by retired requests are
    refilled immediately while the rest of the batch keeps decoding.  This
    is what makes mixed-length traffic cheap: a short request never holds
    the batch hostage to the longest one.
  * ``policy="static"`` is the classic static-batching baseline: requests
    are admitted in gangs of up to ``max_batch`` and the next gang waits
    until EVERY slot has retired.  `benchmarks/bench_runtime.py` runs both
    policies over the same trace to measure what continuous batching buys.
  * ``policy="deadline"`` orders admission by urgency instead of arrival:
    higher ``Request.priority`` first, then smallest deadline slack
    (``t_ready + deadline_ms - now``).  Requests without a deadline sort
    last within their priority band (infinite slack).  Under this policy
    the engine may also PREEMPT a running slot (retire-and-requeue) when a
    waiting request is strictly more urgent than the least-urgent active
    one — see `repro.serving.engine`.

``continuous``/``static`` are FCFS.  Admission capacity is layout-dependent:
the dense engine rejects ``prompt_len >= max_len`` at submission time, while
the paged engine admits anything that FITS IN FREE PAGES — `admissions`
takes an optional ``fits(request)`` callback (the engine's page-reservation
check) and blocks head-of-line when the oldest (or, under ``deadline``, the
most urgent) visible request does not fit, so ordering is preserved instead
of starving large requests.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

POLICIES = ("continuous", "static", "deadline")


def _int_like(x) -> bool:
    return isinstance(x, (int, np.integer)) and not isinstance(x, bool)


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt`` is a 1-D int32 token array; ``max_new_tokens`` caps the
    generation (the first token — sampled from the prefill logits — counts);
    ``eos_id`` retires the request early when sampled.  ``arrival_step``
    hides the request from the scheduler until the engine's decode-step
    clock reaches it (trace replay).  ``frontend`` optionally carries a
    per-request cross-attention source row (vision/audio archs).  ``slo``
    optionally names the request's SLO class — engines built on a
    multi-plan `repro.runtime.PlanSet` route each class to a bound plan
    variant (``Engine(slo_routes=...)``), making the paper's
    accuracy/latency trade-off per-request instead of per-deployment.

    ``priority`` and ``deadline_ms`` feed the ``deadline`` scheduler
    policy: larger priority admits first; within a priority band the
    smallest slack (time until ``t_ready + deadline_ms``) wins.  Neither
    affects the FCFS policies."""
    rid: Any
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival_step: int = 0
    frontend: Optional[np.ndarray] = None
    slo: Optional[str] = None
    priority: int = 0
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, dtype=np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError(f"request {self.rid!r}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid!r}: max_new_tokens must be "
                             f">= 1, got {self.max_new_tokens}")
        if not _int_like(self.arrival_step) or self.arrival_step < 0:
            raise ValueError(f"request {self.rid!r}: arrival_step must be a "
                             f"non-negative int, got {self.arrival_step!r}")
        if self.eos_id is not None and not _int_like(self.eos_id):
            raise ValueError(f"request {self.rid!r}: eos_id must be an int "
                             f"or None, got {self.eos_id!r}")
        if not _int_like(self.priority):
            raise ValueError(f"request {self.rid!r}: priority must be an "
                             f"int, got {self.priority!r}")
        if self.deadline_ms is not None:
            try:
                self.deadline_ms = float(self.deadline_ms)
            except (TypeError, ValueError):
                raise ValueError(
                    f"request {self.rid!r}: deadline_ms must be a finite "
                    f"non-negative number, got {self.deadline_ms!r}") from None
            if math.isnan(self.deadline_ms) or self.deadline_ms < 0:
                raise ValueError(
                    f"request {self.rid!r}: deadline_ms must be a finite "
                    f"non-negative number, got {self.deadline_ms!r}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)


def urgency(req: Request, now: float,
            t_ready: Optional[float] = None) -> Tuple[int, float]:
    """Sort key for the ``deadline`` policy — smaller = more urgent.

    ``(-priority, slack_s)`` where slack is the time remaining until the
    request's deadline (``t_ready + deadline_ms/1e3 - now``); no deadline
    means infinite slack.  ``t_ready`` is when the request became visible
    (defaults to ``now``, i.e. slack = full deadline)."""
    if req.deadline_ms is None:
        slack = math.inf
    else:
        ready = now if t_ready is None else t_ready
        slack = ready + req.deadline_ms / 1e3 - now
    return (-int(req.priority), slack)


class RequestQueue:
    """FCFS queue with arrival-step visibility."""

    def __init__(self):
        self._q: deque[Request] = deque()

    def push(self, req: Request) -> None:
        self._q.append(req)

    def push_front(self, req: Request) -> None:
        """Requeue at the head (preempted/faulted requests resume first
        among equally-urgent peers instead of going to the back)."""
        self._q.appendleft(req)

    def remove(self, req: Request) -> bool:
        """Drop ``req`` from the queue (identity match); True if found."""
        for i, r in enumerate(self._q):
            if r is req:
                del self._q[i]
                return True
        return False

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self):
        return iter(self._q)

    def ready(self, step: int) -> int:
        """How many queued requests are visible at decode step ``step``."""
        return sum(1 for r in self._q if r.arrival_step <= step)

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival_step still queued (None when empty)."""
        return min((r.arrival_step for r in self._q), default=None)

    def pop_ready(self, step: int, k: int, fits=None,
                  order: Optional[Callable[[Request], Any]] = None,
                  ) -> List[Request]:
        """Up to ``k`` visible requests (non-visible ones keep their
        relative order).  ``fits(request) -> bool`` gates admission on
        resources (free KV pages); the first candidate that does NOT fit
        blocks everything behind it — head-of-line blocking keeps the
        admission order fair instead of starving large requests.

        Without ``order`` candidates are considered FCFS.  With ``order``
        (a sort key: smaller = sooner) visible requests are considered in
        key order (stable, so FCFS breaks ties) — the ``deadline`` policy
        passes `urgency`."""
        if order is None:
            out: List[Request] = []
            rest: deque[Request] = deque()
            blocked = False
            while self._q and len(out) < k:
                r = self._q.popleft()
                if r.arrival_step <= step and not blocked:
                    if fits is None or fits(r):
                        out.append(r)
                        continue
                    blocked = True
                rest.append(r)
            rest.extend(self._q)
            self._q = rest
            return out
        visible = [r for r in self._q if r.arrival_step <= step]
        out = []
        taken: set = set()
        for r in sorted(visible, key=order):  # stable: FCFS breaks ties
            if len(out) >= k:
                break
            if fits is not None and not fits(r):
                break  # most-urgent blocks: don't starve it with cheap work
            out.append(r)
            taken.add(id(r))
        if taken:
            self._q = deque(r for r in self._q if id(r) not in taken)
        return out


class Scheduler:
    """Slot-admission policy over a `RequestQueue` (see module docstring)."""

    def __init__(self, policy: str = "continuous"):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {policy!r}")
        self.policy = policy

    @property
    def preempts(self) -> bool:
        """Whether the engine should consider preemption under this policy."""
        return self.policy == "deadline"

    def admissions(self, queue: RequestQueue, free_slots: List[int],
                   n_active: int, step: int, fits=None,
                   now: float = 0.0,
                   t_ready: Optional[Dict[int, float]] = None,
                   ) -> List[Tuple[int, Request]]:
        """``[(slot, request), ...]`` to admit before the next decode step.
        ``fits`` is forwarded to `RequestQueue.pop_ready` (page-aware
        admission, head-of-line blocking).  ``now``/``t_ready`` (a map of
        ``id(request) -> became-visible time``) only matter under the
        ``deadline`` policy, which sorts candidates by `urgency`."""
        if not free_slots:
            return []
        if self.policy == "static" and n_active > 0:
            return []  # gang scheduling: wait for the whole batch to drain
        order = None
        if self.policy == "deadline":
            tr = t_ready or {}
            order = lambda r: urgency(r, now, tr.get(id(r)))
        reqs = queue.pop_ready(step, len(free_slots), fits=fits, order=order)
        return list(zip(free_slots, reqs))
