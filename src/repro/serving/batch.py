"""Slot-based batch state for the continuous-batching engine.

`BatchState` owns the fixed pool of B decode slots: the per-slot sequence
lengths (each slot's KV-cache position), the per-slot last sampled token and
active flags — all host-side numpy, handed to the jitted decode step each
call — plus the device-side cache pool pytree (dense `transformer.init_cache`
or paged `init_paged_cache` layout).

Under the PAGED layout it additionally carries the per-slot page tables
(``page_table`` (B, W) int32 rows of page-pool indices, 0 = unmapped/trash)
and the chunked-prefill progress state: a slot being prefilled is BUSY
(``prefilling``, not eligible for admission) but not yet ACTIVE (not
decoding); ``fill_pos`` tracks how many prompt tokens are already in its
pages.  Retire-predicate inputs (``eos_id``/``max_new``/``n_gen``) are
mirrored into numpy arrays at assignment so the engine's post-decode retire
sweep is one vectorized pass over host data — no per-slot device sync.

Host-side per-slot bookkeeping (the request occupying the slot, its
generated tokens, timing marks) lives in `SlotState`; nothing here touches
jax beyond holding the cache pool reference.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import numpy as np

from repro.serving.scheduler import Request


@dataclasses.dataclass
class SlotState:
    """Host bookkeeping for one occupied slot."""
    request: Request
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_ready: float = 0.0          # wall time the request became schedulable
    t_first: float = 0.0          # wall time its first token materialized
    admitted_step: int = 0


@dataclasses.dataclass
class PendingPrefill:
    """A request whose prompt is still streaming into its pages.

    ``prompt`` is the EFFECTIVE prompt being prefilled — for a fresh
    request it is ``request.prompt``; for a request resuming after
    preemption/fault-requeue it is the original prompt plus every token
    already committed (``prior_tokens``), whose prefill reproduces the
    exact decode state the slot held when it was retired.  ``t_first``
    preserves the original first-token timestamp across a resume (TTFT is
    a property of the first admission, not the resume)."""
    request: Request
    t_ready: float = 0.0
    admitted_step: int = 0
    prompt: Optional[np.ndarray] = None
    prior_tokens: List[int] = dataclasses.field(default_factory=list)
    t_first: Optional[float] = None


class BatchState:
    """Fixed B slots of decode state (see module docstring).

    ``pages_per_slot`` (W) switches on the paged bookkeeping; dense-layout
    engines leave it None and never touch the page fields."""

    def __init__(self, max_batch: int, caches, pages_per_slot: int = None):
        self.max_batch = int(max_batch)
        self.caches = caches                       # device cache pool
        self.lengths = np.zeros(self.max_batch, np.int32)
        self.active = np.zeros(self.max_batch, bool)
        self.last_tok = np.zeros(self.max_batch, np.int32)
        self.slots: List[Optional[SlotState]] = [None] * self.max_batch
        # vectorized-retire inputs, mirrored from the request at assignment
        self.eos_id = np.full(self.max_batch, -1, np.int64)
        self.max_new = np.zeros(self.max_batch, np.int64)
        self.n_gen = np.zeros(self.max_batch, np.int64)
        # per-slot plan-variant key (SLO routing over a PlanSet; None =
        # backend default) and per-slot PRNG key rows (non-greedy sampling;
        # zeros when the engine is greedy — the keys still ride through the
        # jitted calls so the trace shape is sampling-independent)
        self.variant: List[Optional[str]] = [None] * self.max_batch
        self.rng = np.zeros((self.max_batch, 2), np.uint32)
        # paged layout: page tables + chunked-prefill progress
        self.pages_per_slot = pages_per_slot
        if pages_per_slot is not None:
            self.page_table = np.zeros((self.max_batch, int(pages_per_slot)),
                                       np.int32)
            self.slot_pages: List[List[int]] = [[] for _ in
                                                range(self.max_batch)]
        self.prefilling = np.zeros(self.max_batch, bool)
        self.fill_pos = np.zeros(self.max_batch, np.int32)
        self.pending: List[Optional[PendingPrefill]] = \
            [None] * self.max_batch

    # ---- queries ---------------------------------------------------------

    def free_slots(self) -> List[int]:
        """Slots holding neither a decoding nor a prefilling request."""
        return [b for b in range(self.max_batch)
                if not (self.active[b] or self.prefilling[b])]

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    @property
    def n_busy(self) -> int:
        """Active + prefilling (what gang scheduling must wait out)."""
        return int((self.active | self.prefilling).sum())

    def any_active(self) -> bool:
        return bool(self.active.any())

    def any_busy(self) -> bool:
        return bool((self.active | self.prefilling).any())

    # ---- transitions -----------------------------------------------------

    def start_prefill(self, slot: int, req: Request, pages: List[int],
                      hit_len: int, t_ready: float, step: int,
                      prompt: Optional[np.ndarray] = None,
                      prior_tokens: Optional[List[int]] = None,
                      t_first: Optional[float] = None) -> None:
        """Begin chunked prefill of ``req`` in ``slot``: map its ``pages``
        into the slot's page table and start streaming the prompt at
        position ``hit_len`` (>0 when a cached prefix was matched — those
        tokens' KV is already resident in the shared pages).  ``prompt``
        overrides the prefilled token stream for preemption/fault resumes
        (original prompt + committed ``prior_tokens``)."""
        if self.active[slot] or self.prefilling[slot]:
            raise RuntimeError(f"slot {slot} is busy")
        self.prefilling[slot] = True
        self.fill_pos[slot] = hit_len
        self.lengths[slot] = hit_len
        self.slot_pages[slot] = list(pages)
        self.page_table[slot, :] = 0
        self.page_table[slot, :len(pages)] = pages
        self.pending[slot] = PendingPrefill(
            request=req, t_ready=t_ready, admitted_step=step,
            prompt=req.prompt if prompt is None else prompt,
            prior_tokens=list(prior_tokens or []), t_first=t_first)

    def assign(self, slot: int, req: Request, first_token: int,
               t_ready: float, t_first: float, step: int,
               prompt_len: Optional[int] = None,
               prior_tokens: Optional[List[int]] = None) -> SlotState:
        """Occupy ``slot`` with ``req`` whose prefill produced
        ``first_token``; the slot's cache length is the prompt length (the
        first generated token is not in the cache yet).  Resumes pass the
        EFFECTIVE ``prompt_len`` (original + committed tokens already in
        the cache) and ``prior_tokens`` so the slot picks up mid-stream:
        the token count, cache position, eos/max-new accounting all
        continue exactly where the preempted slot left off."""
        if self.active[slot]:
            raise RuntimeError(f"slot {slot} is still active")
        toks = list(prior_tokens or []) + [int(first_token)]
        st = SlotState(request=req, tokens=toks,
                       t_ready=t_ready, t_first=t_first, admitted_step=step)
        self.slots[slot] = st
        self.lengths[slot] = (req.prompt_len if prompt_len is None
                              else int(prompt_len))
        self.active[slot] = True
        self.prefilling[slot] = False
        self.pending[slot] = None
        self.last_tok[slot] = int(first_token)
        self.eos_id[slot] = -1 if req.eos_id is None else int(req.eos_id)
        self.max_new[slot] = int(req.max_new_tokens)
        self.n_gen[slot] = len(toks)
        return st

    def retire(self, slot: int) -> SlotState:
        """Free ``slot`` and return its bookkeeping (the engine turns it
        into a `RequestResult`).  The cache pool is left as-is — admission
        overwrites/remaps the slot's cache wholesale."""
        st = self.slots[slot]
        if st is None:
            raise RuntimeError(f"slot {slot} is not occupied")
        self.active[slot] = False
        self.slots[slot] = None
        self.eos_id[slot] = -1
        self.n_gen[slot] = 0
        self.variant[slot] = None
        return st
