"""Slot-based batch state for the continuous-batching engine.

`BatchState` owns the fixed pool of B decode slots: the per-slot sequence
lengths (each slot's KV-cache position), the per-slot last sampled token and
active flags — all host-side numpy, handed to the jitted decode step each
call — plus the device-side cache pool pytree (`transformer.init_cache`
layout) that `transformer.scatter_cache` writes admitted requests into.

Host-side per-slot bookkeeping (the request occupying the slot, its
generated tokens, timing marks) lives in `SlotState`; nothing here touches
jax beyond holding the cache pool reference.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import numpy as np

from repro.serving.scheduler import Request


@dataclasses.dataclass
class SlotState:
    """Host bookkeeping for one occupied slot."""
    request: Request
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_ready: float = 0.0          # wall time the request became schedulable
    t_first: float = 0.0          # wall time its first token materialized
    admitted_step: int = 0


class BatchState:
    """Fixed B slots of decode state (see module docstring)."""

    def __init__(self, max_batch: int, caches):
        self.max_batch = int(max_batch)
        self.caches = caches                       # device cache pool
        self.lengths = np.zeros(self.max_batch, np.int32)
        self.active = np.zeros(self.max_batch, bool)
        self.last_tok = np.zeros(self.max_batch, np.int32)
        self.slots: List[Optional[SlotState]] = [None] * self.max_batch

    # ---- queries ---------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [b for b in range(self.max_batch) if not self.active[b]]

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def any_active(self) -> bool:
        return bool(self.active.any())

    # ---- transitions -----------------------------------------------------

    def assign(self, slot: int, req: Request, first_token: int,
               t_ready: float, t_first: float, step: int) -> SlotState:
        """Occupy ``slot`` with ``req`` whose prefill produced
        ``first_token``; the slot's cache length is the prompt length (the
        first generated token is not in the cache yet)."""
        if self.active[slot]:
            raise RuntimeError(f"slot {slot} is still active")
        st = SlotState(request=req, tokens=[int(first_token)],
                       t_ready=t_ready, t_first=t_first, admitted_step=step)
        self.slots[slot] = st
        self.lengths[slot] = req.prompt_len
        self.active[slot] = True
        self.last_tok[slot] = int(first_token)
        return st

    def retire(self, slot: int) -> SlotState:
        """Free ``slot`` and return its bookkeeping (the engine turns it
        into a `RequestResult`).  The cache pool is left as-is — admission
        overwrites the slot's cache wholesale."""
        st = self.slots[slot]
        if st is None:
            raise RuntimeError(f"slot {slot} is not occupied")
        self.active[slot] = False
        self.slots[slot] = None
        return st
