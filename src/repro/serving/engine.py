"""`Engine`: continuous-batching inference over (optionally planned) LMs.

One engine owns a fixed pool of ``max_batch`` decode slots and runs the
standard continuous-batching loop (ADMIT -> PREFILL -> DECODE -> RETIRE).
Two KV layouts back the slots:

``kv_layout="paged"`` (default) — the vLLM-style BLOCK-TABLE layout:
  * KV lives in a SHARED pool of ``num_pages`` fixed-size pages
    (`transformer.init_paged_cache`; row 0 is a trash page for masked
    writes).  Each slot maps logical positions to pages through a
    ``(W,)`` int32 page-table row; attention gathers the slot's pages into
    a contiguous view and the existing ``q_pos0``/``kv_len`` per-slot
    masking applies unchanged.  Peak KV memory scales with TOKENS IN
    FLIGHT, not B x worst-case max_len.
  * CHUNKED PREFILL: prompts stream into their pages ``prefill_chunk``
    tokens per engine step, interleaved with decode steps of the other
    slots, so a long prompt neither stalls the batch nor needs a
    monolithic prefill trace.  Admission requires "fits in free pages"
    (per-request reservation of ceil(min(prompt+budget, W*page_size) /
    page_size) pages), not ``prompt_len < max_len``.  Recurrent (SSM /
    xLSTM) state carries across chunks exactly — masked steps are
    identities — so hybrid archs chunk-prefill too.
  * PREFIX CACHING: once a prompt's pages are written they are registered
    under exact token-prefix keys; a later request whose prompt shares the
    prefix maps the SAME pages (copy-on-write for a partially covered tail
    page) and prefills only its unique suffix.  Enabled automatically for
    attention-only, non-MoE, frontend-free archs — recurrent state is not
    page-resident and MoE dispatch is batch-dependent, so sharing would be
    unsound there.

``kv_layout="dense"`` — the PR-5 layout kept as the parity oracle: B slots
of ``max_len`` dense KV, one-shot ragged prefill per admission group
(bucketed prompt length AND group size, so mixed traffic retraces prefill
at most O(log^2) times), `transformer.scatter_cache` admission.

The decode step traces ONCE per layout (fixed pool shapes; the paged chunk
step likewise traces once).  With a `repro.runtime.PlannedBackend` passed
as ``backend``, every jitted call executes covered projections through
their planned split-precision kernels (the name-keyed matmul-backend
protocol resolves statically inside jit), so engine latency IS mapped
latency.

Exactness notes: outputs are token-identical to per-request serving for
every non-MoE arch (padding/masking is exact — see the `repro.serving`
package docstring for the MoE capacity caveat), provided the bound plan
uses STATIC activation scales; dynamic max-abs activation quantization is
computed over the whole pooled batch and therefore depends on batch
composition.
"""
from __future__ import annotations

import contextlib
import math
import time
import warnings
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.managed import matmul_backend
from repro.serving.batch import BatchState
from repro.serving.metrics import RequestResult
from repro.serving.paged import PagePool
from repro.serving.scheduler import Request, RequestQueue, Scheduler

KV_LAYOUTS = ("paged", "dense")

# prefix sharing is only sound when ALL sequence state is page-resident
# (pure attention KV) and per-token compute is batch-composition-free
_PREFIX_SAFE_KINDS = frozenset({"attn", "shared_attn", "mla"})


class Engine:
    """Continuous-batching serving engine (see module docstring).

    Parameters:
      cfg, params   — the LM (`repro.configs` ArchConfig + its weights).
      max_batch     — pool size B (concurrent requests).
      max_len       — per-slot sequence capacity: dense slots hold exactly
                      ``max_len`` tokens; paged slots hold ``W * page_size``
                      with W = ceil(max_len / page_size) (requests beyond
                      that retire as "length_cap").
      backend       — optional matmul backend (e.g. `PlannedBackend`)
                      installed around every jitted call.
      scheduler     — a `Scheduler` (default: continuous policy).
      prefill_bucket— dense layout: minimum prompt padding; group prompt
                      lengths round up to the next power-of-two multiple of
                      it (bounds prefill retraces).
      kv_layout     — "paged" (default) or "dense" (see module docstring).
      page_size     — paged: tokens per KV page (16 default — a multiple of
                      typical attention block tiles, small enough that a
                      short request wastes < page_size tokens per slot).
      num_pages     — paged: pool capacity (default B * W: same worst-case
                      capacity as dense; undercommit for memory savings,
                      overcommit for longer admission queues).
      prefill_chunk — paged: prompt tokens per chunked-prefill step
                      (default 2 * page_size).
      prefix_cache  — paged: hash-share prompt pages across requests
                      (auto-disabled for archs where sharing is unsound).
    """

    def __init__(self, cfg, params, *, max_batch: int = 8, max_len: int = 64,
                 backend=None, scheduler: Optional[Scheduler] = None,
                 prefill_bucket: int = 8, kv_layout: str = "paged",
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = True):
        if kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout must be one of {KV_LAYOUTS}, "
                             f"got {kv_layout!r}")
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.backend = backend
        self.scheduler = scheduler or Scheduler()
        self.prefill_bucket = max(1, int(prefill_bucket))
        self.kv_layout = kv_layout
        self.stats: Dict[str, float] = {}
        # python-side counters bumped inside the traced function bodies:
        # they count TRACES, not calls (tests pin the retrace bound)
        self.trace_counts = {"prefill": 0, "decode": 0, "chunk": 0}

        if kv_layout == "paged":
            self.page_size = int(page_size)
            self.pages_per_slot = -(-self.max_len // self.page_size)
            self.slot_cap = self.pages_per_slot * self.page_size
            self.num_pages = (int(num_pages) if num_pages is not None
                              else self.max_batch * self.pages_per_slot)
            self.prefill_chunk = (int(prefill_chunk) if prefill_chunk
                                  else 2 * self.page_size)
            self.prefix_cache = bool(prefix_cache) and \
                cfg.moe is None and not cfg.frontend and \
                set(cfg.pattern) <= _PREFIX_SAFE_KINDS
            self.pool_mgr = PagePool(self.num_pages, self.page_size)
            # the DEVICE page pool persists across run() calls: the
            # allocator's hash index outlives a run, so the pages it can
            # match must stay resident too (a repeated trace then serves
            # its prompts straight from cache)
            self._paged_caches = None
        else:
            self.slot_cap = self.max_len
            self.prefix_cache = False

        self._kv_axes = T.cache_kv_axes(cfg)
        self._kv_capacity_bytes, self._kv_page_bytes = self._kv_footprint()

        def decode_fn(params, tok, caches, lengths, active):
            self.trace_counts["decode"] += 1
            logits, caches = T.decode_step(params, cfg, tok, caches, lengths,
                                           active=active)
            return jnp.argmax(logits, axis=-1), caches

        def decode_paged_fn(params, tok, caches, lengths, active, pages):
            self.trace_counts["decode"] += 1
            logits, caches = T.decode_step(params, cfg, tok, caches, lengths,
                                           active=active, pages=pages)
            return jnp.argmax(logits, axis=-1), caches

        def prefill_fn(params, prompts, lengths, pool, slots, frontend):
            self.trace_counts["prefill"] += 1
            fresh = T.init_cache(cfg, prompts.shape[0], self.max_len)
            logits, fresh = T.prefill(params, cfg, prompts, fresh,
                                      cross_source=frontend, lengths=lengths)
            tok0 = jnp.argmax(logits, axis=-1)
            return tok0, T.scatter_cache(pool, fresh, slots)

        def chunk_fn(params, tokens, caches, fill, valid, pages, frontend):
            self.trace_counts["chunk"] += 1
            logits, caches = T.prefill_chunk(params, cfg, tokens, caches,
                                             fill, valid, pages,
                                             cross_source=frontend)
            return jnp.argmax(logits, axis=-1), caches

        def reset_fn(caches, slots):
            # zero the per-slot (non-page) state of freshly admitted slots:
            # recurrent state and encoder memory must not leak from the
            # slot's previous occupant (dense admission overwrites via
            # scatter_cache instead)
            def f(leaf, ax):
                if ax == "slot0":
                    return leaf.at[slots].set(jnp.zeros((), leaf.dtype))
                if ax == "slot1":
                    return leaf.at[:, slots].set(jnp.zeros((), leaf.dtype))
                return leaf
            return jax.tree.map(f, caches, self._kv_axes)

        def copy_pages_fn(caches, src, dst):
            # copy-on-write: duplicate shared partially-filled tail pages
            # into pages the new request owns before it writes them
            def f(leaf, ax):
                if ax == "page0":
                    return leaf.at[dst].set(leaf[src])
                if ax == "page1":
                    return leaf.at[:, dst].set(leaf[:, src])
                return leaf
            return jax.tree.map(f, caches, self._kv_axes)

        self._decode = jax.jit(decode_fn)
        self._decode_paged = jax.jit(decode_paged_fn)
        self._prefill = jax.jit(prefill_fn)
        self._chunk = jax.jit(chunk_fn)
        self._reset = jax.jit(reset_fn)
        self._copy_pages = jax.jit(copy_pages_fn)

    # ---- helpers ---------------------------------------------------------

    def _kv_footprint(self):
        """(total sequence-KV bytes of the pool, bytes per page or None).

        Sums only the sequence-indexed attention-KV leaves (the ``"page"``
        markers of `transformer.cache_kv_axes`) — per-slot recurrent state
        is identical across layouts and excluded so dense-vs-paged peak
        numbers compare exactly what paging changes."""
        if self.kv_layout == "paged":
            specs = T.paged_cache_specs(self.cfg, self.max_batch,
                                        self.num_pages + 1, self.page_size)
        else:
            specs = T.cache_specs(self.cfg, self.max_batch, self.max_len)
        total = 0
        per_page = 0
        for leaf, ax in zip(jax.tree.leaves(specs),
                            jax.tree.leaves(self._kv_axes)):
            if not ax.startswith("page"):
                continue
            nbytes = math.prod(leaf.shape) * leaf.dtype.itemsize
            total += nbytes
            if self.kv_layout == "paged":
                # bytes of ONE page across all stacked layers of this leaf:
                # pool-rows axis is 1 under a scan stack ("page1"), else 0
                rows = leaf.shape[1] if ax == "page1" else leaf.shape[0]
                per_page += nbytes // rows
        if self.kv_layout == "paged":
            return per_page * self.num_pages, per_page  # trash row excluded
        return total, None

    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _gbucket(self, k: int) -> int:
        """Admission-group size bucket (next power of two): bounds dense
        prefill retraces to O(log max_batch * log max_len) combinations."""
        g = 1
        while g < k:
            g *= 2
        return min(g, self.max_batch)

    def _ctx(self):
        return (matmul_backend(self.backend) if self.backend is not None
                else contextlib.nullcontext())

    def _pages_needed(self, req: Request) -> int:
        total = min(req.prompt_len + req.max_new_tokens, self.slot_cap)
        return self.pool_mgr.pages_for(total)

    def _frontend_row(self, req: Request):
        if not self.cfg.frontend:
            return None
        if req.frontend is None:
            raise ValueError(
                f"arch {self.cfg.name} needs a per-request cross-attention "
                f"`frontend`, missing on: [{req.rid!r}]")
        return jnp.asarray(req.frontend, jnp.bfloat16)

    def _validate(self, requests: Sequence[Request]):
        for r in requests:
            if self.kv_layout == "dense":
                if r.prompt_len >= self.max_len:
                    raise ValueError(
                        f"request {r.rid!r}: prompt_len {r.prompt_len} does "
                        f"not fit the engine's max_len {self.max_len} "
                        f"(needs prompt_len < max_len)")
                continue
            need = self._pages_needed(r)
            if r.prompt_len >= self.slot_cap or need > self.num_pages:
                warnings.warn(
                    f"unservable request {r.rid!r}: needs {need} pages "
                    f"({r.prompt_len} prompt + {r.max_new_tokens} new tokens "
                    f"@ page_size {self.page_size}) but the pool caps at "
                    f"{self.num_pages} pages x {self.page_size} tokens "
                    f"(slot capacity {self.slot_cap})")
                raise ValueError(
                    f"request {r.rid!r}: needs {need} pages, pool has "
                    f"{self.num_pages} (slot capacity {self.slot_cap} "
                    f"tokens)")

    # ---- retirement (host-side, vectorized) ------------------------------

    def _retire_slot(self, batch: BatchState, slot: int, reason: str,
                     now: float, step: int,
                     results: Dict[int, RequestResult]):
        st = batch.retire(slot)
        req = st.request
        if self.kv_layout == "paged":
            self.pool_mgr.release(batch.slot_pages[slot])
            batch.slot_pages[slot] = []
            batch.page_table[slot, :] = 0
        results[id(req)] = RequestResult(
            rid=req.rid, prompt_len=req.prompt_len, tokens=st.tokens,
            finish_reason=reason, ttft_s=st.t_first - st.t_ready,
            finish_s=now - st.t_ready, admitted_step=st.admitted_step,
            finished_step=step)

    def _slot_reason(self, batch: BatchState, slot: int) -> Optional[str]:
        st = batch.slots[slot]
        req = st.request
        if req.eos_id is not None and st.tokens[-1] == req.eos_id:
            return "eos"
        if len(st.tokens) >= req.max_new_tokens:
            return "max_new_tokens"
        if int(batch.lengths[slot]) >= self.slot_cap:
            return "length_cap"   # no room to embed the next token
        return None

    def _maybe_retire(self, batch: BatchState, slot: int, now: float,
                      step: int, results: Dict[int, RequestResult]) -> bool:
        reason = self._slot_reason(batch, slot)
        if reason is None:
            return False
        self._retire_slot(batch, slot, reason, now, step, results)
        return True

    def _postdecode(self, batch: BatchState, tok: np.ndarray, now: float,
                    step: int, results: Dict[int, RequestResult]):
        """Record one decode step's tokens and retire finished slots — one
        host sync happened already (``tok``); every predicate below reads
        host-side numpy mirrors, no per-slot device pulls."""
        act = batch.active
        idx = np.nonzero(act)[0]
        batch.last_tok[idx] = tok[idx]
        batch.lengths[idx] += 1
        batch.n_gen[idx] += 1
        eos_hit = act & (batch.eos_id >= 0) & (tok == batch.eos_id)
        budget = act & (batch.n_gen >= batch.max_new)
        cap = act & (batch.lengths >= self.slot_cap)
        for b in idx:
            batch.slots[b].tokens.append(int(tok[b]))
        for b in np.nonzero(eos_hit | budget | cap)[0]:
            reason = ("eos" if eos_hit[b] else
                      "max_new_tokens" if budget[b] else "length_cap")
            self._retire_slot(batch, int(b), reason, now, step, results)

    # ---- dense admission -------------------------------------------------

    def _admit_dense(self, batch: BatchState, admits, step: int,
                     t_ready: Dict[int, float]):
        slots = np.asarray([s for s, _ in admits], np.int32)
        reqs = [r for _, r in admits]
        k = len(reqs)
        kp = self._gbucket(k)                 # pad the GROUP SIZE too
        P = self._bucket(max(r.prompt_len for r in reqs))
        prompts = np.zeros((kp, P), np.int32)
        lengths = np.zeros(kp, np.int32)
        for i, r in enumerate(reqs):
            prompts[i, :r.prompt_len] = r.prompt
            lengths[i] = r.prompt_len
        # pad rows repeat the last real request (identical rows compute
        # identical caches, so the duplicate scatter writes are no-ops)
        prompts[k:] = prompts[k - 1]
        lengths[k:] = lengths[k - 1]
        slots_p = np.concatenate([slots, np.full(kp - k, slots[-1],
                                                 np.int32)])
        frontend = None
        if self.cfg.frontend:
            rows = [self._frontend_row(r) for r in reqs]
            frontend = jnp.stack(rows + [rows[-1]] * (kp - k))
        t0 = time.monotonic()
        tok0, batch.caches = self._prefill(self.params, prompts, lengths,
                                           batch.caches, slots_p, frontend)
        tok0 = np.asarray(tok0)           # sync: first tokens materialized
        t1 = time.monotonic()
        self.stats["prefill_s"] += t1 - t0
        self.stats["prefill_calls"] += 1
        for i, (slot, req) in enumerate(admits):
            batch.assign(slot, req, int(tok0[i]),
                         t_ready=t_ready[id(req)], t_first=t1, step=step)
        return [s for s, _ in admits]

    # ---- paged admission + chunked prefill -------------------------------

    def _admit_paged(self, batch: BatchState, admits, step: int,
                     t_ready: Dict[int, float]):
        cow_pairs = []
        slots = []
        for slot, req in admits:
            need = self._pages_needed(req)
            hit_len, shared, cow_src = (
                self.pool_mgr.match(req.prompt) if self.prefix_cache
                else (0, [], None))
            pages = shared + self.pool_mgr.alloc(need - len(shared))
            if cow_src is not None:
                cow_pairs.append((cow_src, pages[len(shared)]))
            batch.start_prefill(slot, req, pages, hit_len,
                                t_ready=t_ready[id(req)], step=step)
            if self.cfg.frontend:
                row = self._frontend_row(req)
                if self._fe_buf is None:
                    self._fe_buf = jnp.zeros(
                        (self.max_batch, *row.shape), jnp.bfloat16)
                self._fe_buf = self._fe_buf.at[slot].set(row)
            slots.append(slot)
        batch.caches = self._reset(batch.caches,
                                   np.asarray(slots, np.int32))
        if cow_pairs:
            src = np.asarray([s for s, _ in cow_pairs], np.int32)
            dst = np.asarray([d for _, d in cow_pairs], np.int32)
            batch.caches = self._copy_pages(batch.caches, src, dst)
            for s, _ in cow_pairs:
                self.pool_mgr.release_cow(s)

    def _register_prompt(self, batch: BatchState, slot: int):
        """Publish a fully prefilled prompt's pages for prefix sharing."""
        if not self.prefix_cache:
            return
        prompt = batch.pending[slot].request.prompt
        pages = batch.slot_pages[slot]
        for key, end in self.pool_mgr.prompt_keys(prompt):
            self.pool_mgr.register(pages[(end - 1) // self.page_size], key)

    def _chunk_step(self, batch: BatchState, step: int,
                    results: Dict[int, RequestResult]):
        """Stream the next ``prefill_chunk`` tokens of EVERY prefilling
        slot in one fixed-shape jitted call; slots whose prompt completes
        get their first token from this chunk's logits and join decode."""
        B, C = self.max_batch, self.prefill_chunk
        sel = np.nonzero(batch.prefilling)[0]
        tokens = np.zeros((B, C), np.int32)
        valid = np.zeros(B, np.int32)
        for b in sel:
            req = batch.pending[b].request
            pos = int(batch.fill_pos[b])
            n = min(C, req.prompt_len - pos)
            tokens[b, :n] = req.prompt[pos:pos + n]
            valid[b] = n
        t0 = time.monotonic()
        tok, batch.caches = self._chunk(
            self.params, tokens, batch.caches, batch.fill_pos.copy(), valid,
            batch.page_table.copy(), self._fe_buf)
        tok = np.asarray(tok)             # sync
        t1 = time.monotonic()
        self.stats["prefill_s"] += t1 - t0
        self.stats["prefill_calls"] += 1
        batch.fill_pos[sel] += valid[sel]
        batch.lengths[sel] = batch.fill_pos[sel]
        for b in sel:
            pend = batch.pending[b]
            if batch.fill_pos[b] >= pend.request.prompt_len:
                self._register_prompt(batch, b)
                batch.assign(b, pend.request, int(tok[b]),
                             t_ready=pend.t_ready, t_first=t1,
                             step=pend.admitted_step)
                self._maybe_retire(batch, int(b), t1, step, results)

    # ---- main loops ------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> List[RequestResult]:
        """Serve ``requests`` to completion; returns one `RequestResult` per
        request, in submission order.  Timing aggregates land in
        ``self.stats``."""
        self._validate(requests)
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "decode_steps": 0,
                      "prefill_calls": 0, "wall_s": 0.0}
        queue = RequestQueue()
        for r in requests:
            queue.push(r)
        results: Dict[int, RequestResult] = {}
        t0 = time.monotonic()
        if self.kv_layout == "paged":
            self._run_paged(queue, results)
        else:
            self._run_dense(queue, results)
        self.stats["wall_s"] = time.monotonic() - t0
        self.stats["kv_capacity_bytes"] = self._kv_capacity_bytes
        if self.kv_layout == "paged":
            ps = self.pool_mgr.stats
            self.stats["kv_peak_pages"] = ps["peak_pages"]
            self.stats["kv_page_bytes"] = self._kv_page_bytes
            self.stats["kv_peak_bytes"] = ps["peak_pages"] * \
                self._kv_page_bytes
            self.stats["prefix_lookups"] = ps["lookups"]
            self.stats["prefix_hit_requests"] = ps["hit_requests"]
            self.stats["prefix_hit_tokens"] = ps["hit_tokens"]
            self.stats["cow_copies"] = ps["cow_copies"]
            self.stats["page_evictions"] = ps["evictions"]
        else:
            # dense pools are fully allocated up front: peak == capacity
            self.stats["kv_peak_bytes"] = self._kv_capacity_bytes
        return [results[id(r)] for r in requests]

    def _run_dense(self, queue: RequestQueue,
                   results: Dict[int, RequestResult]):
        batch = BatchState(self.max_batch,
                           T.init_cache(self.cfg, self.max_batch,
                                        self.max_len))
        t_ready: Dict[int, float] = {}
        step = 0
        with self._ctx():
            while len(queue) or batch.any_active():
                # idle + only future arrivals: fast-forward the step clock
                if not batch.any_active() and queue.ready(step) == 0:
                    step = max(step, queue.next_arrival())
                now = time.monotonic()
                for r in queue:
                    if r.arrival_step <= step and id(r) not in t_ready:
                        t_ready[id(r)] = now
                admits = self.scheduler.admissions(
                    queue, batch.free_slots(), batch.n_active, step)
                if admits:
                    for slot in self._admit_dense(batch, admits, step,
                                                  t_ready):
                        self._maybe_retire(batch, slot, time.monotonic(),
                                           step, results)
                if not batch.any_active():
                    continue
                t = time.monotonic()
                tok, batch.caches = self._decode(
                    self.params, batch.last_tok, batch.caches,
                    batch.lengths, batch.active)
                tok = np.asarray(tok)               # sync
                now = time.monotonic()
                self.stats["decode_s"] += now - t
                self.stats["decode_steps"] += 1
                self._postdecode(batch, tok, now, step, results)
                step += 1

    def _run_paged(self, queue: RequestQueue,
                   results: Dict[int, RequestResult]):
        if self._paged_caches is None:
            rows = self.num_pages + 1                  # + trash page 0
            self._paged_caches = T.init_paged_cache(
                self.cfg, self.max_batch, rows, self.page_size)
        batch = BatchState(self.max_batch, self._paged_caches,
                           pages_per_slot=self.pages_per_slot)
        self._fe_buf = None
        t_ready: Dict[int, float] = {}
        step = 0
        with self._ctx():
            while len(queue) or batch.any_busy():
                if not batch.any_busy() and queue.ready(step) == 0:
                    step = max(step, queue.next_arrival())
                now = time.monotonic()
                for r in queue:
                    if r.arrival_step <= step and id(r) not in t_ready:
                        t_ready[id(r)] = now
                reserved = [0]

                def fits(req):
                    # running reservation: one admission round may pop
                    # several requests before any pages are allocated
                    need = self._pages_needed(req)
                    if reserved[0] + need <= self.pool_mgr.available():
                        reserved[0] += need
                        return True
                    return False

                admits = self.scheduler.admissions(
                    queue, batch.free_slots(), batch.n_busy, step,
                    fits=fits)
                if admits:
                    self._admit_paged(batch, admits, step, t_ready)
                if batch.prefilling.any():
                    self._chunk_step(batch, step, results)
                if batch.any_active():
                    t = time.monotonic()
                    tok, batch.caches = self._decode_paged(
                        self.params, batch.last_tok, batch.caches,
                        batch.lengths, batch.active,
                        batch.page_table.copy())
                    tok = np.asarray(tok)           # sync
                    now = time.monotonic()
                    self.stats["decode_s"] += now - t
                    self.stats["decode_steps"] += 1
                    self._postdecode(batch, tok, now, step, results)
                step += 1
        self._paged_caches = batch.caches       # keep cached pages resident
