"""`Engine`: continuous-batching inference over (optionally planned) LMs.

One engine owns a fixed pool of ``max_batch`` decode slots and runs the
standard continuous-batching loop (ADMIT -> PREFILL -> DECODE -> RETIRE).
Two KV layouts back the slots:

``kv_layout="paged"`` (default) — the vLLM-style BLOCK-TABLE layout:
  * KV lives in a SHARED pool of ``num_pages`` fixed-size pages
    (`transformer.init_paged_cache`; row 0 is a trash page for masked
    writes).  Each slot maps logical positions to pages through a
    ``(W,)`` int32 page-table row; attention gathers the slot's pages into
    a contiguous view and the existing ``q_pos0``/``kv_len`` per-slot
    masking applies unchanged.  Peak KV memory scales with TOKENS IN
    FLIGHT, not B x worst-case max_len.
  * CHUNKED PREFILL: prompts stream into their pages ``prefill_chunk``
    tokens per engine step, interleaved with decode steps of the other
    slots, so a long prompt neither stalls the batch nor needs a
    monolithic prefill trace.  Admission requires "fits in free pages"
    (per-request reservation of ceil(min(prompt+budget, W*page_size) /
    page_size) pages), not ``prompt_len < max_len``.  Recurrent (SSM /
    xLSTM) state carries across chunks exactly — masked steps are
    identities — so hybrid archs chunk-prefill too.
  * PREFIX CACHING: once a prompt's pages are written they are registered
    under exact token-prefix keys; a later request whose prompt shares the
    prefix maps the SAME pages (copy-on-write for a partially covered tail
    page) and prefills only its unique suffix.  Enabled automatically for
    attention-only, non-MoE, frontend-free archs — recurrent state is not
    page-resident and MoE dispatch is batch-dependent, so sharing would be
    unsound there.  SLO routing disables it too: routed variants write
    variant-specific KV numerics, so pages could not be shared across
    classes.

``kv_layout="dense"`` — the PR-5 layout kept as the parity oracle: B slots
of ``max_len`` dense KV, one-shot ragged prefill per admission group
(bucketed prompt length AND group size, so mixed traffic retraces prefill
at most O(log^2) times), `transformer.scatter_cache` admission.

The decode step traces ONCE per layout (fixed pool shapes; the paged chunk
step likewise traces once).  With a `repro.runtime.PlannedBackend` passed
as ``backend``, every jitted call executes covered projections through
their planned split-precision kernels (the name-keyed matmul-backend
protocol resolves statically inside jit), so engine latency IS mapped
latency.

MULTI-PLAN SERVING — with a `repro.runtime.PlanSet` bound as ``backend``
(N precision variants over ONE shared params pytree), the engine can
exploit the variants at serving time:

  * SELF-SPECULATIVE DECODING (``speculate=(draft, target)``): every
    decode round drafts ``draft_k`` greedy tokens per slot with the cheap
    ``draft`` variant (a `lax.scan` over the paged decode step), then
    verifies all of them in ONE fixed-shape `prefill_chunk` call under the
    ``target`` variant (``full_logits=True`` recovers the per-position
    argmax), accepting the longest prefix where draft and target agree
    plus one bonus target token.  Verify overwrites every draft-written
    KV position with target numerics, so the committed cache is exactly
    the target-only cache; for hybrid (recurrent) archs a replay chunk
    restores the pre-round recurrent state of partially-accepting slots
    and re-advances it over the committed tokens only.  Output is
    TOKEN-IDENTICAL to target-only greedy decoding (requires static
    activation scales — see Exactness notes).  Paged-only, greedy-only,
    non-MoE, frontend-free.
  * SLO ROUTING (``slo_routes={"interactive": "draft", ...}``): each
    request's SLO class picks the plan variant serving it.  Decode and
    chunked prefill run once per ACTIVE variant group with the other
    slots masked (masked paged writes land in the trash page, so groups
    cannot corrupt each other's KV); a request's entire KV is written
    under its own variant, keeping per-request numerics identical to
    serving it alone under that variant.  Paged-only.
  * NON-GREEDY SAMPLING (``sampling=SamplingParams(...)``): temperature /
    top-p sampling as jit-safe per-slot state — see `repro.serving
    .sampling`.  OFF by default (argmax, bit-identical to before).

Exactness notes: outputs are token-identical to per-request serving for
every non-MoE arch (padding/masking is exact — see the `repro.serving`
package docstring for the MoE capacity caveat), provided the bound plan
uses STATIC activation scales; dynamic max-abs activation quantization is
computed over the whole pooled batch and therefore depends on batch
composition (this is also why speculative verify, whose batch rows differ
from sequential decode's, requires static scales for token identity).

ROBUSTNESS LAYER (paged layout) — the engine stays on its SLO under
overload and numerical faults instead of degrading unboundedly:

  * DEADLINE SCHEDULING + PREEMPTION (``Scheduler(policy="deadline")``):
    admission is ordered by `repro.serving.scheduler.urgency` (priority,
    then deadline slack) instead of FCFS, and when a waiting request is
    strictly more urgent than the least-urgent running one (and no free
    slot/pages can serve it) the victim slot is RETIRE-AND-REQUEUED: its
    committed tokens are recorded, its pages are released — hashed prefix
    pages park in the `PagePool` LRU, still matchable — and the request
    resumes later by prefilling ``original prompt + committed tokens``,
    which by the prefill/decode logit-equality invariant reproduces the
    exact decode state, so the final token stream is IDENTICAL to an
    unpreempted run (greedy + static scales, like all parity guarantees
    here).  With the prefix cache on, resumption re-prefills only the
    unhashed tail.  At most one preemption fires per step and each
    request is preempted at most ``max_preemptions`` times.
  * LOAD SHEDDING (``max_queue_depth`` / ``page_watermark`` /
    ``request_timeout_s``): instead of queueing without bound, excess
    visible requests are rejected with a structured
    `repro.serving.metrics.ShedResult` — newest-first beyond the queue
    depth, everything behind the head of line when the free-page
    fraction drops below the watermark, and any request (queued OR
    running) that outlives the timeout (running requests retire with
    their partial tokens and ``finish_reason="timeout"``).
  * PRECISION DEGRADATION (``degrade_to=variant, ttft_target_s=...``):
    a sliding p95 over observed TTFTs; on breach, NEW admissions route to
    the cheaper `PlanSet` variant (the paper's accuracy axis spent to buy
    back latency), and route back once p95 recovers below a hysteresis
    fraction of the target.  Every transition is recorded
    (``degrade_log`` / ``stats["degrade_transitions"]``); requests served
    degraded carry ``RequestResult.degraded=True``.
  * FAULT CONTAINMENT (``injector=FaultInjector(...)``): every decode /
    chunk step returns a ``jnp.isfinite`` screen over its logits; a slot
    whose logits go non-finite commits NOTHING that step — its pages are
    purged from the prefix cache (corruption must never be re-matched),
    the slot is quarantined for ``quarantine_steps``, and the request is
    requeued ONCE with its (clean) committed tokens; a second fault sheds
    it with ``ShedResult(reason="fault")``.  Stuck slots — which commit
    nothing, so the logit screen cannot see them — are caught by the
    `repro.distributed.fault_tolerance.HeartbeatMonitor` running on the
    engine's step clock (slots beat on token commit / chunk progress); a
    `StragglerPolicy` EMA over decode-step wall times records outlier
    steps in ``stats["straggler_events"]``.
"""
from __future__ import annotations

import contextlib
import math
import time
import warnings
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.fault_tolerance import HeartbeatMonitor, \
    StragglerPolicy
from repro.models import transformer as T
from repro.models.managed import matmul_backend
from repro.serving.batch import BatchState
from repro.serving.faults import FaultInjector
from repro.serving.metrics import RequestResult, ShedResult, percentile
from repro.serving.paged import PagePool
from repro.serving.sampling import SamplingParams, request_key, sample_tokens
from repro.serving.scheduler import Request, RequestQueue, Scheduler, urgency

EngineResult = Union[RequestResult, ShedResult]

KV_LAYOUTS = ("paged", "dense")

# prefix sharing is only sound when ALL sequence state is page-resident
# (pure attention KV) and per-token compute is batch-composition-free
_PREFIX_SAFE_KINDS = frozenset({"attn", "shared_attn", "mla"})


class Engine:
    """Continuous-batching serving engine (see module docstring).

    Parameters:
      cfg, params   — the LM (`repro.configs` ArchConfig + its weights).
      max_batch     — pool size B (concurrent requests).
      max_len       — per-slot sequence capacity: dense slots hold exactly
                      ``max_len`` tokens; paged slots hold ``W * page_size``
                      with W = ceil(max_len / page_size) (requests beyond
                      that retire as "length_cap").
      backend       — optional matmul backend (e.g. `PlannedBackend` /
                      `PlanSet`) installed around every jitted call.
      scheduler     — a `Scheduler` (default: continuous policy).
      prefill_bucket— dense layout: minimum prompt padding; group prompt
                      lengths round up to the next power-of-two multiple of
                      it (bounds prefill retraces).
      kv_layout     — "paged" (default) or "dense" (see module docstring).
      page_size     — paged: tokens per KV page (16 default — a multiple of
                      typical attention block tiles, small enough that a
                      short request wastes < page_size tokens per slot).
      num_pages     — paged: pool capacity (default B * W: same worst-case
                      capacity as dense; undercommit for memory savings,
                      overcommit for longer admission queues).
      prefill_chunk — paged: prompt tokens per chunked-prefill step
                      (default 2 * page_size).
      prefix_cache  — paged: hash-share prompt pages across requests
                      (auto-disabled for archs where sharing is unsound).
      speculate     — optional ``(draft_variant, target_variant)`` pair of
                      variant names on the bound `PlanSet`: enables
                      self-speculative decoding (see module docstring).
      draft_k       — tokens drafted per speculative round (default 4).
      slo_routes    — optional ``{slo_class: variant_name}`` map routing
                      each request's SLO class to a plan variant.
      sampling      — optional `SamplingParams`; None = greedy (default).

    Robustness (see the ROBUSTNESS LAYER section of the module docstring;
    all of these are paged-only except the queue-level sheds/timeouts):
      max_queue_depth  — shed (``ShedResult(reason="queue_depth")``) the
                         newest visible queued requests beyond this depth.
      page_watermark   — fraction in (0, 1]: when free pages drop below it,
                         shed every visible queued request behind the head
                         of line (``reason="page_watermark"``).
      request_timeout_s— wall-clock budget per request measured from when
                         it became schedulable: queued requests shed
                         (``reason="timeout"``), running requests retire
                         with partial tokens (``finish_reason="timeout"``).
      max_preemptions  — per-request retire-and-requeue cap under the
                         deadline policy (bounds preemption thrash).
      degrade_to       — `PlanSet` variant name new admissions route to
                         while the TTFT p95 estimate breaches
                         ``ttft_target_s`` (required together; hysteresis
                         recovery at ``degrade_recover_frac * target``).
      injector         — optional `repro.serving.faults.FaultInjector`.
      quarantine_steps — steps a slot sits out after a detected fault.
      heartbeat_steps  — step-clock deadline for the stuck-slot monitor.
    """

    def __init__(self, cfg, params, *, max_batch: int = 8, max_len: int = 64,
                 backend=None, scheduler: Optional[Scheduler] = None,
                 prefill_bucket: int = 8, kv_layout: str = "paged",
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = True,
                 speculate: Optional[Tuple[str, str]] = None,
                 draft_k: int = 4,
                 slo_routes: Optional[Dict[str, str]] = None,
                 sampling: Optional[SamplingParams] = None,
                 max_queue_depth: Optional[int] = None,
                 page_watermark: Optional[float] = None,
                 request_timeout_s: Optional[float] = None,
                 max_preemptions: int = 2,
                 degrade_to: Optional[str] = None,
                 ttft_target_s: Optional[float] = None,
                 degrade_window: int = 8,
                 degrade_recover_frac: float = 0.7,
                 injector: Optional[FaultInjector] = None,
                 quarantine_steps: int = 2,
                 heartbeat_steps: int = 32):
        if kv_layout not in KV_LAYOUTS:
            raise ValueError(f"kv_layout must be one of {KV_LAYOUTS}, "
                             f"got {kv_layout!r}")
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.backend = backend
        self.scheduler = scheduler or Scheduler()
        self.prefill_bucket = max(1, int(prefill_bucket))
        self.kv_layout = kv_layout
        self.sampling = sampling
        self.draft_k = int(draft_k)
        self._spec = tuple(speculate) if speculate is not None else None
        self.slo_routes = dict(slo_routes) if slo_routes else None
        self.max_queue_depth = max_queue_depth
        self.page_watermark = page_watermark
        self.request_timeout_s = request_timeout_s
        self.max_preemptions = int(max_preemptions)
        self.degrade_to = degrade_to
        self.injector = injector
        self.quarantine_steps = int(quarantine_steps)
        self.heartbeat_steps = int(heartbeat_steps)
        if max_queue_depth is not None and max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1, "
                             f"got {max_queue_depth}")
        if page_watermark is not None and not 0.0 < page_watermark <= 1.0:
            raise ValueError(f"page_watermark must be in (0, 1], "
                             f"got {page_watermark}")
        if request_timeout_s is not None and request_timeout_s < 0:
            raise ValueError(f"request_timeout_s must be >= 0, "
                             f"got {request_timeout_s}")
        if (degrade_to is None) != (ttft_target_s is None):
            raise ValueError("degrade_to and ttft_target_s come together: "
                             "the degraded variant needs a TTFT target to "
                             "defend (and vice versa)")
        self._degrade = (_DegradeController(ttft_target_s,
                                            window=degrade_window,
                                            recover_frac=degrade_recover_frac)
                         if degrade_to is not None else None)
        self.degrade_log = self._degrade.transitions if self._degrade else []
        self.stats: Dict[str, float] = {}
        # python-side counters bumped inside the traced function bodies:
        # they count TRACES, not calls (tests pin the retrace bound)
        self.trace_counts = {"prefill": 0, "decode": 0, "chunk": 0,
                             "draft": 0, "verify": 0, "replay": 0}

        variant_names = getattr(backend, "variant_names", None)
        if self._spec is not None:
            if len(self._spec) != 2 or not all(
                    isinstance(v, str) for v in self._spec):
                raise ValueError(
                    f"speculate must be a (draft_variant, target_variant) "
                    f"pair of variant names, got {speculate!r}")
            if kv_layout != "paged":
                raise ValueError(
                    "speculative decoding requires kv_layout='paged': the "
                    "dense layout writes garbage KV at masked slots' live "
                    "positions, so draft/verify masking would corrupt "
                    "co-batched state (paged masked writes hit the trash "
                    "page)")
            if cfg.moe is not None:
                raise ValueError(
                    "speculative decoding is unsupported for MoE archs: "
                    "expert dispatch is batch-composition-dependent, so "
                    "verify logits would not match sequential decoding")
            if cfg.frontend:
                raise ValueError(
                    "speculative decoding is unsupported for frontend "
                    "(cross-attention) archs")
            if sampling is not None:
                raise ValueError(
                    "speculative decoding is greedy-only (its token-"
                    "identity guarantee is an argmax property); drop "
                    "`sampling` or `speculate`")
            if slo_routes:
                raise ValueError(
                    "speculate and slo_routes are mutually exclusive: "
                    "speculation pins every slot to the draft/target pair")
            if self.draft_k < 1:
                raise ValueError(f"draft_k must be >= 1, got {draft_k}")
            if variant_names is None:
                raise ValueError(
                    "speculate needs a multi-variant PlanSet backend "
                    "(`repro.runtime.PlanSet`); got "
                    f"{type(backend).__name__ if backend is not None else None}")
            for v in self._spec:
                if v not in variant_names:
                    raise ValueError(
                        f"speculate variant {v!r} is not bound: this "
                        f"PlanSet has {list(variant_names)}")
        if self.slo_routes:
            if kv_layout != "paged":
                raise ValueError(
                    "SLO routing requires kv_layout='paged': variant-"
                    "grouped decode masks the other groups' slots, and "
                    "only the paged layout routes masked KV writes to the "
                    "trash page instead of live positions")
            if variant_names is None:
                raise ValueError(
                    "slo_routes needs a multi-variant PlanSet backend "
                    "(`repro.runtime.PlanSet`); got "
                    f"{type(backend).__name__ if backend is not None else None}")
            for cls, v in self.slo_routes.items():
                if v not in variant_names:
                    raise ValueError(
                        f"slo_routes[{cls!r}] -> {v!r} is not bound: this "
                        f"PlanSet has {list(variant_names)}")
        if self.degrade_to is not None:
            if kv_layout != "paged":
                raise ValueError(
                    "precision degradation requires kv_layout='paged' "
                    "(variant-grouped execution masks into the trash page)")
            if variant_names is None:
                raise ValueError(
                    "degrade_to needs a multi-variant PlanSet backend "
                    "(`repro.runtime.PlanSet`); got "
                    f"{type(backend).__name__ if backend is not None else None}")
            if self.degrade_to not in variant_names:
                raise ValueError(
                    f"degrade_to={self.degrade_to!r} is not bound: this "
                    f"PlanSet has {list(variant_names)}")
        if self.injector is not None and kv_layout != "paged":
            raise ValueError(
                "fault injection requires kv_layout='paged' (containment "
                "releases/purges pages and requeues via chunked prefill)")
        if self._spec is not None and (
                self.injector is not None or self.degrade_to is not None
                or (scheduler is not None and scheduler.preempts)):
            raise ValueError(
                "speculate is incompatible with fault injection, precision "
                "degradation, and deadline preemption: a speculative round "
                "commits multiple tokens under a pinned draft/target pair, "
                "which the per-step containment/routing machinery does not "
                "cover")

        if kv_layout == "paged":
            self.page_size = int(page_size)
            self.pages_per_slot = -(-self.max_len // self.page_size)
            self.slot_cap = self.pages_per_slot * self.page_size
            self.num_pages = (int(num_pages) if num_pages is not None
                              else self.max_batch * self.pages_per_slot)
            self.prefill_chunk = (int(prefill_chunk) if prefill_chunk
                                  else 2 * self.page_size)
            # degrade_to joins slo_routes here: both make KV numerics
            # variant-dependent, so pages cannot be shared across requests
            self.prefix_cache = bool(prefix_cache) and \
                cfg.moe is None and not cfg.frontend and \
                set(cfg.pattern) <= _PREFIX_SAFE_KINDS and \
                not self.slo_routes and self.degrade_to is None
            self.pool_mgr = PagePool(self.num_pages, self.page_size)
            # the DEVICE page pool persists across run() calls: the
            # allocator's hash index outlives a run, so the pages it can
            # match must stay resident too (a repeated trace then serves
            # its prompts straight from cache)
            self._paged_caches = None
        else:
            self.slot_cap = self.max_len
            self.prefix_cache = False

        self._kv_axes = T.cache_kv_axes(cfg)
        self._has_recurrent = any(
            ax.startswith("slot") for ax in jax.tree.leaves(self._kv_axes))
        self._kv_capacity_bytes, self._kv_page_bytes = self._kv_footprint()
        if sampling is not None:
            self._base_key = jax.random.PRNGKey(int(sampling.seed))
        self._req_counter = 0
        # robustness bookkeeping (cleared per run): per-request resume/
        # serving metadata, quarantined-slot release steps, stuck-until
        # markers from the injector
        self._req_meta: Dict[int, dict] = {}
        self._quarantine: Dict[int, int] = {}
        self._stuck: Dict[int, int] = {}
        self._inject_slots: List[int] = []
        self._monitor: Optional[HeartbeatMonitor] = None

        def pick(logits, keys):
            # greedy argmax, or per-slot sampling advancing the PRNG keys
            # (keys ride through unchanged when greedy so trace signatures
            # are sampling-independent)
            if sampling is None:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), keys
            tok, keys = sample_tokens(logits, keys, sampling)
            return tok, keys

        def decode_fn(params, tok, caches, lengths, active, keys):
            self.trace_counts["decode"] += 1
            logits, caches = T.decode_step(params, cfg, tok, caches, lengths,
                                           active=active)
            tok, keys = pick(logits, keys)
            return tok, keys, caches

        def decode_paged_fn(params, tok, caches, lengths, active, pages,
                            keys, inject, *, variant=None):
            # ``inject`` (B,) float32 is the fault-injection vector (zeros
            # in normal operation; NaN at a targeted slot) — a traced
            # argument, so injecting never retraces.  ``ok`` is the
            # containment screen: True iff the slot's logits are finite.
            self.trace_counts["decode"] += 1
            logits, caches = T.decode_step(params, cfg, tok, caches, lengths,
                                           active=active, pages=pages,
                                           variant=variant)
            logits = logits + inject[:, None]
            ok = jnp.isfinite(logits).all(axis=-1)
            tok, keys = pick(logits, keys)
            return tok, keys, ok, caches

        def prefill_fn(params, prompts, lengths, pool, slots, frontend,
                       keys):
            self.trace_counts["prefill"] += 1
            fresh = T.init_cache(cfg, prompts.shape[0], self.max_len)
            logits, fresh = T.prefill(params, cfg, prompts, fresh,
                                      cross_source=frontend, lengths=lengths)
            tok0, keys = pick(logits, keys)
            return tok0, keys, T.scatter_cache(pool, fresh, slots)

        def chunk_fn(params, tokens, caches, fill, valid, pages, frontend,
                     keys, *, variant=None):
            self.trace_counts["chunk"] += 1
            logits, caches = T.prefill_chunk(params, cfg, tokens, caches,
                                             fill, valid, pages,
                                             cross_source=frontend,
                                             variant=variant)
            # the isfinite screen only means anything for slots completing
            # their prompt this chunk (other rows' logits are unread)
            ok = jnp.isfinite(logits).all(axis=-1)
            tok, keys = pick(logits, keys)
            return tok, keys, ok, caches

        def reset_fn(caches, slots):
            # zero the per-slot (non-page) state of freshly admitted slots:
            # recurrent state and encoder memory must not leak from the
            # slot's previous occupant (dense admission overwrites via
            # scatter_cache instead)
            def f(leaf, ax):
                if ax == "slot0":
                    return leaf.at[slots].set(jnp.zeros((), leaf.dtype))
                if ax == "slot1":
                    return leaf.at[:, slots].set(jnp.zeros((), leaf.dtype))
                return leaf
            return jax.tree.map(f, caches, self._kv_axes)

        def copy_pages_fn(caches, src, dst):
            # copy-on-write: duplicate shared partially-filled tail pages
            # into pages the new request owns before it writes them
            def f(leaf, ax):
                if ax == "page0":
                    return leaf.at[dst].set(leaf[src])
                if ax == "page1":
                    return leaf.at[:, dst].set(leaf[:, src])
                return leaf
            return jax.tree.map(f, caches, self._kv_axes)

        def corrupt_pages_fn(caches, pages):
            # fault injection: stomp NaN over the floating-point KV rows of
            # ``pages`` — the damage surfaces as non-finite logits on the
            # next step that attends over them
            def f(leaf, ax):
                if not jnp.issubdtype(leaf.dtype, jnp.floating):
                    return leaf
                if ax == "page0":
                    return leaf.at[pages].set(jnp.nan)
                if ax == "page1":
                    return leaf.at[:, pages].set(jnp.nan)
                return leaf
            return jax.tree.map(f, caches, self._kv_axes)

        self._decode = jax.jit(decode_fn)
        self._decode_paged = jax.jit(decode_paged_fn,
                                     static_argnames=("variant",))
        self._prefill = jax.jit(prefill_fn)
        self._chunk = jax.jit(chunk_fn, static_argnames=("variant",))
        self._reset = jax.jit(reset_fn)
        self._copy_pages = jax.jit(copy_pages_fn)
        self._corrupt_pages = jax.jit(corrupt_pages_fn)

        if self._spec is not None:
            draft_v, target_v = self._spec
            k = self.draft_k
            cap = self.slot_cap

            def restore_slots(caches, snap, mask=None):
                # put recurrent (slot-resident) state back to its pre-draft
                # snapshot; page pools keep the draft writes (verify
                # overwrites every draft-written position).  ``mask`` (B,)
                # limits the restore to selected slots.
                def f(leaf, s, ax):
                    if not ax.startswith("slot"):
                        return leaf
                    if mask is None:
                        return s
                    shape = ((-1,) + (1,) * (leaf.ndim - 1) if ax == "slot0"
                             else (1, -1) + (1,) * (leaf.ndim - 2))
                    return jnp.where(mask.reshape(shape), s, leaf)
                return jax.tree.map(f, caches, snap, self._kv_axes)

            def draft_fn(params, tok, caches, lengths, active, pages):
                # k greedy decode steps under the DRAFT variant; slots at
                # capacity stop advancing (their rows repeat the carry
                # token — verify's per-slot valid count ignores them)
                self.trace_counts["draft"] += 1
                def body(carry, _):
                    tok, caches, pos = carry
                    live = active & (pos < cap)
                    logits, caches = T.decode_step(
                        params, cfg, tok, caches, pos, active=live,
                        pages=pages, variant=draft_v)
                    nxt = jnp.where(
                        live, jnp.argmax(logits, axis=-1).astype(jnp.int32),
                        tok)
                    return (nxt, caches, pos + live.astype(jnp.int32)), nxt
                init = (tok.astype(jnp.int32), caches,
                        lengths.astype(jnp.int32))
                (_, caches, _), toks = jax.lax.scan(body, init, None,
                                                    length=k)
                return jnp.swapaxes(toks, 0, 1), caches        # (B, k)

            def verify_fn(params, tok0, drafted, caches, snap, fill, valid,
                          pages):
                # one fixed-shape chunk of [t0, d1..dk] under the TARGET
                # variant: full logits give the target argmax at every
                # drafted position, and the chunk's KV writes replace all
                # draft-written positions with target numerics
                self.trace_counts["verify"] += 1
                if self._has_recurrent:
                    caches = restore_slots(caches, snap)
                tokens = jnp.concatenate(
                    [tok0[:, None].astype(jnp.int32), drafted], axis=1)
                logits, caches = T.prefill_chunk(
                    params, cfg, tokens, caches, fill, valid, pages,
                    variant=target_v, full_logits=True)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

            def replay_fn(params, tok0, drafted, caches, snap, fill, valid,
                          pages):
                # hybrid archs, partial accepts only: rewind the slot's
                # recurrent state to the round snapshot and re-advance it
                # over exactly the committed tokens (valid = c); the KV
                # rewrite is value-identical, recurrent state ends at the
                # sequential S_{L+c}
                self.trace_counts["replay"] += 1
                caches = restore_slots(caches, snap, mask=valid > 0)
                tokens = jnp.concatenate(
                    [tok0[:, None].astype(jnp.int32), drafted], axis=1)
                _, caches = T.prefill_chunk(params, cfg, tokens, caches,
                                            fill, valid, pages,
                                            variant=target_v)
                return caches

            self._draft = jax.jit(draft_fn)
            self._verify = jax.jit(verify_fn)
            self._replay = jax.jit(replay_fn)

    # ---- helpers ---------------------------------------------------------

    def _kv_footprint(self):
        """(total sequence-KV bytes of the pool, bytes per page or None).

        Sums only the sequence-indexed attention-KV leaves (the ``"page"``
        markers of `transformer.cache_kv_axes`) — per-slot recurrent state
        is identical across layouts and excluded so dense-vs-paged peak
        numbers compare exactly what paging changes."""
        if self.kv_layout == "paged":
            specs = T.paged_cache_specs(self.cfg, self.max_batch,
                                        self.num_pages + 1, self.page_size)
        else:
            specs = T.cache_specs(self.cfg, self.max_batch, self.max_len)
        total = 0
        per_page = 0
        for leaf, ax in zip(jax.tree.leaves(specs),
                            jax.tree.leaves(self._kv_axes)):
            if not ax.startswith("page"):
                continue
            nbytes = math.prod(leaf.shape) * leaf.dtype.itemsize
            total += nbytes
            if self.kv_layout == "paged":
                # bytes of ONE page across all stacked layers of this leaf:
                # pool-rows axis is 1 under a scan stack ("page1"), else 0
                rows = leaf.shape[1] if ax == "page1" else leaf.shape[0]
                per_page += nbytes // rows
        if self.kv_layout == "paged":
            return per_page * self.num_pages, per_page  # trash row excluded
        return total, None

    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _gbucket(self, k: int) -> int:
        """Admission-group size bucket (next power of two): bounds dense
        prefill retraces to O(log max_batch * log max_len) combinations."""
        g = 1
        while g < k:
            g *= 2
        return min(g, self.max_batch)

    def _ctx(self):
        return (matmul_backend(self.backend) if self.backend is not None
                else contextlib.nullcontext())

    def _pages_needed(self, req: Request) -> int:
        total = min(req.prompt_len + req.max_new_tokens, self.slot_cap)
        return self.pool_mgr.pages_for(total)

    def _frontend_row(self, req: Request):
        if not self.cfg.frontend:
            return None
        if req.frontend is None:
            raise ValueError(
                f"arch {self.cfg.name} needs a per-request cross-attention "
                f"`frontend`, missing on: [{req.rid!r}]")
        return jnp.asarray(req.frontend, jnp.bfloat16)

    def _route(self, req: Request) -> Optional[str]:
        """The plan variant serving ``req``: the speculative target (all
        slots), the request's routed SLO class, or the backend default."""
        if self._spec is not None:
            return self._spec[1]
        if self.slo_routes and req.slo is not None:
            return self.slo_routes[req.slo]
        return None

    def _meta(self, req: Request) -> dict:
        """Per-request serving metadata, created at FIRST admission.

        ``variant``/``degraded`` are pinned here and reused on every
        resume — a request's KV numerics must stay under one variant for
        its whole lifetime.  ``tokens``/``t_first`` hold the committed
        state a preempted/faulted request resumes from."""
        meta = self._req_meta.get(id(req))
        if meta is None:
            degraded = self._degrade is not None and self._degrade.active
            meta = {"variant": (self.degrade_to if degraded
                                else self._route(req)),
                    "degraded": degraded, "tokens": [], "t_first": None,
                    "preemptions": 0, "requeues": 0}
            self._req_meta[id(req)] = meta
        return meta

    def _eff_prompt(self, req: Request) -> np.ndarray:
        """The token stream to prefill: the original prompt, plus — for a
        request resuming after preemption/fault-requeue — every committed
        token.  Prefilling that stream reproduces the preempted slot's
        decode state exactly (the logits at its last position equal the
        decode-step logits the slot would have produced next)."""
        meta = self._req_meta.get(id(req))
        if meta and meta["tokens"]:
            return np.concatenate(
                [req.prompt, np.asarray(meta["tokens"], np.int32)])
        return req.prompt

    def _next_key(self) -> np.ndarray:
        """Per-request PRNG key row (zeros when the engine is greedy)."""
        if self.sampling is None:
            return np.zeros(2, np.uint32)
        key = request_key(self._base_key, self._req_counter)
        self._req_counter += 1
        return np.asarray(key, np.uint32)

    def _validate(self, requests: Sequence[Request]):
        for r in requests:
            if self.slo_routes and r.slo is not None \
                    and r.slo not in self.slo_routes:
                raise ValueError(
                    f"request {r.rid!r}: SLO class {r.slo!r} has no route "
                    f"(routes cover {sorted(self.slo_routes)})")
            if self.kv_layout == "dense":
                if r.prompt_len >= self.max_len:
                    raise ValueError(
                        f"request {r.rid!r}: prompt_len {r.prompt_len} does "
                        f"not fit the engine's max_len {self.max_len} "
                        f"(needs prompt_len < max_len)")
                continue
            need = self._pages_needed(r)
            if r.prompt_len >= self.slot_cap or need > self.num_pages:
                warnings.warn(
                    f"unservable request {r.rid!r}: needs {need} pages "
                    f"({r.prompt_len} prompt + {r.max_new_tokens} new tokens "
                    f"@ page_size {self.page_size}) but the pool caps at "
                    f"{self.num_pages} pages x {self.page_size} tokens "
                    f"(slot capacity {self.slot_cap})")
                raise ValueError(
                    f"request {r.rid!r}: needs {need} pages, pool has "
                    f"{self.num_pages} (slot capacity {self.slot_cap} "
                    f"tokens)")

    # ---- retirement (host-side, vectorized) ------------------------------

    def _retire_slot(self, batch: BatchState, slot: int, reason: str,
                     now: float, step: int,
                     results: Dict[int, "EngineResult"]):
        st = batch.retire(slot)
        req = st.request
        if self.kv_layout == "paged":
            self.pool_mgr.release(batch.slot_pages[slot])
            batch.slot_pages[slot] = []
            batch.page_table[slot, :] = 0
        meta = self._req_meta.get(id(req), {})
        results[id(req)] = RequestResult(
            rid=req.rid, prompt_len=req.prompt_len, tokens=st.tokens,
            finish_reason=reason, ttft_s=st.t_first - st.t_ready,
            finish_s=now - st.t_ready, admitted_step=st.admitted_step,
            finished_step=step, slo=req.slo,
            variant=meta.get("variant"),
            degraded=bool(meta.get("degraded", False)),
            preemptions=int(meta.get("preemptions", 0)),
            requeues=int(meta.get("requeues", 0)))

    def _slot_reason(self, batch: BatchState, slot: int) -> Optional[str]:
        st = batch.slots[slot]
        req = st.request
        if req.eos_id is not None and st.tokens[-1] == req.eos_id:
            return "eos"
        if len(st.tokens) >= req.max_new_tokens:
            return "max_new_tokens"
        if int(batch.lengths[slot]) >= self.slot_cap:
            return "length_cap"   # no room to embed the next token
        return None

    def _maybe_retire(self, batch: BatchState, slot: int, now: float,
                      step: int, results: Dict[int, RequestResult]) -> bool:
        reason = self._slot_reason(batch, slot)
        if reason is None:
            return False
        self._retire_slot(batch, slot, reason, now, step, results)
        return True

    def _postdecode(self, batch: BatchState, tok: np.ndarray, now: float,
                    step: int, results: Dict[int, "EngineResult"],
                    exclude: Optional[np.ndarray] = None):
        """Record one decode step's tokens and retire finished slots — one
        host sync happened already (``tok``); every predicate below reads
        host-side numpy mirrors, no per-slot device pulls.  ``exclude``
        masks slots that must NOT commit this step (stuck or faulted:
        their sampled token is garbage or missing)."""
        act = batch.active
        if exclude is not None:
            act = act & ~exclude
        idx = np.nonzero(act)[0]
        if self._monitor is not None:
            for b in idx:               # a commit is a liveness beat
                self._monitor.beat(int(b))
        batch.last_tok[idx] = tok[idx]
        batch.lengths[idx] += 1
        batch.n_gen[idx] += 1
        eos_hit = act & (batch.eos_id >= 0) & (tok == batch.eos_id)
        budget = act & (batch.n_gen >= batch.max_new)
        cap = act & (batch.lengths >= self.slot_cap)
        for b in idx:
            batch.slots[b].tokens.append(int(tok[b]))
        for b in np.nonzero(eos_hit | budget | cap)[0]:
            reason = ("eos" if eos_hit[b] else
                      "max_new_tokens" if budget[b] else "length_cap")
            self._retire_slot(batch, int(b), reason, now, step, results)

    # ---- dense admission -------------------------------------------------

    def _admit_dense(self, batch: BatchState, admits, step: int,
                     t_ready: Dict[int, float]):
        slots = np.asarray([s for s, _ in admits], np.int32)
        reqs = [r for _, r in admits]
        k = len(reqs)
        kp = self._gbucket(k)                 # pad the GROUP SIZE too
        P = self._bucket(max(r.prompt_len for r in reqs))
        prompts = np.zeros((kp, P), np.int32)
        lengths = np.zeros(kp, np.int32)
        keys = np.zeros((kp, 2), np.uint32)
        for i, r in enumerate(reqs):
            prompts[i, :r.prompt_len] = r.prompt
            lengths[i] = r.prompt_len
            keys[i] = self._next_key()
        # pad rows repeat the last real request (identical rows compute
        # identical caches, so the duplicate scatter writes are no-ops)
        prompts[k:] = prompts[k - 1]
        lengths[k:] = lengths[k - 1]
        slots_p = np.concatenate([slots, np.full(kp - k, slots[-1],
                                                 np.int32)])
        frontend = None
        if self.cfg.frontend:
            rows = [self._frontend_row(r) for r in reqs]
            frontend = jnp.stack(rows + [rows[-1]] * (kp - k))
        t0 = time.monotonic()
        tok0, keys_out, batch.caches = self._prefill(
            self.params, prompts, lengths, batch.caches, slots_p, frontend,
            keys)
        tok0 = np.asarray(tok0)           # sync: first tokens materialized
        if self.sampling is not None:
            keys_out = np.asarray(keys_out)
        t1 = time.monotonic()
        self.stats["prefill_s"] += t1 - t0
        self.stats["prefill_calls"] += 1
        for i, (slot, req) in enumerate(admits):
            batch.assign(slot, req, int(tok0[i]),
                         t_ready=t_ready[id(req)], t_first=t1, step=step)
            if self.sampling is not None:
                batch.rng[slot] = keys_out[i]
        return [s for s, _ in admits]

    # ---- paged admission + chunked prefill -------------------------------

    def _admit_paged(self, batch: BatchState, admits, step: int,
                     t_ready: Dict[int, float]):
        cow_pairs = []
        slots = []
        for slot, req in admits:
            meta = self._meta(req)
            prompt = self._eff_prompt(req)
            if meta["tokens"]:
                self.stats["resumes"] += 1
            need = self._pages_needed(req)
            hit_len, shared, cow_src = (
                self.pool_mgr.match(prompt) if self.prefix_cache
                else (0, [], None))
            pages = shared + self.pool_mgr.alloc(need - len(shared))
            if cow_src is not None:
                cow_pairs.append((cow_src, pages[len(shared)]))
            batch.start_prefill(slot, req, pages, hit_len,
                                t_ready=t_ready[id(req)], step=step,
                                prompt=prompt,
                                prior_tokens=meta["tokens"],
                                t_first=meta["t_first"])
            batch.variant[slot] = meta["variant"]
            batch.rng[slot] = self._next_key()
            if self.cfg.frontend:
                row = self._frontend_row(req)
                if self._fe_buf is None:
                    self._fe_buf = jnp.zeros(
                        (self.max_batch, *row.shape), jnp.bfloat16)
                self._fe_buf = self._fe_buf.at[slot].set(row)
            slots.append(slot)
        batch.caches = self._reset(batch.caches,
                                   np.asarray(slots, np.int32))
        if cow_pairs:
            src = np.asarray([s for s, _ in cow_pairs], np.int32)
            dst = np.asarray([d for _, d in cow_pairs], np.int32)
            batch.caches = self._copy_pages(batch.caches, src, dst)
            for s, _ in cow_pairs:
                self.pool_mgr.release_cow(s)

    def _register_prompt(self, batch: BatchState, slot: int):
        """Publish a fully prefilled prompt's pages for prefix sharing.
        Uses the EFFECTIVE prompt (resumes include committed tokens —
        exact content keys, so the entries are as valid as any other)."""
        if not self.prefix_cache:
            return
        prompt = batch.pending[slot].prompt
        pages = batch.slot_pages[slot]
        for key, end in self.pool_mgr.prompt_keys(prompt):
            self.pool_mgr.register(pages[(end - 1) // self.page_size], key)

    def _variant_groups(self, batch: BatchState, sel: np.ndarray):
        """``[(variant, [slots...]), ...]`` grouping ``sel`` by per-slot
        plan variant (deterministic order: default group first)."""
        groups: Dict[Optional[str], List[int]] = {}
        for b in sel:
            groups.setdefault(batch.variant[b], []).append(int(b))
        return sorted(groups.items(),
                      key=lambda kv: (kv[0] is not None, kv[0] or ""))

    def _chunk_step(self, batch: BatchState, step: int,
                    results: Dict[int, "EngineResult"],
                    queue: Optional[RequestQueue] = None,
                    t_ready: Optional[Dict[int, float]] = None):
        """Stream the next ``prefill_chunk`` tokens of EVERY prefilling
        slot in one fixed-shape jitted call per plan-variant group (one
        call total when nothing is routed); slots whose prompt completes
        get their first token from this chunk's logits and join decode.
        Completing slots whose logits fail the isfinite screen go through
        fault containment instead of assignment."""
        B, C = self.max_batch, self.prefill_chunk
        sel = np.nonzero(batch.prefilling)[0]
        tokens = np.zeros((B, C), np.int32)
        valid_all = np.zeros(B, np.int32)
        for b in sel:
            pend = batch.pending[b]
            plen = len(pend.prompt)
            pos = int(batch.fill_pos[b])
            n = min(C, plen - pos)
            tokens[b, :n] = pend.prompt[pos:pos + n]
            valid_all[b] = n
        t0 = time.monotonic()
        outs = []
        for var, group in self._variant_groups(batch, sel):
            valid = np.zeros(B, np.int32)
            valid[group] = valid_all[group]
            tok, keys, ok, batch.caches = self._chunk(
                self.params, tokens, batch.caches, batch.fill_pos.copy(),
                valid, batch.page_table.copy(), self._fe_buf, batch.rng,
                variant=var)
            outs.append((group, tok, keys, ok))
            self.stats["prefill_calls"] += 1
        tok_all = np.zeros(B, np.int32)
        ok_all = np.ones(B, bool)
        keys_all = None
        for group, tok, keys, ok in outs:
            tok_all[group] = np.asarray(tok)[group]     # sync
            ok_all[group] = np.asarray(ok)[group]
            if self.sampling is not None:
                if keys_all is None:
                    keys_all = np.zeros((B, 2), np.uint32)
                keys_all[group] = np.asarray(keys)[group]
        t1 = time.monotonic()
        self.stats["prefill_s"] += t1 - t0
        batch.fill_pos[sel] += valid_all[sel]
        batch.lengths[sel] = batch.fill_pos[sel]
        if self._monitor is not None:
            for b in sel:               # chunk progress is a liveness beat
                self._monitor.beat(int(b))
        for b in sel:
            pend = batch.pending[b]
            if batch.fill_pos[b] >= len(pend.prompt):
                if not ok_all[b] and queue is not None:
                    self._handle_fault(batch, queue, int(b), step, t1,
                                       t_ready or {}, results, purge=True)
                    continue
                self._register_prompt(batch, b)
                tf = pend.t_first if pend.t_first is not None else t1
                st = batch.assign(b, pend.request, int(tok_all[b]),
                                  t_ready=pend.t_ready, t_first=tf,
                                  step=pend.admitted_step,
                                  prompt_len=len(pend.prompt),
                                  prior_tokens=pend.prior_tokens)
                meta = self._req_meta.get(id(pend.request))
                if meta is not None and meta["t_first"] is None:
                    meta["t_first"] = tf
                    if self._degrade is not None:
                        self._degrade.observe(tf - pend.t_ready)
                if self.sampling is not None:
                    # only completing slots consumed their sample; mid-
                    # prompt slots keep their key untouched
                    batch.rng[b] = keys_all[b]
                self._maybe_retire(batch, int(b), t1, step, results)

    # ---- decode: per-variant groups --------------------------------------

    def _decode_groups(self, batch: BatchState, step: int,
                       results: Dict[int, "EngineResult"],
                       queue: Optional[RequestQueue] = None,
                       t_ready: Optional[Dict[int, float]] = None):
        """One decode step: a single jitted call per active plan-variant
        group (exactly one call when nothing is routed), the other groups'
        slots masked inactive — their paged KV writes land in the trash
        page, so groups cannot corrupt each other.  Stuck slots (injected
        liveness faults) are masked out entirely and commit nothing; slots
        failing the isfinite screen commit nothing and go through fault
        containment."""
        t = time.monotonic()
        stuck = np.zeros(self.max_batch, bool)
        for b, until in self._stuck.items():
            if until > step and batch.active[b]:
                stuck[b] = True
        inject = np.zeros(self.max_batch, np.float32)
        inject[self._inject_slots] = np.nan
        self._inject_slots = []
        outs = []
        for var, group in self._variant_groups(
                batch, np.nonzero(batch.active & ~stuck)[0]):
            mask = np.zeros(self.max_batch, bool)
            mask[group] = True
            tok, keys, ok, batch.caches = self._decode_paged(
                self.params, batch.last_tok, batch.caches, batch.lengths,
                mask, batch.page_table.copy(), batch.rng, inject,
                variant=var)
            outs.append((group, tok, keys, ok))
        tok_all = batch.last_tok.copy()
        ok_all = np.ones(self.max_batch, bool)
        for group, tok, keys, ok in outs:
            tok_all[group] = np.asarray(tok)[group]     # sync
            ok_all[group] = np.asarray(ok)[group]
            if self.sampling is not None:
                batch.rng[group] = np.asarray(keys)[group]
        now = time.monotonic()
        self.stats["decode_s"] += now - t
        self.stats["decode_steps"] += 1
        faulted = batch.active & ~ok_all & ~stuck
        self._postdecode(batch, tok_all, now, step, results,
                         exclude=(stuck | faulted))
        if queue is not None:
            for b in np.nonzero(faulted)[0]:
                if batch.active[b]:     # not retired by _postdecode
                    self._handle_fault(batch, queue, int(b), step, now,
                                       t_ready or {}, results, purge=True)

    # ---- self-speculative decoding ---------------------------------------

    def _spec_round(self, batch: BatchState, step: int,
                    results: Dict[int, RequestResult]):
        """One speculative round: draft ``k`` tokens per active slot with
        the draft variant, verify all of them in one target-variant chunk,
        commit the longest agreeing prefix plus the bonus target token
        (applying the per-token retire predicates exactly as sequential
        decoding would), and replay partially-accepting slots' recurrent
        state when the arch has any."""
        k = self.draft_k
        sel = np.nonzero(batch.active)[0]
        tok0 = batch.last_tok.copy()
        fill0 = batch.lengths.copy()
        snap = batch.caches                  # pre-draft arrays (immutable)
        t = time.monotonic()
        drafted, batch.caches = self._draft(
            self.params, tok0, batch.caches, fill0, batch.active.copy(),
            batch.page_table.copy())
        vcount = np.zeros(self.max_batch, np.int32)
        vcount[sel] = np.minimum(k + 1, self.slot_cap - fill0[sel])
        vtok, batch.caches = self._verify(
            self.params, tok0, drafted, batch.caches, snap, fill0, vcount,
            batch.page_table.copy())
        d = np.asarray(drafted)              # sync (both calls dispatched)
        v = np.asarray(vtok)
        now = time.monotonic()
        self.stats["decode_s"] += now - t
        self.stats["decode_steps"] += 1
        self.stats["spec_rounds"] += 1
        replay_valid = np.zeros(self.max_batch, np.int32)
        for b in sel:
            vc = int(vcount[b])
            # drafts that could actually commit: the slot's remaining token
            # budget caps the round, so over-drafting past it is not a
            # draft-quality failure and must not dilute the acceptance rate
            budget_left = int(batch.max_new[b] - batch.n_gen[b])
            m = 0                            # agreeing draft prefix
            while m < vc - 1 and d[b, m] == v[b, m]:
                m += 1
            st = batch.slots[b]
            committed = 0
            retired = False
            for j in range(m + 1):           # m matches + 1 bonus token
                tokj = int(v[b, j])
                st.tokens.append(tokj)
                batch.last_tok[b] = tokj
                batch.lengths[b] += 1
                batch.n_gen[b] += 1
                committed += 1
                reason = self._slot_reason(batch, int(b))
                if reason is not None:
                    self._retire_slot(batch, int(b), reason, now, step,
                                      results)
                    retired = True
                    break
            self.stats["spec_drafted"] += min(vc - 1, budget_left)
            self.stats["spec_accepted"] += min(committed, m)
            self.stats["spec_committed"] += committed
            if not retired and committed < vc:
                replay_valid[b] = committed
        if self._has_recurrent and replay_valid.any():
            t = time.monotonic()
            batch.caches = self._replay(
                self.params, tok0, drafted, batch.caches, snap, fill0,
                replay_valid, batch.page_table.copy())
            self.stats["decode_s"] += time.monotonic() - t

    # ---- robustness: preemption, shedding, faults ------------------------

    def _free_slots(self, batch: BatchState, step: int) -> List[int]:
        """Free slots minus the quarantined ones."""
        return [b for b in batch.free_slots()
                if self._quarantine.get(b, 0) <= step]

    def _maybe_preempt(self, batch: BatchState, queue: RequestQueue,
                       t_ready: Dict[int, float], step: int, now: float):
        """Retire-and-requeue at most ONE active slot when a visible
        queued request is strictly more urgent than the least-urgent
        running one and cannot be served from free capacity.  The victim's
        committed tokens are recorded for resumption; with the prefix
        cache on, its filled pages are registered under the resume
        prompt's keys first, so they park in the LRU and resumption
        re-prefills only the unhashed tail."""
        waiting = [r for r in queue if r.arrival_step <= step]
        if not waiting:
            return
        front = min(waiting, key=lambda r: urgency(r, now,
                                                   t_ready.get(id(r))))
        if self._free_slots(batch, step) and \
                self._pages_needed(front) <= self.pool_mgr.available():
            return          # plain admission can serve it this step
        cands = [b for b in range(self.max_batch)
                 if batch.active[b] and not self._stuck.get(b, 0) > step
                 and self._req_meta[id(batch.slots[b].request)]
                 ["preemptions"] < self.max_preemptions]
        if not cands:
            return
        victim = max(cands, key=lambda b: urgency(
            batch.slots[b].request, now,
            t_ready.get(id(batch.slots[b].request))))
        vreq = batch.slots[victim].request
        if not urgency(front, now, t_ready.get(id(front))) < \
                urgency(vreq, now, t_ready.get(id(vreq))):
            return          # nobody waiting beats the least-urgent runner
        st = batch.retire(victim)
        meta = self._req_meta[id(vreq)]
        meta["tokens"] = list(st.tokens)
        meta["t_first"] = st.t_first
        meta["preemptions"] += 1
        pages = batch.slot_pages[victim]
        if self.prefix_cache:
            # the cache holds resume_prompt[:filled] (the last committed
            # token is not in the cache yet) — publish exactly that, so
            # the resume prefill prefix-matches everything but the tail
            filled = int(batch.lengths[victim])
            resume = self._eff_prompt(vreq)
            for key, end in self.pool_mgr.prompt_keys(resume[:filled]):
                self.pool_mgr.register(pages[(end - 1) // self.page_size],
                                       key)
        self.pool_mgr.release(pages)
        batch.slot_pages[victim] = []
        batch.page_table[victim, :] = 0
        queue.push_front(vreq)
        self.stats["preemptions"] += 1

    def _shed(self, req: Request, reason: str, step: int, waited: float,
              results: Dict[int, "EngineResult"]):
        results[id(req)] = ShedResult(rid=req.rid, reason=reason,
                                      shed_step=step,
                                      waited_s=round(max(waited, 0.0), 6),
                                      slo=req.slo)
        self.stats["shed_requests"] += 1

    def _resumable(self, req: Request) -> bool:
        """Requests holding committed tokens (preempted/faulted, waiting
        to resume) are never backlog-shed — that would discard served
        work.  The wall-clock timeout still applies to them."""
        return bool(self._req_meta.get(id(req), {}).get("tokens"))

    def _timeout_queued(self, queue: RequestQueue,
                        t_ready: Dict[int, float], step: int, now: float,
                        results: Dict[int, "EngineResult"]):
        """Shed visible queued requests that outlived the wall-clock
        budget (run BEFORE admission: a timed-out request is dead even if
        a slot just freed — the client stopped waiting)."""
        if self.request_timeout_s is None:
            return
        for r in [r for r in queue if r.arrival_step <= step]:
            waited = now - t_ready.get(id(r), now)
            if waited > self.request_timeout_s:
                queue.remove(r)
                self.stats["timeouts"] += 1
                self._shed(r, "timeout", step, waited, results)

    def _shed_backlog(self, queue: RequestQueue,
                      t_ready: Dict[int, float], step: int, now: float,
                      results: Dict[int, "EngineResult"],
                      free_frac: Optional[float] = None):
        """Bound the POST-admission backlog (run after the step's
        admissions): overflow beyond ``max_queue_depth`` sheds newest
        visible first; below the free-page watermark everything behind
        the head of line sheds (the head keeps its place — head-of-line
        blocking already guarantees it admits as soon as pages free)."""
        if self.max_queue_depth is not None:
            visible = [r for r in queue if r.arrival_step <= step]
            excess = len(visible) - self.max_queue_depth
            for r in reversed(visible):
                if excess <= 0:
                    break
                if self._resumable(r):
                    continue
                queue.remove(r)
                excess -= 1
                self._shed(r, "queue_depth", step,
                           now - t_ready.get(id(r), now), results)
        if self.page_watermark is not None and free_frac is not None \
                and free_frac < self.page_watermark:
            for r in [r for r in queue if r.arrival_step <= step][1:]:
                if self._resumable(r):
                    continue
                queue.remove(r)
                self._shed(r, "page_watermark", step,
                           now - t_ready.get(id(r), now), results)

    def _timeout_running(self, batch: BatchState, step: int, now: float,
                         results: Dict[int, "EngineResult"]):
        """Retire ACTIVE slots whose request outlived the wall-clock
        budget — they keep their partial tokens, ``finish_reason=
        "timeout"``.  (Prefilling slots complete their bounded prefill
        first and time out on the next sweep.)"""
        if self.request_timeout_s is None:
            return
        for b in range(self.max_batch):
            if batch.active[b] and \
                    now - batch.slots[b].t_ready > self.request_timeout_s:
                self.stats["timeouts"] += 1
                self._retire_slot(batch, b, "timeout", now, step, results)

    def _apply_faults(self, batch: BatchState, step: int):
        """Draw this step's injected faults and arm them: NaN slots for
        the decode inject vector, NaN-stomped KV pages, stuck markers."""
        if self.injector is None:
            return
        occupied = [b for b in range(self.max_batch)
                    if batch.active[b] or batch.prefilling[b]]
        if not occupied:
            return
        for ev in self.injector.draw(step, occupied):
            self.stats["faults_injected"] += 1
            if ev.kind == "nonfinite_logits":
                self._inject_slots.append(ev.slot)
            elif ev.kind == "corrupt_page":
                # corrupt the page holding the slot's newest WRITTEN
                # position — guaranteed inside the attention window, so
                # detection on the next step is certain
                filled = max(int(batch.lengths[ev.slot]), 1)
                pages = batch.slot_pages[ev.slot]
                page = pages[min((filled - 1) // self.page_size,
                                 len(pages) - 1)]
                batch.caches = self._corrupt_pages(
                    batch.caches, np.asarray([page], np.int32))
            elif ev.kind == "stuck":
                self._stuck[ev.slot] = step + ev.duration

    def _handle_fault(self, batch: BatchState, queue: RequestQueue,
                      slot: int, step: int, now: float,
                      t_ready: Dict[int, float],
                      results: Dict[int, "EngineResult"], *,
                      purge: bool, kind: str = "numeric"):
        """Contain a detected fault on ``slot``: release (and for numeric
        faults PURGE — corrupted content must never be prefix-matched)
        its pages, quarantine the slot, and requeue the request ONCE with
        its committed tokens; a second fault sheds it with
        ``ShedResult(reason="fault")``."""
        self.stats["faults_detected"] += 1
        if batch.active[slot]:
            st = batch.retire(slot)
            req, tokens, tf = st.request, list(st.tokens), st.t_first
        else:
            pend = batch.pending[slot]
            req, tokens, tf = (pend.request, list(pend.prior_tokens),
                               pend.t_first)
            batch.prefilling[slot] = False
            batch.pending[slot] = None
            batch.fill_pos[slot] = 0
        pages = batch.slot_pages[slot]
        if purge:
            self.pool_mgr.purge(pages)
        self.pool_mgr.release(pages)
        batch.slot_pages[slot] = []
        batch.page_table[slot, :] = 0
        self._quarantine[slot] = step + self.quarantine_steps
        self._stuck.pop(slot, None)
        meta = self._req_meta[id(req)]
        if meta["requeues"] >= 1:       # requeue-once policy
            self._shed(req, "fault", step,
                       now - t_ready.get(id(req), now), results)
            return
        meta["requeues"] += 1
        meta["tokens"] = tokens         # committed tokens predate the
        meta["t_first"] = tf            # fault: clean, resume from them
        queue.push_front(req)

    # ---- main loops ------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> List["EngineResult"]:
        """Serve ``requests`` to completion; returns one result per
        request, in submission order — a `RequestResult` for requests that
        finished, a `ShedResult` for requests the overload/fault paths
        rejected.  Timing aggregates land in ``self.stats``."""
        self._validate(requests)
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "decode_steps": 0,
                      "prefill_calls": 0, "wall_s": 0.0,
                      "preemptions": 0, "resumes": 0, "shed_requests": 0,
                      "timeouts": 0, "faults_injected": 0,
                      "faults_detected": 0, "degrade_transitions": 0,
                      "straggler_events": 0, "heartbeat_trips": 0}
        if self._spec is not None:
            self.stats.update({"spec_rounds": 0, "spec_drafted": 0,
                               "spec_accepted": 0, "spec_committed": 0})
        self._req_counter = 0
        self._req_meta = {}
        self._quarantine = {}
        self._stuck = {}
        self._inject_slots = []
        if self._degrade is not None:
            self._degrade.reset()
        queue = RequestQueue()
        for r in requests:
            queue.push(r)
        results: Dict[int, EngineResult] = {}
        t0 = time.monotonic()
        if self.kv_layout == "paged":
            self._run_paged(queue, results)
        else:
            self._run_dense(queue, results)
        self.stats["wall_s"] = time.monotonic() - t0
        self.stats["kv_capacity_bytes"] = self._kv_capacity_bytes
        if self._spec is not None:
            drafted = self.stats["spec_drafted"]
            self.stats["spec_acceptance"] = (
                round(self.stats["spec_accepted"] / drafted, 4)
                if drafted else 0.0)
            rounds = self.stats["spec_rounds"]
            self.stats["spec_tokens_per_round"] = (
                round(self.stats["spec_committed"] / rounds, 4)
                if rounds else 0.0)
        if self.kv_layout == "paged":
            ps = self.pool_mgr.stats
            self.stats["kv_peak_pages"] = ps["peak_pages"]
            self.stats["kv_page_bytes"] = self._kv_page_bytes
            self.stats["kv_peak_bytes"] = ps["peak_pages"] * \
                self._kv_page_bytes
            self.stats["prefix_lookups"] = ps["lookups"]
            self.stats["prefix_hit_requests"] = ps["hit_requests"]
            self.stats["prefix_hit_tokens"] = ps["hit_tokens"]
            self.stats["cow_copies"] = ps["cow_copies"]
            self.stats["page_evictions"] = ps["evictions"]
        else:
            # dense pools are fully allocated up front: peak == capacity
            self.stats["kv_peak_bytes"] = self._kv_capacity_bytes
        if self._degrade is not None:
            self.stats["degrade_transitions"] = \
                len(self._degrade.transitions)
        return [results[id(r)] for r in requests]

    def _run_dense(self, queue: RequestQueue,
                   results: Dict[int, "EngineResult"]):
        batch = BatchState(self.max_batch,
                           T.init_cache(self.cfg, self.max_batch,
                                        self.max_len))
        t_ready: Dict[int, float] = {}
        step = 0
        with self._ctx():
            while len(queue) or batch.any_active():
                # idle + only future arrivals: fast-forward the step clock
                if not batch.any_active() and queue.ready(step) == 0:
                    step = max(step, queue.next_arrival())
                now = time.monotonic()
                for r in queue:
                    if r.arrival_step <= step and id(r) not in t_ready:
                        t_ready[id(r)] = now
                self._timeout_queued(queue, t_ready, step, now, results)
                self._timeout_running(batch, step, now, results)
                admits = self.scheduler.admissions(
                    queue, batch.free_slots(), batch.n_active, step,
                    now=now, t_ready=t_ready)
                for _, req in admits:
                    self._meta(req)     # pin variant/degraded at admission
                self._shed_backlog(queue, t_ready, step, now, results)
                if admits:
                    for slot in self._admit_dense(batch, admits, step,
                                                  t_ready):
                        self._maybe_retire(batch, slot, time.monotonic(),
                                           step, results)
                if not batch.any_active():
                    continue
                t = time.monotonic()
                tok, keys, batch.caches = self._decode(
                    self.params, batch.last_tok, batch.caches,
                    batch.lengths, batch.active, batch.rng)
                tok = np.asarray(tok)               # sync
                act = np.nonzero(batch.active)[0]
                if self.sampling is not None:
                    batch.rng[act] = np.asarray(keys)[act]
                now = time.monotonic()
                self.stats["decode_s"] += now - t
                self.stats["decode_steps"] += 1
                self._postdecode(batch, tok, now, step, results)
                step += 1

    def _run_paged(self, queue: RequestQueue,
                   results: Dict[int, "EngineResult"]):
        if self._paged_caches is None:
            rows = self.num_pages + 1                  # + trash page 0
            self._paged_caches = T.init_paged_cache(
                self.cfg, self.max_batch, rows, self.page_size)
        batch = BatchState(self.max_batch, self._paged_caches,
                           pages_per_slot=self.pages_per_slot)
        self._fe_buf = None
        t_ready: Dict[int, float] = {}
        step = 0
        # the liveness monitor runs on the STEP clock (host keys are slot
        # ids): a slot that commits nothing / makes no prefill progress
        # for heartbeat_steps steps is declared stuck
        step_ref = [0]
        self._monitor = HeartbeatMonitor(
            hosts=list(range(self.max_batch)),
            deadline_s=float(self.heartbeat_steps),
            clock=lambda: float(step_ref[0]))
        straggler = StragglerPolicy()
        with self._ctx():
            while len(queue) or batch.any_busy():
                if not batch.any_busy() and queue.ready(step) == 0:
                    step = max(step, queue.next_arrival())
                step_ref[0] = step
                now = time.monotonic()
                for r in queue:
                    if r.arrival_step <= step and id(r) not in t_ready:
                        t_ready[id(r)] = now
                self._timeout_queued(queue, t_ready, step, now, results)
                self._timeout_running(batch, step, now, results)
                if self.scheduler.preempts:
                    self._maybe_preempt(batch, queue, t_ready, step, now)
                reserved = [0]

                def fits(req):
                    # running reservation: one admission round may pop
                    # several requests before any pages are allocated
                    need = self._pages_needed(req)
                    if reserved[0] + need <= self.pool_mgr.available():
                        reserved[0] += need
                        return True
                    return False

                admits = self.scheduler.admissions(
                    queue, self._free_slots(batch, step), batch.n_busy,
                    step, fits=fits, now=now, t_ready=t_ready)
                for _, req in admits:
                    self._meta(req)     # pin variant/degraded at admission
                if admits:
                    self._admit_paged(batch, admits, step, t_ready)
                self._shed_backlog(queue, t_ready, step, now, results,
                                   free_frac=(self.pool_mgr.available()
                                              / self.num_pages))
                self._apply_faults(batch, step)
                if batch.prefilling.any():
                    self._chunk_step(batch, step, results, queue=queue,
                                     t_ready=t_ready)
                if batch.any_active():
                    t_step = time.monotonic()
                    if self._spec is not None:
                        self._spec_round(batch, step, results)
                    else:
                        self._decode_groups(batch, step, results,
                                            queue=queue, t_ready=t_ready)
                    if straggler.observe(step, time.monotonic() - t_step) \
                            != "ok":
                        self.stats["straggler_events"] += 1
                # idle slots are not stuck: keep their heartbeats fresh
                for b in range(self.max_batch):
                    if not (batch.active[b] or batch.prefilling[b]):
                        self._monitor.beat(b)
                for b in self._monitor.dead_hosts():
                    if batch.active[b] or batch.prefilling[b]:
                        self.stats["heartbeat_trips"] += 1
                        self._handle_fault(batch, queue, int(b), step,
                                           time.monotonic(), t_ready,
                                           results, purge=False,
                                           kind="stuck")
                    self._monitor.beat(b)
                if self._degrade is not None:
                    self._degrade.update(step)   # _meta reads .active
                step += 1
        self._monitor = None
        self._paged_caches = batch.caches       # keep cached pages resident


class _DegradeController:
    """Hysteresis switch for graceful precision degradation.

    Observes TTFTs as requests get their first token; `update` (once per
    engine step) flips ``active`` ON when the sliding-window p95 breaches
    the target, and OFF once p95 drops below ``recover_frac * target``.
    The window is cleared at each transition so pre-transition samples
    cannot immediately flip it back, and a minimum sample count must
    accumulate again before the next decision — that is the hysteresis.
    Transitions are recorded as ``(step, "degrade"|"recover", p95_s)``."""

    def __init__(self, target_s: float, window: int = 8,
                 min_samples: int = 4, recover_frac: float = 0.7):
        if target_s <= 0:
            raise ValueError(f"ttft_target_s must be > 0, got {target_s}")
        if not 0.0 < recover_frac <= 1.0:
            raise ValueError(f"degrade_recover_frac must be in (0, 1], "
                             f"got {recover_frac}")
        self.target_s = float(target_s)
        self.min_samples = max(1, min(int(min_samples), int(window)))
        self.recover_frac = float(recover_frac)
        self.samples: deque = deque(maxlen=int(window))
        self.active = False
        self.transitions: List[Tuple[int, str, float]] = []

    def reset(self):
        self.samples.clear()
        self.active = False
        self.transitions.clear()    # in place: Engine.degrade_log aliases

    def observe(self, ttft_s: float):
        self.samples.append(float(ttft_s))

    def update(self, step: int) -> bool:
        if len(self.samples) < self.min_samples:
            return self.active
        p95 = percentile(list(self.samples), 95)
        if not self.active and p95 > self.target_s:
            self.active = True
            self.transitions.append((step, "degrade", round(p95, 6)))
            self.samples.clear()
        elif self.active and p95 < self.recover_frac * self.target_s:
            self.active = False
            self.transitions.append((step, "recover", round(p95, 6)))
            self.samples.clear()
        return self.active
