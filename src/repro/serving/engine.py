"""`Engine`: continuous-batching inference over (optionally planned) LMs.

One engine owns a fixed pool of ``max_batch`` decode slots backed by a
single KV-cache/state pool of sequence capacity ``max_len``, and runs the
standard continuous-batching loop:

  1. ADMIT — the `Scheduler` assigns ready requests to free slots.  The
     admitted group is right-padded to a shared bucketed prompt length and
     RAGGED-prefilled in one jitted call (`transformer.prefill` with
     per-slot ``lengths``); the per-request caches are then scattered into
     the pool at the assigned slots (`transformer.scatter_cache`) and each
     request's first token is sampled from its last VALID position.
  2. DECODE — one jitted step over the whole pool
     (`transformer.decode_step` with a ``(B,)`` index): every slot's token
     is embedded at that slot's own cache length and attention masks the
     cache per slot.  Retired/empty slots ride along masked (`active`).
  3. RETIRE — slots whose request sampled ``eos_id``, exhausted
     ``max_new_tokens``, or hit the pool's ``max_len`` free up and step 1
     refills them — no drain barrier (unless the scheduler runs the
     ``static`` gang-batching baseline).

The decode step traces ONCE (fixed pool shape); prefill retraces per
(group size, bucketed prompt length) — bounded by ``max_batch`` times the
number of buckets.  With a `repro.runtime.PlannedBackend` passed as
``backend``, both traces execute every covered projection through its
planned split-precision kernel (the name-keyed matmul-backend protocol
resolves statically inside jit), so engine latency IS mapped latency.

Exactness notes: outputs are token-identical to per-request serving for
every non-MoE arch (padding/masking is exact — see the `repro.serving`
package docstring for the MoE capacity caveat), provided the bound plan
uses STATIC activation scales; dynamic max-abs activation quantization is
computed over the whole pooled batch and therefore depends on batch
composition.
"""
from __future__ import annotations

import contextlib
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.managed import matmul_backend
from repro.serving.batch import BatchState
from repro.serving.metrics import RequestResult
from repro.serving.scheduler import Request, RequestQueue, Scheduler


class Engine:
    """Continuous-batching serving engine (see module docstring).

    Parameters:
      cfg, params   — the LM (`repro.configs` ArchConfig + its weights).
      max_batch     — pool size B (concurrent requests).
      max_len       — per-slot sequence capacity (prompt + generated - 1
                      must fit; longer requests retire as "length_cap").
      backend       — optional matmul backend (e.g. `PlannedBackend`)
                      installed around every jitted call.
      scheduler     — a `Scheduler` (default: continuous policy).
      prefill_bucket— minimum prompt padding; group prompt lengths round up
                      to the next power-of-two multiple of it (bounds
                      prefill retraces).
    """

    def __init__(self, cfg, params, *, max_batch: int = 8, max_len: int = 64,
                 backend=None, scheduler: Optional[Scheduler] = None,
                 prefill_bucket: int = 8):
        self.cfg = cfg
        self.params = params
        self.max_batch = int(max_batch)
        self.max_len = int(max_len)
        self.backend = backend
        self.scheduler = scheduler or Scheduler()
        self.prefill_bucket = max(1, int(prefill_bucket))
        self.stats: Dict[str, float] = {}

        def decode_fn(params, tok, caches, lengths, active):
            logits, caches = T.decode_step(params, cfg, tok, caches, lengths,
                                           active=active)
            return jnp.argmax(logits, axis=-1), caches

        def prefill_fn(params, prompts, lengths, pool, slots, frontend):
            fresh = T.init_cache(cfg, prompts.shape[0], self.max_len)
            logits, fresh = T.prefill(params, cfg, prompts, fresh,
                                      cross_source=frontend, lengths=lengths)
            tok0 = jnp.argmax(logits, axis=-1)
            return tok0, T.scatter_cache(pool, fresh, slots)

        self._decode = jax.jit(decode_fn)
        self._prefill = jax.jit(prefill_fn)

    # ---- helpers ---------------------------------------------------------

    def _bucket(self, n: int) -> int:
        b = self.prefill_bucket
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _ctx(self):
        return (matmul_backend(self.backend) if self.backend is not None
                else contextlib.nullcontext())

    def _admit(self, batch: BatchState, admits, step: int,
               t_ready: Dict[int, float]):
        slots = np.asarray([s for s, _ in admits], np.int32)
        reqs = [r for _, r in admits]
        k = len(reqs)
        P = self._bucket(max(r.prompt_len for r in reqs))
        prompts = np.zeros((k, P), np.int32)
        lengths = np.zeros(k, np.int32)
        for i, r in enumerate(reqs):
            prompts[i, :r.prompt_len] = r.prompt
            lengths[i] = r.prompt_len
        frontend = None
        if self.cfg.frontend:
            missing = [r.rid for r in reqs if r.frontend is None]
            if missing:
                raise ValueError(
                    f"arch {self.cfg.name} needs a per-request cross-"
                    f"attention `frontend`, missing on: {missing}")
            frontend = jnp.stack(
                [jnp.asarray(r.frontend, jnp.bfloat16) for r in reqs])
        t0 = time.monotonic()
        tok0, batch.caches = self._prefill(self.params, prompts, lengths,
                                           batch.caches, slots, frontend)
        tok0 = np.asarray(tok0)           # sync: first tokens materialized
        t1 = time.monotonic()
        self.stats["prefill_s"] += t1 - t0
        self.stats["prefill_calls"] += 1
        for i, (slot, req) in enumerate(admits):
            batch.assign(slot, req, int(tok0[i]),
                         t_ready=t_ready[id(req)], t_first=t1, step=step)
        return [s for s, _ in admits]

    def _maybe_retire(self, batch: BatchState, slot: int, now: float,
                      step: int, results: Dict[int, RequestResult]) -> bool:
        st = batch.slots[slot]
        req = st.request
        reason = None
        if req.eos_id is not None and st.tokens[-1] == req.eos_id:
            reason = "eos"
        elif len(st.tokens) >= req.max_new_tokens:
            reason = "max_new_tokens"
        elif int(batch.lengths[slot]) >= self.max_len:
            reason = "length_cap"   # no room to embed the next token
        if reason is None:
            return False
        st = batch.retire(slot)
        results[id(req)] = RequestResult(
            rid=req.rid, prompt_len=req.prompt_len, tokens=st.tokens,
            finish_reason=reason, ttft_s=st.t_first - st.t_ready,
            finish_s=now - st.t_ready, admitted_step=st.admitted_step,
            finished_step=step)
        return True

    # ---- main loop -------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> List[RequestResult]:
        """Serve ``requests`` to completion; returns one `RequestResult` per
        request, in submission order.  Timing aggregates land in
        ``self.stats``."""
        for r in requests:
            if r.prompt_len >= self.max_len:
                raise ValueError(
                    f"request {r.rid!r}: prompt_len {r.prompt_len} does not "
                    f"fit the engine's max_len {self.max_len} (needs "
                    f"prompt_len < max_len)")
        queue = RequestQueue()
        for r in requests:
            queue.push(r)
        batch = BatchState(self.max_batch,
                           T.init_cache(self.cfg, self.max_batch,
                                        self.max_len))
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0, "decode_steps": 0,
                      "prefill_calls": 0, "wall_s": 0.0}
        results: Dict[int, RequestResult] = {}
        t_ready: Dict[int, float] = {}
        t0 = time.monotonic()
        step = 0
        with self._ctx():
            while len(queue) or batch.any_active():
                # idle + only future arrivals: fast-forward the step clock
                if not batch.any_active() and queue.ready(step) == 0:
                    step = max(step, queue.next_arrival())
                now = time.monotonic()
                for r in queue:
                    if r.arrival_step <= step and id(r) not in t_ready:
                        t_ready[id(r)] = now
                admits = self.scheduler.admissions(
                    queue, batch.free_slots(), batch.n_active, step)
                if admits:
                    for slot in self._admit(batch, admits, step, t_ready):
                        self._maybe_retire(batch, slot, time.monotonic(),
                                           step, results)
                if not batch.any_active():
                    continue
                t = time.monotonic()
                tok, batch.caches = self._decode(
                    self.params, batch.last_tok, batch.caches,
                    batch.lengths, batch.active)
                tok = np.asarray(tok)               # sync
                now = time.monotonic()
                self.stats["decode_s"] += now - t
                self.stats["decode_steps"] += 1
                for b in range(self.max_batch):
                    if not batch.active[b]:
                        continue
                    batch.slots[b].tokens.append(int(tok[b]))
                    batch.last_tok[b] = tok[b]
                    batch.lengths[b] += 1
                    self._maybe_retire(batch, b, now, step, results)
                step += 1
        self.stats["wall_s"] = time.monotonic() - t0
        return [results[id(r)] for r in requests]
