"""Seeded fault injection for the serving engine's containment layer.

Production inference fleets see numerical faults (a bad kernel, a flaky
HBM bank, an XLA miscompile on one host) and liveness faults (a wedged
device stream).  `FaultInjector` reproduces three representative kinds
inside the engine's step loop so the detection/containment machinery is
testable and benchable:

  * ``"nonfinite_logits"`` — a NaN is added to the target slot's decode
    logits INSIDE the jitted step (the injection vector is a traced
    argument, so injecting never retraces).  Models a corrupted matmul.
  * ``"corrupt_page"``     — NaN is written into the floating-point KV
    leaves of one of the slot's resident pages; the damage surfaces on
    the NEXT step through attention.  Models bad memory.  Because pages
    are shared (prefix cache), the corruption may hit OTHER slots too —
    each sees non-finite logits and is contained the same way.
  * ``"stuck"``            — the slot is silently excluded from decode
    for ``duration`` steps: it commits nothing, which only the
    `repro.distributed.HeartbeatMonitor` wired into the step loop can
    notice.  Models a wedged slot/host.

Faults are injected from an explicit event plan and/or seeded per-step
Bernoulli rates; both are deterministic given the seed.  Detection and
recovery live in `repro.serving.engine`: a ``jnp.isfinite`` screen over
committed logits, slot quarantine, page purge, and requeue-once (a second
fault on the same request sheds it with ``ShedResult(reason="fault")``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("nonfinite_logits", "corrupt_page", "stuck")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One planned fault: ``kind`` fires at engine ``step`` on ``slot``.

    ``duration`` only matters for ``"stuck"`` (how many steps the slot
    stays silent; detection usually ends it earlier by requeueing the
    request)."""
    kind: str
    step: int
    slot: int
    duration: int = 1_000_000

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"fault kind must be one of {FAULT_KINDS}, "
                             f"got {self.kind!r}")
        if self.step < 0 or self.slot < 0 or self.duration < 1:
            raise ValueError(f"bad fault event: {self!r}")


class FaultInjector:
    """Deterministic fault source for the engine step loop.

    ``events`` is an explicit plan; ``rates`` maps a fault kind to a
    per-step, per-active-slot Bernoulli probability drawn from a seeded
    generator.  ``draw(step, slots)`` returns the faults firing this step
    on currently-occupied slots and logs them in ``fired``."""

    def __init__(self, events: Iterable[FaultEvent] = (),
                 rates: Optional[Dict[str, float]] = None, seed: int = 0):
        self.events: List[FaultEvent] = list(events)
        self.rates = {k: float(v) for k, v in (rates or {}).items()}
        for kind in self.rates:
            if kind not in FAULT_KINDS:
                raise ValueError(f"fault kind must be one of {FAULT_KINDS}, "
                                 f"got {kind!r}")
        self._rng = np.random.default_rng(seed)
        self.fired: List[Tuple[int, int, str]] = []   # (step, slot, kind)

    @staticmethod
    def parse(spec: str, seed: int = 0) -> "FaultInjector":
        """Build an injector from a CLI spec: comma-separated
        ``kind@step:slot[xduration]`` events and/or ``kind~rate`` rates,
        e.g. ``"nonfinite_logits@3:0,stuck@5:1x20,corrupt_page~0.01"``."""
        events, rates = [], {}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            if "~" in part:
                kind, rate = part.split("~", 1)
                rates[kind.strip()] = float(rate)
                continue
            try:
                kind, where = part.split("@", 1)
                step_s, slot_s = where.split(":", 1)
                dur = 1_000_000
                if "x" in slot_s:
                    slot_s, dur_s = slot_s.split("x", 1)
                    dur = int(dur_s)
                events.append(FaultEvent(kind.strip(), int(step_s),
                                         int(slot_s), duration=dur))
            except ValueError as e:
                raise ValueError(
                    f"bad fault spec {part!r} (want kind@step:slot[xN] "
                    f"or kind~rate): {e}") from None
        return FaultInjector(events=events, rates=rates, seed=seed)

    def draw(self, step: int, slots: Sequence[int]) -> List[FaultEvent]:
        """Faults firing at ``step`` on any of the occupied ``slots``."""
        slots = list(slots)
        out = [e for e in self.events
               if e.step == step and e.slot in slots]
        for kind in sorted(self.rates):
            rate = self.rates[kind]
            if rate <= 0:
                continue
            for s in slots:
                if self._rng.random() < rate:
                    out.append(FaultEvent(kind, step, s))
        self.fired.extend((e.step, e.slot, e.kind) for e in out)
        return out
