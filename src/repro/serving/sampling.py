"""Jit-safe non-greedy sampling for the serving engine.

OFF BY DEFAULT: an engine without a `SamplingParams` takes ``argmax``
exactly as before, so every greedy parity oracle in the test suite (and
the speculative-decoding token-identity guarantee) stays valid.

With sampling enabled, randomness is PER-SLOT STATE threaded through the
jitted step functions: `repro.serving.batch.BatchState` carries a
``(B, 2)`` uint32 PRNG-key row per slot, each request gets its own
independent key at admission (``fold_in(base_key, request_counter)``), and
`sample_tokens` splits each slot's key inside the trace — consuming one
split per sampled token — and returns the advanced keys alongside the
tokens.  The engine merges advanced keys back ONLY for slots that actually
consumed a sample, so a request's token stream depends on nothing but its
own key and its own logits: co-batched traffic, admission order of OTHER
requests, and chunked-prefill interleaving cannot perturb it (the same
per-slot exactness contract the greedy engine pins in tests).

Temperature scales the logits (``logits / max(temperature, 1e-6)``);
``top_p`` < 1 applies nucleus filtering BEFORE sampling: tokens are ranked
by logit and kept while the cumulative probability of strictly
higher-ranked tokens is below ``top_p`` (the top-1 token always survives,
so ``top_p -> 0`` degenerates to greedy).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Engine-level sampling configuration (one policy per engine run).

    ``temperature`` > 0 softens/sharpens the distribution; ``top_p`` in
    (0, 1] keeps the smallest logit-ranked nucleus with cumulative
    probability >= top_p; ``seed`` derives every request's per-slot key —
    two runs with the same seed over the same trace sample identical
    tokens."""
    temperature: float = 1.0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if not (self.temperature > 0.0):
            raise ValueError(f"temperature must be > 0 (greedy decoding is "
                             f"sampling=None), got {self.temperature}")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


def request_key(base: jax.Array, counter: int) -> jax.Array:
    """The per-request PRNG key: independent stream per admission index."""
    return jax.random.fold_in(base, counter)


def sample_tokens(logits: jax.Array, keys: jax.Array,
                  params: SamplingParams):
    """Sample one token per slot.  ``logits`` (B, V), ``keys`` (B, 2)
    uint32 per-slot PRNG keys.  Returns ``(tokens (B,) int32, advanced
    keys (B, 2))`` — jit-safe, one key split per slot per call.

    Slots whose logits are garbage (inactive/masked rows) still consume a
    split here; the engine discards those keys by merging back only the
    rows that actually sampled, so inactive slots' streams are untouched."""
    keys = jnp.asarray(keys, jnp.uint32)
    nxt = jax.vmap(lambda k: jax.random.split(k))(keys)     # (B, 2, 2)
    carry, use = nxt[:, 0], nxt[:, 1]
    l = logits.astype(jnp.float32) / jnp.maximum(params.temperature, 1e-6)
    if params.top_p < 1.0:
        sort = jnp.sort(l, axis=-1)[:, ::-1]                # descending
        probs = jax.nn.softmax(sort, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep while the mass STRICTLY above this rank is < top_p: the
        # top-1 token is always kept (cum - probs == 0 at rank 0)
        keep = (cum - probs) < params.top_p
        kth = jnp.min(jnp.where(keep, sort, jnp.inf), axis=-1,
                      keepdims=True)
        l = jnp.where(l >= kth, l, -jnp.inf)
    tok = jax.vmap(jax.random.categorical)(use, l)
    return tok.astype(jnp.int32), carry
