"""Per-request latency/throughput metrics for the serving engine.

`RequestResult` is what the engine hands back per request: the generated
tokens plus the request-level latency numbers the repo's "latency" story
was missing — TTFT (submission-to-first-token, queueing included: that is
exactly what static batching inflates) and the steady decode rate.
`summarize` aggregates a run into the p50/p95 TTFT + total-throughput
record `benchmarks/bench_runtime.py` persists."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence


@dataclasses.dataclass
class RequestResult:
    """One finished request."""
    rid: Any
    prompt_len: int
    tokens: List[int]                 # all generated tokens, first included
    finish_reason: str                # "eos" | "max_new_tokens" | "length_cap"
    ttft_s: float                     # became-schedulable -> first token
    finish_s: float                   # became-schedulable -> last token
    admitted_step: int
    finished_step: int
    slo: Any = None                   # SLO class tag (None = unrouted)

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    @property
    def decode_tok_s(self) -> float:
        """Steady decode rate: tokens after the first over post-TTFT time."""
        dt = self.finish_s - self.ttft_s
        return (self.n_tokens - 1) / dt if dt > 0 else 0.0


def percentile(xs: Sequence[float], p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]); 0.0 on empty input."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    k = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
    return float(xs[k])


def summarize(results: List[RequestResult], wall_s: float) -> Dict[str, Any]:
    """Aggregate a run: total token throughput + TTFT/decode-rate tails.

    When any result carries an SLO class tag, a ``by_slo`` breakdown is
    added: per-class request count, TTFT p50/p95 and decode-rate p50 — the
    per-class latency record SLO routing is judged by."""
    ttfts = [r.ttft_s for r in results]
    toks = sum(r.n_tokens for r in results)
    out = {
        "requests": len(results),
        "total_tokens": toks,
        "wall_s": round(wall_s, 4),
        "total_tok_s": round(toks / wall_s, 2) if wall_s > 0 else 0.0,
        "ttft_p50_s": round(percentile(ttfts, 50), 4),
        "ttft_p95_s": round(percentile(ttfts, 95), 4),
        "decode_tok_s_p50": round(
            percentile([r.decode_tok_s for r in results], 50), 2),
        "finish_reasons": {
            reason: sum(1 for r in results if r.finish_reason == reason)
            for reason in sorted({r.finish_reason for r in results})},
    }
    classes = sorted({r.slo for r in results if r.slo is not None})
    if classes:
        out["by_slo"] = {}
        for cls in classes:
            rs = [r for r in results if r.slo == cls]
            cls_ttfts = [r.ttft_s for r in rs]
            out["by_slo"][cls] = {
                "requests": len(rs),
                "total_tokens": sum(r.n_tokens for r in rs),
                "ttft_p50_s": round(percentile(cls_ttfts, 50), 4),
                "ttft_p95_s": round(percentile(cls_ttfts, 95), 4),
                "decode_tok_s_p50": round(
                    percentile([r.decode_tok_s for r in rs], 50), 2),
            }
    return out
