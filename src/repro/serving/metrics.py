"""Per-request latency/throughput metrics for the serving engine.

`RequestResult` is what the engine hands back per finished request: the
generated tokens plus the request-level latency numbers the repo's
"latency" story was missing — TTFT (submission-to-first-token, queueing
included: that is exactly what static batching inflates) and the steady
decode rate.  `ShedResult` is the structured rejection the overload paths
return instead of a result (queue-depth / page-watermark shedding, queued
or running timeouts, double faults) — a run's result list may mix both.
`summarize` aggregates a run into the p50/p95/p99 TTFT + total-throughput
+ shed/degradation-rate record `benchmarks/bench_runtime.py` persists."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Union


@dataclasses.dataclass
class RequestResult:
    """One finished request."""
    rid: Any
    prompt_len: int
    tokens: List[int]                 # all generated tokens, first included
    finish_reason: str                # "eos" | "max_new_tokens" | "length_cap"
                                      # | "timeout"
    ttft_s: float                     # became-schedulable -> first token
    finish_s: float                   # became-schedulable -> last token
    admitted_step: int
    finished_step: int
    slo: Any = None                   # SLO class tag (None = unrouted)
    variant: Any = None               # PlanSet variant that served the request
    degraded: bool = False            # served by the degrade_to variant
    preemptions: int = 0              # retire-and-requeue round-trips
    requeues: int = 0                 # fault-recovery requeues

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    @property
    def decode_tok_s(self) -> float:
        """Steady decode rate: tokens after the first over post-TTFT time."""
        dt = self.finish_s - self.ttft_s
        return (self.n_tokens - 1) / dt if dt > 0 else 0.0


@dataclasses.dataclass
class ShedResult:
    """One request the engine rejected instead of finishing.

    ``reason`` says which overload/fault path fired:

      * ``"queue_depth"``  — admission queue exceeded ``max_queue_depth``
      * ``"page_watermark"`` — free-page fraction below ``page_watermark``
        with the queue backed up
      * ``"timeout"``      — waited longer than ``request_timeout_s``
        without being admitted (a RUNNING request that times out instead
        retires with partial tokens and ``finish_reason="timeout"``)
      * ``"fault"``        — hit an injected/detected fault more than once
        (requeue-once policy)
    """
    rid: Any
    reason: str
    shed_step: int
    waited_s: float
    slo: Any = None

    @property
    def n_tokens(self) -> int:
        return 0


Result = Union[RequestResult, ShedResult]


def percentile(xs: Sequence[float], p: float) -> float:
    """Nearest-rank percentile (p in [0, 100]); 0.0 on empty input.
    Non-finite samples are dropped (a NaN TTFT must not poison the tail)."""
    xs = [x for x in xs if math.isfinite(x)]
    if not xs:
        return 0.0
    xs = sorted(xs)
    k = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
    return float(xs[k])


def summarize(results: List[Result], wall_s: float) -> Dict[str, Any]:
    """Aggregate a run: total token throughput + TTFT/decode-rate tails.

    ``results`` may mix `RequestResult` and `ShedResult`; sheds contribute
    to ``requests``/``shed``/``shed_rate`` but not to the latency tails.
    All aggregates guard empty inputs and zero-duration windows (an
    all-shed run, or a decode window of zero wall time, yields zeros —
    never a ZeroDivisionError or NaN percentile).

    When any result carries an SLO class tag, a ``by_slo`` breakdown is
    added: per-class request count, TTFT p50/p95 and decode-rate p50 — the
    per-class latency record SLO routing is judged by."""
    done = [r for r in results if isinstance(r, RequestResult)]
    shed = [r for r in results if isinstance(r, ShedResult)]
    ttfts = [r.ttft_s for r in done]
    toks = sum(r.n_tokens for r in done)
    n = len(results)
    out = {
        "requests": n,
        "completed": len(done),
        "total_tokens": toks,
        "wall_s": round(wall_s, 4),
        "total_tok_s": round(toks / wall_s, 2) if wall_s > 0 else 0.0,
        "ttft_p50_s": round(percentile(ttfts, 50), 4),
        "ttft_p95_s": round(percentile(ttfts, 95), 4),
        "ttft_p99_s": round(percentile(ttfts, 99), 4),
        "decode_tok_s_p50": round(
            percentile([r.decode_tok_s for r in done], 50), 2),
        "finish_reasons": {
            reason: sum(1 for r in done if r.finish_reason == reason)
            for reason in sorted({r.finish_reason for r in done})},
        "shed": len(shed),
        "shed_rate": round(len(shed) / n, 4) if n else 0.0,
        "preemptions": sum(r.preemptions for r in done),
        "degraded": sum(1 for r in done if r.degraded),
        "degrade_rate": (round(sum(1 for r in done if r.degraded) / len(done),
                               4) if done else 0.0),
    }
    if shed:
        out["shed_reasons"] = {
            reason: sum(1 for r in shed if r.reason == reason)
            for reason in sorted({r.reason for r in shed})}
    classes = sorted(
        {r.slo for r in results if r.slo is not None}, key=str)
    if classes:
        out["by_slo"] = {}
        for cls in classes:
            rs = [r for r in done if r.slo == cls]
            cls_ttfts = [r.ttft_s for r in rs]
            out["by_slo"][cls] = {
                "requests": len(rs),
                "shed": sum(1 for r in shed if r.slo == cls),
                "total_tokens": sum(r.n_tokens for r in rs),
                "ttft_p50_s": round(percentile(cls_ttfts, 50), 4),
                "ttft_p95_s": round(percentile(cls_ttfts, 95), 4),
                "decode_tok_s_p50": round(
                    percentile([r.decode_tok_s for r in rs], 50), 2),
            }
    return out
