"""`SearchPipeline`: the ODiMO search as composable stages.

The paper's training flow (Sec. III-B) —

    pretrain (fp) -> DNAS search (Eq. 2) -> discretize -> finetune -> evaluate

— is decomposed into stage objects that share one jit-compiled train/eval
step and a mutable `PipelineState`.  The default stage list reproduces
`engine.run_odimo` bit-for-bit; swapping stages composes other flows, e.g.
``[ApplyMapping(a), FinetuneFixed(), Evaluate()]`` is the fixed-mapping
baseline evaluation.  Per-stage/per-step callbacks replace the old
``verbose`` flag, and `Discretize` emits a serializable `MappingArtifact`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.artifact import MappingArtifact
from repro.api.handle import ModelHandle
from repro.api.platforms import Platform
from repro.core import engine, losses, odimo
from repro.core.cost_models import CostModel
from repro.core.odimo import ODiMOSpec
from repro.optim import adamw

# Disjoint data-stream offsets, inherited from the legacy engine so that
# pipeline runs are bit-identical to historical `run_odimo` results.
SEARCH_DATA_OFFSET = 10_000
FINETUNE_DATA_OFFSET = 20_000
EVAL_DATA_OFFSET = 90_000


# --------------------------------------------------------------------------
# Callbacks
# --------------------------------------------------------------------------

class PipelineCallback:
    """Observer hooks; override any subset."""

    def on_stage_start(self, stage: "Stage", state: "PipelineState") -> None:
        pass

    def on_stage_end(self, stage: "Stage", state: "PipelineState") -> None:
        pass

    def on_step(self, stage: "Stage", step: int,
                metrics: Dict[str, float]) -> None:
        pass


class VerboseCallback(PipelineCallback):
    """Legacy-style progress prints every ``every`` steps."""

    def __init__(self, every: int = 100):
        self.every = every

    def on_step(self, stage, step, metrics):
        if step % self.every:
            return
        extra = " ".join(f"{k}={v:.4g}" for k, v in metrics.items()
                         if k != "loss")
        print(f"[{stage.name} {step}] loss={metrics.get('loss', 0.0):.4f}"
              + (f" {extra}" if extra else ""))


# --------------------------------------------------------------------------
# State + context
# --------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineState:
    """Mutable state threaded through the stages."""
    params: Any
    history: Dict[str, list] = dataclasses.field(default_factory=dict)
    assignments: List[np.ndarray] | None = None
    counts: List[np.ndarray] | None = None
    accuracy: float | None = None
    latency: float | None = None
    energy: float | None = None
    artifact: MappingArtifact | None = None


@dataclasses.dataclass
class PipelineContext:
    """Immutable per-run machinery shared by all stages."""
    handle: ModelHandle
    spec: ODiMOSpec
    cost_model: CostModel
    scfg: engine.SearchConfig
    data_fn: Callable[[int, int], Any]
    plan: list
    train_step: Callable
    eval_step: Callable
    apply_fn: Callable
    ocfg: adamw.AdamWConfig
    platform_name: str | None
    callbacks: Sequence[PipelineCallback]

    @property
    def geoms(self):
        return [g for (_, g, _) in self.plan]

    @property
    def searchable(self):
        return [s for (_, _, s) in self.plan]

    def emit_step(self, stage, step, metrics):
        for cb in self.callbacks:
            cb.on_step(stage, step, metrics)


@dataclasses.dataclass
class PipelineResult:
    """Search outcome; superset of the legacy `engine.SearchResult`."""
    params: Any
    assignments: List[np.ndarray]
    counts: List[np.ndarray]
    accuracy: float
    latency: float
    energy: float
    history: dict
    artifact: MappingArtifact | None = None


# --------------------------------------------------------------------------
# Stages
# --------------------------------------------------------------------------

class Stage:
    name = "stage"

    def run(self, ctx: PipelineContext, state: PipelineState) -> None:
        raise NotImplementedError


@dataclasses.dataclass
class Pretrain(Stage):
    """Phase 1: full-precision task pretraining."""
    steps: int | None = None
    name = "pretrain"

    def run(self, ctx, state):
        scfg = ctx.scfg
        steps = self.steps if self.steps is not None else scfg.pretrain_steps
        opt = adamw.init(state.params, ctx.ocfg)
        hist = state.history.setdefault("pretrain", [])
        for step in range(steps):
            batch = ctx.data_fn(step, scfg.batch)
            state.params, opt, l, task, _ = ctx.train_step(
                state.params, opt, batch, 1.0, scfg.lr, "fp")
            hist.append(float(l))
            ctx.emit_step(self, step, {"loss": float(l)})


@dataclasses.dataclass
class DNASSearch(Stage):
    """Phase 2: DNAS over channel->domain alphas (Eq. 2, tau annealed)."""
    steps: int | None = None
    name = "search"

    def run(self, ctx, state):
        scfg = ctx.scfg
        steps = self.steps if self.steps is not None else scfg.search_steps
        opt = adamw.init(state.params, ctx.ocfg)
        hist = state.history.setdefault("search", [])
        for step in range(steps):
            tau = float(odimo.tau_schedule(step, steps, ctx.spec))
            batch = ctx.data_fn(SEARCH_DATA_OFFSET + step, scfg.batch)
            state.params, opt, l, task, reg = ctx.train_step(
                state.params, opt, batch, tau, scfg.lr, "search")
            hist.append((float(task), float(reg)))
            ctx.emit_step(self, step, {"loss": float(l), "task": float(task),
                                       "reg": float(reg), "tau": tau})


def _layer_scales(layer_dicts) -> list:
    """Schema-v2 per-layer quant scales from the trained ODiMO states (None
    for unmanaged layers) — what `repro.runtime.lower` executes with."""
    scales = []
    for d in layer_dicts:
        if "odimo" in d:
            entry = {"w_log_scales": [float(v) for v in
                                      np.asarray(d["odimo"]["log_scales"])]}
            als = d.get("act_log_scale")
            entry["act_log_scale"] = (float(als) if als is not None
                                      else None)
            scales.append(entry)
        else:
            scales.append(None)
    return scales


@dataclasses.dataclass
class Discretize(Stage):
    """Phase 3: argmax assignment per channel + mapping artifact."""
    name = "discretize"

    def run(self, ctx, state):
        layer_dicts = ctx.handle.layers(state.params)
        assignments, counts = [], []
        for d, s in zip(layer_dicts, ctx.searchable):
            if s and "odimo" in d:
                a = np.asarray(odimo.assignment(d["odimo"]))
            else:
                a = np.zeros(d["w"].shape[-1], dtype=np.int64)  # pinned: dom 0
            assignments.append(a)
            counts.append(np.asarray([int((a == i).sum())
                                      for i in range(ctx.spec.n_domains)]))
        state.assignments, state.counts = assignments, counts
        state.artifact = MappingArtifact.from_search(
            ctx.handle.name, ctx.spec, ctx.plan, assignments, counts,
            platform=ctx.platform_name, objective=ctx.scfg.objective,
            lam=ctx.scfg.lam, seed=ctx.scfg.seed,
            scales=_layer_scales(layer_dicts))


@dataclasses.dataclass
class Finetune(Stage):
    """Phase 4: task-loss-only finetuning in exact discretized formats."""
    steps: int | None = None
    lr_scale: float = 0.3
    name = "finetune"

    def run(self, ctx, state):
        scfg = ctx.scfg
        steps = self.steps if self.steps is not None else scfg.finetune_steps
        opt = adamw.init(state.params, ctx.ocfg)
        hist = state.history.setdefault("finetune", [])
        for step in range(steps):
            batch = ctx.data_fn(FINETUNE_DATA_OFFSET + step, scfg.batch)
            state.params, opt, l, task, _ = ctx.train_step(
                state.params, opt, batch, 1.0, scfg.lr * self.lr_scale,
                "finetune")
            hist.append(float(l))
            ctx.emit_step(self, step, {"loss": float(l)})


@dataclasses.dataclass
class ApplyMapping(Stage):
    """Inject a FIXED channel->domain mapping (one-hot alphas) — the entry
    stage of baseline evaluations.  Functional: see
    `ModelHandle.with_assignments`."""
    assignments: Sequence[np.ndarray] = ()
    name = "apply_mapping"

    def run(self, ctx, state):
        assigns = [np.asarray(a, dtype=np.int64) for a in self.assignments]
        state.params = ctx.handle.with_assignments(
            state.params, assigns, ctx.spec.n_domains)
        state.assignments = assigns
        state.counts = [np.asarray([int((a == i).sum())
                                    for i in range(ctx.spec.n_domains)])
                        for a in assigns]
        state.artifact = MappingArtifact.from_search(
            ctx.handle.name, ctx.spec, ctx.plan, assigns, state.counts,
            platform=ctx.platform_name, objective=ctx.scfg.objective,
            lam=ctx.scfg.lam, seed=ctx.scfg.seed,
            scales=_layer_scales(ctx.handle.layers(state.params)))


@dataclasses.dataclass
class FinetuneFixed(Stage):
    """Train with frozen alphas (fixed mapping), task loss only."""
    steps: int | None = None
    name = "finetune_fixed"

    def run(self, ctx, state):
        scfg = ctx.scfg
        steps = self.steps if self.steps is not None else (
            scfg.pretrain_steps + scfg.finetune_steps)

        @jax.jit
        def ft_step(params, opt, batch):
            def lf(p):
                x, y = batch
                logits = ctx.apply_fn(p, x, "finetune", 1.0)
                return losses.cross_entropy(logits, y)
            l, grads = jax.value_and_grad(lf)(params)
            grads = jax.tree_util.tree_map_with_path(
                lambda path, g: (jnp.zeros_like(g)
                                 if any(getattr(q, "key", None) == "alpha"
                                        for q in path) else g), grads)
            params, opt, _ = adamw.update(grads, opt, params, ctx.ocfg,
                                          lr=scfg.lr)
            return params, opt, l

        opt = adamw.init(state.params, ctx.ocfg)
        hist = state.history.setdefault("finetune_fixed", [])
        for step in range(steps):
            state.params, opt, l = ft_step(state.params, opt,
                                           ctx.data_fn(step, scfg.batch))
            hist.append(float(l))
            ctx.emit_step(self, step, {"loss": float(l)})


@dataclasses.dataclass
class Evaluate(Stage):
    """Final accuracy + exact (discretized) latency/energy."""
    name = "evaluate"

    def run(self, ctx, state):
        scfg = ctx.scfg
        accs = []
        for b in range(scfg.eval_batches):
            batch = ctx.data_fn(EVAL_DATA_OFFSET + b, scfg.batch)
            accs.append(float(ctx.eval_step(state.params, batch, 1.0,
                                            "finetune")))
        state.accuracy = float(np.mean(accs))
        if state.counts is None:
            raise ValueError("Evaluate needs counts: run Discretize or "
                             "ApplyMapping first")
        state.latency = float(losses.exact_latency(ctx.cost_model, ctx.geoms,
                                                   state.counts))
        state.energy = float(losses.exact_energy(ctx.cost_model, ctx.geoms,
                                                 state.counts))
        if state.artifact is not None:
            state.artifact.metrics.update(accuracy=state.accuracy,
                                          latency=state.latency,
                                          energy=state.energy)


def default_stages() -> List[Stage]:
    """The paper's full flow (== legacy `run_odimo`)."""
    return [Pretrain(), DNASSearch(), Discretize(), Finetune(), Evaluate()]


def fixed_mapping_stages(assignments,
                         train_steps: int | None = None) -> List[Stage]:
    """Baseline flow (== legacy `evaluate_fixed_mapping`)."""
    return [ApplyMapping(assignments), FinetuneFixed(train_steps), Evaluate()]


# --------------------------------------------------------------------------
# Pipeline
# --------------------------------------------------------------------------

class SearchPipeline:
    """Composable ODiMO mapping search over a `ModelHandle`.

    Hardware comes either from a registered `Platform` (by name or instance)
    or from an explicit (spec, cost_model) pair; explicit values override the
    platform's defaults.

        pipe = SearchPipeline(cnn_handle(cfg), platform="diana",
                              config=SearchConfig(lam=5e-7), data_fn=data_fn)
        res = pipe.run()            # PipelineResult, res.artifact is JSON-able

    Stage-level checkpointing: with ``checkpoint_dir`` set, params are
    persisted (via `repro.checkpoint`, atomic + hash-verified) after every
    `Pretrain` stage — the expensive prefix shared by all lambda points of a
    Pareto sweep.  A later pipeline constructed with
    ``resume_from=checkpoint_dir`` (same handle/stage list) restores those
    params and restarts at the stage AFTER the checkpointed one (the paper
    flow: straight at `DNASSearch`), bit-identical to an uninterrupted run
    because the search/finetune data streams are offset-addressed.
    """

    def __init__(self, handle: ModelHandle, platform=None, *,
                 spec: ODiMOSpec | None = None,
                 cost_model: CostModel | None = None,
                 config: engine.SearchConfig | None = None,
                 data_fn: Callable[[int, int], Any],
                 stages: Sequence[Stage] | None = None,
                 callbacks: Sequence[PipelineCallback] = (),
                 checkpoint_dir: str | None = None,
                 resume_from: str | None = None):
        self.handle = handle
        plat = Platform.get(platform) if platform is not None else None
        self.platform_name = plat.name if plat is not None else None
        if spec is not None:
            self.spec = spec
        elif plat is not None:
            self.spec = plat.spec()
        else:
            self.spec = ODiMOSpec()
        if cost_model is not None:
            self.cost_model = cost_model
        elif plat is not None:
            self.cost_model = plat.cost_model()
        else:
            raise ValueError("SearchPipeline needs a platform or an explicit "
                             "cost_model")
        self.scfg = config if config is not None else engine.SearchConfig()
        self.data_fn = data_fn
        self.stages = list(stages) if stages is not None else default_stages()
        self.callbacks = tuple(callbacks)
        self.checkpoint_dir = checkpoint_dir
        self.resume_from = resume_from

    @classmethod
    def fixed_mapping(cls, handle, assignments, platform=None, *,
                      train_steps: int | None = None, **kw) -> "SearchPipeline":
        """Pipeline evaluating a FIXED mapping (baselines)."""
        return cls(handle, platform,
                   stages=fixed_mapping_stages(assignments, train_steps), **kw)

    # ------------------------------------------------------------------

    def _build_context(self) -> PipelineContext:
        scfg, spec = self.scfg, self.spec
        handle = self.handle
        plan = handle.plan()
        apply_fn = lambda p, x, mode, tau: handle.apply(p, x, spec, mode, tau)
        ocfg = adamw.AdamWConfig(lr=scfg.lr)
        loss_fn = engine.make_loss_fn(apply_fn, plan, spec, self.cost_model,
                                      scfg, handle.layers)

        @partial(jax.jit, static_argnames=("mode",))
        def train_step(params, opt, batch, tau, lr, mode):
            (l, (task, reg)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, tau, mode)
            # alpha gets its own lr by pre-scaling its grads
            ratio = scfg.alpha_lr / scfg.lr

            def scale(path, g):
                if any(getattr(p, "key", None) == "alpha" for p in path):
                    return g * ratio
                return g
            grads = jax.tree_util.tree_map_with_path(scale, grads)
            params, opt, _ = adamw.update(grads, opt, params, ocfg, lr=lr)
            return params, opt, l, task, reg

        @partial(jax.jit, static_argnames=("mode",))
        def eval_step(params, batch, tau, mode):
            x, y = batch
            logits = apply_fn(params, x, mode=mode, tau=tau)
            return jnp.mean(jnp.argmax(logits, -1) == y)

        return PipelineContext(handle=handle, spec=spec,
                               cost_model=self.cost_model, scfg=scfg,
                               data_fn=self.data_fn, plan=plan,
                               train_step=train_step, eval_step=eval_step,
                               apply_fn=apply_fn, ocfg=ocfg,
                               platform_name=self.platform_name,
                               callbacks=self.callbacks)

    def run(self, init_params=None) -> PipelineResult:
        from repro.checkpoint import checkpoint as ckpt
        ctx = self._build_context()
        if init_params is None:
            key = jax.random.PRNGKey(self.scfg.seed)
            init_params = self.handle.init(key, self.spec)
        stages = list(enumerate(self.stages))
        if self.resume_from is not None:
            step = ckpt.latest_step(self.resume_from)
            if step is None:
                raise FileNotFoundError(
                    f"resume_from={self.resume_from!r}: no committed "
                    f"pipeline checkpoint found")
            extra = ckpt.restore_extra(self.resume_from, step)
            init_params = ckpt.restore(self.resume_from, step, init_params)
            done = int(extra["stage_index"])
            stages = stages[done + 1:]
        state = PipelineState(params=init_params)
        for i, stage in stages:
            for cb in self.callbacks:
                cb.on_stage_start(stage, state)
            stage.run(ctx, state)
            if self.checkpoint_dir is not None and isinstance(stage, Pretrain):
                ckpt.save(self.checkpoint_dir, i + 1, state.params,
                          extra={"stage": stage.name, "stage_index": i})
            for cb in self.callbacks:
                cb.on_stage_end(stage, state)
        return PipelineResult(
            params=state.params,
            assignments=state.assignments if state.assignments is not None
            else [],
            counts=state.counts if state.counts is not None else [],
            accuracy=state.accuracy if state.accuracy is not None else 0.0,
            latency=state.latency if state.latency is not None else 0.0,
            energy=state.energy if state.energy is not None else 0.0,
            history=state.history, artifact=state.artifact)
