"""`Platform`: named bundles of precision domains + a cost model.

A platform is everything hardware-specific that the search needs: the
``PrecisionDomain`` tuple (which fixes the alpha dimensionality and the
fake-quant formats) and a ``CostModel`` factory (which prices a channel
split).  Registering a new accelerator is one ``Platform.register(...)``
call instead of edits across cost_models/engine/examples/benchmarks.

Built-ins:
    "diana"                 DIANA SoC analytical models (paper Sec. III-C)
    "diana_abstract"        Fig. 5 abstract model, P_idle = P_act
    "diana_ideal_shutdown"  Fig. 5 abstract model, P_idle = 0
    "tpu_v5e"               TPU roofline model (int8 vs bf16 MXU domains)
    "gap9_like"             GAP9-class 3-domain SoC: digital int8 NE16,
                            analog 2-bit in-memory array, fp16 DSP cluster
    "gpu_tc_like"           GPU tensor-core pair: int8 MMA @2x fp16
                            throughput (mixed layers fuse to the
                            split_precision kernel)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence, Tuple

from repro.core import quant
from repro.core.cost_models import (AbstractCostModel, CostModel,
                                    DianaCostModel, TPUCostModel)
from repro.core.odimo import ODiMOSpec
from repro.core.quant import PrecisionDomain

_REGISTRY: Dict[str, "Platform"] = {}


@dataclasses.dataclass(frozen=True)
class Platform:
    """A named accelerator target for the mapping search."""
    name: str
    domains: Tuple[PrecisionDomain, ...]
    cost_model_factory: Callable[[], CostModel]
    description: str = ""

    def spec(self, **overrides) -> ODiMOSpec:
        """ODiMOSpec for this platform; shared activations default to the
        worst-case bit-width across domains (paper Sec. III-B)."""
        kw = dict(domains=self.domains,
                  act_bits=min(d.act_bits for d in self.domains))
        kw.update(overrides)
        return ODiMOSpec(**kw)

    def cost_model(self, **kw) -> CostModel:
        return self.cost_model_factory(**kw)

    def kernel_capabilities(self) -> Dict[Tuple[str, ...], Tuple[str, str]]:
        """Project the runtime's capability-keyed kernel registry onto this
        platform: for every domain subset a mapping could activate in one
        layer (each single domain and each ordered pair), the ``(kernel,
        note)`` the runtime would lower it to — fp fallbacks carry the
        reason.  ``dryrun --mapping`` and docs use this to show at a glance
        which pairings fuse (e.g. diana: digital+aimc -> split_ternary)."""
        from repro.runtime.lower import select_kernel
        n = len(self.domains)
        bits = [d.weight_bits for d in self.domains]
        out: Dict[Tuple[str, ...], Tuple[str, str]] = {}
        singles = [(i,) for i in range(n)]
        pairs = [(i, j) for i in range(n) for j in range(n) if i < j]
        for idx in singles + pairs:
            counts = [1 if i in idx else 0 for i in range(n)]
            out[tuple(self.domains[i].name for i in idx)] = \
                select_kernel(counts, bits)
        return out

    # ---- registry --------------------------------------------------------

    @staticmethod
    def register(platform: "Platform", overwrite: bool = False) -> "Platform":
        if platform.name in _REGISTRY and not overwrite:
            raise ValueError(
                f"platform {platform.name!r} already registered "
                f"(pass overwrite=True to replace)")
        _REGISTRY[platform.name] = platform
        return platform

    @staticmethod
    def get(name: "str | Platform") -> "Platform":
        if isinstance(name, Platform):
            return name
        try:
            return _REGISTRY[name]
        except KeyError:
            raise KeyError(f"unknown platform {name!r}; "
                           f"registered: {sorted(_REGISTRY)}") from None

    @staticmethod
    def names() -> Sequence[str]:
        return sorted(_REGISTRY)

    @staticmethod
    def unregister(name: str) -> None:
        _REGISTRY.pop(name, None)


Platform.register(Platform(
    name="diana",
    domains=tuple(quant.DIANA_DOMAINS),
    cost_model_factory=DianaCostModel,
    description="DIANA digital (8-bit) + AIMC (ternary), Sec. III-C models"))

Platform.register(Platform(
    name="diana_abstract",
    domains=tuple(quant.DIANA_DOMAINS),
    cost_model_factory=lambda **kw: AbstractCostModel(ideal_shutdown=False,
                                                      **kw),
    description="Fig. 5 abstract HW, P_idle = P_act"))

Platform.register(Platform(
    name="diana_ideal_shutdown",
    domains=tuple(quant.DIANA_DOMAINS),
    cost_model_factory=lambda **kw: AbstractCostModel(ideal_shutdown=True,
                                                      **kw),
    description="Fig. 5 abstract HW, P_idle = 0 (ideal shutdown)"))

Platform.register(Platform(
    name="tpu_v5e",
    domains=tuple(quant.TPU_DOMAINS),
    cost_model_factory=TPUCostModel,
    description="TPU v5e roofline: int8 MXU @2x peak vs bf16"))

# GAP9-class 3-domain SoC.  Domain 0 stays the digital int8 accelerator so
# the paper's pinning convention (depthwise / non-searchable layers -> domain
# 0) keeps its meaning; the analog in-memory array is fastest/cheapest but
# 2-bit, the fp16 DSP cluster is the slow high-precision escape hatch.
GAP9_DOMAINS = (
    PrecisionDomain("ne16", weight_bits=8, act_bits=8),
    PrecisionDomain("analog", weight_bits=2, act_bits=7),
    PrecisionDomain("cluster_fp16", weight_bits=16, act_bits=16),
)

Platform.register(Platform(
    name="gap9_like",
    domains=GAP9_DOMAINS,
    cost_model_factory=lambda **kw: AbstractCostModel(
        ideal_shutdown=False, domains=GAP9_DOMAINS,
        p_act=(10.0, 1.0, 40.0), throughput=(4.0, 16.0, 1.0), **kw),
    description="GAP9-like: digital int8 NE16 + analog 2-bit array + "
                "fp16 cluster, OP-proportional latency model"))

# GPU tensor-core pair: int8 tensor cores at ~2x fp16 MMA throughput but
# higher accuracy pressure, fp16 as the high-precision escape hatch.  The
# int8 domain is ordered FIRST so mixed int8+fp16 layers match the fused
# split_precision kernel's ("q", "f") registry key — int8 columns lead,
# identity columns trail.  Energy: int8 MACs move half the operand bytes,
# so P_act favors the int8 domain.
GPU_TC_DOMAINS = (
    PrecisionDomain("tc_int8", weight_bits=8, act_bits=8),
    PrecisionDomain("tc_fp16", weight_bits=16, act_bits=16),
)

Platform.register(Platform(
    name="gpu_tc_like",
    domains=GPU_TC_DOMAINS,
    cost_model_factory=lambda **kw: AbstractCostModel(
        ideal_shutdown=True, domains=GPU_TC_DOMAINS,
        p_act=(20.0, 45.0), throughput=(2.0, 1.0), **kw),
    description="GPU tensor-core pair: int8 MMA @2x fp16 throughput, "
                "idle SMs clock-gated (ideal shutdown), OP-proportional "
                "latency"))
