"""repro.api — the first-class mapping API.

This package is the one way examples, benchmarks, tests, and the launch
drivers run a precision-aware mapping search.  Three pillars:

`ModelHandle` (repro.api.handle)
    Typed model façade: ``init(key, spec)``, ``apply(params, x, spec, mode,
    tau)``, ``plan()`` and ``managed_layers`` (defaults to resolving plan
    names as paths into the params pytree).  Adapters:

        cnn_handle(cnn.RESNET20_CFG)       # paper CNNs (repro.models.cnn)
        mlp_handle(in_dim=192, widths=(128, 128), n_classes=10)
        transformer_handle(n_tokens=16, d_model=64, n_layers=2, n_classes=10)
        ModelHandle.from_legacy((init_fn, apply_fn, plan_fn), cfg)  # shim

`Platform` (repro.api.platforms)
    Registry bundling `PrecisionDomain`s + a `CostModel` under a string
    name.  Built-ins: ``"diana"``, ``"diana_abstract"``,
    ``"diana_ideal_shutdown"``, ``"tpu_v5e"``, ``"gap9_like"``,
    ``"gpu_tc_like"`` (GPU tensor-core int8+fp16 pair — mixed layers fuse
    to the split_precision kernel).  A new accelerator is one
    registration::

        Platform.register(Platform("my_soc", domains, MyCostModel))
        plat = Platform.get("my_soc"); plat.spec(); plat.cost_model()

`SearchPipeline` (repro.api.pipeline)
    The paper's flow as composable stages — `Pretrain`, `DNASSearch`,
    `Discretize`, `Finetune`, `Evaluate` — sharing one jitted step, with
    `PipelineCallback` hooks per stage/step.  ``SearchPipeline.fixed_mapping``
    (stages `ApplyMapping`, `FinetuneFixed`, `Evaluate`) evaluates baseline
    mappings.  Example::

        pipe = SearchPipeline(cnn_handle(cfg), platform="diana",
                              config=SearchConfig(lam=5e-7, objective="latency"),
                              data_fn=data_fn, callbacks=[VerboseCallback()])
        res = pipe.run()
        res.artifact.save("experiments/mapping.json")

Mapping artifact (repro.api.artifact) — schema v2
    `Discretize`/`ApplyMapping` emit a `MappingArtifact`, serialized as::

        {"schema_version": 2, "model": ..., "platform": ..., "objective": ...,
         "lam": ..., "seed": ...,
         "domains": [{"name", "weight_bits", "act_bits"}, ...],
         "layers":  [{"name", "searchable", "assignment": [dom per out ch],
                      "counts": [ch per dom],
                      "scales": {"w_log_scales": [per domain],
                                 "act_log_scale": f | null}},  # v2, optional
                     ...],
         "metrics": {"accuracy", "latency", "energy"}}

    Consumers: `lower` (below), ``launch/serve.py --mapping art.json`` and
    ``core.discretize.reorg_chain_from_artifact`` (Fig. 3 reorg pass driven
    by the stored assignment; takes the plain dict, so `core` never imports
    `api`).  ``launch/train.py --emit-mapping`` writes one from a static
    min-cost split, scales included.

Execution plans (re-exported from repro.runtime)
    `lower(artifact, params=..., handle=...)` compiles an artifact into an
    `ExecutionPlan`: per layer, the Fig. 3 channel permutation, the
    block-aligned domain boundaries, the quant scales, optional kernel
    block-size tuning (``lower(..., tuning={name: {"bm","bn","bk"}})``,
    threaded through to the Pallas calls) and the chosen kernel
    (split-precision / split-ternary / quant-matmul / ternary / fp
    fallback — see the kernel capability matrix at the end of this
    docstring), with shape + capability validation (`LoweringError` on
    mismatch)::

        plan = lower(res.artifact, params=res.params, handle=handle)
        backend = runtime.PlannedBackend(plan, res.params, handle=handle)
        logits = handle.apply(res.params, x, spec, "deploy", 1.0)  # with
        # repro.models.managed.matmul_backend(backend) installed, every
        # covered dense executes through its planned Pallas kernel.

    The backend protocol is NAME-KEYED and jit-safe:
    ``backend(name, p, x, conv=...) -> y | None``, where ``name`` is the
    layer's pytree path (artifact layer names ARE these paths).  Because
    plans resolve by a static string at trace time, the whole planned
    forward pass runs under ``jax.jit`` — ``serve.py --mapping`` jits
    prefill/decode with planned kernels inside the trace.  Three layer
    layouts execute: 2-D dense weights; scan-stacked weights (artifact
    names ``base@r``, one layer per repeat — bound repeats are indexed
    inside the layer scan via ``repro.models._backend.scan_slot``); and
    4-D HWIO conv weights (im2col'd onto the dense kernels — CNN
    artifacts serve end to end).  Binding failures and plan/model
    mismatches raise `repro.runtime.ExecutionError` — a name-matched
    layer never silently falls back to fp.

    ``launch/serve.py --mapping`` runs exactly this path (LM archs and
    ``cnn:<config>`` façades), reports bound/unbound coverage, and exits
    nonzero under ``--require-full-coverage`` when any planned layer did
    not execute as mapped; ``launch/dryrun.py --mapping`` reports the
    per-layer kernel selection against an arch's weight shapes.  Grouped/
    depthwise convs lower too: an artifact layer carrying ``"groups": G``
    binds its per-group weight zero-embedded into block-diagonal dense
    form, so e.g. mbv1's own artifact passes ``--require-full-coverage``.

Serving engine (repro.serving)
    Request-level serving is a separate subsystem layered on the planned
    backend: `repro.serving.Engine` continuously batches mixed-length
    requests over a fixed slot pool (ragged prefill, per-slot-masked jitted
    decode, admission into freed slots between steps) and reports
    per-request TTFT / tokens-per-second.  ``launch/serve.py`` is a thin
    client (``serve_batch`` wraps the engine; ``serve --engine --trace``
    replays JSONL request traces); ``benchmarks/bench_runtime.py`` has a
    continuous-vs-static batching leg.  For reproducible per-request
    outputs under a planned backend, emit artifacts with STATIC activation
    scales (``emit_static_mapping(..., act_log_scale=...)``) — dynamic
    max-abs activation quantization depends on batch composition.  See the
    `repro.serving` package docstring for the engine architecture and the
    request lifecycle.

Multi-plan precision bank (repro.runtime.PlanSet)
    Several mapping artifacts of the SAME weights — e.g. a ternary-heavy
    "draft" and an int8-heavy "target" emitted via ``emit_static_mapping(
    ..., bias=("aimc", 0.05))`` / ``bias=("digital", 1.0)`` — lower to
    independent plans and bind as ONE `repro.runtime.PlanSet`: prepared
    weight buffers deduplicate wherever a layer's (plan, weight, domain
    bit-widths, block size) coincide, so a two-variant bank costs strictly
    less memory than two independent binds whenever any layer agrees
    (``memory_report()`` shows the accounting, ``coverage_diff()`` the
    per-variant unbound layer NAMES).  The active variant is a
    trace-static key (`repro.models._backend.plan_variant`, or the
    ``variant=`` kwarg on the transformer entry points), which the serving
    engine exploits for SELF-SPECULATIVE DECODING (draft k tokens cheaply,
    verify in one target-variant chunk — token-identical to target-only
    greedy serving) and per-request SLO ROUTING (each request's class
    routed to a variant, per-class latency tails in ``summarize``).
    ``launch/serve.py --engine --speculate DRAFT.json`` /
    ``--slo-variant CLASS=MAPPING.json`` are the CLI clients; see the
    `repro.serving` docstring for the exactness argument.

    Migration (v1 -> v2): v1 artifacts (no per-layer ``scales``) still load
    and lower — executors then derive weight scales from max-abs statistics
    of the weights they bind to and quantize activations dynamically.
    Documents with ``schema_version`` > 2 are rejected.

    Migration (PR 2 -> PR 3 backends): the old protocol was
    ``backend(p, x)`` with weight leaves matched by ``id()`` — it could
    not see weights that exist only as tracers (any jitted call, every
    scan-stacked layer), so those silently fell back to the default path.
    Custom backends must add the leading ``name`` parameter and key on it
    (see `repro.models._backend` for the full contract).

Migrating from the tuple façade
    Old::

        engine.run_odimo((init_fn, apply_fn, plan_fn), cfg, spec, cost_model,
                         scfg, data_fn, managed_fn=managed_fn)

    New::

        SearchPipeline(ModelHandle.from_legacy((init_fn, apply_fn, plan_fn),
                                               cfg, managed_fn),
                       platform="diana", config=scfg, data_fn=data_fn).run()

    ``engine.run_odimo`` / ``engine.evaluate_fixed_mapping`` remain as thin
    wrappers over the pipeline and return the legacy `SearchResult`.
"""
from repro.api.artifact import MappingArtifact
from repro.runtime.registry import capability_matrix as _capability_matrix

# Kernel capability matrix — generated from the runtime's capability-keyed
# registry (repro.runtime.registry), so these docs can never drift from
# what lower() actually selects.  A new (bits, bits) pairing is one
# ``runtime.register_kernel`` call; ``Platform.kernel_capabilities()``
# projects this table onto a platform's own domains.
if __doc__:  # absent under python -OO
    __doc__ += (
        "\nKernel capability matrix (generated from repro.runtime.registry;"
        "\nactive domains' weight-bit classes, in plan order -> kernel)::\n\n"
        + "".join(f"    {row}\n" for row in _capability_matrix()))

from repro.api.handle import (ModelHandle, cnn_handle, mlp_handle,
                              transformer_handle)
from repro.api.pipeline import (ApplyMapping, Discretize, DNASSearch,
                                Evaluate, Finetune, FinetuneFixed,
                                PipelineCallback, PipelineResult,
                                PipelineState, Pretrain, SearchPipeline,
                                Stage, VerboseCallback, default_stages,
                                fixed_mapping_stages)
from repro.api.platforms import Platform
from repro.core.engine import SearchConfig, SearchResult
from repro.runtime import ExecutionPlan, LayerPlan, LoweringError, lower

__all__ = [
    "ApplyMapping", "Discretize", "DNASSearch", "Evaluate", "ExecutionPlan",
    "Finetune", "FinetuneFixed", "LayerPlan", "LoweringError",
    "MappingArtifact", "ModelHandle", "Platform", "PipelineCallback",
    "PipelineResult", "PipelineState", "Pretrain", "SearchConfig",
    "SearchPipeline", "SearchResult", "Stage", "VerboseCallback",
    "cnn_handle", "default_stages", "fixed_mapping_stages", "lower",
    "mlp_handle", "transformer_handle",
]
