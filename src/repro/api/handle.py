"""`ModelHandle`: the typed model façade the mapping API searches over.

A handle bundles everything ODiMO needs from a model — parameter init, the
mode-aware forward pass, the layer plan (geometry + searchability), and a way
to locate the ODiMO-managed layer dicts inside the params pytree — replacing
the old positional ``(init_fn, apply_fn, plan_fn)`` tuple plus ``managed_fn``
kwarg.  Model config is bound at construction time, so the engine never sees
it.

The default managed-layer lookup resolves the *plan names* as slash-separated
paths into the params pytree (``"blocks/0/c1"`` -> ``params["blocks"][0]["c1"]``),
which covers every façade in the repo; custom pytree layouts override
``managed_layers``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_models import LayerGeometry
from repro.models.managed import get_by_path

Plan = List[Tuple[str, LayerGeometry, bool]]


@dataclasses.dataclass(frozen=True)
class ModelHandle:
    """Typed façade over a searchable model.

    init(key, spec)                  -> params pytree
    apply(params, x, spec, mode, tau)-> logits
    plan()                           -> [(name, LayerGeometry, searchable)]
    managed_layers(params)           -> managed layer dicts, plan order
                                        (None => path lookup by plan names)
    """
    name: str
    init: Callable[..., Any]
    apply: Callable[..., jax.Array]
    plan: Callable[[], Plan]
    managed_layers: Callable[[Any], List[dict]] | None = None
    config: Any = None

    def layers(self, params) -> List[dict]:
        """Managed layer dicts of ``params``, in plan order."""
        if self.managed_layers is not None:
            return self.managed_layers(params)
        return [get_by_path(params, name) for (name, _, _) in self.plan()]

    def geometries(self) -> List[LayerGeometry]:
        return [g for (_, g, _) in self.plan()]

    def searchable(self) -> List[bool]:
        return [s for (_, _, s) in self.plan()]

    def with_assignments(self, params, assignments: Sequence[np.ndarray],
                         n_domains: int, margin: float = 10.0):
        """Return a NEW params pytree whose alphas one-hot-encode a fixed
        channel->domain mapping (large-margin logits).

        Functional: the managed dicts are located via ``layers`` and the
        matching alpha leaves are swapped by identity, so nothing depends on
        dict aliasing into ``params`` and the input pytree is left untouched.
        """
        layers = self.layers(params)
        if len(assignments) != len(layers):
            raise ValueError(
                f"{self.name}: {len(assignments)} assignments for "
                f"{len(layers)} managed layers (one per plan entry required)")
        replacements = {}
        for d, a in zip(layers, assignments):
            if "odimo" not in d:
                continue
            onehot = jnp.asarray(np.eye(n_domains)[np.asarray(a)].T * margin,
                                 dtype=jnp.float32)
            replacements[id(d["odimo"]["alpha"])] = onehot
        leaf_ids = {id(leaf) for leaf in jax.tree.leaves(params)}
        if not set(replacements).issubset(leaf_ids):
            raise ValueError(
                f"{self.name}: managed_layers returned alpha arrays that are "
                "not leaves of the given params pytree; with_assignments "
                "needs the original (non-copied) layer dicts")
        return jax.tree.map(lambda leaf: replacements.get(id(leaf), leaf),
                            params)

    # ---- adapters --------------------------------------------------------

    @classmethod
    def from_legacy(cls, model, cfg, managed_fn=None,
                    name: str | None = None) -> "ModelHandle":
        """Wrap the old ``(init_fn, apply_fn, plan_fn)`` tuple (+ optional
        ``managed_fn``).  Back-compat shim for `engine.run_odimo`."""
        init_fn, apply_raw, plan_fn = model
        return cls(
            name=name or getattr(cfg, "name", "legacy"),
            init=lambda key, spec: init_fn(key, cfg, spec),
            apply=lambda p, x, spec, mode, tau: apply_raw(p, x, cfg, spec,
                                                          mode, tau),
            plan=lambda: plan_fn(cfg),
            managed_layers=managed_fn,
            config=cfg,
        )


def cnn_handle(cfg) -> ModelHandle:
    """Handle over the paper CNN façades (``repro.models.cnn``)."""
    from repro.models import cnn
    return ModelHandle.from_legacy(cnn.get_model(cfg), cfg, name=cfg.name)


def mlp_handle(cfg=None, **kw) -> ModelHandle:
    """Handle over the managed-Dense MLP façade (``repro.models.facades``)."""
    from repro.models import facades
    if cfg is None:
        cfg = facades.MLPConfig(**kw)
    return ModelHandle.from_legacy(
        (facades.mlp_init, facades.mlp_apply, facades.mlp_plan), cfg,
        name=cfg.name)


def transformer_handle(cfg=None, **kw) -> ModelHandle:
    """Handle over the managed transformer-encoder classifier façade."""
    from repro.models import facades
    if cfg is None:
        cfg = facades.EncoderConfig(**kw)
    return ModelHandle.from_legacy(
        (facades.encoder_init, facades.encoder_apply, facades.encoder_plan),
        cfg, name=cfg.name)
