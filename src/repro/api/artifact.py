"""`MappingArtifact`: the serializable result of a mapping search.

One JSON document records everything needed to re-deploy (or re-evaluate) a
discovered channel->domain mapping without re-running the DNAS:

    {
      "schema_version": 2,
      "model": "resnet20_tiny",
      "platform": "diana",            # registry name, or null for ad hoc
      "objective": "latency",
      "lam": 5e-07,
      "seed": 0,
      "domains": [{"name": "digital", "weight_bits": 8, "act_bits": 8}, ...],
      "layers": [{"name": "stem", "searchable": true,
                  "assignment": [0, 1, ...],     # domain idx per out channel
                  "counts": [12, 4],             # channels per domain
                  "scales": {                    # v2: quant scales (optional)
                    "w_log_scales": [s_dom0, s_dom1, ...],
                    "act_log_scale": 0.13 | null}}, ...],
      "metrics": {"accuracy": ..., "latency": ..., "energy": ...}
    }

Schema v2 adds the optional per-layer ``scales`` block so the artifact is
self-contained for *execution*: `repro.runtime.lower` compiles it into an
`ExecutionPlan` (per-layer kernel + reorg permutation + aligned boundaries).
v1 documents (no ``scales``) still load and lower — executors then fall back
to max-abs scale statistics of the weights they bind to.

Consumers: `repro.runtime.lower` (-> per-layer planned execution in
``launch/serve.py --mapping``), `launch/serve.py:apply_mapping_artifact`
(global majority-dtype FALLBACK) and `core/discretize.
reorg_chain_from_artifact` (the latter takes the plain dict so `core` never
imports `api`).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Sequence

import numpy as np

SCHEMA_VERSION = 2


@dataclasses.dataclass
class MappingArtifact:
    model: str
    domains: List[Dict[str, Any]]
    layers: List[Dict[str, Any]]
    platform: str | None = None
    objective: str | None = None
    lam: float | None = None
    seed: int | None = None
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    schema_version: int = SCHEMA_VERSION

    # ---- construction ----------------------------------------------------

    @classmethod
    def from_search(cls, model_name: str, spec, plan, assignments,
                    counts, platform=None, objective=None, lam=None,
                    seed=None, metrics=None, scales=None) -> "MappingArtifact":
        """``scales``: optional per-layer list of
        ``{"w_log_scales": [...], "act_log_scale": float | None}`` dicts
        (None entries allowed) — the schema-v2 execution scales."""
        if not (len(plan) == len(assignments) == len(counts)):
            raise ValueError(f"plan/assignments/counts length mismatch: "
                             f"{len(plan)}/{len(assignments)}/{len(counts)}")
        if scales is not None and len(scales) != len(plan):
            raise ValueError(f"plan/scales length mismatch: "
                             f"{len(plan)}/{len(scales)}")
        domains = [dict(name=d.name, weight_bits=d.weight_bits,
                        act_bits=d.act_bits) for d in spec.domains]
        layers = []
        for i, ((name, geom, searchable), a, c) in enumerate(
                zip(plan, assignments, counts)):
            layer = dict(name=name, searchable=bool(searchable),
                         assignment=[int(v) for v in np.asarray(a)],
                         counts=[int(v) for v in np.asarray(c)])
            # grouped/depthwise convs carry their group count so the
            # runtime can lower them block-diagonally (LayerPlan.groups)
            groups = int(getattr(geom, "groups", 1) or 1)
            if groups > 1:
                layer["groups"] = groups
            if scales is not None and scales[i] is not None:
                layer["scales"] = scales[i]
            layers.append(layer)
        return cls(model=model_name, domains=domains, layers=layers,
                   platform=platform, objective=objective, lam=lam,
                   seed=seed, metrics=dict(metrics or {}))

    # ---- accessors -------------------------------------------------------

    def assignments(self) -> List[np.ndarray]:
        return [np.asarray(l["assignment"], dtype=np.int64)
                for l in self.layers]

    def counts(self) -> List[np.ndarray]:
        return [np.asarray(l["counts"], dtype=np.int64) for l in self.layers]

    @property
    def n_domains(self) -> int:
        return len(self.domains)

    def domain_channel_fractions(self, searchable_only: bool = False
                                 ) -> np.ndarray:
        """Fraction of all channels assigned to each domain.

        ``searchable_only=True`` counts only ``searchable: true`` layers —
        pinned layers never had a choice, so they must not vote when a
        consumer (e.g. the serve fallback) derives a majority domain.  Falls
        back to all layers when none are searchable.
        """
        tot = np.zeros(self.n_domains, dtype=np.float64)
        for l in self.layers:
            if searchable_only and not l.get("searchable", True):
                continue
            tot += np.asarray(l["counts"], dtype=np.float64)
        if searchable_only and tot.sum() == 0.0:
            return self.domain_channel_fractions(searchable_only=False)
        return tot / max(tot.sum(), 1.0)

    # ---- (de)serialization ----------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "MappingArtifact":
        d = dict(d)
        version = d.pop("schema_version", SCHEMA_VERSION)
        if version > SCHEMA_VERSION:
            raise ValueError(f"mapping artifact schema v{version} is newer "
                             f"than supported v{SCHEMA_VERSION}")
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(schema_version=version,
                   **{k: v for k, v in d.items() if k in fields})

    @classmethod
    def from_json(cls, s: str) -> "MappingArtifact":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json())
        return p

    @classmethod
    def load(cls, path) -> "MappingArtifact":
        return cls.from_json(Path(path).read_text())
