"""`MappingArtifact`: the serializable result of a mapping search.

One JSON document records everything needed to re-deploy (or re-evaluate) a
discovered channel->domain mapping without re-running the DNAS:

    {
      "schema_version": 1,
      "model": "resnet20_tiny",
      "platform": "diana",            # registry name, or null for ad hoc
      "objective": "latency",
      "lam": 5e-07,
      "seed": 0,
      "domains": [{"name": "digital", "weight_bits": 8, "act_bits": 8}, ...],
      "layers": [{"name": "stem", "searchable": true,
                  "assignment": [0, 1, ...],     # domain idx per out channel
                  "counts": [12, 4]}, ...],      # channels per domain
      "metrics": {"accuracy": ..., "latency": ..., "energy": ...}
    }

`launch/serve.py --mapping` and `core/discretize.reorg_chain_from_artifact`
consume this document directly (the latter takes the plain dict so `core`
never imports `api`).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Sequence

import numpy as np

SCHEMA_VERSION = 1


@dataclasses.dataclass
class MappingArtifact:
    model: str
    domains: List[Dict[str, Any]]
    layers: List[Dict[str, Any]]
    platform: str | None = None
    objective: str | None = None
    lam: float | None = None
    seed: int | None = None
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    schema_version: int = SCHEMA_VERSION

    # ---- construction ----------------------------------------------------

    @classmethod
    def from_search(cls, model_name: str, spec, plan, assignments,
                    counts, platform=None, objective=None, lam=None,
                    seed=None, metrics=None) -> "MappingArtifact":
        if not (len(plan) == len(assignments) == len(counts)):
            raise ValueError(f"plan/assignments/counts length mismatch: "
                             f"{len(plan)}/{len(assignments)}/{len(counts)}")
        domains = [dict(name=d.name, weight_bits=d.weight_bits,
                        act_bits=d.act_bits) for d in spec.domains]
        layers = [dict(name=name, searchable=bool(searchable),
                       assignment=[int(v) for v in np.asarray(a)],
                       counts=[int(v) for v in np.asarray(c)])
                  for (name, _, searchable), a, c
                  in zip(plan, assignments, counts)]
        return cls(model=model_name, domains=domains, layers=layers,
                   platform=platform, objective=objective, lam=lam,
                   seed=seed, metrics=dict(metrics or {}))

    # ---- accessors -------------------------------------------------------

    def assignments(self) -> List[np.ndarray]:
        return [np.asarray(l["assignment"], dtype=np.int64)
                for l in self.layers]

    def counts(self) -> List[np.ndarray]:
        return [np.asarray(l["counts"], dtype=np.int64) for l in self.layers]

    @property
    def n_domains(self) -> int:
        return len(self.domains)

    def domain_channel_fractions(self) -> np.ndarray:
        """Fraction of all channels assigned to each domain."""
        tot = np.zeros(self.n_domains, dtype=np.float64)
        for l in self.layers:
            tot += np.asarray(l["counts"], dtype=np.float64)
        return tot / max(tot.sum(), 1.0)

    # ---- (de)serialization ----------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "MappingArtifact":
        d = dict(d)
        version = d.pop("schema_version", SCHEMA_VERSION)
        if version > SCHEMA_VERSION:
            raise ValueError(f"mapping artifact schema v{version} is newer "
                             f"than supported v{SCHEMA_VERSION}")
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(schema_version=version,
                   **{k: v for k, v in d.items() if k in fields})

    @classmethod
    def from_json(cls, s: str) -> "MappingArtifact":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json())
        return p

    @classmethod
    def load(cls, path) -> "MappingArtifact":
        return cls.from_json(Path(path).read_text())
