"""Capability-keyed kernel registry: which fused kernel executes a layer
whose ACTIVE domains have a given weight-bit signature.

`lower()` used to hardcode an if/elif ladder over bit-widths; adding a
pairing (e.g. the DIANA ternary+int8 mixed layer) meant edits across
lower/plan/execute.  The registry replaces the ladder with one table:

    key:   tuple of BIT CLASSES in PLAN (domain) order —
             "t"  ternary        (weight_bits == 2)
             "q"  int-quantized  (2 < weight_bits <= 8)
             "f"  identity       (weight_bits >= 16)
    value: a `KernelCapability` naming the plan-level kernel.

Built-in registrations:

    ("q",)      quant_matmul       ("t",)  ternary_matmul   ("f",)  fp
    ("q", "f")  split_precision    (int8 cols | identity cols)
    ("q", "t")  split_ternary      (int8 cols | 2-bit-packed ternary cols)

A new pairing is ONE ``register_kernel`` call; `kernel_for` turns a layer's
active bit-widths into ``(kernel, note)`` with ordering hints when only the
flipped key is registered (the fused kernels fix which domain owns the low
columns).  Introspection: `capability_matrix()` renders the table for docs
(`repro.api` embeds it) and `Platform.kernel_capabilities()` projects it
onto a platform's domain pairs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.runtime.plan import (KERNEL_FP, KERNEL_QUANT, KERNEL_SPLIT,
                                KERNEL_SPLIT_TERNARY, KERNEL_TERNARY, KERNELS)

#: bit-class codes -> human description (doc rendering)
BIT_CLASSES = {"t": "ternary (2-bit)", "q": "int (3..8-bit)",
               "f": "identity (>=16-bit)"}


def bit_class(bits: int) -> str | None:
    """Canonical capability class of a weight bit-width (None: no kernel
    covers this width — e.g. 1-bit or 9..15-bit domains)."""
    bits = int(bits)
    if bits == 2:
        return "t"
    if 2 < bits <= 8:
        return "q"
    if bits >= 16:
        return "f"
    return None


@dataclasses.dataclass(frozen=True)
class KernelCapability:
    """One registry row: a bit-class key executed by a named kernel."""
    key: Tuple[str, ...]
    kernel: str
    description: str = ""


_REGISTRY: Dict[Tuple[str, ...], KernelCapability] = {}


def register_kernel(key: Sequence[str], kernel: str, description: str = "",
                    overwrite: bool = False) -> KernelCapability:
    """Register ``kernel`` (a `repro.runtime.plan` kernel name) for layers
    whose active domains match ``key`` (bit classes in plan order)."""
    key = tuple(key)
    for cls in key:
        if cls not in BIT_CLASSES:
            raise ValueError(f"unknown bit class {cls!r} in {key} "
                             f"(known: {sorted(BIT_CLASSES)})")
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r} (known: {KERNELS})")
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"capability {key} already registered to "
                         f"{_REGISTRY[key].kernel!r} (pass overwrite=True)")
    cap = KernelCapability(key=key, kernel=kernel, description=description)
    _REGISTRY[key] = cap
    return cap


def unregister_kernel(key: Sequence[str]) -> None:
    _REGISTRY.pop(tuple(key), None)


def registered() -> List[KernelCapability]:
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def _bits_text(bits: Sequence[int]) -> str:
    return " + ".join(f"{int(b)}-bit" for b in bits)


def kernel_for(bits: Sequence[int]) -> Tuple[str, str]:
    """(kernel, note) for a layer from its ACTIVE domains' weight bit-widths
    in plan order.  ``note`` is non-empty iff no registered kernel covers
    the signature and the layer must fall back to fp."""
    bits = [int(b) for b in bits]
    if not bits:
        return KERNEL_FP, "no channels assigned"
    classes = tuple(bit_class(b) for b in bits)
    if None in classes:
        bad = bits[classes.index(None)]
        return KERNEL_FP, f"no kernel for {bad}-bit weights"
    cap = _REGISTRY.get(classes)
    if cap is not None:
        return cap.kernel, ""
    flipped = _REGISTRY.get(tuple(reversed(classes)))
    if flipped is not None:
        return KERNEL_FP, (
            f"{flipped.kernel} needs the {BIT_CLASSES[flipped.key[0]]} "
            f"domain ordered before the {BIT_CLASSES[flipped.key[1]]} "
            f"domain (got {_bits_text(bits)})")
    if len(classes) > 2:
        return KERNEL_FP, (f"{len(classes)} active domains "
                           f"({_bits_text(bits)}) exceed the fused kernels")
    return KERNEL_FP, f"no fused kernel for {_bits_text(bits)} domains"


def capability_matrix() -> List[str]:
    """The registry rendered as aligned text rows (doc embedding)."""
    rows = []
    for cap in registered():
        sig = " | ".join(BIT_CLASSES[c] for c in cap.key)
        rows.append(f"{sig:<44} -> {cap.kernel:<16} {cap.description}")
    return rows


# --------------------------------------------------------------------------
# built-in capabilities (one line per kernel — THE place new pairings land)
# --------------------------------------------------------------------------

register_kernel(("f",), KERNEL_FP, "single identity domain, no quant")
register_kernel(("q",), KERNEL_QUANT, "w8a8, int32 accumulate")
register_kernel(("t",), KERNEL_TERNARY, "codes in {-1,0,+1}, int8 MXU path")
register_kernel(("q", "f"), KERNEL_SPLIT,
                "fused int8 cols | bf16 cols (paper Fig. 3)")
register_kernel(("q", "t"), KERNEL_SPLIT_TERNARY,
                "fused int8 cols | 2-bit-packed ternary cols (DIANA)")
