"""`ExecutionPlan`: the lowered, executable form of a mapping artifact.

Where a `MappingArtifact` records *what the search decided* (a domain index
per output channel), an `ExecutionPlan` records *how to run it*: per layer,
the stable channel permutation that makes same-domain channels contiguous
(paper Fig. 3), the resulting cumulative domain boundaries both raw and
rounded up to the Pallas N-block size (`kernels.ops` alignment rule), the
weight/activation quantization scales, and the kernel that executes the
layer:

    "split_precision"   fused two-domain matmul (int8 cols | identity cols)
    "split_ternary"     fused two-domain matmul (int8 cols | 2-bit-packed
                        ternary cols — the DIANA digital+AIMC pairing)
    "quant_matmul"      single quantized domain, w8a8 int32-accumulate
    "ternary_matmul"    single 2-bit domain, codes in {-1, 0, +1}
    "fp"                identity fallback (reason recorded in ``note``)

The kernel choice is driven by the capability registry in
`repro.runtime.registry` — new (bits, bits) pairings are one
``register_kernel`` call, not edits across lower/plan/execute.

Plans serialize to JSON (schema v2, shared with the artifact's
``schema_version``) so a lowered mapping can ship alongside its artifact:

    {"schema_version": 2, "model": ..., "platform": ..., "block_n": 128,
     "domains": [{"name", "weight_bits", "act_bits"}, ...],
     "layers": [{"name", "kernel", "c_in", "c_out", "perm": [...],
                 "counts": [...], "boundaries": [...],
                 "aligned_boundaries": [...], "w_log_scales": [...] | null,
                 "act_log_scale": float | null, "searchable": bool,
                 "note": str, "groups": int}, ...]}

``groups`` > 1 marks a grouped/depthwise conv layer: the executors
zero-embed its per-group weight into a block-diagonal dense matrix at bind
time so it runs through the same im2col'd Pallas kernels (see
`repro.runtime.execute.prepare_layer`).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List

import numpy as np

PLAN_SCHEMA_VERSION = 2

KERNEL_SPLIT = "split_precision"
KERNEL_SPLIT_TERNARY = "split_ternary"
KERNEL_QUANT = "quant_matmul"
KERNEL_TERNARY = "ternary_matmul"
KERNEL_FP = "fp"
KERNELS = (KERNEL_SPLIT, KERNEL_SPLIT_TERNARY, KERNEL_QUANT, KERNEL_TERNARY,
           KERNEL_FP)


class LoweringError(ValueError):
    """An artifact cannot be lowered onto the given model/kernels."""


@dataclasses.dataclass
class LayerPlan:
    """Execution recipe for one ODiMO-managed layer."""
    name: str
    kernel: str                       # one of KERNELS
    c_in: int
    c_out: int
    perm: np.ndarray                  # (C_out,) stable domain-grouping perm
    counts: List[int]                 # channels per domain (plan order)
    boundaries: List[int]             # cumulative domain boundaries, raw
    aligned_boundaries: List[int]     # rounded up to block_n (ops.py rule)
    w_log_scales: List[float] | None  # per-domain weight quant log-scales
    act_log_scale: float | None       # activation log-scale (None = dynamic)
    searchable: bool = True
    note: str = ""                    # e.g. why the fp fallback was chosen
    tuning: Dict[str, int] | None = None  # kernel block sizes: bm/bn/bk
    groups: int = 1                   # grouped/depthwise conv group count

    def __post_init__(self):
        self.perm = np.asarray(self.perm, dtype=np.int64)
        if self.kernel not in KERNELS:
            raise LoweringError(f"{self.name}: unknown kernel {self.kernel!r}"
                                f" (known: {KERNELS})")

    def inv_perm(self) -> np.ndarray:
        """Inverse permutation: planned-order outputs -> original order."""
        return np.argsort(self.perm)

    def active_domains(self) -> List[int]:
        """Domain indices that actually own channels in this layer."""
        return [i for i, c in enumerate(self.counts) if c > 0]

    def split_boundary(self) -> int:
        """First column of the LAST active domain (the split kernel's
        int8/identity boundary when exactly two domains are active)."""
        act = self.active_domains()
        if len(act) < 2:
            return self.c_out
        return int(sum(self.counts[: act[-1]]))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["perm"] = [int(v) for v in self.perm]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "LayerPlan":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


@dataclasses.dataclass
class ExecutionPlan:
    """A fully lowered mapping: one `LayerPlan` per artifact layer."""
    model: str
    domains: List[Dict[str, Any]]
    layers: List[LayerPlan]
    platform: str | None = None
    block_n: int = 128
    schema_version: int = PLAN_SCHEMA_VERSION

    @property
    def n_domains(self) -> int:
        return len(self.domains)

    def __getitem__(self, name: str) -> LayerPlan:
        for lp in self.layers:
            if lp.name == name:
                return lp
        raise KeyError(name)

    def kernel_histogram(self) -> Dict[str, int]:
        hist: Dict[str, int] = {}
        for lp in self.layers:
            hist[lp.kernel] = hist.get(lp.kernel, 0) + 1
        return hist

    def fallback_reasons(self) -> Dict[str, List[str]]:
        """``{note: [layer names]}`` for every layer that recorded a note —
        the capability fp fallbacks a coverage report should surface."""
        out: Dict[str, List[str]] = {}
        for lp in self.layers:
            if lp.note:
                # lower() prefixes notes with the layer name; strip it so
                # layers sharing a reason group into one report line
                reason = lp.note.removeprefix(f"{lp.name}: ")
                out.setdefault(reason, []).append(lp.name)
        return out

    def histogram_lines(self) -> List[str]:
        """Human-readable per-kernel layer histogram + decline reasons (the
        ``serve --mapping`` / ``dryrun --mapping`` at-a-glance report)."""
        hist = self.kernel_histogram()
        lines = ["kernel histogram: " +
                 " ".join(f"{k}:{v}" for k, v in sorted(hist.items()))]
        for note, names in sorted(self.fallback_reasons().items()):
            shown = ", ".join(names[:6]) + (" ..." if len(names) > 6 else "")
            lines.append(f"  fallback x{len(names)} ({note}): {shown}")
        return lines

    def summary(self) -> str:
        hist = " ".join(f"{k}:{v}"
                        for k, v in sorted(self.kernel_histogram().items()))
        return (f"ExecutionPlan({self.model}, platform={self.platform}, "
                f"{len(self.layers)} layers, {hist})")

    # ---- (de)serialization ----------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["layers"] = [lp.to_dict() for lp in self.layers]
        return d

    def to_json(self, indent: int | None = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "ExecutionPlan":
        d = dict(d)
        version = d.pop("schema_version", PLAN_SCHEMA_VERSION)
        if version > PLAN_SCHEMA_VERSION:
            raise ValueError(f"execution plan schema v{version} is newer "
                             f"than supported v{PLAN_SCHEMA_VERSION}")
        d["layers"] = [LayerPlan.from_dict(l) for l in d.get("layers", [])]
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(schema_version=version,
                   **{k: v for k, v in d.items() if k in fields})

    @classmethod
    def from_json(cls, s: str) -> "ExecutionPlan":
        return cls.from_dict(json.loads(s))

    def save(self, path) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json())
        return p

    @classmethod
    def load(cls, path) -> "ExecutionPlan":
        return cls.from_json(Path(path).read_text())
