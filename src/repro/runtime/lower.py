"""`lower()`: compile a `MappingArtifact` onto the repo's kernels.

The compiler takes the artifact (object or plain dict — this module never
imports `repro.api`) plus, optionally, the model's params/handle, and emits
an `ExecutionPlan`:

  * reorg: `core.discretize.stable_perm` groups each layer's output channels
    by domain; `split_points` gives the cumulative boundaries; the
    `kernels.ops.align_boundary` rule rounds them up to the Pallas N-block.
  * validation: artifact channel counts vs actual weight shapes, boundary
    monotonicity/alignment, domain->kernel capability checks.
  * kernel selection per layer (see `select_kernel`, driven by the
    capability-keyed registry in `repro.runtime.registry`):
      - one active >=16-bit domain            -> "fp"
      - one active <=8-bit domain             -> "quant_matmul" (2-bit:
                                                 "ternary_matmul")
      - int8-ish + identity domains, quant
        domain ordered first                  -> "split_precision"
      - int8-ish + ternary domains, int8
        domain ordered first                  -> "split_ternary" (DIANA)
      - anything else                         -> "fp" fallback, reason
                                                 (with layer name + bits
                                                 pair) in ``note``
                                                 (LoweringError if
                                                 ``strict=True``)
  * scales: artifact v2 per-layer scales win; otherwise the ODiMO state of
    the resolved layer dict; otherwise max-abs statistics of the concrete
    weight; otherwise None (v1 artifacts "lower without scales" — executors
    then derive scales from the weights they bind to).

CLI (the artifact pipeline's middle step, exercised by scripts/ci_smoke.sh):

    PYTHONPATH=src python -m repro.runtime.lower mapping.json \
        --out plan.json [--arch yi-9b --reduce] [--block-n 128]
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import numpy as np

from repro.core import quant
from repro.core.discretize import split_points, stable_perm
from repro.kernels.ops import align_boundary
from repro.runtime import registry
from repro.runtime.plan import (ExecutionPlan, LayerPlan, LoweringError,
                                PLAN_SCHEMA_VERSION)


def _artifact_dict(artifact) -> dict:
    if hasattr(artifact, "to_dict"):
        artifact = artifact.to_dict()
    version = artifact.get("schema_version", 1)
    if version > PLAN_SCHEMA_VERSION:
        raise LoweringError(f"mapping artifact schema v{version} is newer "
                            f"than supported v{PLAN_SCHEMA_VERSION}")
    return artifact


def _index_stacked(node, r: int):
    """Repeat ``r`` of a scan-stacked param node: every array leaf loses its
    leading R axis (ShapeDtypeStructs are re-specced, concrete arrays
    sliced).  Returns None when the repeat is out of range."""
    def one(leaf):
        if type(leaf).__name__ == "ShapeDtypeStruct":
            if not leaf.shape or r >= leaf.shape[0]:
                raise IndexError(r)
            return type(leaf)(leaf.shape[1:], leaf.dtype)
        if getattr(leaf, "ndim", 0) >= 1:
            if r >= leaf.shape[0]:
                raise IndexError(r)
            return leaf[r]
        return leaf
    try:
        if isinstance(node, dict):
            return {k: one(v) for k, v in node.items()}
        return one(node)
    except (IndexError, TypeError):
        return None


def _walk_path(params, name: str):
    """Resolve a slash-separated layer name into the params pytree; returns
    None when any segment is missing.  A ``base@r`` name addresses repeat
    ``r`` of the scan-stacked node at ``base`` (leaves carry a leading R
    axis — the `jax.lax.scan` layer-stacking convention of
    `repro.models.transformer`)."""
    base, _, rep = name.partition("@")
    node = params
    for part in base.split("/"):
        try:
            if isinstance(node, (list, tuple)):
                node = node[int(part)]
            elif isinstance(node, dict):
                node = node[part]
            else:
                return None
        except (KeyError, IndexError, ValueError, TypeError):
            return None
    if rep:
        try:
            node = _index_stacked(node, int(rep))
        except ValueError:
            return None
    return node


def resolve_layer_params(artifact, params=None, handle=None):
    """Per artifact layer, the param node it names: a managed-layer dict
    (``{"w": ..., "b"?, "odimo"?, "act_log_scale"?}``), a bare weight leaf,
    or None when unresolvable / no params were given.

    With a ``handle`` (any object with ``layers(params)``, e.g. a
    `repro.api.ModelHandle`), layers come back in plan order — artifact
    order by construction.  Otherwise artifact layer names are resolved as
    slash-separated paths into ``params`` (the `launch/train.py
    --emit-mapping` convention); ``base@r`` names address repeat ``r`` of a
    scan-stacked node (leaves with a leading R axis), and 4-D HWIO conv
    weights resolve like dense ones (the executors im2col their inputs).
    """
    art = _artifact_dict(artifact)
    names = [l["name"] for l in art["layers"]]
    if handle is not None and params is not None:
        dicts = handle.layers(params)
        if len(dicts) != len(names):
            raise LoweringError(
                f"handle resolves {len(dicts)} managed layers but the "
                f"artifact has {len(names)}")
        return list(zip(names, dicts))
    if params is None:
        return [(n, None) for n in names]
    return [(n, _walk_path(params, n)) for n in names]


def _layer_weight(node) -> Any | None:
    """The weight array (or ShapeDtypeStruct) of a resolved param node."""
    if node is None:
        return None
    if isinstance(node, dict):
        w = node.get("w")
        return w if getattr(w, "ndim", 0) >= 2 else None
    return node if getattr(node, "ndim", 0) >= 2 else None


def _is_concrete(w) -> bool:
    return w is not None and hasattr(w, "dtype") and not (
        type(w).__name__ == "ShapeDtypeStruct")


def select_kernel(counts: Sequence[int],
                  domain_bits: Sequence[int]) -> Tuple[str, str]:
    """(kernel, note) for a layer from its per-domain channel counts and the
    domains' weight bit-widths.  ``note`` is non-empty iff the layer fell
    back to fp for a capability reason.

    Delegates to the capability-keyed registry (`repro.runtime.registry`):
    the active domains' bit-widths, in plan order, look up the kernel — a
    new (bits, bits) pairing is one ``register_kernel`` call."""
    active = [i for i, c in enumerate(counts) if c > 0]
    return registry.kernel_for([domain_bits[i] for i in active])


def _layer_scales(art_layer: dict, node) -> Tuple[List[float] | None,
                                                  float | None]:
    """(w_log_scales, act_log_scale) by priority: artifact v2 scales ->
    ODiMO state of the resolved layer dict -> None (lower() then falls back
    to max-abs statistics of the concrete weight, when one is bound)."""
    sc = art_layer.get("scales")
    if sc:
        wls = sc.get("w_log_scales")
        als = sc.get("act_log_scale")
        return ([float(v) for v in wls] if wls is not None else None,
                float(als) if als is not None else None)
    if isinstance(node, dict) and "odimo" in node:
        wls = [float(v) for v in np.asarray(node["odimo"]["log_scales"])]
        als = node.get("act_log_scale")
        return wls, (float(als) if als is not None else None)
    return None, None


def lower(artifact, params=None, handle=None, *, block_n: int = 128,
          strict: bool = False, tuning=None) -> ExecutionPlan:
    """Compile ``artifact`` into an `ExecutionPlan`.

    ``params``/``handle`` enable shape validation and scale recovery (see
    `resolve_layer_params`); without them the plan is lowered from the
    artifact alone.  ``strict=True`` turns capability fallbacks (layers that
    would silently run fp) into `LoweringError`s; shape mismatches always
    raise.  ``tuning`` optionally maps a layer name (or ``"*"`` for every
    layer) to kernel block sizes ``{"bm", "bn", "bk"}``, recorded on each
    `LayerPlan` and threaded through to the Pallas kernels by the
    executors; a tuned ``bn`` also becomes the layer's boundary-alignment
    block.
    """
    art = _artifact_dict(artifact)
    domains = [dict(d) for d in art["domains"]]
    domain_bits = [int(d["weight_bits"]) for d in domains]
    n_domains = len(domains)
    tuning = tuning or {}
    resolved = resolve_layer_params(art, params=params, handle=handle)

    layers: List[LayerPlan] = []
    for art_layer, (name, node) in zip(art["layers"], resolved):
        assign = np.asarray(art_layer["assignment"], dtype=np.int64)
        if assign.size and (assign.min() < 0 or assign.max() >= n_domains):
            raise LoweringError(
                f"layer {name!r}: assignment references domain "
                f"{int(assign.max())} but the artifact declares only "
                f"{n_domains} domains")
        counts = [int((assign == i).sum()) for i in range(n_domains)]
        art_counts = [int(c) for c in art_layer.get("counts", counts)]
        if art_counts != counts:
            raise LoweringError(
                f"layer {name!r}: stored counts {art_counts} disagree with "
                f"the assignment's {counts}")

        if params is not None and handle is None and node is None:
            raise LoweringError(
                f"layer {name!r}: no param node at this path — the artifact "
                f"was produced for a different model/config")
        w = _layer_weight(node)
        c_out = int(assign.size)
        c_in = int(art_layer.get("c_in", 0))
        groups = int(art_layer.get("groups", 1))
        if groups > 1 and c_out % groups:
            raise LoweringError(
                f"layer {name!r}: {c_out} output channels do not divide "
                f"into {groups} conv groups")
        if w is not None:
            if int(w.shape[-1]) != c_out:
                raise LoweringError(
                    f"layer {name!r}: artifact assigns {c_out} output "
                    f"channels but the bound weight has shape "
                    f"{tuple(w.shape)} ({int(w.shape[-1])} channels) — "
                    f"the artifact does not match this model")
            if groups > 1 and getattr(w, "ndim", 0) != 4:
                raise LoweringError(
                    f"layer {name!r}: groups={groups} needs a 4-D HWIO conv "
                    f"weight, got shape {tuple(w.shape)}")
            # grouped convs execute zero-embedded over the FULL input
            # channels (kh*kw*c_in_per_group*groups) — record that K
            c_in = int(np.prod(w.shape[:-1])) * groups

        perm = stable_perm(assign)
        bounds = split_points(assign[perm], n_domains)
        layer_tuning = tuning.get(name, tuning.get("*"))
        # the ops clamp the N-block to min(bn, max(128, n)); align with the
        # SAME effective block so the plan records what actually executes
        bn = int((layer_tuning or {}).get("bn", block_n))
        bn_eff = min(bn, max(128, c_out)) if c_out else bn
        aligned = [min(align_boundary(b, bn_eff),
                       align_boundary(c_out, bn_eff)) for b in bounds]
        if any(b2 < b1 for b1, b2 in zip(aligned, aligned[1:])):
            raise LoweringError(f"layer {name!r}: aligned boundaries "
                                f"{aligned} are not monotone")

        kernel, note = select_kernel(counts, domain_bits)
        if note:
            # fallback reasons reach users via plan JSON / coverage reports
            # far from the artifact: carry the layer context in the string
            note = f"{name}: {note}"
        if strict and note:
            raise LoweringError(f"layer {note}")

        w_ls, act_ls = _layer_scales(art_layer, node)
        if w_ls is None and _is_concrete(w):
            ls = float(quant.init_log_scale(np.asarray(w, dtype=np.float32)))
            w_ls = [ls] * n_domains

        layers.append(LayerPlan(
            name=name, kernel=kernel, c_in=c_in, c_out=c_out, perm=perm,
            counts=counts, boundaries=[int(b) for b in bounds],
            aligned_boundaries=[int(b) for b in aligned],
            w_log_scales=w_ls, act_log_scale=act_ls,
            searchable=bool(art_layer.get("searchable", True)), note=note,
            tuning=(dict(layer_tuning) if layer_tuning else None),
            groups=groups))

    return ExecutionPlan(model=art.get("model", "unknown"), domains=domains,
                         layers=layers, platform=art.get("platform"),
                         block_n=block_n)


# --------------------------------------------------------------------------
# CLI: mapping.json -> plan.json
# --------------------------------------------------------------------------

def _lm_param_shapes(arch: str, reduce: bool):
    """ShapeDtypeStruct pytree of an LM's params (cheap: jax.eval_shape)."""
    import jax
    from repro.configs import base as cfgbase
    from repro.models import transformer as T
    cfgbase.load_all()
    cfg = cfgbase.get(arch)
    if reduce:
        cfg = cfgbase.reduce_for_smoke(cfg)
    return jax.eval_shape(lambda k: T.init_lm(k, cfg),
                          jax.random.PRNGKey(0))


def main(argv=None):
    import argparse
    import json
    import sys
    from pathlib import Path

    ap = argparse.ArgumentParser(
        description="lower a mapping artifact to an execution plan")
    ap.add_argument("artifact", help="mapping artifact JSON (repro.api)")
    ap.add_argument("--out", default=None, help="plan JSON output path")
    ap.add_argument("--arch", default=None,
                    help="validate against this LM arch's weight shapes")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--block-n", type=int, default=128)
    ap.add_argument("--strict", action="store_true",
                    help="fail on fp capability fallbacks")
    args = ap.parse_args(argv)

    artifact = json.loads(Path(args.artifact).read_text())
    params = (_lm_param_shapes(args.arch, args.reduce)
              if args.arch else None)
    try:
        plan = lower(artifact, params=params, block_n=args.block_n,
                     strict=args.strict)
    except LoweringError as e:
        print(f"[lower] ERROR: {e}", file=sys.stderr)
        sys.exit(2)
    print(f"[lower] {plan.summary()}")
    for lp in plan.layers:
        extra = f"  ({lp.note})" if lp.note else ""
        print(f"[lower]   {lp.name}: {lp.kernel} counts={lp.counts} "
              f"aligned={lp.aligned_boundaries}{extra}")
    if args.out:
        plan.save(args.out)
        print(f"[lower] wrote {args.out}")
    return plan


if __name__ == "__main__":
    main()
