"""repro.runtime — mapping-execution runtime.

Turns a `repro.api.MappingArtifact` (the *metadata* a search produces) into
an *executable object*: an `ExecutionPlan` whose per-layer entries record the
Fig. 3 channel permutation, the block-aligned domain boundaries, the quant
scales and the chosen kernel, plus executors that run a planned layer through
the matching Pallas kernel (interpret mode on CPU).

    artifact = MappingArtifact.load("mapping.json")
    plan     = lower(artifact, params=params)        # compile
    backend  = PlannedBackend(plan, params)          # bind to weights
    with matmul_backend(backend):                    # execute
        logits = model_apply(params, x)

`lower` validates the artifact against real weight shapes, reuses
`core.discretize.stable_perm`/`split_points` for the reorg and the
`kernels.ops` block-alignment rule, and picks one kernel per layer:
``split_precision`` (fused int8+bf16), ``quant_matmul`` (w8a8),
``ternary_matmul`` (AIMC analogue) or ``fp`` (identity fallback, with the
reason recorded in ``LayerPlan.note``).

This package never imports `repro.api` (artifacts are duck-typed via
``to_dict``), so `repro.api` can re-export `lower`/`ExecutionPlan` as the
public entry points without an import cycle.
"""
from repro.runtime.plan import (KERNEL_FP, KERNEL_QUANT, KERNEL_SPLIT,
                                KERNEL_TERNARY, KERNELS, ExecutionPlan,
                                LayerPlan, LoweringError)
from repro.runtime.lower import lower, resolve_layer_params
from repro.runtime.execute import (PlannedBackend, PreparedLayer,
                                   execute_layer, prepare_layer,
                                   reference_layer)

__all__ = [
    "ExecutionPlan", "LayerPlan", "LoweringError", "PlannedBackend",
    "PreparedLayer", "KERNELS", "KERNEL_FP", "KERNEL_QUANT", "KERNEL_SPLIT",
    "KERNEL_TERNARY", "execute_layer", "lower", "prepare_layer",
    "reference_layer", "resolve_layer_params",
]
