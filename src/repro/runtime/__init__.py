"""repro.runtime — mapping-execution runtime.

Turns a `repro.api.MappingArtifact` (the *metadata* a search produces) into
an *executable object*: an `ExecutionPlan` whose per-layer entries record the
Fig. 3 channel permutation, the block-aligned domain boundaries, the quant
scales and the chosen kernel, plus executors that run a planned layer through
the matching Pallas kernel (interpret mode on CPU).

    artifact = MappingArtifact.load("mapping.json")
    plan     = lower(artifact, params=params)        # compile
    backend  = PlannedBackend(plan, params)          # bind, keyed by name
    with matmul_backend(backend):                    # execute (jit-safe)
        logits = jax.jit(model_apply)(params, x)

`lower` validates the artifact against real weight shapes, reuses
`core.discretize.stable_perm`/`split_points` for the reorg and the
`kernels.ops` block-alignment rule, and picks one kernel per layer:
``split_precision`` (fused int8+bf16), ``quant_matmul`` (w8a8),
``ternary_matmul`` (AIMC analogue) or ``fp`` (identity fallback, with the
reason recorded in ``LayerPlan.note``).  Layer names are pytree paths; 4-D
HWIO conv weights lower too (executed via im2col), and ``base@r`` names
address repeat ``r`` of scan-stacked weights — `PlannedBackend` stacks those
per repeat and indexes them inside the jitted layer scan.

Errors split by phase: `LoweringError` (the artifact cannot be compiled
onto the model/kernels) vs `ExecutionError` (a lowered plan cannot bind or
execute — wrong weights, missing scan index, unsupported conv).

This package never imports `repro.api` (artifacts are duck-typed via
``to_dict``), so `repro.api` can re-export `lower`/`ExecutionPlan` as the
public entry points without an import cycle.
"""
from repro.runtime.plan import (KERNEL_FP, KERNEL_QUANT, KERNEL_SPLIT,
                                KERNEL_SPLIT_TERNARY, KERNEL_TERNARY,
                                KERNELS, ExecutionPlan, LayerPlan,
                                LoweringError)
from repro.runtime.registry import (KernelCapability, capability_matrix,
                                    kernel_for, register_kernel,
                                    unregister_kernel)
from repro.runtime.lower import lower, resolve_layer_params
from repro.runtime.execute import (ExecutionError, PlannedBackend, PlanSet,
                                   PreparedLayer, execute_conv_layer,
                                   execute_layer, im2col, prepare_layer,
                                   prepared_nbytes, reference_layer)

__all__ = [
    "ExecutionError", "ExecutionPlan", "KernelCapability", "LayerPlan",
    "LoweringError", "PlanSet", "PlannedBackend", "PreparedLayer", "KERNELS",
    "KERNEL_FP", "KERNEL_QUANT", "KERNEL_SPLIT", "KERNEL_SPLIT_TERNARY",
    "KERNEL_TERNARY", "capability_matrix", "execute_conv_layer",
    "execute_layer", "im2col", "kernel_for", "lower", "prepare_layer",
    "prepared_nbytes", "reference_layer", "register_kernel",
    "resolve_layer_params", "unregister_kernel",
]
