"""Per-layer executors for `ExecutionPlan`s.

`prepare_layer` binds one `LayerPlan` to a concrete weight: it applies the
plan's channel permutation, quantizes the weight stream PER DOMAIN with the
plan's scales (each active quantized domain's columns carry that domain's
own log-scale/step; max-abs fallback when the plan was lowered without
scales), and packages everything the kernels need.  Both 2-D dense weights
and 4-D HWIO conv weights bind — conv weights are flattened to
``(kh*kw*c_in, c_out)`` and executed through `execute_conv_layer`, which
im2cols the NHWC input so CNN artifacts run through the same split-precision
/ quant Pallas kernels as dense layers.

`execute_layer` runs an input through the matching Pallas kernel —
interpret mode on CPU — or through the pure-jnp reference oracle
(``reference=True``), always returning outputs in the ORIGINAL channel
order (the inverse permutation is applied, mirroring
`kernels.ops.odimo_deployed_dense`; the full Fig. 3 reorg removes it by
rewriting the next layer's input channels).

`PlannedBackend` binds a whole plan to a params pytree and implements the
NAME-KEYED matmul-backend protocol of `repro.models`
(``backend(name, p, x, conv=...) -> y | None``): plans are resolved by the
layer's pytree path — a static string — so planned execution traces cleanly
under ``jax.jit`` (weights may be tracers; the prepared arrays are baked
into the trace as constants).  Scan-stacked plans (``base@r`` layer names)
are stacked per repeat and indexed inside the scan body with the index
published by ``repro.models._backend.scan_slot``; repeats with heterogeneous
kernels/boundaries dispatch through ``jax.lax.switch`` instead.  Install it
with ``repro.models.managed.matmul_backend(backend)`` and every managed/LM
dense or conv whose layer the plan covers executes through its planned
kernel, bias included — no model code forks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels import ops, ref
from repro.models import _backend
from repro.runtime.lower import _layer_weight, _walk_path
from repro.runtime.plan import (KERNEL_FP, KERNEL_QUANT, KERNEL_SPLIT,
                                KERNEL_TERNARY, ExecutionPlan, LayerPlan)


class ExecutionError(RuntimeError):
    """A planned layer cannot be executed as lowered."""


@dataclasses.dataclass
class PreparedLayer:
    """A `LayerPlan` bound to concrete arrays, ready to execute."""
    plan: LayerPlan
    inv: np.ndarray                  # inverse channel permutation
    w_perm: jax.Array | None         # permuted weights, original dtype (K, N)
                                     # (None for stacked quant/ternary slices
                                     # — those kernels never read it)
    b: jax.Array | None              # bias, ORIGINAL channel order
    w_q: jax.Array | None            # int8 codes, permuted (quantized paths)
    sw: jax.Array | None             # (N,) per-column dequant step, f32
    act_log_scale: float | None      # None -> dynamic max-abs per call
    block_n: int = 128               # N-block the plan was aligned with
    conv_shape: Tuple[int, ...] | None = None  # HWIO shape of a conv weight

    @property
    def kernel(self) -> str:
        return self.plan.kernel


def _quant_domain(lp: LayerPlan, domain_bits: List[int]) -> int:
    """Index of the first active quantized domain (drives the codes of any
    identity-domain columns that execute in int8 through block padding)."""
    active = lp.active_domains()
    quantized = [i for i in active if domain_bits[i] < 16]
    if not quantized:
        raise ExecutionError(f"{lp.name}: no quantized domain for kernel "
                             f"{lp.kernel}")
    return quantized[0]


def _per_column_quant(lp: LayerPlan, wf: jax.Array,
                      domain_bits: List[int]) -> Tuple[jax.Array, jax.Array]:
    """(w_q int8 codes, sw (N,) f32 steps) in PERMUTED column order, built
    per domain: each active quantized domain's columns are quantized with
    that domain's own ``w_log_scales`` entry and bit-width, so multi-
    quantized-domain plans (e.g. 3-domain ``gap9_like``) dequantize every
    column with the right step.  Identity (>=16-bit) columns inherit the
    driving quantized domain's codes — conservative for the block-aligned
    extra columns the split kernel executes in int8."""
    drive = _quant_domain(lp, domain_bits)
    if lp.w_log_scales is not None:
        ls_of = lambda d: float(lp.w_log_scales[d])
    else:  # lowered without scales: max-abs of the bound weight
        ls = float(quant.init_log_scale(wf))
        ls_of = lambda d: ls
    bits_of = lambda d: (2 if lp.kernel == KERNEL_TERNARY
                         else min(int(domain_bits[d]), 8))
    col_ls = np.zeros(lp.c_out, np.float32)
    col_levels = np.ones(lp.c_out, np.float32)
    start = 0
    for d, c in enumerate(lp.counts):
        if c:
            src = d if domain_bits[d] < 16 else drive
            col_ls[start:start + c] = ls_of(src)
            col_levels[start:start + c] = quant.qlevels(bits_of(src))
        start += c
    scale = jnp.asarray(np.exp(col_ls))
    levels = jnp.asarray(col_levels)
    w_q = jnp.round(jnp.clip(wf / scale[None, :], -1.0, 1.0) *
                    levels[None, :]).astype(jnp.int8)
    sw = (scale / levels).astype(jnp.float32)
    return w_q, sw


def prepare_layer(lp: LayerPlan, w, b=None,
                  domain_bits: List[int] | None = None,
                  block_n: int = 128) -> PreparedLayer:
    """Bind ``lp`` to a concrete weight (+ optional bias): a 2-D
    (C_in, C_out) dense matrix or a 4-D (kh, kw, C_in, C_out) HWIO conv
    kernel (flattened to ``(kh*kw*C_in, C_out)``; run conv layers through
    `execute_conv_layer`)."""
    ndim = getattr(w, "ndim", 0)
    if ndim not in (2, 4):
        raise ExecutionError(f"{lp.name}: planned execution covers 2-D "
                             f"(dense) and 4-D (HWIO conv) weights, got "
                             f"shape {tuple(getattr(w, 'shape', ()))}")
    if int(w.shape[-1]) != lp.c_out:
        raise ExecutionError(f"{lp.name}: weight has {int(w.shape[-1])} "
                             f"output channels, plan expects {lp.c_out}")
    conv_shape = tuple(int(s) for s in w.shape) if ndim == 4 else None
    w2 = jnp.asarray(w).reshape(-1, int(w.shape[-1]))
    if domain_bits is None:
        domain_bits = [8] * len(lp.counts)
    w_perm = jnp.take(w2, lp.perm, axis=-1)
    w_q = sw = None
    if lp.kernel in (KERNEL_QUANT, KERNEL_TERNARY, KERNEL_SPLIT):
        w_q, sw = _per_column_quant(lp, w_perm.astype(jnp.float32),
                                    domain_bits)
    return PreparedLayer(plan=lp, inv=lp.inv_perm(), w_perm=w_perm,
                         b=(jnp.asarray(b) if b is not None else None),
                         w_q=w_q, sw=sw, act_log_scale=lp.act_log_scale,
                         block_n=block_n, conv_shape=conv_shape)


def _act_quant(xf: jax.Array, act_log_scale):
    """(x_q int8, sx step); dynamic max-abs when no scale was lowered (the
    v1-artifact migration path)."""
    if act_log_scale is not None:
        xl = jnp.asarray(act_log_scale, jnp.float32)
    else:
        xl = jnp.log(jnp.maximum(jnp.max(jnp.abs(xf)), 1e-8))
    x_q = quant.quantize_int(xf, xl, 8)
    sx = (jnp.exp(xl) / quant.qlevels(8)).astype(jnp.float32)
    return x_q, sx


def execute_layer(prep: PreparedLayer, x, *, interpret=None,
                  reference: bool = False) -> jax.Array:
    """Run ``x (..., C_in)`` through the prepared layer's kernel; returns
    ``(..., C_out)`` in the original channel order, bias applied, in
    ``x.dtype``.  ``reference=True`` routes through the pure-jnp oracles
    (`kernels.ref`) instead of the Pallas kernels — the bit-tolerance
    reference path.  Jit-safe: ``x`` (and the prepared arrays, for stacked
    repeats) may be tracers."""
    lp = prep.plan
    wk = prep.w_perm if prep.w_perm is not None else prep.w_q
    if int(x.shape[-1]) != int(wk.shape[-2]):
        raise ExecutionError(f"{lp.name}: input has {int(x.shape[-1])} "
                             f"features, weight expects "
                             f"{int(wk.shape[-2])}")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xf = x2.astype(jnp.float32)

    if lp.kernel == KERNEL_FP:
        y = xf @ prep.w_perm.astype(jnp.float32)
    elif lp.kernel in (KERNEL_QUANT, KERNEL_TERNARY):
        x_q, sx = _act_quant(xf, prep.act_log_scale)
        if reference:
            fn = (ref.ternary_matmul_ref if lp.kernel == KERNEL_TERNARY
                  else ref.quant_matmul_ref)
            y = fn(x_q, prep.w_q, sx, prep.sw)
        else:
            fn = (ops.ternary_matmul_op if lp.kernel == KERNEL_TERNARY
                  else ops.quant_matmul_op)
            y = fn(x_q, prep.w_q, sx, prep.sw, interpret=interpret)
    elif lp.kernel == KERNEL_SPLIT:
        x_q, sx = _act_quant(xf, prep.act_log_scale)
        xb = x2.astype(jnp.bfloat16)
        wb = prep.w_perm.astype(jnp.bfloat16)
        boundary = lp.split_boundary()
        # the op clamps the N-block to min(bn, max(128, n)) and rounds the
        # boundary up to it; the oracle must split at the same column
        bn_eff = min(prep.block_n, max(128, lp.c_out))
        if reference:
            y = ref.split_precision_matmul_ref(
                xb, x_q, sx, wb, prep.w_q, prep.sw,
                ops.align_boundary(boundary, bn_eff))
        else:
            y = ops.split_precision_op(xb, x_q, sx, wb, prep.w_q, prep.sw,
                                       boundary, bn=prep.block_n,
                                       interpret=interpret)
    else:  # pragma: no cover - __post_init__ rejects unknown kernels
        raise ExecutionError(f"{lp.name}: unknown kernel {lp.kernel}")

    y = jnp.take(y, jnp.asarray(prep.inv), axis=-1)
    if prep.b is not None:
        y = y + prep.b.astype(y.dtype)
    return y.reshape(*lead, lp.c_out).astype(x.dtype)


# --------------------------------------------------------------------------
# Conv execution: im2col onto the dense kernels
# --------------------------------------------------------------------------

def _same_pads(size: int, k: int, stride: int) -> Tuple[int, int, int]:
    out = -(-size // stride)
    pad = max((out - 1) * stride + k - size, 0)
    return out, pad // 2, pad - pad // 2


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           padding: str = "SAME") -> jax.Array:
    """NHWC input -> (B, OH, OW, kh*kw*C) patches whose last axis matches a
    flattened HWIO conv weight ``w.reshape(kh*kw*C, C_out)`` (row-major
    (kh, kw, C) order), with XLA's SAME/VALID padding semantics — so
    ``im2col(x) @ w_flat == lax.conv_general_dilated(x, w)``."""
    B, H, W, C = x.shape
    if padding == "SAME":
        oh, pt, pb = _same_pads(H, kh, stride)
        ow, pl, pr = _same_pads(W, kw, stride)
        x = jnp.pad(x, ((0, 0), (pt, pb), (pl, pr), (0, 0)))
    elif padding == "VALID":
        oh = (H - kh) // stride + 1
        ow = (W - kw) // stride + 1
    else:
        raise ExecutionError(f"unsupported conv padding {padding!r}")
    if oh < 1 or ow < 1:
        raise ExecutionError(f"conv kernel ({kh}x{kw}) exceeds input "
                             f"({H}x{W}) under {padding} padding")
    cols = [x[:, i:i + (oh - 1) * stride + 1:stride,
              j:j + (ow - 1) * stride + 1:stride, :]
            for i in range(kh) for j in range(kw)]
    return jnp.concatenate(cols, axis=-1)


def execute_conv_layer(prep: PreparedLayer, x, stride: int = 1,
                       padding: str = "SAME", *, interpret=None,
                       reference: bool = False) -> jax.Array:
    """Run an NHWC input through a prepared CONV layer: im2col the input to
    ``(B, OH, OW, kh*kw*C_in)`` patches and execute them through the layer's
    planned dense kernel (groups == 1 only)."""
    if prep.conv_shape is None:
        raise ExecutionError(f"{prep.plan.name}: not a conv layer (bound "
                             f"weight was 2-D)")
    kh, kw, ci, _ = prep.conv_shape
    if int(x.shape[-1]) != ci:
        raise ExecutionError(f"{prep.plan.name}: input has "
                             f"{int(x.shape[-1])} channels, conv weight "
                             f"expects {ci}")
    patches = im2col(x, kh, kw, stride=stride, padding=padding)
    return execute_layer(prep, patches, interpret=interpret,
                         reference=reference)


def reference_layer(prep: PreparedLayer, x) -> jax.Array:
    """Full-precision oracle: ``x @ w + b`` on the ORIGINAL weight order
    (the parity target planned execution is pinned against)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    w = jnp.take(prep.w_perm, jnp.asarray(prep.inv), axis=-1)
    y = x2 @ w.astype(jnp.float32)
    if prep.b is not None:
        y = y + prep.b.astype(y.dtype)
    return y.reshape(*lead, prep.plan.c_out).astype(x.dtype)


# --------------------------------------------------------------------------
# Scan-stacked prepared layers
# --------------------------------------------------------------------------

def _stack_key(prep: PreparedLayer):
    """Repeats can share one stacked execution only when everything STATIC
    about their kernels agrees — arrays may differ, trace structure may
    not."""
    lp = prep.plan
    return (lp.kernel, lp.c_in, lp.c_out, tuple(lp.counts),
            tuple(lp.aligned_boundaries), prep.block_n, prep.conv_shape,
            prep.b is None, prep.act_log_scale is None)


class _StackedPrepared:
    """Homogeneous per-repeat `PreparedLayer`s stacked on a leading R axis;
    ``at(r)`` slices repeat ``r`` (r may be a traced scan index — this is
    what executes scan-stacked LM layers inside the jitted layer scan)."""

    def __init__(self, preps: List[PreparedLayer]):
        p0 = preps[0]
        self.plan, self.block_n = p0.plan, p0.block_n
        self.conv_shape = p0.conv_shape
        st = lambda get: (None if get(p0) is None
                          else jnp.stack([jnp.asarray(get(p)) for p in preps]))
        self._inv = jnp.stack([jnp.asarray(p.inv) for p in preps])
        # quant/ternary kernels never read the fp weights — stacking them
        # would hold R full-precision copies next to the int8 codes
        self._w_perm = (st(lambda p: p.w_perm)
                        if p0.plan.kernel in (KERNEL_SPLIT, KERNEL_FP)
                        else None)
        self._b = st(lambda p: p.b)
        self._w_q = st(lambda p: p.w_q)
        self._sw = st(lambda p: p.sw)
        self._act = (None if p0.act_log_scale is None else
                     jnp.asarray([p.act_log_scale for p in preps],
                                 jnp.float32))

    def at(self, r) -> PreparedLayer:
        take = lambda a: None if a is None else jnp.take(a, r, axis=0)
        return PreparedLayer(
            plan=self.plan, inv=take(self._inv), w_perm=take(self._w_perm),
            b=take(self._b), w_q=take(self._w_q), sw=take(self._sw),
            act_log_scale=(None if self._act is None
                           else jnp.take(self._act, r)),
            block_n=self.block_n, conv_shape=self.conv_shape)

    def execute(self, x, r, conv=None, *, interpret=None, reference=False):
        prep = self.at(r)
        if conv is not None:
            return execute_conv_layer(prep, x, conv["stride"],
                                      conv["padding"], interpret=interpret,
                                      reference=reference)
        return execute_layer(prep, x, interpret=interpret,
                             reference=reference)


class _SwitchPrepared:
    """Heterogeneous per-repeat `PreparedLayer`s (different kernels or
    boundaries across repeats): a traced scan index dispatches through
    ``jax.lax.switch`` — every repeat's kernel is traced once, none fall
    back to fp."""

    def __init__(self, preps: List[PreparedLayer]):
        # mirror _StackedPrepared: quant/ternary repeats never read the fp
        # weights, so don't keep their (K, N) float copies alive
        self.preps = [dataclasses.replace(p, w_perm=None)
                      if p.plan.kernel in (KERNEL_QUANT, KERNEL_TERNARY)
                      else p for p in preps]
        self.conv_shape = preps[0].conv_shape

    def execute(self, x, r, conv=None, *, interpret=None, reference=False):
        def run(prep, xx):
            if conv is not None:
                return execute_conv_layer(prep, xx, conv["stride"],
                                          conv["padding"],
                                          interpret=interpret,
                                          reference=reference)
            return execute_layer(prep, xx, interpret=interpret,
                                 reference=reference)
        if not isinstance(r, jax.core.Tracer):
            return run(self.preps[int(r)], x)
        branches = [lambda xx, p=p: run(p, xx) for p in self.preps]
        return jax.lax.switch(jnp.asarray(r, jnp.int32), branches, x)


# --------------------------------------------------------------------------
# Pluggable matmul backend over a whole plan
# --------------------------------------------------------------------------

class PlannedBackend:
    """Binds an `ExecutionPlan` to a params pytree and serves the NAME-KEYED
    `repro.models` matmul-backend protocol: ``backend(name, p, x, conv=...)``
    resolves the layer's plan by ``name`` — the layer's pytree path, a
    static string — at TRACE time, so ``serve.py --mapping`` jits prefill/
    decode with planned kernels executing inside the trace (the prepared
    weights are baked in as constants; the traced ``p`` is ignored).

    Layers resolve exactly like `lower()` resolves them (handle plan order,
    or artifact layer names as params paths).  ``base@r`` names (scan-
    stacked weights) are grouped per base: homogeneous repeats stack into
    one `_StackedPrepared` indexed by the scan index published via
    ``repro.models._backend.scan_slot``; heterogeneous repeats dispatch
    through ``lax.switch``.  ``bound``/``unbound`` record the bind-time
    coverage split (per artifact layer name, ``@r`` included);
    ``runtime_declines`` records trace-time declines (e.g. grouped convs).
    Calls that name-match a plan but cannot execute it raise
    `ExecutionError` — never a silent fp fallback.
    """

    def __init__(self, plan: ExecutionPlan, params, handle=None, *,
                 interpret=None, reference: bool = False):
        self.plan = plan
        self.interpret = interpret
        self.reference = reference
        domain_bits = [int(d["weight_bits"]) for d in plan.domains]
        if handle is not None:
            dicts = handle.layers(params)
            if len(dicts) != len(plan.layers):
                raise ExecutionError(
                    f"handle resolves {len(dicts)} managed layers but the "
                    f"plan has {len(plan.layers)}")
            resolved = list(zip(plan.layers, dicts))
        else:
            resolved = [(lp, _walk_path(params, lp.name))
                        for lp in plan.layers]
        self._by_name: Dict[str, Any] = {}
        self.bound: List[str] = []
        self.unbound: List[str] = []
        self.runtime_declines: Dict[str, str] = {}
        stacked: Dict[str, List[Tuple[int, LayerPlan, Any]]] = {}
        for lp, node in resolved:
            base, _, rep = lp.name.partition("@")
            if rep:
                stacked.setdefault(base, []).append((int(rep), lp, node))
                continue
            prep = self._try_prepare(lp, node, domain_bits)
            if prep is None:
                self.unbound.append(lp.name)
            else:
                self._by_name[lp.name] = prep
                self.bound.append(lp.name)
        for base, entries in sorted(stacked.items()):
            entries.sort(key=lambda e: e[0])
            reps = [r for r, _, _ in entries]
            if reps != list(range(len(reps))):
                raise ExecutionError(
                    f"{base}: stacked plan repeats {reps} are not the "
                    f"contiguous range 0..{len(reps) - 1}")
            if handle is None:
                # a plan covering FEWER repeats than the model's stack would
                # index out of range inside the scan (NaN fill) — reject at
                # bind time instead
                stack_w = _layer_weight(_walk_path(params, base))
                if getattr(stack_w, "ndim", 0) in (3, 5) and \
                        int(stack_w.shape[0]) != len(reps):
                    raise ExecutionError(
                        f"{base}: plan covers {len(reps)} repeats but the "
                        f"stacked weight carries {int(stack_w.shape[0])} — "
                        f"the artifact does not match this model's layer "
                        f"stack")
            preps = [self._try_prepare(lp, node, domain_bits)
                     for _, lp, node in entries]
            if any(p is None for p in preps):
                self.unbound.extend(lp.name for _, lp, _ in entries)
                continue
            if len({_stack_key(p) for p in preps}) == 1:
                self._by_name[base] = _StackedPrepared(preps)
            else:
                self._by_name[base] = _SwitchPrepared(preps)
            self.bound.extend(lp.name for _, lp, _ in entries)

    def _try_prepare(self, lp: LayerPlan, node, domain_bits):
        w = _layer_weight(node)
        if not isinstance(node, dict) or getattr(w, "ndim", 0) not in (2, 4) \
                or isinstance(w, jax.ShapeDtypeStruct):
            return None
        return prepare_layer(lp, w, b=node.get("b"), domain_bits=domain_bits,
                             block_n=self.plan.block_n)

    def __call__(self, name, p, x, *, conv=None):
        """Matmul-backend hook: resolve ``name`` to a prepared plan; returns
        the planned output (bias applied) or None to decline (unknown /
        unnamed layer, or an unsupported conv).  ``conv`` carries the call
        site's ``{"stride", "padding", "groups"}`` for conv layers."""
        if name is None:
            return None
        entry = self._by_name.get(name)
        if entry is None:
            return None
        conv_shape = entry.conv_shape
        if conv is not None and conv_shape is None:
            raise ExecutionError(
                f"{name}: conv call site but the plan bound a 2-D dense "
                f"weight — the artifact does not match this model")
        if conv is None and conv_shape is not None:
            raise ExecutionError(
                f"{name}: dense call site but the plan bound a conv weight "
                f"— the artifact does not match this model")
        if conv is not None and conv.get("groups", 1) != 1:
            # trace-time decline, surfaced via runtime_declines (grouped /
            # depthwise convs have no im2col lowering yet)
            self.runtime_declines[name] = (
                f"grouped conv (groups={conv['groups']}) has no im2col "
                f"lowering; executed on the default path")
            return None
        if isinstance(entry, (_StackedPrepared, _SwitchPrepared)):
            r = _backend.current_scan_index()
            if r is None:
                raise ExecutionError(
                    f"{name}: scan-stacked plan executed outside a "
                    f"scan_slot context (no repeat index to select the "
                    f"prepared kernels)")
            return entry.execute(x, r, conv=conv, interpret=self.interpret,
                                 reference=self.reference)
        if conv is not None:
            return execute_conv_layer(entry, x, conv["stride"],
                                      conv["padding"],
                                      interpret=self.interpret,
                                      reference=self.reference)
        return execute_layer(entry, x, interpret=self.interpret,
                             reference=self.reference)

    @property
    def fully_covered(self) -> bool:
        return not self.unbound

    def coverage(self) -> str:
        return (f"{len(self.bound)}/{len(self.plan.layers)} planned layers "
                f"bound to weights, {len(self.unbound)} unbound")
