"""Per-layer executors for `ExecutionPlan`s.

`prepare_layer` binds one `LayerPlan` to a concrete weight: it applies the
plan's channel permutation, quantizes the weight stream with the plan's
scales (max-abs fallback when the plan was lowered without scales), and
packages everything the kernels need.  `execute_layer` then runs an input
through the matching Pallas kernel — interpret mode on CPU — or through the
pure-jnp reference oracle (``reference=True``), always returning outputs in
the ORIGINAL channel order (the inverse permutation is applied, mirroring
`kernels.ops.odimo_deployed_dense`; the full Fig. 3 reorg removes it by
rewriting the next layer's input channels).

`PlannedBackend` binds a whole plan to a params pytree and implements the
pluggable matmul-backend protocol of `repro.models` (``backend(p, x) -> y``
or ``None`` to decline): install it with
``repro.models.managed.matmul_backend(backend)`` and every managed/LM dense
whose weight the plan covers executes through its planned kernel, bias
included — no model code forks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels import ops, ref
from repro.runtime.lower import _layer_weight, _walk_path
from repro.runtime.plan import (KERNEL_FP, KERNEL_QUANT, KERNEL_SPLIT,
                                KERNEL_TERNARY, ExecutionPlan, LayerPlan,
                                LoweringError)


class ExecutionError(RuntimeError):
    """A planned layer cannot be executed as lowered."""


@dataclasses.dataclass
class PreparedLayer:
    """A `LayerPlan` bound to concrete arrays, ready to execute."""
    plan: LayerPlan
    inv: np.ndarray                  # inverse channel permutation
    w_perm: jax.Array                # permuted weights, original dtype (K, N)
    b: jax.Array | None              # bias, ORIGINAL channel order
    w_q: jax.Array | None            # int8 codes, permuted (quantized paths)
    sw: jax.Array | None             # (N,) per-column dequant step, f32
    act_log_scale: float | None      # None -> dynamic max-abs per call
    block_n: int = 128               # N-block the plan was aligned with

    @property
    def kernel(self) -> str:
        return self.plan.kernel


def _quant_domain(lp: LayerPlan, domain_bits: List[int]) -> int:
    """Index of the quantized domain whose scale drives the weight codes."""
    active = lp.active_domains()
    quantized = [i for i in active if domain_bits[i] < 16]
    if not quantized:
        raise ExecutionError(f"{lp.name}: no quantized domain for kernel "
                             f"{lp.kernel}")
    return quantized[0]


def prepare_layer(lp: LayerPlan, w, b=None,
                  domain_bits: List[int] | None = None,
                  block_n: int = 128) -> PreparedLayer:
    """Bind ``lp`` to a concrete (C_in, C_out) weight (+ optional bias)."""
    if getattr(w, "ndim", 0) != 2:
        raise ExecutionError(f"{lp.name}: planned execution covers 2-D "
                             f"(dense) weights, got shape "
                             f"{tuple(getattr(w, 'shape', ()))}")
    if int(w.shape[-1]) != lp.c_out:
        raise ExecutionError(f"{lp.name}: weight has {int(w.shape[-1])} "
                             f"output channels, plan expects {lp.c_out}")
    if domain_bits is None:
        domain_bits = [8] * len(lp.counts)
    w_perm = jnp.take(jnp.asarray(w), lp.perm, axis=-1)
    w_q = sw = None
    if lp.kernel in (KERNEL_QUANT, KERNEL_TERNARY, KERNEL_SPLIT):
        dom = _quant_domain(lp, domain_bits)
        bits = 2 if lp.kernel == KERNEL_TERNARY else min(domain_bits[dom], 8)
        if lp.w_log_scales is not None:
            ls = jnp.asarray(lp.w_log_scales[dom], jnp.float32)
        else:  # lowered without scales: max-abs of the bound weight
            ls = quant.init_log_scale(w_perm.astype(jnp.float32))
        wf = w_perm.astype(jnp.float32)
        # the whole (padded) matrix carries codes so block-aligned extra
        # columns of the split kernel execute conservatively in int8
        w_q = quant.quantize_int(wf, ls, bits)
        step = jnp.exp(ls) / quant.qlevels(bits)
        sw = jnp.full((lp.c_out,), step, jnp.float32)
    return PreparedLayer(plan=lp, inv=lp.inv_perm(), w_perm=w_perm,
                         b=(jnp.asarray(b) if b is not None else None),
                         w_q=w_q, sw=sw, act_log_scale=lp.act_log_scale,
                         block_n=block_n)


def _act_quant(xf: jax.Array, act_log_scale: float | None):
    """(x_q int8, sx step, xl log-scale); dynamic max-abs when no scale was
    lowered (the v1-artifact migration path)."""
    if act_log_scale is not None:
        xl = jnp.asarray(act_log_scale, jnp.float32)
    else:
        xl = jnp.log(jnp.maximum(jnp.max(jnp.abs(xf)), 1e-8))
    x_q = quant.quantize_int(xf, xl, 8)
    sx = (jnp.exp(xl) / quant.qlevels(8)).astype(jnp.float32)
    return x_q, sx


def execute_layer(prep: PreparedLayer, x, *, interpret=None,
                  reference: bool = False) -> jax.Array:
    """Run ``x (..., C_in)`` through the prepared layer's kernel; returns
    ``(..., C_out)`` in the original channel order, bias applied, in
    ``x.dtype``.  ``reference=True`` routes through the pure-jnp oracles
    (`kernels.ref`) instead of the Pallas kernels — the bit-tolerance
    reference path."""
    lp = prep.plan
    if int(x.shape[-1]) != int(prep.w_perm.shape[0]):
        raise ExecutionError(f"{lp.name}: input has {int(x.shape[-1])} "
                             f"features, weight expects "
                             f"{int(prep.w_perm.shape[0])}")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xf = x2.astype(jnp.float32)

    if lp.kernel == KERNEL_FP:
        y = xf @ prep.w_perm.astype(jnp.float32)
    elif lp.kernel in (KERNEL_QUANT, KERNEL_TERNARY):
        x_q, sx = _act_quant(xf, prep.act_log_scale)
        if reference:
            fn = (ref.ternary_matmul_ref if lp.kernel == KERNEL_TERNARY
                  else ref.quant_matmul_ref)
            y = fn(x_q, prep.w_q, sx, prep.sw)
        else:
            fn = (ops.ternary_matmul_op if lp.kernel == KERNEL_TERNARY
                  else ops.quant_matmul_op)
            y = fn(x_q, prep.w_q, sx, prep.sw, interpret=interpret)
    elif lp.kernel == KERNEL_SPLIT:
        x_q, sx = _act_quant(xf, prep.act_log_scale)
        xb = x2.astype(jnp.bfloat16)
        wb = prep.w_perm.astype(jnp.bfloat16)
        boundary = lp.split_boundary()
        # the op clamps the N-block to min(bn, max(128, n)) and rounds the
        # boundary up to it; the oracle must split at the same column
        bn_eff = min(prep.block_n, max(128, lp.c_out))
        if reference:
            y = ref.split_precision_matmul_ref(
                xb, x_q, sx, wb, prep.w_q, prep.sw,
                ops.align_boundary(boundary, bn_eff))
        else:
            y = ops.split_precision_op(xb, x_q, sx, wb, prep.w_q, prep.sw,
                                       boundary, bn=prep.block_n,
                                       interpret=interpret)
    else:  # pragma: no cover - __post_init__ rejects unknown kernels
        raise ExecutionError(f"{lp.name}: unknown kernel {lp.kernel}")

    y = jnp.take(y, jnp.asarray(prep.inv), axis=-1)
    if prep.b is not None:
        y = y + prep.b.astype(y.dtype)
    return y.reshape(*lead, lp.c_out).astype(x.dtype)


def reference_layer(prep: PreparedLayer, x) -> jax.Array:
    """Full-precision oracle: ``x @ w + b`` on the ORIGINAL weight order
    (the parity target planned execution is pinned against)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    w = jnp.take(prep.w_perm, jnp.asarray(prep.inv), axis=-1)
    y = x2 @ w.astype(jnp.float32)
    if prep.b is not None:
        y = y + prep.b.astype(y.dtype)
    return y.reshape(*lead, prep.plan.c_out).astype(x.dtype)


# --------------------------------------------------------------------------
# Pluggable matmul backend over a whole plan
# --------------------------------------------------------------------------

class PlannedBackend:
    """Binds an `ExecutionPlan` to a params pytree and serves the
    `repro.models` matmul-backend protocol.

    Layers resolve exactly like `lower()` resolves them (handle plan order,
    or artifact layer names as params paths); each resolved 2-D weight leaf
    is prepared once and thereafter matched BY IDENTITY inside
    ``dense(p, x)`` — stacked/scanned weights (leaves that only exist as
    tracers inside a `jax.lax.scan` body) therefore never match and fall
    through to the caller's default path.  ``bound``/``unbound`` record the
    coverage split.
    """

    def __init__(self, plan: ExecutionPlan, params, handle=None, *,
                 interpret=None, reference: bool = False):
        self.plan = plan
        self.interpret = interpret
        self.reference = reference
        domain_bits = [int(d["weight_bits"]) for d in plan.domains]
        if handle is not None:
            dicts = handle.layers(params)
            if len(dicts) != len(plan.layers):
                raise LoweringError(
                    f"handle resolves {len(dicts)} managed layers but the "
                    f"plan has {len(plan.layers)}")
            resolved = list(zip(plan.layers, dicts))
        else:
            resolved = [(lp, _walk_path(params, lp.name))
                        for lp in plan.layers]
        self._by_id: Dict[int, PreparedLayer] = {}
        self.bound: List[str] = []
        self.unbound: List[str] = []
        for lp, node in resolved:
            w = _layer_weight(node)
            if not isinstance(node, dict) or getattr(w, "ndim", 0) != 2 \
                    or isinstance(w, jax.ShapeDtypeStruct):
                self.unbound.append(lp.name)
                continue
            prep = prepare_layer(lp, w, b=node.get("b"),
                                 domain_bits=domain_bits,
                                 block_n=plan.block_n)
            self._by_id[id(w)] = prep
            self.bound.append(lp.name)

    def __call__(self, p, x):
        """Matmul-backend hook: ``p`` is a dense param dict.  Returns the
        planned output (bias applied) or None to decline."""
        w = p.get("w") if isinstance(p, dict) else None
        prep = self._by_id.get(id(w)) if w is not None else None
        if prep is None:
            return None
        return execute_layer(prep, x, interpret=self.interpret,
                             reference=self.reference)

    def coverage(self) -> str:
        return (f"{len(self.bound)}/{len(self.plan.layers)} planned layers "
                f"bound to weights")
