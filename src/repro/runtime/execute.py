"""Per-layer executors for `ExecutionPlan`s.

`prepare_layer` binds one `LayerPlan` to a concrete weight and hoists
EVERYTHING per-call work can be hoisted out of: the plan's channel
permutation and its inverse (as a device array), per-domain weight
quantization with the plan's scales (each active quantized domain's columns
carry that domain's own log-scale/step; max-abs fallback when the plan was
lowered without scales), the bf16 weight cast of the split kernel, the
2-bit-packed ternary stream of the split_ternary kernel, the static
activation-quant scale/step, the block-aligned split boundary, and the
resolved kernel block sizes (``LayerPlan.tuning`` overrides threaded down
to the Pallas calls).  `execute_layer` itself only quantizes the
activations and calls the kernel — nothing about the weights is rebuilt
per call.  Both 2-D dense weights and 4-D HWIO conv weights bind — conv
weights are flattened to ``(kh*kw*c_in, c_out)`` and executed through
`execute_conv_layer`, which im2cols the NHWC input so CNN artifacts run
through the same fused Pallas kernels as dense layers.

`execute_layer` runs an input through the matching Pallas kernel —
interpret mode on CPU — or through the pure-jnp reference oracle
(``reference=True``), always returning outputs in the ORIGINAL channel
order (the inverse permutation is applied, mirroring
`kernels.ops.odimo_deployed_dense`; the full Fig. 3 reorg removes it by
rewriting the next layer's input channels).

`PlanSet` binds a BANK of plans — N `ExecutionPlan` variants of the same
weights (e.g. a ternary-heavy "draft" and an int8-heavy "target" mapping)
— to one params pytree and implements the NAME-KEYED matmul-backend
protocol of `repro.models`
(``backend(name, p, x, conv=...) -> y | None``): plans are resolved by the
layer's pytree path — a static string — so planned execution traces cleanly
under ``jax.jit`` (weights may be tracers; the prepared arrays are baked
into the trace as constants).  The active variant is the trace-static key
published via ``repro.models._backend.plan_variant`` (default variant
outside any context), and prepared weight buffers are DEDUPLICATED across
variants wherever a layer's (plan, weight, domain-bits, block) tuple
coincides — ``prepared_bytes()``/``memory_report()`` account for the
sharing.  `PlannedBackend` is the single-variant special case (the
original API).  Scan-stacked plans (``base@r`` layer names) are GROUPED by
their static stack key: repeats whose kernels/boundaries/blocks agree
stack on a leading axis and execute as one gather indexed by the scan
index published by ``repro.models._backend.scan_slot``; a heterogeneous
stack dispatches ``jax.lax.switch`` over its GROUPS (G <= R branches)
rather than over every repeat — ``stack_mode="switch"`` restores the
one-branch-per-repeat dispatch as a benchmark baseline.  Install the
backend with ``repro.models.managed.matmul_backend(backend)`` and every
managed/LM dense or conv whose layer the plan covers executes through its
planned kernel, bias included — no model code forks.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.kernels import ops, ref
from repro.kernels.ternary_packed import pack_ternary
from repro.models import _backend
from repro.runtime.lower import _layer_weight, _walk_path
from repro.runtime.plan import (KERNEL_FP, KERNEL_QUANT, KERNEL_SPLIT,
                                KERNEL_SPLIT_TERNARY, KERNEL_TERNARY,
                                ExecutionPlan, LayerPlan)

DEFAULT_BM, DEFAULT_BK = 128, 512


class ExecutionError(RuntimeError):
    """A planned layer cannot be executed as lowered."""


@dataclasses.dataclass
class PreparedLayer:
    """A `LayerPlan` bound to concrete arrays, ready to execute.

    Everything static or weight-derived is materialized here ONCE — per-call
    execution touches only the activations."""
    plan: LayerPlan
    inv: jax.Array                   # inverse channel permutation (device)
    w_perm: jax.Array | None         # permuted weights, original dtype (K, N)
                                     # (None for stacked quant/ternary slices
                                     # — those kernels never read it)
    b: jax.Array | None              # bias, ORIGINAL channel order
    w_q: jax.Array | None            # int8 codes, permuted (quantized paths)
    sw: jax.Array | None             # (N,) per-column dequant step, f32
    act_log_scale: float | None      # None -> dynamic max-abs per call
    block_n: int = 128               # N-block the plan was aligned with
    conv_shape: Tuple[int, ...] | None = None  # HWIO shape of a conv weight
    # ---- hoisted per-call state (derived; see prepare_layer) -------------
    w_bf16: jax.Array | None = None  # split kernel: bf16 cast of w_perm
    w_t_packed: jax.Array | None = None  # split_ternary: 2-bit-packed codes
    act_scale: jax.Array | None = None   # exp(act_log_scale), f32 scalar
    act_sx: jax.Array | None = None      # act dequant step, f32 scalar
    boundary: int = 0                # raw split boundary (static)
    blocks: Tuple[int, int, int] = (DEFAULT_BM, 128, DEFAULT_BK)  # bm,bn,bk

    @property
    def kernel(self) -> str:
        return self.plan.kernel

    @property
    def conv_groups(self) -> int:
        return self.plan.groups


def _quant_domain(lp: LayerPlan, domain_bits: List[int]) -> int:
    """Index of the first active quantized domain (drives the codes of any
    identity-domain columns that execute in int8 through block padding)."""
    active = lp.active_domains()
    quantized = [i for i in active if domain_bits[i] < 16]
    if not quantized:
        raise ExecutionError(f"{lp.name}: no quantized domain for kernel "
                             f"{lp.kernel}")
    return quantized[0]


def _per_column_quant(lp: LayerPlan, wf: jax.Array,
                      domain_bits: List[int]) -> Tuple[jax.Array, jax.Array]:
    """(w_q int8 codes, sw (N,) f32 steps) in PERMUTED column order, built
    per domain: each active quantized domain's columns are quantized with
    that domain's own ``w_log_scales`` entry and bit-width, so multi-
    quantized-domain plans (e.g. 3-domain ``gap9_like``) dequantize every
    column with the right step.  Identity (>=16-bit) columns inherit the
    driving quantized domain's codes — conservative for the block-aligned
    extra columns the split kernel executes in int8."""
    drive = _quant_domain(lp, domain_bits)
    if lp.w_log_scales is not None:
        ls_of = lambda d: float(lp.w_log_scales[d])
    else:  # lowered without scales: max-abs of the bound weight
        ls = float(quant.init_log_scale(wf))
        ls_of = lambda d: ls
    bits_of = lambda d: (2 if lp.kernel == KERNEL_TERNARY
                         else min(int(domain_bits[d]), 8))
    col_ls = np.zeros(lp.c_out, np.float32)
    col_levels = np.ones(lp.c_out, np.float32)
    start = 0
    for d, c in enumerate(lp.counts):
        if c:
            src = d if domain_bits[d] < 16 else drive
            col_ls[start:start + c] = ls_of(src)
            col_levels[start:start + c] = quant.qlevels(bits_of(src))
        start += c
    scale = jnp.asarray(np.exp(col_ls))
    levels = jnp.asarray(col_levels)
    w_q = jnp.round(jnp.clip(wf / scale[None, :], -1.0, 1.0) *
                    levels[None, :]).astype(jnp.int8)
    sw = (scale / levels).astype(jnp.float32)
    return w_q, sw


def _resolve_blocks(lp: LayerPlan, block_n: int) -> Tuple[int, int, int]:
    """(bm, bn, bk) for the layer's kernel calls: plan-level ``block_n``
    with `LayerPlan.tuning` overrides."""
    tun = lp.tuning or {}
    bm = int(tun.get("bm", DEFAULT_BM))
    bn = int(tun.get("bn", block_n))
    bk = int(tun.get("bk", DEFAULT_BK))
    if min(bm, bn, bk) < 1:
        raise ExecutionError(f"{lp.name}: invalid kernel tuning {tun}")
    if lp.kernel == KERNEL_SPLIT_TERNARY and bk % 4 != 0:
        raise ExecutionError(f"{lp.name}: split_ternary needs bk % 4 == 0 "
                             f"(2-bit packing), got bk={bk}")
    return bm, bn, bk


def _pack_ternary_stream(lp: LayerPlan, w_q: jax.Array) -> jax.Array:
    """The split_ternary kernel's compressed weight side: 2-bit-pack the
    ternary-domain columns of the per-domain codes (int8 columns zeroed —
    the kernel never reads them from the packed stream), K padded up to a
    multiple of 4 with code 0."""
    K, N = w_q.shape
    boundary = lp.split_boundary()
    cols = jnp.arange(N)[None, :]
    w_t = jnp.where(cols >= boundary, w_q, 0).astype(jnp.int8)
    k4 = -(-K // 4) * 4
    if k4 != K:
        w_t = jnp.pad(w_t, ((0, k4 - K), (0, 0)))
    return pack_ternary(w_t)


def _expand_grouped(w, groups: int) -> jax.Array:
    """Zero-embed a grouped conv weight ``(kh, kw, C_in/G, C_out)`` into the
    block-diagonal full matrix ``(kh, kw, C_in, C_out)``: input-channel
    block g only reaches output-channel block g (XLA's
    ``feature_group_count`` semantics), every other entry is exactly zero.
    Zeros quantize to code 0 in every domain, so the expanded weight runs
    through the SAME im2col'd dense kernels as an ungrouped conv — trading
    G-fold redundant MACs for kernel coverage (the cost model still prices
    the true grouped geometry via ``LayerGeometry.groups``)."""
    kh, kw, cpg, c_out = (int(s) for s in w.shape)
    if c_out % groups:
        raise ExecutionError(f"{c_out} output channels do not divide into "
                             f"{groups} conv groups")
    opg = c_out // groups
    eye = jnp.eye(groups, dtype=w.dtype)
    w5 = jnp.asarray(w).reshape(kh, kw, cpg, groups, opg)
    full = jnp.einsum("hwcgo,gG->hwGcgo", w5, eye)
    return full.reshape(kh, kw, groups * cpg, c_out)


def prepare_layer(lp: LayerPlan, w, b=None,
                  domain_bits: List[int] | None = None,
                  block_n: int = 128) -> PreparedLayer:
    """Bind ``lp`` to a concrete weight (+ optional bias): a 2-D
    (C_in, C_out) dense matrix or a 4-D (kh, kw, C_in, C_out) HWIO conv
    kernel (flattened to ``(kh*kw*C_in, C_out)``; run conv layers through
    `execute_conv_layer`).  A plan with ``groups > 1`` binds a grouped/
    depthwise conv weight ``(kh, kw, C_in/G, C_out)`` — zero-embedded into
    its block-diagonal dense form (`_expand_grouped`) so it executes
    through the same kernels."""
    ndim = getattr(w, "ndim", 0)
    if ndim not in (2, 4):
        raise ExecutionError(f"{lp.name}: planned execution covers 2-D "
                             f"(dense) and 4-D (HWIO conv) weights, got "
                             f"shape {tuple(getattr(w, 'shape', ()))}")
    if int(w.shape[-1]) != lp.c_out:
        raise ExecutionError(f"{lp.name}: weight has {int(w.shape[-1])} "
                             f"output channels, plan expects {lp.c_out}")
    if lp.groups > 1:
        if ndim != 4:
            raise ExecutionError(f"{lp.name}: groups={lp.groups} needs a "
                                 f"4-D HWIO conv weight, got shape "
                                 f"{tuple(w.shape)}")
        w = _expand_grouped(w, lp.groups)
    conv_shape = tuple(int(s) for s in w.shape) if ndim == 4 else None
    w2 = jnp.asarray(w).reshape(-1, int(w.shape[-1]))
    if domain_bits is None:
        domain_bits = [8] * len(lp.counts)
    w_perm = jnp.take(w2, jnp.asarray(lp.perm), axis=-1)
    w_q = sw = w_bf16 = w_t_packed = act_scale = act_sx = None
    if lp.kernel in (KERNEL_QUANT, KERNEL_TERNARY, KERNEL_SPLIT,
                     KERNEL_SPLIT_TERNARY):
        w_q, sw = _per_column_quant(lp, w_perm.astype(jnp.float32),
                                    domain_bits)
    if lp.kernel == KERNEL_SPLIT:
        w_bf16 = w_perm.astype(jnp.bfloat16)
    if lp.kernel == KERNEL_SPLIT_TERNARY:
        w_t_packed = _pack_ternary_stream(lp, w_q)
    if lp.act_log_scale is not None:
        act_scale = jnp.asarray(np.exp(lp.act_log_scale), jnp.float32)
        act_sx = (act_scale / quant.qlevels(8)).astype(jnp.float32)
    return PreparedLayer(plan=lp, inv=jnp.asarray(lp.inv_perm()),
                         w_perm=w_perm,
                         b=(jnp.asarray(b) if b is not None else None),
                         w_q=w_q, sw=sw, act_log_scale=lp.act_log_scale,
                         block_n=block_n, conv_shape=conv_shape,
                         w_bf16=w_bf16, w_t_packed=w_t_packed,
                         act_scale=act_scale, act_sx=act_sx,
                         boundary=lp.split_boundary(),
                         blocks=_resolve_blocks(lp, block_n))


def _act_quant(xf: jax.Array, prep: PreparedLayer):
    """(x_q int8, sx step): the prepared static scale when one was lowered
    (exp/step hoisted into `prepare_layer`), else dynamic max-abs (the
    v1-artifact migration path)."""
    if prep.act_scale is not None:
        scale, sx = prep.act_scale, prep.act_sx
    else:
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-8)
        sx = (scale / quant.qlevels(8)).astype(jnp.float32)
    x_q = jnp.round(jnp.clip(xf / scale, -1.0, 1.0) *
                    quant.qlevels(8)).astype(jnp.int8)
    return x_q, sx


def execute_layer(prep: PreparedLayer, x, *, interpret=None,
                  reference: bool = False) -> jax.Array:
    """Run ``x (..., C_in)`` through the prepared layer's kernel; returns
    ``(..., C_out)`` in the original channel order, bias applied, in
    ``x.dtype``.  ``reference=True`` routes through the pure-jnp oracles
    (`kernels.ref`) instead of the Pallas kernels — the bit-tolerance
    reference path.  Jit-safe: ``x`` (and the prepared arrays, for stacked
    repeats) may be tracers."""
    lp = prep.plan
    wk = prep.w_perm if prep.w_perm is not None else prep.w_q
    if int(x.shape[-1]) != int(wk.shape[-2]):
        raise ExecutionError(f"{lp.name}: input has {int(x.shape[-1])} "
                             f"features, weight expects "
                             f"{int(wk.shape[-2])}")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    xf = x2.astype(jnp.float32)
    bm, bn, bk = prep.blocks
    # the ops clamp the N-block to min(bn, max(128, n)) and round the
    # boundary up to it; the oracles must split at the same column
    bn_eff = min(bn, max(128, lp.c_out))

    if lp.kernel == KERNEL_FP:
        y = xf @ prep.w_perm.astype(jnp.float32)
    elif lp.kernel in (KERNEL_QUANT, KERNEL_TERNARY):
        x_q, sx = _act_quant(xf, prep)
        if reference:
            fn = (ref.ternary_matmul_ref if lp.kernel == KERNEL_TERNARY
                  else ref.quant_matmul_ref)
            y = fn(x_q, prep.w_q, sx, prep.sw)
        else:
            fn = (ops.ternary_matmul_op if lp.kernel == KERNEL_TERNARY
                  else ops.quant_matmul_op)
            y = fn(x_q, prep.w_q, sx, prep.sw, bm=bm, bn=bn, bk=bk,
                   interpret=interpret)
    elif lp.kernel == KERNEL_SPLIT_TERNARY:
        x_q, sx = _act_quant(xf, prep)
        if reference:
            y = ref.split_ternary_matmul_ref(
                x_q, prep.w_q, prep.w_q, sx, prep.sw,
                ops.align_boundary(prep.boundary, bn_eff))
        else:
            y = ops.split_ternary_op(x_q, prep.w_q, prep.w_t_packed, sx,
                                     prep.sw, prep.boundary, bm=bm, bn=bn,
                                     bk=bk, interpret=interpret)
    elif lp.kernel == KERNEL_SPLIT:
        x_q, sx = _act_quant(xf, prep)
        xb = x2.astype(jnp.bfloat16)
        if reference:
            y = ref.split_precision_matmul_ref(
                xb, x_q, sx, prep.w_bf16, prep.w_q, prep.sw,
                ops.align_boundary(prep.boundary, bn_eff))
        else:
            y = ops.split_precision_op(xb, x_q, sx, prep.w_bf16, prep.w_q,
                                       prep.sw, prep.boundary, bm=bm, bn=bn,
                                       bk=bk, interpret=interpret)
    else:  # pragma: no cover - __post_init__ rejects unknown kernels
        raise ExecutionError(f"{lp.name}: unknown kernel {lp.kernel}")

    y = jnp.take(y, prep.inv, axis=-1)
    if prep.b is not None:
        y = y + prep.b.astype(y.dtype)
    return y.reshape(*lead, lp.c_out).astype(x.dtype)


# --------------------------------------------------------------------------
# Conv execution: im2col onto the dense kernels
# --------------------------------------------------------------------------

def _same_pads(size: int, k: int, stride: int) -> Tuple[int, int, int]:
    out = -(-size // stride)
    pad = max((out - 1) * stride + k - size, 0)
    return out, pad // 2, pad - pad // 2


def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           padding: str = "SAME") -> jax.Array:
    """NHWC input -> (B, OH, OW, kh*kw*C) patches whose last axis matches a
    flattened HWIO conv weight ``w.reshape(kh*kw*C, C_out)`` (row-major
    (kh, kw, C) order), with XLA's SAME/VALID padding semantics — so
    ``im2col(x) @ w_flat == lax.conv_general_dilated(x, w)``."""
    B, H, W, C = x.shape
    if padding == "SAME":
        oh, pt, pb = _same_pads(H, kh, stride)
        ow, pl, pr = _same_pads(W, kw, stride)
        x = jnp.pad(x, ((0, 0), (pt, pb), (pl, pr), (0, 0)))
    elif padding == "VALID":
        oh = (H - kh) // stride + 1
        ow = (W - kw) // stride + 1
    else:
        raise ExecutionError(f"unsupported conv padding {padding!r}")
    if oh < 1 or ow < 1:
        raise ExecutionError(f"conv kernel ({kh}x{kw}) exceeds input "
                             f"({H}x{W}) under {padding} padding")
    cols = [x[:, i:i + (oh - 1) * stride + 1:stride,
              j:j + (ow - 1) * stride + 1:stride, :]
            for i in range(kh) for j in range(kw)]
    return jnp.concatenate(cols, axis=-1)


def execute_conv_layer(prep: PreparedLayer, x, stride: int = 1,
                       padding: str = "SAME", *, interpret=None,
                       reference: bool = False) -> jax.Array:
    """Run an NHWC input through a prepared CONV layer: im2col the input to
    ``(B, OH, OW, kh*kw*C_in)`` patches and execute them through the layer's
    planned dense kernel (groups == 1 only)."""
    if prep.conv_shape is None:
        raise ExecutionError(f"{prep.plan.name}: not a conv layer (bound "
                             f"weight was 2-D)")
    kh, kw, ci, _ = prep.conv_shape
    if int(x.shape[-1]) != ci:
        raise ExecutionError(f"{prep.plan.name}: input has "
                             f"{int(x.shape[-1])} channels, conv weight "
                             f"expects {ci}")
    patches = im2col(x, kh, kw, stride=stride, padding=padding)
    return execute_layer(prep, patches, interpret=interpret,
                         reference=reference)


def reference_layer(prep: PreparedLayer, x) -> jax.Array:
    """Full-precision oracle: ``x @ w + b`` on the ORIGINAL weight order
    (the parity target planned execution is pinned against)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    w = jnp.take(prep.w_perm, prep.inv, axis=-1)
    y = x2 @ w.astype(jnp.float32)
    if prep.b is not None:
        y = y + prep.b.astype(y.dtype)
    return y.reshape(*lead, prep.plan.c_out).astype(x.dtype)


# --------------------------------------------------------------------------
# Scan-stacked prepared layers
# --------------------------------------------------------------------------

def _stack_key(prep: PreparedLayer):
    """Repeats can share one stacked execution only when everything STATIC
    about their kernels agrees — arrays may differ, trace structure may
    not."""
    lp = prep.plan
    return (lp.kernel, lp.c_in, lp.c_out, tuple(lp.counts),
            tuple(lp.aligned_boundaries), prep.boundary, prep.blocks,
            prep.block_n, prep.conv_shape, prep.b is None,
            prep.act_log_scale is None)


#: kernels whose execute paths never read the fp32 weight copy (split reads
#: the hoisted bf16 cast instead) — stacking w_perm would hold R
#: full-precision matrices that only the eager `reference_layer` oracle
#: could use, and stacked entries never route there
_DROPS_FP_STACK = (KERNEL_QUANT, KERNEL_TERNARY, KERNEL_SPLIT_TERNARY,
                   KERNEL_SPLIT)


class _StackedPrepared:
    """Homogeneous per-repeat `PreparedLayer`s stacked on a leading R axis;
    ``at(r)`` slices repeat ``r`` (r may be a traced scan index — this is
    what executes scan-stacked LM layers inside the jitted layer scan)."""

    def __init__(self, preps: List[PreparedLayer]):
        p0 = preps[0]
        self.plan, self.block_n = p0.plan, p0.block_n
        self.conv_shape = p0.conv_shape
        self.conv_groups = p0.plan.groups
        self.boundary, self.blocks = p0.boundary, p0.blocks
        self.n_repeats = len(preps)
        st = lambda get: (None if get(p0) is None
                          else jnp.stack([jnp.asarray(get(p)) for p in preps]))
        self._inv = st(lambda p: p.inv)
        self._w_perm = (st(lambda p: p.w_perm)
                        if p0.plan.kernel not in _DROPS_FP_STACK else None)
        self._w_bf16 = st(lambda p: p.w_bf16)
        self._w_t_packed = st(lambda p: p.w_t_packed)
        self._b = st(lambda p: p.b)
        self._w_q = st(lambda p: p.w_q)
        self._sw = st(lambda p: p.sw)
        self._act_scale = st(lambda p: p.act_scale)
        self._act_sx = st(lambda p: p.act_sx)

    def at(self, r) -> PreparedLayer:
        take = lambda a: None if a is None else jnp.take(a, r, axis=0)
        return PreparedLayer(
            plan=self.plan, inv=take(self._inv), w_perm=take(self._w_perm),
            b=take(self._b), w_q=take(self._w_q), sw=take(self._sw),
            act_log_scale=self.plan.act_log_scale,
            block_n=self.block_n, conv_shape=self.conv_shape,
            w_bf16=take(self._w_bf16), w_t_packed=take(self._w_t_packed),
            act_scale=take(self._act_scale), act_sx=take(self._act_sx),
            boundary=self.boundary, blocks=self.blocks)

    def execute(self, x, r, conv=None, *, interpret=None, reference=False):
        prep = self.at(r)
        if conv is not None:
            return execute_conv_layer(prep, x, conv["stride"],
                                      conv["padding"], interpret=interpret,
                                      reference=reference)
        return execute_layer(prep, x, interpret=interpret,
                             reference=reference)


class _SingleRepeat:
    """A one-repeat stack (R=1, e.g. every reduced-config layer stack): the
    scan index is necessarily 0, so the prepared arrays execute DIRECTLY —
    no leading stack axis, no per-iteration dynamic gather."""

    def __init__(self, prep: PreparedLayer):
        # same fp32-copy drop as the other stack containers: stacked
        # entries never route to reference_layer, so w_perm is dead weight
        if prep.plan.kernel in _DROPS_FP_STACK:
            prep = dataclasses.replace(prep, w_perm=None)
        self.prep = prep
        self.conv_shape = prep.conv_shape
        self.conv_groups = prep.plan.groups

    def execute(self, x, r, conv=None, *, interpret=None, reference=False):
        if conv is not None:
            return execute_conv_layer(self.prep, x, conv["stride"],
                                      conv["padding"], interpret=interpret,
                                      reference=reference)
        return execute_layer(self.prep, x, interpret=interpret,
                             reference=reference)


def _stack_group(preps: List[PreparedLayer]):
    """One homogeneous group: direct execution for a single repeat, a
    stacked gather otherwise."""
    return (_SingleRepeat(preps[0]) if len(preps) == 1
            else _StackedPrepared(preps))


class _GroupedPrepared:
    """Per-repeat `PreparedLayer`s grouped by static stack key: every group
    is a `_StackedPrepared` over the repeats that share its trace structure,
    and a (possibly traced) scan index dispatches ``jax.lax.switch`` over
    the G GROUPS — not over all R repeats — selecting the repeat inside the
    group with a stacked gather.  Heterogeneous stacks with recurring layer
    patterns (the common case: a few distinct mappings tiled across the
    depth) trace G kernels instead of R."""

    def __init__(self, preps: List[PreparedLayer]):
        buckets: Dict[Any, List[int]] = {}
        for r, p in enumerate(preps):
            buckets.setdefault(_stack_key(p), []).append(r)
        order = list(buckets.values())
        self.groups = [_stack_group([preps[r] for r in idxs])
                       for idxs in order]
        self.group_of = np.zeros(len(preps), np.int32)
        self.pos_of = np.zeros(len(preps), np.int32)
        for g, idxs in enumerate(order):
            for pos, r in enumerate(idxs):
                self.group_of[r] = g
                self.pos_of[r] = pos
        self.conv_shape = preps[0].conv_shape
        self.conv_groups = preps[0].plan.groups

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    def execute(self, x, r, conv=None, *, interpret=None, reference=False):
        run = lambda grp, pos: grp.execute(x, pos, conv=conv,
                                           interpret=interpret,
                                           reference=reference)
        if not isinstance(r, jax.core.Tracer):
            ri = int(r)
            return run(self.groups[self.group_of[ri]], int(self.pos_of[ri]))
        # homogeneous stacks never construct _GroupedPrepared (they route
        # through _stack_group), so there are always >= 2 groups here
        pos = jnp.take(jnp.asarray(self.pos_of), r)
        branches = [lambda xx, grp=grp: grp.execute(
            xx, pos, conv=conv, interpret=interpret, reference=reference)
            for grp in self.groups]
        g = jnp.take(jnp.asarray(self.group_of), r)
        return jax.lax.switch(g, branches, x)


class _SwitchPrepared:
    """One ``jax.lax.switch`` branch PER REPEAT — the pre-grouping dispatch,
    kept as the benchmark baseline (``PlannedBackend(stack_mode="switch")``):
    every repeat's kernel is traced once even when repeats share their
    structure."""

    def __init__(self, preps: List[PreparedLayer]):
        # stacked repeats never read the fp32 weights (split reads its bf16
        # cast), so don't keep their (K, N) float copies alive
        self.preps = [dataclasses.replace(p, w_perm=None)
                      if p.plan.kernel in _DROPS_FP_STACK else p
                      for p in preps]
        self.conv_shape = preps[0].conv_shape
        self.conv_groups = preps[0].plan.groups

    def execute(self, x, r, conv=None, *, interpret=None, reference=False):
        def run(prep, xx):
            if conv is not None:
                return execute_conv_layer(prep, xx, conv["stride"],
                                          conv["padding"],
                                          interpret=interpret,
                                          reference=reference)
            return execute_layer(prep, xx, interpret=interpret,
                                 reference=reference)
        if not isinstance(r, jax.core.Tracer):
            return run(self.preps[int(r)], x)
        branches = [lambda xx, p=p: run(p, xx) for p in self.preps]
        return jax.lax.switch(jnp.asarray(r, jnp.int32), branches, x)


_STACKED_TYPES = (_SingleRepeat, _StackedPrepared, _GroupedPrepared,
                  _SwitchPrepared)


# --------------------------------------------------------------------------
# Prepared-buffer accounting
# --------------------------------------------------------------------------

#: PreparedLayer fields that hold device arrays (the bind-time weight
#: memory a plan keeps alive)
_PREP_ARRAY_FIELDS = ("inv", "w_perm", "b", "w_q", "sw", "w_bf16",
                      "w_t_packed", "act_scale", "act_sx")


def _entry_arrays(entry):
    """Every device array a bound entry (plain or stacked) keeps alive."""
    if isinstance(entry, PreparedLayer):
        for f in _PREP_ARRAY_FIELDS:
            a = getattr(entry, f)
            if a is not None:
                yield a
    elif isinstance(entry, _SingleRepeat):
        yield from _entry_arrays(entry.prep)
    elif isinstance(entry, _StackedPrepared):
        for a in (entry._inv, entry._w_perm, entry._w_bf16,
                  entry._w_t_packed, entry._b, entry._w_q, entry._sw,
                  entry._act_scale, entry._act_sx):
            if a is not None:
                yield a
    elif isinstance(entry, _GroupedPrepared):
        for g in entry.groups:
            yield from _entry_arrays(g)
    elif isinstance(entry, _SwitchPrepared):
        for p in entry.preps:
            yield from _entry_arrays(p)


def prepared_nbytes(entries) -> int:
    """Total bytes of the UNIQUE arrays held by ``entries`` — arrays shared
    between entries (the PlanSet dedup) are counted once."""
    seen, total = set(), 0
    for e in entries:
        for a in _entry_arrays(e):
            if id(a) not in seen:
                seen.add(id(a))
                total += int(a.nbytes)
    return total


# --------------------------------------------------------------------------
# Pluggable matmul backend over a bank of plans
# --------------------------------------------------------------------------

def _node_weight_ok(node):
    w = _layer_weight(node)
    return (isinstance(node, dict) and getattr(w, "ndim", 0) in (2, 4)
            and not isinstance(w, jax.ShapeDtypeStruct))


class _BoundPlan:
    """One `ExecutionPlan` variant bound to the owning `PlanSet`'s params:
    resolves layers exactly like the single-plan `PlannedBackend` always
    did (handle plan order, or artifact layer names as params paths) but
    routes every prepare through the owner's shared prep cache, so
    identical (layer plan, weight, domain-bits, block) tuples across
    variants bind to ONE set of prepared arrays."""

    def __init__(self, variant: str, plan: ExecutionPlan, params, handle,
                 owner: "PlanSet"):
        self.variant = variant
        self.plan = plan
        domain_bits = [int(d["weight_bits"]) for d in plan.domains]
        dsig = tuple(domain_bits)
        if handle is not None:
            dicts = handle.layers(params)
            if len(dicts) != len(plan.layers):
                raise ExecutionError(
                    f"handle resolves {len(dicts)} managed layers but the "
                    f"plan has {len(plan.layers)}")
            # node identity for the shared prep cache: handle position
            resolved = [(lp, node, ("h", i))
                        for i, (lp, node) in enumerate(zip(plan.layers,
                                                           dicts))]
        else:
            resolved = [(lp, _walk_path(params, lp.name), ("p", lp.name))
                        for lp in plan.layers]
        self.by_name: Dict[str, Any] = {}
        self.bound: List[str] = []
        self.unbound: List[str] = []
        stacked: Dict[str, List[Tuple[int, LayerPlan, Any, Any]]] = {}
        for lp, node, nkey in resolved:
            base, _, rep = lp.name.partition("@")
            if rep:
                stacked.setdefault(base, []).append((int(rep), lp, node,
                                                     nkey))
                continue
            if not _node_weight_ok(node):
                self.unbound.append(lp.name)
                continue
            key = ("layer", nkey, owner._plan_sig(lp), dsig,
                   int(plan.block_n))
            prep = owner._memo(
                key, variant, lp.name,
                lambda: prepare_layer(lp, _layer_weight(node),
                                      b=node.get("b"),
                                      domain_bits=domain_bits,
                                      block_n=plan.block_n))
            self.by_name[lp.name] = prep
            self.bound.append(lp.name)
        for base, entries in sorted(stacked.items()):
            entries.sort(key=lambda e: e[0])
            reps = [r for r, _, _, _ in entries]
            if reps != list(range(len(reps))):
                raise ExecutionError(
                    f"{base}: stacked plan repeats {reps} are not the "
                    f"contiguous range 0..{len(reps) - 1}")
            if handle is None:
                # a plan covering FEWER repeats than the model's stack would
                # index out of range inside the scan (NaN fill) — reject at
                # bind time instead
                stack_w = _layer_weight(_walk_path(params, base))
                if getattr(stack_w, "ndim", 0) in (3, 5) and \
                        int(stack_w.shape[0]) != len(reps):
                    raise ExecutionError(
                        f"{base}: plan covers {len(reps)} repeats but the "
                        f"stacked weight carries {int(stack_w.shape[0])} — "
                        f"the artifact does not match this model's layer "
                        f"stack")
            if not all(_node_weight_ok(node) for _, _, node, _ in entries):
                self.unbound.extend(lp.name for _, lp, _, _ in entries)
                continue
            # stack entries dedup at WHOLE-STACK granularity: the stacked
            # containers jnp.stack fresh arrays, so per-repeat sharing
            # cannot alias device buffers — one divergent repeat forks the
            # whole stack for that base
            key = ("stack", entries[0][3][0], base,
                   tuple(owner._plan_sig(lp) for _, lp, _, _ in entries),
                   dsig, int(plan.block_n), owner.stack_mode)
            entry = owner._memo(
                key, variant, base,
                lambda: owner._stack_entry(
                    [prepare_layer(lp, _layer_weight(node),
                                   b=node.get("b"),
                                   domain_bits=domain_bits,
                                   block_n=plan.block_n)
                     for _, lp, node, _ in entries]))
            self.by_name[base] = entry
            self.bound.extend(lp.name for _, lp, _, _ in entries)


class PlanSet:
    """A precision bank: N `ExecutionPlan` variants of the SAME weights
    bound against one params pytree, serving the NAME-KEYED `repro.models`
    matmul-backend protocol (``backend(name, p, x, conv=...)``).

    The active variant is selected by the trace-static key published via
    ``repro.models._backend.plan_variant`` (threaded through the
    transformer/façade ``variant=`` kwargs); calls outside any variant
    context execute ``default``.  Because the key is static, each variant
    traces its own kernels — jitted callers must make it a static argument
    (``static_argnames=("variant",)``).

    Prepared weight buffers DEDUPLICATE across variants: wherever a
    layer's (layer plan, resolved weight, domain bit-widths, block size)
    tuple coincides — same kernel, same domain boundary, same scales — the
    variants share one set of prepared arrays (per plain layer; per whole
    stack for scan-stacked ``base@r`` entries, whose containers stack
    fresh arrays).  ``prepared_bytes()`` / ``memory_report()`` measure the
    dedup: a two-variant bank stays strictly below two independent binds
    whenever any layer coincides.

    Layer resolution, scan-stack grouping (``stack_mode``), coverage
    bookkeeping and the fail-loud `ExecutionError` semantics are exactly
    the single-plan `PlannedBackend`'s — which is now the one-variant
    special case of this class.
    """

    def __init__(self, variants: Dict[str, ExecutionPlan], params,
                 handle=None, *, default: str | None = None,
                 interpret=None, reference: bool = False,
                 stack_mode: str = "grouped"):
        if stack_mode not in ("grouped", "switch"):
            raise ValueError(f"stack_mode must be 'grouped' or 'switch', "
                             f"got {stack_mode!r}")
        if not variants:
            raise ValueError("PlanSet needs at least one plan variant")
        for v in variants:
            if not isinstance(v, str) or not v:
                raise ValueError(f"variant names must be non-empty strings, "
                                 f"got {v!r}")
        self.interpret = interpret
        self.reference = reference
        self.stack_mode = stack_mode
        self.variant_names: Tuple[str, ...] = tuple(variants)
        self.default = self.variant_names[0] if default is None else default
        if self.default not in variants:
            raise ValueError(f"default variant {self.default!r} is not one "
                             f"of {list(self.variant_names)}")
        self.runtime_declines: Dict[str, str] = {}
        self._prep_cache: Dict[Any, Any] = {}
        self._share: Dict[Any, List[Tuple[str, str]]] = {}
        self._sig_cache: Dict[int, str] = {}
        self._variants: Dict[str, _BoundPlan] = {}
        for vname, plan in variants.items():
            self._variants[vname] = _BoundPlan(vname, plan, params, handle,
                                               self)

    # ---- shared prepare cache -------------------------------------------

    def _plan_sig(self, lp: LayerPlan) -> str:
        sig = self._sig_cache.get(id(lp))
        if sig is None:
            sig = json.dumps(lp.to_dict(), sort_keys=True)
            self._sig_cache[id(lp)] = sig
        return sig

    def _memo(self, key, variant: str, display_name: str, build):
        if key not in self._prep_cache:
            self._prep_cache[key] = build()
        self._share.setdefault(key, []).append((variant, display_name))
        return self._prep_cache[key]

    def _stack_entry(self, preps: List[PreparedLayer]):
        if self.stack_mode == "switch":
            return _SwitchPrepared(preps)
        if len({_stack_key(p) for p in preps}) == 1:
            return _stack_group(preps)
        return _GroupedPrepared(preps)

    # ---- backend protocol -----------------------------------------------

    def _resolve_variant(self) -> _BoundPlan:
        v = _backend.current_plan_variant()
        if v is None:
            v = self.default
        bp = self._variants.get(v)
        if bp is None:
            raise ExecutionError(
                f"unknown plan variant {v!r}: this PlanSet binds "
                f"{list(self.variant_names)}")
        return bp

    def __call__(self, name, p, x, *, conv=None):
        """Matmul-backend hook: resolve ``name`` against the ACTIVE variant
        (``_backend.current_plan_variant()`` or ``default``); returns the
        planned output (bias applied) or None to decline (unknown /
        unnamed layer, or an unsupported conv).  ``conv`` carries the call
        site's ``{"stride", "padding", "groups"}`` for conv layers."""
        if name is None:
            return None
        bp = self._resolve_variant()
        entry = bp.by_name.get(name)
        if entry is None:
            return None
        conv_shape = entry.conv_shape
        if conv is not None and conv_shape is None:
            raise ExecutionError(
                f"{name}: conv call site but the plan bound a 2-D dense "
                f"weight — the artifact does not match this model")
        if conv is None and conv_shape is not None:
            raise ExecutionError(
                f"{name}: dense call site but the plan bound a conv weight "
                f"— the artifact does not match this model")
        if conv is not None:
            cg = int(conv.get("groups", 1))
            pg = entry.conv_groups
            if cg != pg:
                if pg == 1:
                    # plan lowered without a groups record (pre-groups
                    # artifact): loud trace-time decline, surfaced via
                    # runtime_declines — re-emit the artifact to get the
                    # block-diagonal grouped lowering
                    self.runtime_declines[self._decline_key(bp, name)] = (
                        f"grouped conv (groups={cg}) but the plan was "
                        f"lowered without groups; executed on the default "
                        f"path")
                    return None
                raise ExecutionError(
                    f"{name}: call site has groups={cg} but the plan was "
                    f"lowered with groups={pg} — the artifact does not "
                    f"match this model")
        if isinstance(entry, _STACKED_TYPES):
            r = _backend.current_scan_index()
            if r is None:
                raise ExecutionError(
                    f"{name}: scan-stacked plan executed outside a "
                    f"scan_slot context (no repeat index to select the "
                    f"prepared kernels)")
            return entry.execute(x, r, conv=conv, interpret=self.interpret,
                                 reference=self.reference)
        if conv is not None:
            return execute_conv_layer(entry, x, conv["stride"],
                                      conv["padding"],
                                      interpret=self.interpret,
                                      reference=self.reference)
        return execute_layer(entry, x, interpret=self.interpret,
                             reference=self.reference)

    def _decline_key(self, bp: _BoundPlan, name: str) -> str:
        # single-variant banks keep the bare-name key (the PlannedBackend
        # contract); multi-variant banks qualify it so variants don't alias
        return name if len(self._variants) == 1 else f"{bp.variant}:{name}"

    # ---- coverage -------------------------------------------------------

    def variant(self, name: str) -> _BoundPlan:
        """The bound state of one variant (plan / bound / unbound)."""
        return self._variants[name]

    @property
    def fully_covered(self) -> bool:
        """True when EVERY variant bound every planned layer."""
        return all(not bp.unbound for bp in self._variants.values())

    def coverage(self) -> str:
        parts = []
        for v, bp in self._variants.items():
            s = (f"{len(bp.bound)}/{len(bp.plan.layers)} planned layers "
                 f"bound to weights, {len(bp.unbound)} unbound")
            parts.append(s if len(self._variants) == 1 else f"{v}: {s}")
        return "; ".join(parts)

    def coverage_diff(self) -> Dict[str, List[str]]:
        """Per-variant UNBOUND layer names (only variants with gaps): the
        actionable diff when one variant binds fewer layers than another —
        names, not counts."""
        return {v: list(bp.unbound) for v, bp in self._variants.items()
                if bp.unbound}

    # ---- memory accounting ----------------------------------------------

    def prepared_bytes(self, variant: str | None = None) -> int:
        """Bytes of unique prepared device arrays held by ``variant`` (or
        by the whole bank when None) — buffers shared across variants count
        once, which is the point of the bank."""
        if variant is None:
            entries = [e for bp in self._variants.values()
                       for e in bp.by_name.values()]
        else:
            entries = list(self._variants[variant].by_name.values())
        return prepared_nbytes(entries)

    def shared_layers(self) -> Dict[str, Tuple[str, ...]]:
        """Display name -> variants whose prepared buffers coincide (>= 2
        variants sharing one prep-cache entry)."""
        out: Dict[str, Tuple[str, ...]] = {}
        for users in self._share.values():
            vs = tuple(dict.fromkeys(v for v, _ in users))
            if len(vs) > 1:
                out[users[0][1]] = vs
        return out

    def memory_report(self) -> Dict[str, Any]:
        per_variant = {v: self.prepared_bytes(v) for v in self.variant_names}
        total = self.prepared_bytes()
        return {
            "variants": per_variant,
            "prepared_bytes": total,
            "sum_variant_bytes": sum(per_variant.values()),
            "dedup_saved_bytes": sum(per_variant.values()) - total,
            "shared_layers": self.shared_layers(),
        }


class PlannedBackend(PlanSet):
    """A one-plan `PlanSet` — the original single-mapping binding, kept as
    the common case and the backward-compatible API: ``plan`` / ``bound`` /
    ``unbound`` / ``coverage()`` address the single variant directly, and
    ``runtime_declines`` keys stay bare layer names."""

    def __init__(self, plan: ExecutionPlan, params, handle=None, *,
                 interpret=None, reference: bool = False,
                 stack_mode: str = "grouped"):
        super().__init__({"default": plan}, params, handle=handle,
                         interpret=interpret, reference=reference,
                         stack_mode=stack_mode)

    @property
    def plan(self) -> ExecutionPlan:
        return self._variants["default"].plan

    @property
    def bound(self) -> List[str]:
        return self._variants["default"].bound

    @property
    def unbound(self) -> List[str]:
        return self._variants["default"].unbound

    @property
    def _by_name(self) -> Dict[str, Any]:
        return self._variants["default"].by_name
