"""``python -m repro.runtime`` — the artifact->plan lowering CLI.

Thin alias for ``repro.runtime.lower.main`` (avoids the runpy double-import
warning of ``-m repro.runtime.lower``).
"""
from repro.runtime.lower import main

if __name__ == "__main__":
    main()
