"""Pallas TPU kernel: w8a8 matmul with int32 accumulation + per-column scales.

Target: TPU v5e MXU int8 path (2x bf16 peak).  Grid (M/bm, N/bn, K/bk) with
the K dimension innermost ('arbitrary') accumulating into a VMEM scratch;
block shapes are MXU-aligned multiples of 128 (lane) x 8/32 (sublane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 512


def _kernel(x_ref, w_ref, sw_ref, sx_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        sx = sx_ref[0]
        sw = sw_ref[...]  # (1, bn)
        o_ref[...] = acc_ref[...].astype(jnp.float32) * sx * sw


def quant_matmul(x_q, w_q, sx, sw, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                 bk=DEFAULT_BK, interpret=False):
    """x_q (M,K) int8, w_q (K,N) int8, sx scalar f32, sw (N,) f32 -> (M,N) f32.

    Shapes must be multiples of the block sizes (ops.py pads otherwise).
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (x_q.shape, w_q.shape, bm, bn, bk)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),  # sx scalar, full
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, sw.reshape(1, n), sx.reshape(1))
