"""Pallas TPU kernel: ternary-weight matmul (the AIMC-accelerator analogue).

Weights are codes in {-1, 0, +1} stored as int8.  On TPU the MXU's int8 path
executes this at 2x bf16 peak, and ternary codes make the weight stream
maximally compressible (the HBM->VMEM term of the roofline shrinks by 8x vs
bf16 at 2-bit packing; we stream int8 codes here and note 4x-packing as a
further step).  Structure mirrors quant_matmul with an int32 accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 512


def _kernel(x_ref, w_ref, sw_ref, sx_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * sx_ref[0] * sw_ref[...]


def ternary_matmul(x_q, w_t, sx, sw, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                   bk=DEFAULT_BK, interpret=False):
    """x_q (M,K) int8; w_t (K,N) int8 codes in {-1,0,1}; sw (N,) f32."""
    m, k = x_q.shape
    _, n = w_t.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_t, sw.reshape(1, n), sx.reshape(1))
