"""Pallas TPU kernel: fused ODiMO split-precision matmul — the paper's
deployment hot-spot (Fig. 3) adapted to TPU.

After the reorg pass, a layer's output channels are contiguous per precision
domain: columns [0, boundary) belong to the int8 domain, [boundary, N) to the
bf16 domain.  This kernel computes BOTH domains' output slices in one
pallas_call: each N-block selects its path by comparing its column range to
the boundary (block-aligned by construction — ops.py rounds the boundary up
to the block size, mirroring the paper's channel-group alignment).

This is the zero-data-marshaling claim of Fig. 3 made concrete on TPU: one
kernel, one output buffer, no gather/concat between domains.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 512


def _kernel(x_ref, xq_ref, wb_ref, wq_ref, sw_ref, sx_ref, o_ref,
            acc_i_ref, acc_f_ref, *, nk: int, bn: int, boundary: int):
    j = pl.program_id(1)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_i_ref[...] = jnp.zeros_like(acc_i_ref)
        acc_f_ref[...] = jnp.zeros_like(acc_f_ref)

    col0 = j * bn
    is_int8_block = col0 < boundary

    @pl.when(is_int8_block)
    def _int8_path():
        acc_i_ref[...] += jax.lax.dot_general(
            xq_ref[...], wq_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(jnp.logical_not(is_int8_block))
    def _bf16_path():
        acc_f_ref[...] += jax.lax.dot_general(
            x_ref[...], wb_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        int8_out = acc_i_ref[...].astype(jnp.float32) * sx_ref[0] * sw_ref[...]
        o_ref[...] = jnp.where(is_int8_block, int8_out, acc_f_ref[...])


def split_precision_matmul(x, x_q, sx, w_bf16, w_q, sw, boundary, *,
                           bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                           interpret=False):
    """Fused two-domain matmul.

    x (M,K) bf16; x_q (M,K) int8; w_bf16/w_q (K,N); sw (N,) f32;
    boundary: int (static) — first bf16-domain column, multiple of bn.
    """
    m, k = x.shape
    _, n = w_bf16.shape
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert boundary % bn == 0, "ops.py aligns the domain split to bn"
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, bn=bn, boundary=boundary),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, x_q, w_bf16, w_q, sw.reshape(1, n), sx.reshape(1))
