"""Jit'd public wrappers for the Pallas kernels: padding to block multiples,
layout handling, interpret-mode fallback on CPU, and an ODiMO deployment
helper that runs a reorganized layer through the fused split-precision
kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.split_precision import split_precision_matmul
from repro.kernels.split_ternary import split_ternary_matmul
from repro.kernels.ternary_matmul import ternary_matmul


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def align_boundary(boundary: int, bn: int) -> int:
    """Round a domain boundary UP to the N-block size.  The extra columns
    execute on the quantized domain — conservative, matching the paper's
    group-aligned channel split.  This is THE alignment rule: the runtime's
    `lower()` records boundaries aligned with exactly this function so plans
    agree with what `split_precision_op` executes."""
    return int(-(-int(boundary) // int(bn)) * int(bn))


def _pad_to(x, mult, axis):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul_op(x_q, w_q, sx, sw, bm=128, bn=128, bk=512,
                    interpret=None):
    """Shape-flexible w8a8 matmul (pads to block multiples, then slices)."""
    interpret = _on_cpu() if interpret is None else interpret
    m, n = x_q.shape[0], w_q.shape[1]
    bm_, bn_, bk_ = (min(bm, max(8, m)), min(bn, max(128, n)), bk)
    xq = _pad_to(_pad_to(x_q, bm_, 0), bk_, 1)
    wq = _pad_to(_pad_to(w_q, bk_, 0), bn_, 1)
    swp = _pad_to(sw, bn_, 0)
    out = quant_matmul(xq, wq, sx, swp, bm=bm_, bn=bn_, bk=bk_,
                       interpret=interpret)
    return out[:m, :n]


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ternary_matmul_op(x_q, w_t, sx, sw, bm=128, bn=128, bk=512,
                      interpret=None):
    interpret = _on_cpu() if interpret is None else interpret
    m, n = x_q.shape[0], w_t.shape[1]
    bm_, bn_, bk_ = (min(bm, max(8, m)), min(bn, max(128, n)), bk)
    xq = _pad_to(_pad_to(x_q, bm_, 0), bk_, 1)
    wt = _pad_to(_pad_to(w_t, bk_, 0), bn_, 1)
    swp = _pad_to(sw, bn_, 0)
    out = ternary_matmul(xq, wt, sx, swp, bm=bm_, bn=bn_, bk=bk_,
                         interpret=interpret)
    return out[:m, :n]


@partial(jax.jit, static_argnames=("boundary", "bm", "bn", "bk", "interpret"))
def split_precision_op(x, x_q, sx, w_bf16, w_q, sw, boundary,
                       bm=128, bn=128, bk=512, interpret=None):
    """Fused ODiMO layer; ``boundary`` is rounded UP to the N-block size
    (extra columns execute on the int8 domain — conservative, matching the
    paper's group-aligned channel split)."""
    interpret = _on_cpu() if interpret is None else interpret
    m, n = x.shape[0], w_bf16.shape[1]
    bm_, bn_, bk_ = (min(bm, max(8, m)), min(bn, max(128, n)), bk)
    b_al = align_boundary(boundary, bn_)
    xp = _pad_to(_pad_to(x, bm_, 0), bk_, 1)
    xqp = _pad_to(_pad_to(x_q, bm_, 0), bk_, 1)
    wb = _pad_to(_pad_to(w_bf16, bk_, 0), bn_, 1)
    wq = _pad_to(_pad_to(w_q, bk_, 0), bn_, 1)
    swp = _pad_to(sw, bn_, 0)
    out = split_precision_matmul(xp, xqp, sx, wb, wq, swp, b_al,
                                 bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:m, :n]


@partial(jax.jit, static_argnames=("boundary", "bm", "bn", "bk", "interpret"))
def split_ternary_op(x_q, w_q, w_packed, sx, sw, boundary,
                     bm=128, bn=128, bk=512, interpret=None):
    """Fused ternary+int8 layer (DIANA pairing); ``boundary`` — the first
    ternary-domain column — is rounded UP to the N-block size, so straddling
    blocks execute on the int8 path (safe: ``w_q`` carries every column's
    codes, ternary ones included, each with its own ``sw`` step).

    ``w_packed`` is the 2-bit-packed ternary stream, ``ceil(K/4)`` rows
    (rows past K pad with code 0); ``w_q`` has K rows.
    """
    interpret = _on_cpu() if interpret is None else interpret
    m, n = x_q.shape[0], w_q.shape[1]
    k = x_q.shape[1]
    k4 = 4 * w_packed.shape[0]
    assert k <= k4 <= k + 3, (x_q.shape, w_packed.shape)
    bm_, bn_, bk_ = (min(bm, max(8, m)), min(bn, max(128, n)), bk)
    assert bk_ % 4 == 0
    b_al = align_boundary(boundary, bn_)
    xq = _pad_to(_pad_to(x_q, bm_, 0), bk_, 1) if k4 == k else \
        _pad_to(_pad_to(jnp.pad(x_q, ((0, 0), (0, k4 - k))), bm_, 0), bk_, 1)
    wq = _pad_to(jnp.pad(w_q, ((0, k4 - k), (0, 0))), bk_, 0)
    wq = _pad_to(wq, bn_, 1)
    wp = _pad_to(_pad_to(w_packed, bk_ // 4, 0), bn_, 1)
    swp = _pad_to(sw, bn_, 0)
    out = split_ternary_matmul(xq, wq, wp, sx, swp, b_al,
                               bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:m, :n]


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_op(q, k, v, causal=True, bq=256, bk=512, interpret=None):
    """(B,H,Sq,D) x (B,KVH,Sk,D) -> (B,H,Sq,D); pads Sq/Sk as needed."""
    interpret = _on_cpu() if interpret is None else interpret
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    bq_, bk_ = min(bq, max(8, Sq)), min(bk, max(128, Sk))
    qp = _pad_to(q, bq_, 2)
    kp = _pad_to(k, bk_, 2)
    vp = _pad_to(v, bk_, 2)
    if kp.shape[2] > Sk:  # padded KV must not receive probability mass
        # rely on causal mask for causal=True; for non-causal pad K with -inf
        # surrogate: set padded keys to large negative via masking in ref path
        pass
    out = flash_attention(qp, kp, vp, causal=causal, bq=bq_, bk=bk_,
                          interpret=interpret)
    return out[:, :, :Sq, :]


def odimo_deployed_dense(x, w, assign, w_log_scale, x_log_scale,
                         interpret=None):
    """Run an ODiMO-discretized Dense layer via the fused kernel.

    x (M,K); w (K,N); assign (N,) domain per column (0 = int8, 1 = bf16);
    w_log_scale / x_log_scale: int8-domain quant log-scales.
    Performs the Fig. 3 reorg (stable sort by domain), the fused two-domain
    matmul, and the inverse permutation — returning outputs in the ORIGINAL
    channel order so callers need no graph rewrite (the full reorg pass
    removes the inverse permutation by rewriting the next layer's input
    channels; see core/discretize.py).
    """
    from repro.core import quant
    assign = np.asarray(assign)
    perm = np.argsort(assign, kind="stable")
    inv = np.argsort(perm)
    boundary = int((assign == 0).sum())
    wp = w[:, perm]
    sx_step = jnp.exp(x_log_scale) / quant.qlevels(8)
    sw_step = jnp.exp(w_log_scale) / quant.qlevels(8)
    x_q = quant.quantize_int(x, x_log_scale, 8)
    w_q = quant.quantize_int(wp, w_log_scale, 8)
    sw = jnp.full((w.shape[1],), sw_step, jnp.float32)
    out = split_precision_op(x.astype(jnp.bfloat16), x_q,
                             sx_step.reshape(()).astype(jnp.float32),
                             wp.astype(jnp.bfloat16), w_q, sw, boundary,
                             interpret=interpret)
    return out[:, inv]
