"""Pallas TPU kernel: fused ternary+int8 split matmul — DIANA's exact
domain pairing (digital int8 accelerator + ternary AIMC array) in one
``pallas_call``.

After the Fig. 3 reorg a DIANA mixed layer's output channels are contiguous
per domain: columns [0, boundary) belong to the int8 (digital) domain,
[boundary, N) to the ternary (AIMC) domain.  Both domains contract the SAME
int8 activations on the MXU int8 path; they differ only in the weight
stream and the per-column dequant step:

  * int8 blocks read ``w_q`` — int8 codes, streamed as-is;
  * ternary blocks read ``w_packed`` — 2-bit-packed codes (4 per byte, the
    `ternary_packed` layout), unpacked in VMEM with VPU shifts.  The
    HBM->VMEM weight stream of the ternary side is 4x smaller than int8 —
    the analogue of DIANA's weights-resident-in-array term (LAT_aimc).

One int32 accumulator serves both paths because ternary codes ARE valid
int8 codes; the per-column ``sw`` step carries each domain's own dequant
scale, applied once at flush.  This closes the paper's zero-data-marshaling
claim for the headline platform: no gather/concat between domains, and no
fp fallback for ternary+int8 mixed layers.

Column layout contract (matching `runtime.lower` / `kernels.ops`): the
boundary is rounded UP to the N-block size, so a block straddling the raw
boundary executes on the int8 path — safe, because ``w_q`` holds every
column's codes (ternary columns included) and ``sw`` its per-domain step.
``w_packed`` only needs valid codes at columns >= the raw boundary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams
from repro.kernels.ternary_packed import unpack_ternary

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 512


def _kernel(xq_ref, wq_ref, wp_ref, sw_ref, sx_ref, o_ref, acc_ref, *,
            nk: int, bn: int, boundary: int):
    j = pl.program_id(1)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    col0 = j * bn
    is_int8_block = col0 < boundary

    @pl.when(is_int8_block)
    def _int8_path():
        acc_ref[...] += jax.lax.dot_general(
            xq_ref[...], wq_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(jnp.logical_not(is_int8_block))
    def _ternary_path():
        w = unpack_ternary(wp_ref[...])             # (bk//4, bn) -> (bk, bn)
        acc_ref[...] += jax.lax.dot_general(
            xq_ref[...], w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * sx_ref[0] * sw_ref[...]


def split_ternary_matmul(x_q, w_q, w_packed, sx, sw, boundary, *,
                         bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                         interpret=False):
    """Fused int8+ternary two-domain matmul.

    x_q (M,K) int8; w_q (K,N) int8 codes (every column — ternary columns
    hold their {-1,0,+1} codes); w_packed (K//4,N) uint8 2-bit-packed codes
    (read only at columns >= boundary); sw (N,) f32 per-column dequant step;
    boundary: int (static) — first ternary-domain column, multiple of bn.
    """
    m, k = x_q.shape
    _, n = w_q.shape
    kp = w_packed.shape[0]
    assert kp * 4 == k, (w_packed.shape, x_q.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % 4 == 0, "the 2-bit packing needs a K-block multiple of 4"
    assert boundary % bn == 0, "ops.py aligns the domain split to bn"
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, bn=bn, boundary=boundary),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, w_packed, sw.reshape(1, n), sx.reshape(1))
