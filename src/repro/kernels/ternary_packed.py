"""Pallas TPU kernel: ternary matmul with 2-bit-PACKED weights.

The AIMC analogue taken to its conclusion: ternary codes {-1,0,+1} need 2
bits, so 4 codes pack into one uint8 — the HBM->VMEM weight stream is 4x
smaller than int8 (8x smaller than bf16), which is exactly the term DIANA's
AIMC array removes in the paper's Eq. for LAT_aimc (weights resident in the
array).  The kernel unpacks in VMEM (VPU shifts) and feeds the MXU int8 path.

Packing layout: w_packed[k, n] holds codes for K rows 4k..4k+3 of column n,
code c in bits (2c..2c+1), biased by +1 (00 -> -1, 01 -> 0, 10 -> +1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 512


def pack_ternary(w_t: jax.Array) -> jax.Array:
    """(K, N) int8 codes in {-1,0,1} -> (K//4, N) uint8 packed."""
    K, N = w_t.shape
    assert K % 4 == 0
    biased = (w_t + 1).astype(jnp.uint8)           # {0,1,2}
    b = biased.reshape(K // 4, 4, N)
    return (b[:, 0] | (b[:, 1] << 2) | (b[:, 2] << 4) | (b[:, 3] << 6))


def unpack_ternary(w_p: jax.Array) -> jax.Array:
    """(K//4, N) uint8 -> (K, N) int8 codes (jnp reference)."""
    Kp, N = w_p.shape
    parts = [((w_p >> (2 * j)) & 3).astype(jnp.int8) - 1 for j in range(4)]
    return jnp.stack(parts, axis=1).reshape(Kp * 4, N)


def _kernel(x_ref, wp_ref, sw_ref, sx_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    wp = wp_ref[...]                                # (bk//4, bn) uint8
    parts = [((wp >> (2 * j)) & 3).astype(jnp.int8) - 1 for j in range(4)]
    w = jnp.stack(parts, axis=1).reshape(wp.shape[0] * 4, wp.shape[1])
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * sx_ref[0] * sw_ref[...]


def ternary_packed_matmul(x_q, w_packed, sx, sw, *, bm=DEFAULT_BM,
                          bn=DEFAULT_BN, bk=DEFAULT_BK, interpret=False):
    """x_q (M,K) int8; w_packed (K//4, N) uint8; sw (N,) f32 -> (M,N) f32."""
    m, k = x_q.shape
    kp, n = w_packed.shape
    assert kp * 4 == k and m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 4, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_packed, sw.reshape(1, n), sx.reshape(1))
