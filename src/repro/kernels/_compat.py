"""JAX version compatibility for the Pallas TPU kernels."""
from jax.experimental.pallas import tpu as _pltpu

# Renamed TPUCompilerParams -> CompilerParams across JAX releases.
CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    _pltpu.TPUCompilerParams
