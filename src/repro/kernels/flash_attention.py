"""Pallas TPU kernel: causal flash attention with online softmax + GQA.

Grid (B*H, Sq/bq, Sk/bk) with the KV dimension innermost ('arbitrary');
running max/denominator/accumulator live in VMEM scratch.  GQA is handled in
the BlockSpec index maps: the kv block for flat head h reads kv head h // G,
so KV is never repeated in memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

DEFAULT_BQ, DEFAULT_BK = 256, 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, nk: int, bq: int, bk: int, scale: float, causal: bool):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip fully-masked blocks (strictly above the causal diagonal)
    run = jnp.logical_or(not causal, ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0]          # (bq, d)
        k = k_ref[0]          # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, bq=DEFAULT_BQ, bk=DEFAULT_BK,
                    interpret=False):
    """q (B,H,Sq,D); k,v (B,KVH,Sk,D), H = KVH*G. Returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    KVH, Sk = k.shape[1], k.shape[2]
    G = H // KVH
    bq = min(bq, Sq)
    bk_ = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk_ == 0
    nk = Sk // bk_
    scale = D ** -0.5

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * KVH, Sk, D)
    vf = v.reshape(B * KVH, Sk, D)

    # kv index map: flat q head (b*H + h) -> flat kv head (b*KVH + h // G)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bk=bk_, scale=scale,
                          causal=causal),
        grid=(B * H, Sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk_, D),
                         lambda h, iq, ik: ((h // H) * KVH + (h % H) // G, ik, 0)),
            pl.BlockSpec((1, bk_, D),
                         lambda h, iq, ik: ((h // H) * KVH + (h % H) // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)
