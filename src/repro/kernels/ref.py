"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul_ref(x_q, w_q, sx, sw):
    """int8 x (M,K) @ int8 w (K,N) with per-tensor sx and per-column sw.
    Returns f32 (M,N): (x_q @ w_q) * sx * sw."""
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * sx * sw[None, :]


def ternary_matmul_ref(x_q, w_t, sx, sw):
    """Ternary weights (codes in {-1,0,1}) — same contraction as quant."""
    return quant_matmul_ref(x_q, w_t, sx, sw)


def split_precision_matmul_ref(x, x_q, sx, w_bf16, w_q, sw, boundary):
    """ODiMO deployed layer: output cols [0, boundary) from the int8 domain,
    [boundary, N) from the bf16 domain (Fig. 3 contiguous split).

    x (M,K) bf16; x_q (M,K) int8; w_bf16 (K,N) bf16; w_q (K,N) int8;
    sw (N,) per-col scales. Returns f32 (M,N)."""
    n = w_bf16.shape[1]
    lo = quant_matmul_ref(x_q, w_q, sx, sw)
    hi = jnp.dot(x.astype(jnp.float32), w_bf16.astype(jnp.float32))
    cols = jnp.arange(n)[None, :]
    return jnp.where(cols < boundary, lo, hi)


def split_ternary_matmul_ref(x_q, w_q, w_t, sx, sw, boundary):
    """Fused ternary+int8 layer (DIANA pairing): output cols [0, boundary)
    from the int8 codes ``w_q``, [boundary, N) from the ternary codes
    ``w_t`` (both contract the shared int8 activations; ``sw`` carries each
    domain's per-column dequant step).

    x_q (M,K) int8; w_q / w_t (K,N) int8 codes; sw (N,) f32. Returns f32
    (M,N)."""
    n = w_q.shape[1]
    lo = quant_matmul_ref(x_q, w_q, sx, sw)
    hi = quant_matmul_ref(x_q, w_t, sx, sw)
    cols = jnp.arange(n)[None, :]
    return jnp.where(cols < boundary, lo, hi)


def flash_attention_ref(q, k, v, causal=True):
    """q (B,H,Sq,D); k,v (B,KVH,Sk,D) with H = KVH*G. f32 softmax."""
    B, H, Sq, D = q.shape
    KVH, Sk = k.shape[1], k.shape[2]
    G = H // KVH
    qg = q.reshape(B, KVH, G, Sq, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * D ** -0.5
    if causal:
        mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)
