"""int8 gradient compression with error feedback (distributed-optimization
trick for the cross-pod all-reduce; DESIGN.md §5).

The pod axis crosses the slow DCN boundary: compressing gradients 4x (f32 ->
int8 + per-leaf scale) cuts that collective's bytes 4x.  Error feedback
(Seide et al.; Karimireddy et al. 2019) accumulates the quantization residual
locally so the compressed SGD converges like the uncompressed one.

Usage in the train step (pure-jax, works under pjit):
    comp, new_residual = compress_with_feedback(grads, residual)
    grads = decompress(comp)        # after the (cheap) all-reduce
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Q8(NamedTuple):
    """Compressed leaf: int8 codes + f32 scale (a pytree leaf marker —
    plain tuples would collide with tuple-structured params)."""
    codes: jax.Array
    scale: jax.Array


def _quant_leaf(g, r):
    g = g.astype(jnp.float32) + r
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    err = g - q.astype(jnp.float32) * scale
    return Q8(q, scale), err


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, residual):
    """-> (compressed tree of (int8 codes, scale), new residual tree)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    qs, errs = [], []
    for g, r in zip(flat_g, flat_r):
        q8, e = _quant_leaf(g, r)
        qs.append(q8)
        errs.append(e)
    return (jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, errs))


def decompress(compressed):
    def one(leaf):
        return leaf.codes.astype(jnp.float32) * leaf.scale
    return jax.tree_util.tree_map(one, compressed,
                                  is_leaf=lambda x: isinstance(x, Q8))


def compressed_bytes(compressed) -> int:
    tot = 0
    for leaf in jax.tree_util.tree_leaves(
            compressed, is_leaf=lambda x: isinstance(x, Q8)):
        tot += leaf.codes.size + 4
    return tot
