"""AdamW in pure JAX, with configurable moment dtype (bf16 moments let the
arctic-480b optimizer state fit a single v5e pod — DESIGN.md §5) and global
gradient-norm clipping.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float | None = 1.0
    moment_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=cfg.moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def update(grads, state: AdamWState, params, cfg: AdamWConfig, lr=None):
    """Returns (new_params, new_state, grad_norm)."""
    lr = cfg.lr if lr is None else lr
    if cfg.clip_norm is not None:
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gn = global_norm(grads)
    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd_one(p, g, m, v):
        g = g.astype(jnp.float32)
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        upd_ = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))
        return (newp.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    # NOTE: a lax.map-over-layer-slices variant of this update was tried to
    # shrink the f32 staging buffers on giant stacked leaves; it REGRESSED
    # arctic-480b train temps 37.3 -> 47.6 GiB/dev (map blocks XLA's
    # elementwise fusion + buffer reuse). Reverted; see EXPERIMENTS.md §Perf.
    upd = upd_one

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gn


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    """Linear warmup then cosine decay to floor*peak."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup, warm, cos)
