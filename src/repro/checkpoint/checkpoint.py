"""Sharded, atomic, resharding-capable checkpointing (no orbax offline).

Layout: one directory per step:
    <dir>/step_000123/
        manifest.json   — tree structure, shapes, dtypes, content hashes
        arrays.npz      — flat leaf arrays (host-gathered)
        _COMMITTED      — sentinel written LAST (atomic visibility)

Fault-tolerance properties:
  * atomic: writers stage into step_X.tmp-<nonce>/ and rename; readers only
    trust directories containing _COMMITTED  -> a killed writer never
    corrupts restore state (test_fault_tolerance.py simulates the kill)
  * self-validating: SHA1 per leaf, verified on load
  * resharding restore: arrays are saved unsharded (host view); restore
    applies ANY target sharding via jax.device_put — this is the elastic
    rescale path (save on 256 chips, restore on 512 or on 1 CPU)
  * async: save() can run on a background thread (async_save), with a
    .wait() handle, overlapping I/O with the next training step
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str):
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _encode(arr: np.ndarray) -> np.ndarray:
    """Byte view (npz can't store bfloat16 natively)."""
    return np.ascontiguousarray(arr).view(np.uint8).reshape(-1)


def _decode(raw: np.ndarray, dtype: str, shape) -> np.ndarray:
    return raw.view(_np_dtype(dtype)).reshape(shape)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_struct_str(treedef) -> str:
    return str(treedef)


def save(ckpt_dir: str | Path, step: int, tree: Any, extra: dict | None = None):
    """Synchronous atomic checkpoint of an arbitrary pytree of arrays."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(prefix=final.name + ".tmp-", dir=ckpt_dir))
    try:
        leaves, treedef = _flatten(tree)
        arrays = {}
        hashes = {}
        dtypes, shapes = {}, {}
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            dtypes[f"leaf_{i}"] = str(arr.dtype)
            shapes[f"leaf_{i}"] = list(arr.shape)
            raw = _encode(arr)
            arrays[f"leaf_{i}"] = raw
            hashes[f"leaf_{i}"] = hashlib.sha1(raw.tobytes()).hexdigest()
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": _tree_struct_str(treedef),
            "hashes": hashes,
            "dtypes": dtypes,
            "shapes": shapes,
            "extra": extra or {},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        (tmp / "_COMMITTED").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    """Highest COMMITTED step, ignoring torn/partial writes."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    best = None
    for d in ckpt_dir.iterdir():
        if d.is_dir() and d.name.startswith("step_") and ".tmp-" not in d.name \
                and (d / "_COMMITTED").exists():
            s = int(d.name.split("_")[1])
            best = s if best is None or s > best else best
    return best


def restore(ckpt_dir: str | Path, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of
    jax.sharding.Sharding — THE RESHARDING PATH: the checkpoint may have been
    written under any previous mesh; device_put lays it out for the new one.
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    if not (d / "_COMMITTED").exists():
        raise FileNotFoundError(f"no committed checkpoint at {d}")
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")
    leaves_like, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, target structure "
            f"has {len(leaves_like)} — refusing to restore")
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_like))
    out = []
    for i, (tgt, shd) in enumerate(zip(leaves_like, shard_leaves)):
        raw = data[f"leaf_{i}"]
        h = hashlib.sha1(raw.tobytes()).hexdigest()
        if h != manifest["hashes"][f"leaf_{i}"]:
            raise IOError(f"checkpoint corruption detected in leaf_{i}")
        arr = _decode(raw, manifest["dtypes"][f"leaf_{i}"],
                      manifest["shapes"][f"leaf_{i}"])
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"leaf_{i}: saved {arr.shape} != target {tgt.shape}")
        arr = np.asarray(arr.astype(_np_dtype(str(jax.numpy.dtype(tgt.dtype)))))
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_extra(ckpt_dir: str | Path, step: int) -> dict:
    d = Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text())["extra"]


class AsyncCheckpointer:
    """Background-thread checkpointing: snapshot to host, write off-thread,
    overlap with the next step.  One in-flight save at a time (a second save
    waits — bounded memory)."""

    def __init__(self, ckpt_dir: str | Path):
        self.ckpt_dir = Path(ckpt_dir)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: dict | None = None):
        self.wait()
        # snapshot on the caller thread (device_get) so the training loop can
        # donate/overwrite buffers immediately afterwards
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _run():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def prune_old(ckpt_dir: str | Path, keep: int = 3):
    """Retain the newest ``keep`` committed checkpoints."""
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    steps = sorted(
        int(d.name.split("_")[1]) for d in ckpt_dir.iterdir()
        if d.is_dir() and d.name.startswith("step_") and ".tmp-" not in d.name
        and (d / "_COMMITTED").exists())
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)
