"""Differentiable hardware cost models (paper Sec. III-C + Fig. 5 + TPU).

Every model maps a layer geometry plus the *expected* number of output
channels assigned to each precision domain, ``c_out_i(alpha)``, to a latency
per domain.  ``c_out_i`` is continuous during the DNAS search (sum of softmax
masses) and integer after discretization, so one code path serves both.

Ceil is handled with a straight-through estimator: exact forward value
(preserving the paper's rank-fidelity claim), identity gradient backward.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.quant import PrecisionDomain


def ste_ceil(x: jax.Array) -> jax.Array:
    """ceil(x) forward, identity gradient backward (cost models only)."""
    return x + jax.lax.stop_gradient(jnp.ceil(x) - x)


@dataclasses.dataclass(frozen=True)
class LayerGeometry:
    """Geometry of a Conv/FC layer as used by the latency models.

    Dense layers are the ``fx = fy = ox = oy = 1`` special case.
    """
    c_in: int
    c_out: int
    fx: int = 1
    fy: int = 1
    ox: int = 1
    oy: int = 1
    groups: int = 1  # depthwise convs: groups == c_in (pinned, not searched)

    @property
    def macs_per_out_channel(self) -> float:
        return (self.c_in // self.groups) * self.fx * self.fy * self.ox * self.oy

    def macs(self, c_out: float) -> float:
        return self.macs_per_out_channel * c_out


class CostModel:
    """Interface: latency per domain + active/idle powers per domain."""

    domains: Sequence[PrecisionDomain]

    def latency(self, geom: LayerGeometry, c_out_per_domain: jax.Array) -> jax.Array:
        """-> array (N,) of latencies, one per domain (0 channels -> 0)."""
        raise NotImplementedError

    def p_act(self) -> jax.Array:
        raise NotImplementedError

    def p_idle(self) -> jax.Array:
        raise NotImplementedError


class DianaCostModel(CostModel):
    """The paper's analytical DIANA models (Sec. III-C), bit-exact.

    Domain order is (digital, aimc).  Latencies are in cycles @ 260 MHz.
    Powers (mW) are representative of the ISSCC'22 DIANA numbers; they scale
    Table-I-style energy accounting but cancel in relative comparisons.
    """

    AIMC_ROWS = 1152     # c_in * fx * fy folded onto array rows
    AIMC_COLS = 512      # output channels per array program
    AIMC_DMA_FACTOR = 2 * 4
    DIG_PE_COUT = 16
    DIG_PE_OY = 16
    FREQ_HZ = 260e6

    def __init__(self, p_act_mw=(28.0, 12.0), p_idle_mw=(4.0, 2.0)):
        from repro.core.quant import DIANA_DOMAINS
        self.domains = DIANA_DOMAINS
        self._p_act = jnp.asarray(p_act_mw)
        self._p_idle = jnp.asarray(p_idle_mw)

    def lat_aimc(self, geom: LayerGeometry, c_out: jax.Array) -> jax.Array:
        n_col_programs = ste_ceil(c_out / self.AIMC_COLS)
        compute = (
            ste_ceil(geom.c_in * geom.fx * geom.fy / self.AIMC_ROWS)
            * n_col_programs * geom.ox * geom.oy
        )
        dma = self.AIMC_DMA_FACTOR * geom.c_in * n_col_programs
        return compute + dma

    def lat_digital(self, geom: LayerGeometry, c_out: jax.Array) -> jax.Array:
        compute = (
            ste_ceil(c_out / self.DIG_PE_COUT) * ste_ceil(geom.oy / self.DIG_PE_OY)
            * geom.c_in * geom.ox * geom.fx * geom.fy
        )
        wload = geom.c_in * c_out * geom.fx * geom.fy
        return compute + wload

    def latency(self, geom: LayerGeometry, c_out_per_domain: jax.Array) -> jax.Array:
        c_dig, c_aimc = c_out_per_domain[0], c_out_per_domain[1]
        lat = jnp.stack([self.lat_digital(geom, c_dig), self.lat_aimc(geom, c_aimc)])
        # A domain with (continuously) zero channels contributes zero latency.
        active = (c_out_per_domain > 1e-6).astype(lat.dtype)
        return lat * active

    def p_act(self) -> jax.Array:
        return self._p_act

    def p_idle(self) -> jax.Array:
        return self._p_idle

    def cycles_to_ms(self, cycles) -> jax.Array:
        return jnp.asarray(cycles) / self.FREQ_HZ * 1e3

    def energy_uj(self, lat_cycles: jax.Array, layer_max: jax.Array) -> jax.Array:
        """Eq. 4 for one layer, cycles+mW -> uJ."""
        t = lat_cycles / self.FREQ_HZ
        tm = layer_max / self.FREQ_HZ
        return jnp.sum(self._p_act * t + self._p_idle * (tm - t)) * 1e3


class AbstractCostModel(CostModel):
    """Fig. 5 models: latency proportional to OPs; P_act,8 = 10 * P_act,ter.

    ``ideal_shutdown=False`` -> P_idle = P_act  (energy == latency objective)
    ``ideal_shutdown=True``  -> P_idle = 0

    Generalizes to any domain tuple: ``domains`` (default: the paper's
    2-domain DIANA) with per-domain ``p_act`` and ``throughput`` (MACs per
    time unit; default 1 per domain reproduces the Fig. 5 OP-proportional
    latency) — enough to describe N-accelerator SoCs like the 3-domain
    ``gap9_like`` platform.
    """

    def __init__(self, ideal_shutdown: bool, p_act=(10.0, 1.0),
                 domains=None, throughput=None):
        from repro.core.quant import DIANA_DOMAINS
        self.domains = tuple(domains) if domains is not None \
            else tuple(DIANA_DOMAINS)
        n = len(self.domains)
        self.ideal_shutdown = ideal_shutdown
        self._p_act = jnp.asarray(p_act, jnp.float32)
        self._thr = (jnp.asarray(throughput, jnp.float32)
                     if throughput is not None else jnp.ones(n))
        if self._p_act.shape[0] != n or self._thr.shape[0] != n:
            raise ValueError(f"p_act/throughput must match {n} domains")
        self._p_idle = jnp.zeros(n) if ideal_shutdown else self._p_act

    def latency(self, geom: LayerGeometry, c_out_per_domain: jax.Array) -> jax.Array:
        return geom.macs_per_out_channel * c_out_per_domain / self._thr

    def p_act(self) -> jax.Array:
        return self._p_act

    def p_idle(self) -> jax.Array:
        return self._p_idle


class TPUCostModel(CostModel):
    """TPU-native roofline cost model (the hardware adaptation, DESIGN.md §2).

    Each precision domain i owns ``chips_i`` chips of the tensor-parallel
    group and computes its channel slice as
      LAT_i = max(FLOPs_i / (chips_i * peak_i),  bytes_i / (chips_i * hbm_bw))
    with int8 at 2x the bf16 MXU peak and weight bytes scaling with
    bit-width.  Energy uses per-FLOP/per-byte energies; idle power models the
    straggler cost of an unbalanced split, exactly the paper's Eq. 4 role.

    v5e constants: 197 TFLOP/s bf16, 394 TOP/s int8, 819 GB/s HBM.
    """

    HBM_BW = 819e9
    PEAK_BF16 = 197e12
    E_PER_FLOP_BF16 = 0.6e-12   # J, representative
    E_PER_BYTE = 12e-12         # J, HBM access
    P_IDLE_W = 60.0             # per-chip idle draw

    def __init__(self, domains: Sequence[PrecisionDomain] | None = None,
                 chips_per_domain: Sequence[int] = (1, 1)):
        from repro.core.quant import TPU_DOMAINS
        self.domains = tuple(domains) if domains is not None else TPU_DOMAINS
        self.chips = jnp.asarray(chips_per_domain, dtype=jnp.float32)
        peaks, wbytes, eflops = [], [], []
        for d in self.domains:
            if d.weight_bits <= 8:
                peaks.append(2 * self.PEAK_BF16)       # int8 MXU path
                wbytes.append(max(d.weight_bits, 4) / 8.0)
                eflops.append(self.E_PER_FLOP_BF16 / 2)
            else:
                peaks.append(self.PEAK_BF16)
                wbytes.append(2.0)
                eflops.append(self.E_PER_FLOP_BF16)
        self.peaks = jnp.asarray(peaks)
        self.wbytes = jnp.asarray(wbytes)
        self.eflops = jnp.asarray(eflops)

    def latency(self, geom: LayerGeometry, c_out_per_domain: jax.Array) -> jax.Array:
        flops = 2.0 * geom.macs_per_out_channel * c_out_per_domain
        bytes_ = geom.c_in * geom.fx * geom.fy * c_out_per_domain * self.wbytes
        t_comp = flops / (self.chips * self.peaks)
        t_mem = bytes_ / (self.chips * self.HBM_BW)
        lat = jnp.maximum(t_comp, t_mem)
        active = (c_out_per_domain > 1e-6).astype(lat.dtype)
        return lat * active

    def p_act(self) -> jax.Array:
        # Effective active power ~ peak * energy/flop per domain.
        return self.peaks * self.eflops

    def p_idle(self) -> jax.Array:
        return jnp.full(len(self.domains), self.P_IDLE_W)
