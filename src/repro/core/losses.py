"""ODiMO regularized objective (paper Eq. 2-4).

total = task_loss + lambda * cost_loss(alpha)

The per-layer latency is the max over parallel accelerators, smoothed with a
temperature-controlled LogSumExp (the paper's "smooth differentiable
approximation" of max).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.cost_models import CostModel, LayerGeometry


def smooth_max(x: jax.Array, beta: float = 1.0e-2, axis=-1) -> jax.Array:
    """LogSumExp smooth max: beta -> 0 recovers the hard max.

    ``beta`` is in units of x (it is a scale, not inverse scale):
    smax = beta * log(sum(exp(x / beta))).  Shift-invariant form for
    numerical stability.
    """
    m = jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    return (m + beta * jnp.log(jnp.sum(jnp.exp((x - m) / beta),
                                       axis=axis, keepdims=True))).squeeze(axis)


def expected_channels(alpha_bar: jax.Array) -> jax.Array:
    """alpha_bar: (N, C_out) softmax masses -> expected C_out per domain (N,)."""
    return jnp.sum(alpha_bar, axis=-1)


def latency_loss(cost_model: CostModel,
                 geoms: Sequence[LayerGeometry],
                 alpha_bars: Sequence[jax.Array],
                 smooth_beta: float | None = None) -> jax.Array:
    """Eq. 3: sum over layers of the (smooth) max latency across domains."""
    total = 0.0
    for geom, ab in zip(geoms, alpha_bars):
        lat = cost_model.latency(geom, expected_channels(ab))
        if smooth_beta is None:
            # auto scale: ~2% of the layer's mean latency
            beta = jnp.maximum(jnp.mean(lat) * 0.02, 1e-9)
        else:
            beta = smooth_beta
        total = total + smooth_max(lat, beta)
    return total


def energy_loss(cost_model: CostModel,
                geoms: Sequence[LayerGeometry],
                alpha_bars: Sequence[jax.Array],
                smooth_beta: float | None = None) -> jax.Array:
    """Eq. 4: sum_l sum_i P_act_i*LAT_i + P_idle_i*(M_l - LAT_i)."""
    p_act, p_idle = cost_model.p_act(), cost_model.p_idle()
    total = 0.0
    for geom, ab in zip(geoms, alpha_bars):
        lat = cost_model.latency(geom, expected_channels(ab))
        if smooth_beta is None:
            beta = jnp.maximum(jnp.mean(lat) * 0.02, 1e-9)
        else:
            beta = smooth_beta
        m = smooth_max(lat, beta)
        total = total + jnp.sum(p_act * lat + p_idle * (m - lat))
    return total


def exact_latency(cost_model: CostModel, geoms, counts_per_domain) -> jax.Array:
    """Hard-max latency of a discretized mapping (evaluation path)."""
    total = 0.0
    for geom, counts in zip(geoms, counts_per_domain):
        total = total + jnp.max(cost_model.latency(geom, jnp.asarray(counts, jnp.float32)))
    return total


def exact_energy(cost_model: CostModel, geoms, counts_per_domain) -> jax.Array:
    p_act, p_idle = cost_model.p_act(), cost_model.p_idle()
    total = 0.0
    for geom, counts in zip(geoms, counts_per_domain):
        lat = cost_model.latency(geom, jnp.asarray(counts, jnp.float32))
        m = jnp.max(lat)
        total = total + jnp.sum(p_act * lat + p_idle * (m - lat))
    return total


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
