"""ODiMO channel-wise DNAS mixing (paper Sec. III-A, Eq. 1).

For a weight tensor W with output channels on the LAST axis, we keep one
trainable vector alpha_i in R^{C_out} per precision domain plus one trainable
fake-quant log-scale per domain.  The effective weight is the per-channel
softmax(alpha / tau)-weighted sum of the N fake-quantized copies.

Pure-functional: parameters live in plain dicts (pytrees).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import PrecisionDomain


@dataclasses.dataclass(frozen=True)
class ODiMOSpec:
    """Search configuration shared by every ODiMO-managed layer."""
    domains: Sequence[PrecisionDomain] = quant.DIANA_DOMAINS
    init_tau: float = 1.0
    final_tau: float = 0.05
    act_bits: int = 7          # worst case of the domains (paper Sec. III-B)

    @property
    def n_domains(self) -> int:
        return len(self.domains)


def init_layer_state(key: jax.Array, w: jax.Array, spec: ODiMOSpec) -> dict:
    """Per-layer ODiMO state: alpha (N, C_out) and per-domain log-scales."""
    c_out = w.shape[-1]
    n = spec.n_domains
    # Small symmetric noise so channels can break ties; near-uniform start.
    alpha = 0.01 * jax.random.normal(key, (n, c_out), dtype=jnp.float32)
    log_scales = jnp.stack([quant.init_log_scale(w) for _ in range(n)])
    return {"alpha": alpha, "log_scales": log_scales}


def alpha_bar(alpha: jax.Array, tau: float) -> jax.Array:
    """(N, C_out) softmax over the domain axis with temperature tau."""
    return jax.nn.softmax(alpha / tau, axis=0)


def effective_weight(w: jax.Array, state: dict, spec: ODiMOSpec,
                     tau: float) -> jax.Array:
    """Eq. 1: hat(W)_c = sum_i abar_{c,i} * fake_quant_i(W_c)."""
    ab = alpha_bar(state["alpha"], tau)  # (N, C_out)
    out = jnp.zeros_like(w)
    for i, dom in enumerate(spec.domains):
        wq = quant.fake_quant(w, state["log_scales"][i], dom.weight_bits)
        out = out + ab[i] * wq  # broadcast over the last (C_out) axis
    return out


def discretized_weight(w: jax.Array, state: dict, spec: ODiMOSpec) -> jax.Array:
    """Post-search weight: each channel quantized by its argmax domain."""
    assign = jnp.argmax(state["alpha"], axis=0)  # (C_out,)
    out = jnp.zeros_like(w)
    for i, dom in enumerate(spec.domains):
        wq = quant.fake_quant(w, state["log_scales"][i], dom.weight_bits)
        out = out + jnp.where(assign == i, wq, 0.0)
    return out


def assignment(state: dict) -> jax.Array:
    """(C_out,) int array: argmax domain index per output channel."""
    return jnp.argmax(state["alpha"], axis=0)


def domain_counts(state: dict, n_domains: int) -> jax.Array:
    """Discrete channel count per domain after argmax."""
    a = assignment(state)
    return jnp.asarray([jnp.sum(a == i) for i in range(n_domains)])


def expected_counts(state: dict, tau: float) -> jax.Array:
    """Continuous (search-time) channel mass per domain: sum_c abar."""
    return jnp.sum(alpha_bar(state["alpha"], tau), axis=-1)


def tau_schedule(step: int | jax.Array, total_steps: int, spec: ODiMOSpec):
    """Exponential temperature annealing init_tau -> final_tau."""
    frac = jnp.clip(jnp.asarray(step, jnp.float32) / max(total_steps, 1), 0.0, 1.0)
    log_t = (1 - frac) * jnp.log(spec.init_tau) + frac * jnp.log(spec.final_tau)
    return jnp.exp(log_t)
