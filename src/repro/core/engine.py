"""ODiMO end-to-end search engine (paper Sec. III-B training flow).

    pretrain (fp) -> DNAS search (Eq. 2, tau annealed) -> discretize
    -> fine-tune (task loss only, exact formats) -> evaluate mapping

Generic over a model façade (init/apply/plan from models/cnn.py or the LM
zoo).  Jit-compiled steps; everything runs on CPU for the repro and on the
production mesh via launch/train.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, odimo
from repro.core.cost_models import CostModel, LayerGeometry
from repro.core.odimo import ODiMOSpec
from repro.optim import adamw


@dataclasses.dataclass
class SearchConfig:
    lam: float = 1e-6                 # lambda, regularization strength (Eq. 2)
    objective: str = "latency"        # or "energy"
    pretrain_steps: int = 300
    search_steps: int = 400
    finetune_steps: int = 300
    batch: int = 64
    lr: float = 2e-3
    alpha_lr: float = 1e-2
    eval_batches: int = 8
    seed: int = 0


def _split_params(params):
    """Partition pytree leaves into (alpha, rest) for two-group optimization."""
    def is_alpha(path):
        return any(getattr(p, "key", None) == "alpha" for p in path)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return flat


def make_loss_fn(apply_fn, plan, spec: ODiMOSpec, cost_model: CostModel,
                 cfg: SearchConfig, managed_paths_fn):
    geoms = [g for (_, g, s) in plan]
    searchable = [s for (_, g, s) in plan]

    def loss_fn(params, batch, tau, mode):
        x, y = batch
        logits = apply_fn(params, x, mode=mode, tau=tau)
        task = losses.cross_entropy(logits, y)
        if mode != "search":
            return task, (task, 0.0)
        layer_dicts = managed_paths_fn(params)
        abars, g_s = [], []
        for d, geom, s in zip(layer_dicts, geoms, searchable):
            if not s or "odimo" not in d:
                continue
            abars.append(odimo.alpha_bar(d["odimo"]["alpha"], tau))
            g_s.append(geom)
        if cfg.objective == "latency":
            reg = losses.latency_loss(cost_model, g_s, abars)
        else:
            reg = losses.energy_loss(cost_model, g_s, abars)
        return task + cfg.lam * reg, (task, reg)

    return loss_fn


@dataclasses.dataclass
class SearchResult:
    params: Any
    assignments: List[np.ndarray]       # per searchable layer, (C_out,) ints
    counts: List[np.ndarray]
    accuracy: float
    latency: float                      # exact, discretized (cost-model units)
    energy: float
    history: dict


def run_odimo(model, cfg_model, spec: ODiMOSpec, cost_model: CostModel,
              scfg: SearchConfig, data_fn: Callable[[int, int], Any],
              verbose: bool = False, managed_fn=None) -> SearchResult:
    """Full paper pipeline on a model façade.

    model = (init_fn, apply_fn, plan_fn) with signatures from models/cnn.py;
    data_fn(step, batch) -> (x, y).  ``managed_fn(params) -> [layer dicts]``
    overrides the CNN-path lookup for non-CNN façades (e.g. MLP/transformer
    stacks; see examples/odimo_tpu_domains.py).
    """
    init_fn, apply_raw, plan_fn = model
    plan = plan_fn(cfg_model)
    geoms = [g for (_, g, _) in plan]
    searchable = [s for (_, _, s) in plan]

    if managed_fn is None:
        from repro.models import cnn as _cnn
        managed_fn = lambda p: _cnn.managed_layer_dicts(p, cfg_model)
    managed_paths_fn = managed_fn

    apply_fn = lambda p, x, mode, tau: apply_raw(p, x, cfg_model, spec, mode, tau)

    key = jax.random.PRNGKey(scfg.seed)
    params = init_fn(key, cfg_model, spec)

    ocfg = adamw.AdamWConfig(lr=scfg.lr)
    loss_fn = make_loss_fn(apply_fn, plan, spec, cost_model, scfg, managed_paths_fn)

    @partial(jax.jit, static_argnames=("mode",))
    def train_step(params, opt, batch, tau, lr, mode):
        (l, (task, reg)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, tau, mode)
        # alpha gets its own lr by pre-scaling its grads
        ratio = scfg.alpha_lr / scfg.lr

        def scale(path, g):
            if any(getattr(p, "key", None) == "alpha" for p in path):
                return g * ratio
            return g
        grads = jax.tree_util.tree_map_with_path(scale, grads)
        params, opt, gn = adamw.update(grads, opt, params, ocfg, lr=lr)
        return params, opt, l, task, reg

    @partial(jax.jit, static_argnames=("mode",))
    def eval_step(params, batch, tau, mode):
        x, y = batch
        logits = apply_fn(params, x, mode=mode, tau=tau)
        return jnp.mean(jnp.argmax(logits, -1) == y)

    history = {"pretrain": [], "search": [], "finetune": []}

    # ---- phase 1: fp pretrain -------------------------------------------
    opt = adamw.init(params, ocfg)
    for step in range(scfg.pretrain_steps):
        batch = data_fn(step, scfg.batch)
        params, opt, l, task, _ = train_step(params, opt, batch, 1.0, scfg.lr, "fp")
        if verbose and step % 100 == 0:
            print(f"[pretrain {step}] loss={float(l):.4f}")
        history["pretrain"].append(float(l))

    # ---- phase 2: DNAS search (Eq. 2) -----------------------------------
    opt = adamw.init(params, ocfg)
    for step in range(scfg.search_steps):
        tau = float(odimo.tau_schedule(step, scfg.search_steps, spec))
        batch = data_fn(10_000 + step, scfg.batch)
        params, opt, l, task, reg = train_step(params, opt, batch, tau, scfg.lr, "search")
        if verbose and step % 100 == 0:
            print(f"[search {step}] loss={float(l):.4f} task={float(task):.4f} "
                  f"reg={float(reg):.3e} tau={tau:.3f}")
        history["search"].append((float(task), float(reg)))

    # ---- phase 3: discretize --------------------------------------------
    layer_dicts = managed_paths_fn(params)
    assignments, counts = [], []
    for d, s in zip(layer_dicts, searchable):
        if s and "odimo" in d:
            a = np.asarray(odimo.assignment(d["odimo"]))
        else:
            a = np.zeros(d["w"].shape[-1], dtype=np.int64)  # pinned: domain 0
        assignments.append(a)
        counts.append(np.asarray([int((a == i).sum()) for i in range(spec.n_domains)]))

    # ---- phase 4: fine-tune (task loss only, exact formats) --------------
    opt = adamw.init(params, ocfg)
    for step in range(scfg.finetune_steps):
        batch = data_fn(20_000 + step, scfg.batch)
        params, opt, l, task, _ = train_step(params, opt, batch, 1.0,
                                             scfg.lr * 0.3, "finetune")
        history["finetune"].append(float(l))

    # ---- evaluate --------------------------------------------------------
    accs = []
    for b in range(scfg.eval_batches):
        batch = data_fn(90_000 + b, scfg.batch)
        accs.append(float(eval_step(params, batch, 1.0, "finetune")))
    acc = float(np.mean(accs))

    lat = float(losses.exact_latency(cost_model, geoms, counts))
    en = float(losses.exact_energy(cost_model, geoms, counts))
    return SearchResult(params=params, assignments=assignments, counts=counts,
                        accuracy=acc, latency=lat, energy=en, history=history)


def evaluate_fixed_mapping(model, cfg_model, spec, cost_model: CostModel,
                           scfg: SearchConfig, data_fn,
                           assignments: List[np.ndarray],
                           train_steps: int | None = None) -> SearchResult:
    """Train a model with a FIXED channel->domain mapping (the baselines)."""
    init_fn, apply_raw, plan_fn = model
    plan = plan_fn(cfg_model)
    geoms = [g for (_, g, _) in plan]
    apply_fn = lambda p, x, mode, tau: apply_raw(p, x, cfg_model, spec, mode, tau)

    key = jax.random.PRNGKey(scfg.seed)
    params = init_fn(key, cfg_model, spec)

    # overwrite alpha with one-hot of the fixed assignment (large margin)
    from repro.models import cnn as _cnn
    layer_dicts = _cnn.managed_layer_dicts(params, cfg_model)
    for d, a in zip(layer_dicts, assignments):
        onehot = jnp.asarray(np.eye(spec.n_domains)[a].T * 10.0)  # (N, C)
        d["odimo"]["alpha"] = onehot

    ocfg = adamw.AdamWConfig(lr=scfg.lr)
    loss_fn = make_loss_fn(apply_fn, plan, spec, cost_model, scfg,
                           lambda p: _cnn.managed_layer_dicts(p, cfg_model))

    @jax.jit
    def ft_step(params, opt, batch, lr):
        def lf(p):
            x, y = batch
            logits = apply_fn(p, x, mode="finetune", tau=1.0)
            return losses.cross_entropy(logits, y)
        l, grads = jax.value_and_grad(lf)(params)
        # freeze alpha during fixed-mapping training
        grads = jax.tree_util.tree_map_with_path(
            lambda path, g: (jnp.zeros_like(g)
                             if any(getattr(q, "key", None) == "alpha" for q in path)
                             else g), grads)
        params, opt, _ = adamw.update(grads, opt, params, ocfg, lr=lr)
        return params, opt, l

    @jax.jit
    def eval_step(params, batch):
        x, y = batch
        logits = apply_fn(params, x, mode="finetune", tau=1.0)
        return jnp.mean(jnp.argmax(logits, -1) == y)

    steps = train_steps if train_steps is not None else (
        scfg.pretrain_steps + scfg.finetune_steps)
    opt = adamw.init(params, ocfg)
    for step in range(steps):
        params, opt, l = ft_step(params, opt, data_fn(step, scfg.batch), scfg.lr)

    accs = [float(eval_step(params, data_fn(90_000 + b, scfg.batch)))
            for b in range(scfg.eval_batches)]
    counts = [np.asarray([int((a == i).sum()) for i in range(spec.n_domains)])
              for a in assignments]
    lat = float(losses.exact_latency(cost_model, geoms, counts))
    en = float(losses.exact_energy(cost_model, geoms, counts))
    return SearchResult(params=params, assignments=list(assignments),
                        counts=counts, accuracy=float(np.mean(accs)),
                        latency=lat, energy=en, history={})
