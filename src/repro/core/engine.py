"""ODiMO end-to-end search engine (paper Sec. III-B training flow).

    pretrain (fp) -> DNAS search (Eq. 2, tau annealed) -> discretize
    -> fine-tune (task loss only, exact formats) -> evaluate mapping

The flow itself lives in `repro.api.pipeline` as composable stages; this
module keeps the shared configuration/result types, the Eq. 2 loss builder,
and thin back-compat wrappers (`run_odimo`, `evaluate_fixed_mapping`) over
the legacy ``(init_fn, apply_fn, plan_fn)`` tuple façade.  New code should
use `repro.api` directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List

import numpy as np

from repro.core import losses, odimo
from repro.core.cost_models import CostModel
from repro.core.odimo import ODiMOSpec


@dataclasses.dataclass
class SearchConfig:
    lam: float = 1e-6                 # lambda, regularization strength (Eq. 2)
    objective: str = "latency"        # or "energy"
    pretrain_steps: int = 300
    search_steps: int = 400
    finetune_steps: int = 300
    batch: int = 64
    lr: float = 2e-3
    alpha_lr: float = 1e-2
    eval_batches: int = 8
    seed: int = 0


def make_loss_fn(apply_fn, plan, spec: ODiMOSpec, cost_model: CostModel,
                 cfg: SearchConfig, managed_paths_fn):
    geoms = [g for (_, g, s) in plan]
    searchable = [s for (_, g, s) in plan]

    def loss_fn(params, batch, tau, mode):
        x, y = batch
        logits = apply_fn(params, x, mode=mode, tau=tau)
        task = losses.cross_entropy(logits, y)
        if mode != "search":
            return task, (task, 0.0)
        layer_dicts = managed_paths_fn(params)
        abars, g_s = [], []
        for d, geom, s in zip(layer_dicts, geoms, searchable):
            if not s or "odimo" not in d:
                continue
            abars.append(odimo.alpha_bar(d["odimo"]["alpha"], tau))
            g_s.append(geom)
        if cfg.objective == "latency":
            reg = losses.latency_loss(cost_model, g_s, abars)
        else:
            reg = losses.energy_loss(cost_model, g_s, abars)
        return task + cfg.lam * reg, (task, reg)

    return loss_fn


@dataclasses.dataclass
class SearchResult:
    params: Any
    assignments: List[np.ndarray]       # per searchable layer, (C_out,) ints
    counts: List[np.ndarray]
    accuracy: float
    latency: float                      # exact, discretized (cost-model units)
    energy: float
    history: dict


def _as_search_result(res) -> SearchResult:
    return SearchResult(params=res.params, assignments=res.assignments,
                        counts=res.counts, accuracy=res.accuracy,
                        latency=res.latency, energy=res.energy,
                        history=res.history)


def run_odimo(model, cfg_model, spec: ODiMOSpec, cost_model: CostModel,
              scfg: SearchConfig, data_fn: Callable[[int, int], Any],
              verbose: bool = False, managed_fn=None) -> SearchResult:
    """Back-compat wrapper: full paper pipeline on a legacy model façade.

    ``model = (init_fn, apply_fn, plan_fn)`` with signatures from
    models/cnn.py.  ``managed_fn(params) -> [layer dicts]`` overrides the
    default plan-name path lookup for custom pytree layouts.  New code:
    ``repro.api.SearchPipeline``.
    """
    from repro.api import ModelHandle, SearchPipeline, VerboseCallback
    handle = ModelHandle.from_legacy(model, cfg_model, managed_fn=managed_fn)
    pipe = SearchPipeline(handle, spec=spec, cost_model=cost_model,
                          config=scfg, data_fn=data_fn,
                          callbacks=(VerboseCallback(),) if verbose else ())
    return _as_search_result(pipe.run())


def evaluate_fixed_mapping(model, cfg_model, spec, cost_model: CostModel,
                           scfg: SearchConfig, data_fn,
                           assignments: List[np.ndarray],
                           train_steps: int | None = None,
                           managed_fn=None) -> SearchResult:
    """Back-compat wrapper: train with a FIXED channel->domain mapping (the
    baselines).  New code: ``repro.api.SearchPipeline.fixed_mapping``."""
    from repro.api import ModelHandle, SearchPipeline
    handle = ModelHandle.from_legacy(model, cfg_model, managed_fn=managed_fn)
    pipe = SearchPipeline.fixed_mapping(handle, assignments,
                                        train_steps=train_steps, spec=spec,
                                        cost_model=cost_model, config=scfg,
                                        data_fn=data_fn)
    return _as_search_result(pipe.run())
