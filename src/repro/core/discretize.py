"""Discretization + layer-reorganization pass (paper Fig. 3).

After the DNAS search, every output channel is assigned to its argmax
domain.  Channels mapped to the same accelerator are in general scattered;
this pass permutes each layer's output channels (and the NEXT layer's input
channels) so same-domain channels become contiguous, splitting the layer into
N independent sub-layers deployable in parallel with zero data marshaling.

Weight layout conventions:
  Dense  W: (C_in, C_out)          -> out axis -1, in axis 0
  Conv   W: (kh, kw, C_in, C_out)  -> out axis -1, in axis -2
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ReorgLayer:
    """One ODiMO-managed layer in a sequential chain."""
    w: jax.Array                 # out channels on last axis
    b: jax.Array | None          # (C_out,) or None
    assign: np.ndarray           # (C_out,) int domain index per channel
    in_axis: int = 0             # axis of w indexed by the PREVIOUS layer's perm
    extras: dict | None = None   # other per-out-channel tensors (e.g. bn stats)


def stable_perm(assign: np.ndarray) -> np.ndarray:
    """Permutation grouping channels by domain id, preserving relative order."""
    return np.argsort(assign, kind="stable")


def split_points(assign_sorted: np.ndarray, n_domains: int) -> List[int]:
    """Cumulative boundaries of the contiguous domain groups after sorting."""
    counts = [int(np.sum(assign_sorted == i)) for i in range(n_domains)]
    bounds, acc = [], 0
    for c in counts:
        acc += c
        bounds.append(acc)
    return bounds


def reorg_chain(layers: Sequence[ReorgLayer], n_domains: int):
    """Apply the Fig. 3 pass to a sequential chain of layers.

    Returns (new_layers, per-layer split boundaries).  Layer l's output perm
    is propagated into layer l+1's input axis; the final layer's outputs are
    NOT permuted (network outputs must keep their meaning), matching the
    paper's deployment flow where the classifier output order is fixed.
    """
    new_layers: List[ReorgLayer] = []
    bounds_per_layer: List[List[int]] = []
    prev_perm: np.ndarray | None = None
    last = len(layers) - 1
    for li, layer in enumerate(layers):
        w, b = layer.w, layer.b
        if prev_perm is not None:
            w = jnp.take(w, prev_perm, axis=layer.in_axis)
        if li == last:
            perm = np.arange(layer.assign.shape[0])
        else:
            perm = stable_perm(layer.assign)
        w = jnp.take(w, perm, axis=-1)
        if b is not None:
            b = jnp.take(b, perm, axis=0)
        extras = None
        if layer.extras:
            extras = {k: jnp.take(v, perm, axis=-1) for k, v in layer.extras.items()}
        a_sorted = layer.assign[perm]
        new_layers.append(ReorgLayer(w=w, b=b, assign=a_sorted,
                                     in_axis=layer.in_axis, extras=extras))
        bounds_per_layer.append(split_points(a_sorted, n_domains))
        prev_perm = perm
    return new_layers, bounds_per_layer


def sublayer_slices(bounds: List[int]):
    """[(start, end)] per domain from cumulative boundaries."""
    out, start = [], 0
    for end in bounds:
        out.append((start, end))
        start = end
    return out


# --------------------------------------------------------------------------
# Mapping-artifact consumption (repro.api JSON schema; plain dicts here so
# core never imports api)
# --------------------------------------------------------------------------

def assignments_from_artifact(artifact) -> List[np.ndarray]:
    """Per-layer (C_out,) domain assignments from a mapping artifact
    (a `repro.api.MappingArtifact` or its plain-dict/JSON form)."""
    if hasattr(artifact, "to_dict"):
        artifact = artifact.to_dict()
    return [np.asarray(l["assignment"], dtype=np.int64)
            for l in artifact["layers"]]


def reorg_chain_from_artifact(layers: Sequence[ReorgLayer], artifact):
    """Fig. 3 reorg pass driven by a stored mapping artifact.

    ``layers`` is the sequential chain in artifact layer order; each layer's
    ``assign`` is overridden by the artifact's assignment, then `reorg_chain`
    runs with the artifact's domain count."""
    if hasattr(artifact, "to_dict"):
        artifact = artifact.to_dict()
    assigns = assignments_from_artifact(artifact)
    if len(assigns) != len(layers):
        raise ValueError(f"artifact has {len(assigns)} layers, chain has "
                         f"{len(layers)}")
    layers = [dataclasses.replace(l, assign=a)
              for l, a in zip(layers, assigns)]
    return reorg_chain(layers, len(artifact["domains"]))
