"""ODiMO core: precision-aware multi-accelerator mapping as differentiable search."""
from repro.core.quant import (
    PrecisionDomain, DIANA_DOMAINS, TPU_DOMAINS, DIANA_DIGITAL, DIANA_AIMC,
    fake_quant, fake_quant_act, quantize_int, dequantize_int,
)
from repro.core.odimo import (
    ODiMOSpec, init_layer_state, effective_weight, discretized_weight,
    alpha_bar, assignment, domain_counts, expected_counts, tau_schedule,
)
from repro.core.cost_models import (
    CostModel, DianaCostModel, AbstractCostModel, TPUCostModel, LayerGeometry,
)
from repro.core.losses import (
    smooth_max, latency_loss, energy_loss, exact_latency, exact_energy,
    cross_entropy,
)
from repro.core.discretize import ReorgLayer, reorg_chain, sublayer_slices, split_points
from repro.core import baselines
