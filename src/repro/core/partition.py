"""Precision-domain -> tensor-parallel sub-mesh planning (DESIGN.md §2.2).

On DIANA the N accelerators are physically distinct units sharing an L1; on
a TPU pod the analogue is a PARTITION of the tensor-parallel axis: domain i
gets a contiguous sub-group of the `model` axis sized proportionally to its
latency share, so all domains finish together (the paper's smooth-max
balance, solved exactly at the device-allocation level).

Given the per-layer channel counts ODiMO discretized, this module:
  * sizes each domain's sub-group (water-filling on the roofline latency),
  * emits per-layer column offsets into the reorganized weight matrix
    (Fig. 3 layout) for each sub-group,
  * verifies the plan (all channels covered exactly once, device counts sum
    to the axis size).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.cost_models import CostModel, LayerGeometry


@dataclasses.dataclass(frozen=True)
class DomainShard:
    domain: int          # precision-domain index
    devices: int         # devices of the model axis assigned to the domain
    col_start: int       # first output channel (post-reorg) of this domain
    col_end: int


@dataclasses.dataclass
class LayerPlan:
    geom: LayerGeometry
    shards: List[DomainShard]

    def check(self, tp_size: int):
        cols = sorted((s.col_start, s.col_end) for s in self.shards)
        assert cols[0][0] == 0 and cols[-1][1] == self.geom.c_out
        for (a, b), (c, d) in zip(cols, cols[1:]):
            assert b == c, "channel ranges must tile exactly"
        assert sum(s.devices for s in self.shards) == tp_size


def size_subgroups(cost_model: CostModel, geom: LayerGeometry,
                   counts: Sequence[int], tp_size: int) -> List[int]:
    """Devices per domain ∝ that domain's single-device latency share
    (equalizes finish times — the max in Eq. 3 becomes tight)."""
    lat = np.asarray(cost_model.latency(
        geom, np.asarray(counts, np.float32)))
    lat = np.maximum(lat, 0.0)
    if lat.sum() == 0:
        out = [0] * len(counts)
        out[0] = tp_size
        return out
    raw = lat / lat.sum() * tp_size
    dev = np.floor(raw).astype(int)
    # give leftovers to the largest fractional parts; every active domain
    # gets at least one device
    active = np.asarray(counts) > 0
    dev[active & (dev == 0)] = 1
    while dev.sum() > tp_size:
        i = int(np.argmax(dev))
        dev[i] -= 1
    frac = raw - np.floor(raw)
    order = np.argsort(-frac)
    k = 0
    while dev.sum() < tp_size:
        i = int(order[k % len(order)])
        if active[i] or dev.sum() + 1 == tp_size:
            dev[i] += 1
        k += 1
    return [int(d) for d in dev]


def plan_layer(cost_model: CostModel, geom: LayerGeometry,
               counts: Sequence[int], tp_size: int) -> LayerPlan:
    """Reorg-ordered channel ranges + device allocation for one layer."""
    devs = size_subgroups(cost_model, geom, counts, tp_size)
    shards, col = [], 0
    for i, (c, d) in enumerate(zip(counts, devs)):
        shards.append(DomainShard(domain=i, devices=d, col_start=col,
                                  col_end=col + int(c)))
        col += int(c)
    plan = LayerPlan(geom=geom, shards=shards)
    plan.check(tp_size)
    return plan


def plan_network(cost_model: CostModel, geoms: Sequence[LayerGeometry],
                 counts_per_layer: Sequence[Sequence[int]],
                 tp_size: int) -> List[LayerPlan]:
    return [plan_layer(cost_model, g, c, tp_size)
            for g, c in zip(geoms, counts_per_layer)]
