"""Fake-quantization primitives (paper Eq. 5, FQ-Conv / PACT style).

Q(x) = (e^s / (2^{n-1}-1)) * round((2^{n-1}-1) * clip(x / e^s, -1, 1))

with a trainable log-scale ``s`` and straight-through-estimator (STE)
gradients through ``round``.  ``n = 2`` yields ternarization {-1, 0, +1}
(DIANA's AIMC weight format); ``n = 8`` is the digital accelerator format.

All functions are pure and jit-safe.  Output-channel axis is always the
LAST axis of a weight tensor (Dense: (in, out); Conv HWIO: (kh, kw, in, out)).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


def _ste_round(x: jax.Array) -> jax.Array:
    """round(x) forward, identity gradient backward."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _ste_floor(x: jax.Array) -> jax.Array:
    return x + jax.lax.stop_gradient(jnp.floor(x) - x)


def qlevels(n_bits: int) -> int:
    """Number of positive levels of a symmetric signed n-bit format."""
    return 2 ** (n_bits - 1) - 1


def fake_quant(x: jax.Array, log_scale: jax.Array, n_bits: int) -> jax.Array:
    """Symmetric signed fake-quantization with trainable scale (Eq. 5).

    ``log_scale`` may be a scalar (per-tensor) or broadcastable to the last
    axis of ``x`` (per-channel).
    """
    if n_bits >= 16:  # identity domain (bf16/fp: no fake-quant error modeled)
        return x
    levels = qlevels(n_bits)
    scale = jnp.exp(log_scale)
    xn = jnp.clip(x / scale, -1.0, 1.0)
    q = _ste_round(xn * levels) / levels
    return q * scale


def quantize_int(x: jax.Array, log_scale: jax.Array, n_bits: int) -> jax.Array:
    """True integer quantization (deployment path): returns int8 codes."""
    levels = qlevels(n_bits)
    scale = jnp.exp(log_scale)
    xn = jnp.clip(x / scale, -1.0, 1.0)
    return jnp.round(xn * levels).astype(jnp.int8)


def dequantize_int(q: jax.Array, log_scale: jax.Array, n_bits: int) -> jax.Array:
    levels = qlevels(n_bits)
    return q.astype(jnp.float32) * (jnp.exp(log_scale) / levels)


def fake_quant_act(x: jax.Array, log_scale: jax.Array, n_bits: int) -> jax.Array:
    """Unsigned activation fake-quantization (post-ReLU ranges), clip [0, 1].

    The paper stores shared activations on 8-bit and truncates the LSB for the
    AIMC 7-bit converters; ``truncate_lsb`` models that exactly.
    """
    if n_bits >= 16:
        return x
    levels = 2**n_bits - 1
    scale = jnp.exp(log_scale)
    xn = jnp.clip(x / scale, 0.0, 1.0)
    q = _ste_round(xn * levels) / levels
    return q * scale


def truncate_lsb(x_codes: jax.Array) -> jax.Array:
    """Drop the least-significant bit of 8-bit activation codes (7-bit D/A)."""
    return (x_codes.astype(jnp.int32) >> 1) << 1


def init_log_scale(w: jax.Array, per_channel: bool = False) -> jax.Array:
    """Initialize the log-scale from the tensor's max-abs statistics."""
    if per_channel:
        red = tuple(range(w.ndim - 1))
        m = jnp.max(jnp.abs(w), axis=red)
    else:
        m = jnp.max(jnp.abs(w))
    return jnp.log(jnp.maximum(m, 1e-8))


@dataclasses.dataclass(frozen=True)
class PrecisionDomain:
    """One 'accelerator' in ODiMO's view: a precision + a cost identity.

    On DIANA: ``digital`` (8-bit) and ``aimc`` (ternary, n=2).
    On TPU: precision domains of the MXU (int8 @ 2x peak, bf16) and/or
    disjoint tensor-parallel sub-groups.
    """
    name: str
    weight_bits: int          # 2 => ternary, 8 => int8, >=16 => bf16 identity
    act_bits: int = 8

    @property
    def is_identity(self) -> bool:
        return self.weight_bits >= 16


# The DIANA SoC of the paper (Sec. II-A / III-B).
DIANA_DIGITAL = PrecisionDomain("digital", weight_bits=8, act_bits=8)
DIANA_AIMC = PrecisionDomain("aimc", weight_bits=2, act_bits=7)
DIANA_DOMAINS: Sequence[PrecisionDomain] = (DIANA_DIGITAL, DIANA_AIMC)

# TPU precision domains (int8 MXU path at 2x bf16 peak; bf16 identity).
TPU_INT8 = PrecisionDomain("int8", weight_bits=8, act_bits=8)
TPU_INT4 = PrecisionDomain("int4", weight_bits=4, act_bits=8)
TPU_BF16 = PrecisionDomain("bf16", weight_bits=16, act_bits=16)
TPU_DOMAINS: Sequence[PrecisionDomain] = (TPU_INT8, TPU_BF16)
