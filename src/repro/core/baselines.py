"""Baseline mappings from the paper (Sec. IV-A).

  all_8bit        : every channel on the digital (8-bit) accelerator
  all_ternary     : every channel on the AIMC (ternary) accelerator
  io8_backbone_ter: first and last layers digital, everything else AIMC [6]
  min_cost        : per-layer channel split statically minimizing Eq. 3 or
                    Eq. 4, ignoring accuracy; ties maximize digital channels.

Assignments are (C_out,) int arrays with the cost model's domain indexing
(domain 0 = digital/8-bit, domain 1 = AIMC/ternary for DIANA).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np
import jax.numpy as jnp

from repro.core.cost_models import CostModel, LayerGeometry


def all_domain(geoms: Sequence[LayerGeometry], domain: int) -> List[np.ndarray]:
    return [np.full(g.c_out, domain, dtype=np.int64) for g in geoms]


def all_8bit(geoms: Sequence[LayerGeometry]) -> List[np.ndarray]:
    return all_domain(geoms, 0)


def all_ternary(geoms: Sequence[LayerGeometry]) -> List[np.ndarray]:
    return all_domain(geoms, 1)


def io8_backbone_ternary(geoms: Sequence[LayerGeometry]) -> List[np.ndarray]:
    out = all_domain(geoms, 1)
    out[0][:] = 0
    out[-1][:] = 0
    return out


def _layer_cost(cm: CostModel, geom: LayerGeometry, k_dig: int,
                objective: str) -> float:
    counts = jnp.asarray([k_dig, geom.c_out - k_dig], dtype=jnp.float32)
    lat = cm.latency(geom, counts)
    m = jnp.max(lat)
    if objective == "latency":
        return float(m)
    p_act, p_idle = cm.p_act(), cm.p_idle()
    return float(jnp.sum(p_act * lat + p_idle * (m - lat)))


def min_cost(cm: CostModel, geoms: Sequence[LayerGeometry],
             objective: str = "latency",
             searchable: Sequence[bool] | None = None) -> List[np.ndarray]:
    """Exhaustive per-layer split search (C_out <= few thousand => cheap).

    ``searchable[l] = False`` pins layer l to the digital domain (the paper's
    depthwise-conv rule on DIANA).
    """
    assigns: List[np.ndarray] = []
    for li, geom in enumerate(geoms):
        if searchable is not None and not searchable[li]:
            assigns.append(np.zeros(geom.c_out, dtype=np.int64))
            continue
        best_k, best_cost = 0, float("inf")
        for k in range(geom.c_out + 1):
            c = _layer_cost(cm, geom, k, objective)
            # ties keep the LARGER digital count (expected to help accuracy)
            rel = abs(best_cost) if best_cost != float("inf") else 1.0
            if c < best_cost - 1e-9 * rel or abs(c - best_cost) <= 1e-9 * rel:
                best_cost, best_k = min(c, best_cost), k
        a = np.ones(geom.c_out, dtype=np.int64)
        a[:best_k] = 0
        assigns.append(a)
    return assigns


def counts_from_assignments(assigns: Sequence[np.ndarray], n_domains: int):
    return [np.asarray([int(np.sum(a == i)) for i in range(n_domains)])
            for a in assigns]
