"""Fault-tolerance + elasticity substrate (DESIGN.md §5).

On a real multi-pod deployment these hooks bind to the cluster runtime
(health RPCs, preemption notices).  The logic itself — restart bookkeeping,
straggler deadlines, elastic re-sharding decisions, gradient-skip on
divergence — is hardware-independent and fully unit-tested here on CPU
(tests/test_fault_tolerance.py).

Components:
  HeartbeatMonitor   — per-host liveness with a deadline; flags dead hosts
  StragglerPolicy    — EMA of step times; flags outlier steps/hosts and
                       recommends within-step mitigation (skip-and-average)
  ElasticPlan        — given surviving host count, proposes the new mesh and
                       whether a checkpoint reshard is needed
  TrainSupervisor    — ties it together around a training loop: run_step()
                       wrapper that checkpoints, restarts from the latest
                       committed step after a (simulated) crash, skips
                       non-finite gradient steps, and records every event

The serving engine (`repro.serving.engine`) reuses HeartbeatMonitor and
StragglerPolicy at INFERENCE time: each decode slot is a "host" beating on
every committed token, with the monitor's clock bound to the engine step
counter — a slot silent for ``heartbeat_steps`` steps (a stuck fault) is
quarantined and its request requeued; StragglerPolicy flags outlier decode
steps into ``engine.stats["straggler_events"]``.  Both are clock-agnostic
by construction (``clock`` is injectable), which is what makes the same
logic serve wall-clock training and step-clock inference.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


class HeartbeatMonitor:
    def __init__(self, hosts: List[str], deadline_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline = deadline_s
        self.clock = clock
        self.last_seen: Dict[str, float] = {h: clock() for h in hosts}

    def beat(self, host: str):
        self.last_seen[host] = self.clock()

    def dead_hosts(self) -> List[str]:
        now = self.clock()
        return [h for h, t in self.last_seen.items()
                if now - t > self.deadline]

    def all_alive(self) -> bool:
        return not self.dead_hosts()


class StragglerPolicy:
    """EMA-based step-time outlier detection.

    A step slower than ``threshold`` x the EMA is a straggler event; after
    ``tolerance`` consecutive events the policy recommends escalation
    (checkpoint + evict the slow host = elastic downscale)."""

    def __init__(self, threshold: float = 2.0, ema_alpha: float = 0.1,
                 tolerance: int = 3):
        self.threshold = threshold
        self.alpha = ema_alpha
        self.tolerance = tolerance
        self.ema: Optional[float] = None
        self.consecutive = 0
        self.events: List[dict] = []

    def observe(self, step: int, dt: float) -> str:
        """-> 'ok' | 'straggler' | 'escalate'."""
        if self.ema is None:
            self.ema = dt
            return "ok"
        verdict = "ok"
        if dt > self.threshold * self.ema:
            self.consecutive += 1
            verdict = ("escalate" if self.consecutive >= self.tolerance
                       else "straggler")
            self.events.append({"step": step, "dt": dt, "ema": self.ema,
                                "verdict": verdict})
        else:
            self.consecutive = 0
        # stragglers do not poison the EMA
        if verdict == "ok":
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return verdict


@dataclasses.dataclass
class ElasticPlan:
    """Mesh proposal after a membership change.

    Keeps the model axis intact (TP re-layout is expensive: weights move);
    shrinks/grows the data axes, which only re-shards the FSDP dimension —
    exactly what checkpoint.restore(..., shardings=new) implements."""
    old_shape: tuple
    new_hosts: int
    chips_per_host: int = 4

    def propose(self) -> tuple:
        chips = self.new_hosts * self.chips_per_host
        model = self.old_shape[-1]
        data = max(1, chips // model)
        return (data, model)

    @property
    def needs_reshard(self) -> bool:
        return self.propose() != tuple(self.old_shape)


class TrainSupervisor:
    """Checkpoint/restart + bad-step skipping around a step function.

    step_fn(state, step) -> (state, metrics); metrics must include
    'grad_norm'.  save_fn(step, state) / restore_fn() -> (step, state) bind
    to checkpoint.py.  ``inject_crash_at`` simulates a node failure for
    tests."""

    def __init__(self, step_fn, save_fn, restore_fn, ckpt_every: int = 50,
                 max_bad_steps: int = 5, inject_crash_at: Optional[int] = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.ckpt_every = ckpt_every
        self.max_bad = max_bad_steps
        self.inject_crash_at = inject_crash_at
        self.log: List[dict] = []
        self.straggler = StragglerPolicy()

    def run(self, total_steps: int):
        step, state = self.restore_fn()
        bad = 0
        crashed = False
        while step < total_steps:
            t0 = time.monotonic()
            if self.inject_crash_at is not None and step == self.inject_crash_at \
                    and not crashed:
                crashed = True
                self.log.append({"event": "crash", "step": step})
                step, state = self.restore_fn()   # restart from checkpoint
                continue
            new_state, metrics = self.step_fn(state, step)
            gn = float(metrics.get("grad_norm", 0.0))
            if not np.isfinite(gn):
                bad += 1
                self.log.append({"event": "skip_nonfinite", "step": step})
                if bad > self.max_bad:
                    raise RuntimeError("too many non-finite steps")
                step += 1          # skip the update, keep the old state
                continue
            bad = 0
            state = new_state
            verdict = self.straggler.observe(step, time.monotonic() - t0)
            if verdict != "ok":
                self.log.append({"event": verdict, "step": step})
            step += 1
            if step % self.ckpt_every == 0:
                self.save_fn(step, state)
        self.save_fn(step, state)
        return step, state
