"""Sharding rules: FSDP over the data axes x TP over the model axis (+EP for
MoE experts), applied by param-path pattern.

Conventions (DESIGN.md §5):
  * projections writing model-parallel features (wq/wk/wv/up/gate/...):
      (in, out) -> P(data_axes, "model")      [FSDP on in, TP on out]
  * projections reading model-parallel features (wo/down/out_proj):
      (in, out) -> P("model", data_axes)
  * expert-stacked MoE weights: expert dim over "model" (expert parallelism)
  * embeddings / LM head: vocab over "model", d_model over data (FSDP)
  * 1-D params (norm scales, biases, gates): replicated
  * stacked scan params get a leading None for the repeat axis (any rank
    excess over the rule's rank is padded with None on the left)

``set_mesh_axes``/``constrain`` let model code place activation constraints
without importing mesh machinery; with no mesh configured they no-op, so the
same model code runs in single-device tests.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_AXES: dict = {"dp": None, "tp": None, "mesh": None}


def set_mesh_axes(dp: Tuple[str, ...], tp: str, mesh: Optional[Mesh] = None):
    _AXES["dp"], _AXES["tp"], _AXES["mesh"] = tuple(dp), tp, mesh


def clear_mesh_axes():
    _AXES["dp"], _AXES["tp"], _AXES["mesh"] = None, None, None


def axes_configured() -> bool:
    return _AXES["dp"] is not None


def dp_axes() -> Tuple[str, ...]:
    return _AXES["dp"]


def tp_axis() -> str:
    return _AXES["tp"]


def constrain(x, kind: str):
    """Activation sharding constraint; no-op without a configured mesh.
    Axes that do not divide the corresponding dim are dropped."""
    if not axes_configured():
        return x
    dp, tp = _AXES["dp"], _AXES["tp"]
    mesh = _AXES["mesh"]
    spec = {
        # residual stream: batch over data AND features over model — scanned
        # layer boundaries are SAVED for backward, so an unsharded D costs
        # L x B x S x D/16 extra per device (the 73 GiB/dev yi-9b train bug,
        # EXPERIMENTS.md §Perf it0)
        "act": (dp, None, tp),                  # (B, S, D)
        "act_rep": (dp, None, None),            # (B, S, D), D replicated
        "moe_grouped": (dp, None, tp, None),    # (G, T, E, C): G->data, E->model
        "moe_expert": (dp, tp, None, None),     # (G, E, C, D)
    }[kind]
    if x.ndim < len(spec):
        return x
    spec = tuple(spec) + (None,) * (x.ndim - len(spec))
    if mesh is not None:
        spec = tuple(a if _divides(mesh, a, d) and d > 1 else None
                     for a, d in zip(spec, x.shape))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, RuntimeError):
        return x


# ------------------------------------------------------------- param rules

# (regex on 'a/b/c' path string, spec builder for the LAST dims)
def _param_rules(dp, tp):
    return [
        (r"(wq|wk|wv|w_if|w_in|in_proj|kv_a|kv_b)/w(_q)?$", (dp, tp)),
        (r"(up|gate)/w(_q)?$", (dp, tp)),
        (r"(wo|down|out_proj)/w(_q)?$", (tp, dp)),
        (r"head/w(_q)?$", (dp, tp)),
        (r"\bemb$", (tp, dp)),
        (r"moe/(up|gate)$", (tp, dp, None)),     # (E, D, F): EP on E
        (r"moe/down$", (tp, None, dp)),          # (E, F, D)
        (r"router/w$", (None, None)),
        (r"shared/(up|gate)/w(_q)?$", (dp, tp)),
        (r"shared/down/w(_q)?$", (tp, dp)),
        (r"w[qkv]_bd$", (None, None, tp)),  # mlstm block-diag (H,hd,hd)
        (r"/r$", (None, None, None)),  # slstm recurrent (H,hd,4hd): replicate
                                       # (sharding hd forces a per-step
                                       #  reshard of the carry — see sweep.log)
        (r"conv_w$", (None, None)),
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _divides(mesh: Mesh, axes, size: int) -> bool:
    if axes is None:
        return True
    names = (axes,) if isinstance(axes, str) else tuple(axes)
    total = int(np.prod([mesh.shape[a] for a in names]))
    return size % total == 0


def param_spec(mesh: Mesh, path_str: str, shape, dp, tp) -> P:
    """PartitionSpec for one param leaf; falls back to replication on any
    divisibility mismatch (correct, just less sharded)."""
    if len(shape) <= 1:
        return P()
    for pat, spec in _param_rules(dp, tp):
        if re.search(pat, path_str):
            spec = tuple(spec)
            if len(spec) > len(shape):
                return P()
            full = (None,) * (len(shape) - len(spec)) + spec
            # verify divisibility per dim; drop axis if mismatched
            fixed = []
            for dim, axes in zip(shape, full):
                fixed.append(axes if _divides(mesh, axes, dim) else None)
            return P(*fixed)
    return P()


def shardings_for_params(mesh: Mesh, params_shape, dp, tp):
    """Tree of NamedSharding matching a tree of ShapeDtypeStruct."""
    def one(path, leaf):
        spec = param_spec(mesh, _path_str(path), leaf.shape, dp, tp)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shape)


# --------------------------------------------------------------- input rules

def batch_spec(mesh: Mesh, shape, dp) -> P:
    """(B, ...) arrays: shard batch over the data axes if divisible."""
    if len(shape) == 0:
        return P()
    if _divides(mesh, dp, shape[0]) and shape[0] > 1:
        return P(dp, *((None,) * (len(shape) - 1)))
    return P()


def cache_leaf_spec(mesh: Mesh, shape, dp, tp) -> P:
    """KV-cache / recurrent-state leaves.

    Layout conventions: (R, B, S, KVH, hd) stacked KV, (B, S, KVH, hd)
    unstacked, (R, B, S, L) MLA latent, SSM states (R, B, H, hd, N)...
    Strategy: shard the batch dim over dp when divisible; then shard the
    largest remaining dim that the model axis divides (prefer heads, then
    sequence) over tp.
    """
    nd = len(shape)
    spec = [None] * nd
    # find batch dim: first dim whose index is 0 (unstacked) or 1 (stacked)
    bdim = 1 if nd >= 2 and shape[0] <= 64 and nd >= 4 else 0
    if _divides(mesh, dp, shape[bdim]) and shape[bdim] > 1:
        spec[bdim] = dp
    tp_size = mesh.shape[tp]
    # prefer a heads-like or large dim for tp
    order = sorted(range(nd), key=lambda i: -shape[i])
    for i in order:
        if spec[i] is None and shape[i] % tp_size == 0 and shape[i] > 1:
            spec[i] = tp
            break
    return P(*spec)


def shardings_for_tree(mesh: Mesh, tree_shape, spec_fn):
    def one(leaf):
        return NamedSharding(mesh, spec_fn(leaf.shape))
    return jax.tree.map(one, tree_shape)
