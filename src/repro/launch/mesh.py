"""Production mesh construction.

Importing this module never touches jax device state; meshes are built only
inside the function, so tests see 1 CPU device while the dry-run (which sets
XLA_FLAGS before any import) sees 512 host devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = ("data", "model") — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = ("pod", "data", "model") — 512 chips; the pod
    axis extends data parallelism across the inter-pod (DCN) boundary.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devs)}; the "
            "dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def mesh_axes(mesh) -> tuple:
    """(dp_axes, tp_axis) for a mesh built by make_production_mesh."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


def smoke_mesh():
    """1-device mesh for CPU tests of the sharding machinery."""
    return jax.make_mesh((1, 1), ("data", "model"))
