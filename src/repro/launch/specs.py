"""Input ShapeDtypeStruct stand-ins + step functions for every
(architecture x input-shape) dry-run cell.

Shapes (assigned):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill_step
  decode_32k   cache 32,768 global_batch 128  -> serve_step (1 new token)
  long_500k    cache 524,288 global_batch 1   -> serve_step; only for
               sub-quadratic archs (cfg.subquadratic)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import adamw

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def cell_is_runnable(cfg: ArchConfig, shape: str) -> bool:
    """long_500k requires sub-quadratic sequence mixing (DESIGN.md §4)."""
    if shape == "long_500k":
        return cfg.subquadratic
    return True


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ArchConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    meta = SHAPES[shape]
    B, S = meta["batch"], meta["seq"]
    if meta["kind"] == "train":
        specs = {"tokens": _i32(B, S), "targets": _i32(B, S)}
        if cfg.frontend:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        return {"batch": specs}
    if meta["kind"] == "prefill":
        specs = {"tokens": _i32(B, S),
                 "caches": T.cache_specs(cfg, B, S)}
        if cfg.frontend:
            specs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token with a cache of length S; cross-attention KV
    # lives in the cache (written at prefill), so no frontend input
    specs = {"token": _i32(B), "caches": T.cache_specs(cfg, B, S),
             "index": jax.ShapeDtypeStruct((), jnp.int32)}
    return specs


def param_specs(cfg: ArchConfig):
    return jax.eval_shape(lambda k: T.init_lm(k, cfg), jax.random.PRNGKey(0))


def opt_config(cfg: ArchConfig) -> adamw.AdamWConfig:
    # bf16 moments keep arctic-480b's optimizer state within a v5e pod
    moment_dtype = jnp.bfloat16 if cfg.name == "arctic-480b" else jnp.float32
    return adamw.AdamWConfig(lr=1e-4, weight_decay=0.01,
                             moment_dtype=moment_dtype)


def opt_specs(cfg: ArchConfig):
    ps = param_specs(cfg)
    return jax.eval_shape(lambda p: adamw.init(p, opt_config(cfg)), ps)


# ------------------------------------------------------------ step functions

def make_train_step(cfg: ArchConfig, grad_shardings=None):
    ocfg = opt_config(cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(p, cfg, batch, remat=True))(params)
        if grad_shardings is not None:
            # pin gradient cotangents to the param layout — without this the
            # scan-transpose accumulates REPLICATED f32 grads (74 GiB/dev on
            # yi-9b; see EXPERIMENTS.md §Perf)
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params, ocfg)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, tokens, caches, frontend=None):
        logits, caches = T.prefill(params, cfg, tokens, caches,
                                   cross_source=frontend)
        return logits, caches

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, token, caches, index):
        logits, caches = T.decode_step(params, cfg, token, caches, index)
        return logits, caches

    return serve_step
