"""Batched serving driver: continuous-batching-style loop with prefill +
decode over a shared KV cache pool.

Example (CPU, reduced model):
    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduce \
        --requests 8 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.models import transformer as T


def apply_mapping_artifact(cfg, artifact):
    """Pick serving dtypes from a `repro.api.MappingArtifact`.

    The artifact's majority precision domain (by assigned channels) decides
    the weight stream: a <=8-bit majority serves int8 projections; an int8
    activation majority additionally quantizes the KV cache.  Returns the
    updated cfg and the majority domain dict.
    """
    fractions = artifact.domain_channel_fractions()
    dom = artifact.domains[int(np.argmax(fractions))]
    updates = {}
    if dom["weight_bits"] <= 8:
        updates["serve_weight_dtype"] = "int8"
    if dom.get("act_bits", 16) <= 8:
        updates["kv_cache_dtype"] = "int8"
    if updates:
        cfg = dataclasses.replace(cfg, **updates)
    return cfg, dom


def sample_greedy(logits):
    return jnp.argmax(logits, axis=-1)


def serve_batch(cfg, params, prompts, gen_len: int, frontend=None):
    """prompts: (B, P) int32. Returns generated (B, gen_len)."""
    B, P = prompts.shape
    S_max = P + gen_len
    caches = T.init_cache(cfg, B, S_max)

    prefill = jax.jit(lambda p, t, c, f: T.prefill(p, cfg, t, c,
                                                   cross_source=f))
    decode = jax.jit(lambda p, t, c, i: T.decode_step(p, cfg, t, c, i))

    t0 = time.monotonic()
    logits, caches = prefill(params, prompts, caches, frontend)
    tok = sample_greedy(logits)
    t_prefill = time.monotonic() - t0

    out = [tok]
    t0 = time.monotonic()
    for i in range(gen_len - 1):
        logits, caches = decode(params, tok, caches, P + i)
        tok = sample_greedy(logits)
        out.append(tok)
    t_decode = time.monotonic() - t0
    gen = jnp.stack(out, axis=1)
    return gen, {"prefill_s": t_prefill, "decode_s": t_decode,
                 "tok_per_s": B * (gen_len - 1) / max(t_decode, 1e-9)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mapping", default=None,
                    help="mapping artifact JSON (repro.api schema); the "
                         "majority domain picks the serving dtypes")
    args = ap.parse_args(argv)

    cfgbase.load_all()
    cfg = cfgbase.get(args.arch)
    if args.reduce:
        cfg = cfgbase.reduce_for_smoke(cfg)
    if args.mapping:
        from repro.api import MappingArtifact
        art = MappingArtifact.load(args.mapping)
        cfg, dom = apply_mapping_artifact(cfg, art)
        print(f"[serve] mapping {args.mapping}: model={art.model} "
              f"platform={art.platform} majority domain={dom['name']} "
              f"-> weights={cfg.serve_weight_dtype} kv={cfg.kv_cache_dtype}")

    key = jax.random.PRNGKey(args.seed)
    params = T.init_lm(key, cfg)
    prompts = jax.random.randint(key, (args.requests, args.prompt_len),
                                 0, cfg.vocab)
    frontend = None
    if cfg.frontend:
        frontend = jax.random.normal(
            key, (args.requests, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    gen, stats = serve_batch(cfg, params, prompts, args.gen_len, frontend)
    assert gen.shape == (args.requests, args.gen_len)
    assert np.isfinite(np.asarray(gen)).all()
    print(f"[serve] {cfg.name}: {args.requests} reqs, prefill "
          f"{stats['prefill_s']*1e3:.0f}ms, decode {stats['decode_s']*1e3:.0f}ms "
          f"({stats['tok_per_s']:.1f} tok/s)")
    print("[serve] sample generations:", np.asarray(gen[:2, :8]))
    return gen, stats


if __name__ == "__main__":
    main()
