"""Serving driver: a thin client of the `repro.serving` continuous-batching
engine.

``serve_batch`` (same-length batch, fixed generation budget) and ``serve
--mapping`` submit their requests to an `repro.serving.Engine` — B slots,
one shared KV-cache pool, jitted ragged prefill + per-slot-masked decode.
``--engine`` exposes the engine directly: it replays a mixed-length request
trace (``--trace requests.jsonl``, or a seeded synthetic trace) with
continuous slot admission/retirement and reports PER-REQUEST latency — TTFT
p50/p95 and decode tok/s — alongside the per-kernel coverage histogram:

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --reduce \
        --engine --requests 8 --mapping art.json --require-full-coverage

With ``--mapping`` the driver lowers the mapping artifact onto the model's
actual weights (`repro.runtime.lower`) and executes every projection matmul
the plan binds to through its per-layer planned kernel — split-precision /
quant-matmul / ternary, interpret mode on CPU — via the NAME-KEYED pluggable
matmul backend (`repro.runtime.PlannedBackend`).  Because plans resolve by
the layer's pytree path (a static string), prefill and decode run under
``jax.jit`` with the planned kernels executing INSIDE the trace, and
scan-stacked LM weights (``base@r`` plan names) bind too — the measured
latency/energy is the mapped latency/energy, not a silent fp fallback.  The
artifact's activation majority still decides the KV-cache dtype (an
activation-precision choice the per-layer weight kernels don't cover).
Artifacts that fail to lower or bind (shape mismatch / wrong model /
stacked repeat-count mismatch) fall back to the legacy global
majority-dtype path (`apply_mapping_artifact`);
``--require-full-coverage`` turns partial binding into a nonzero exit
instead.

MULTI-PLAN SERVING — a second mapping artifact of the SAME weights turns
the backend into a `repro.runtime.PlanSet` precision bank (prepared
buffers deduplicated wherever layers coincide across artifacts):

  * ``--speculate draft.json`` binds ``{"draft", "target"}`` variants
    (``--mapping`` is the target) and serves with SELF-SPECULATIVE
    decoding: ``--draft-k`` tokens drafted per round with the draft
    variant, verified in one target-variant chunk — token-identical to
    target-only greedy serving (``--check-spec-parity`` replays the trace
    target-only and asserts it).  Emit the pair with ``train
    --emit-mapping --mapping-bias aimc ...`` / ``--mapping-bias digital``
    and a static ``--mapping-act-scale``.
  * ``--slo-variant CLASS=alt.json`` (repeatable) binds one variant per
    SLO class and routes each request's class to its variant (synthetic
    traces are tagged round-robin with the route classes); ``summarize``
    then reports per-class TTFT/decode-rate.
  * ``--require-full-coverage`` checks EVERY variant of the bank and exits
    2 naming the first offending variant; the per-variant coverage diff
    prints layer NAMES, not counts.

ROBUSTNESS — the engine's deadline scheduling, overload handling and fault
containment are driven from the same CLI:

  * ``--policy deadline`` orders admission by priority/slack and preempts
    a running slot for a more urgent arrival (``--priorities`` /
    ``--deadlines-ms`` tag synthetic requests round-robin);
    ``--check-preempt-parity`` replays the trace FCFS-without-preemption
    and exits nonzero unless every completed request's tokens match.
  * ``--poisson RATE`` restamps arrivals as a seeded open-loop Poisson
    process at RATE requests/step; ``--max-queue-depth`` /
    ``--page-watermark`` / ``--request-timeout`` shed overload as
    structured `ShedResult`s instead of queueing forever.
  * ``--fault-spec`` injects seeded faults
    (``kind@step:slot[xN]`` / ``kind~rate``; kinds: nonfinite_logits,
    corrupt_page, stuck) that the engine detects, quarantines and
    requeues — the summary line reports detections/requeues/sheds.
  * ``--degrade-to CLASS --ttft-target-s S`` routes NEW requests to the
    CLASS variant of the ``--slo-variant`` bank while the sliding p95
    TTFT exceeds S, and back once it recovers.

A ``--trace`` path that is missing or malformed exits 2 with a message
naming the file (and line) instead of a traceback.

CNN artifacts serve through the same flag with the ``cnn:<config>`` arch
convention — the conv layers execute through the im2col'd planned kernels:

    PYTHONPATH=src python -m repro.launch.serve --arch cnn:resnet20_tiny \
        --requests 8 --mapping art.json --require-full-coverage

Example (CPU, reduced LM):
    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduce \
        --requests 8 --prompt-len 32 --gen-len 16 [--mapping art.json]
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.models import transformer as T
from repro.models.managed import matmul_backend


def apply_mapping_artifact(cfg, artifact):
    """FALLBACK consumer: pick GLOBAL serving dtypes from a
    `repro.api.MappingArtifact` majority vote.

    Only ``searchable: true`` layers vote (pinned layers never had a choice;
    counting them would let a wide pinned stem outvote the search).  The
    majority precision domain decides the weight stream: a <=8-bit majority
    serves int8 projections; an int8 activation majority additionally
    quantizes the KV cache.  Returns the updated cfg and the majority domain
    dict.

    This is the documented fallback when no `ExecutionPlan` can be lowered —
    the first-class path is per-layer planned execution via
    `plan_mapping_execution`.
    """
    fractions = artifact.domain_channel_fractions(searchable_only=True)
    dom = artifact.domains[int(np.argmax(fractions))]
    updates = {}
    if dom["weight_bits"] <= 8:
        updates["serve_weight_dtype"] = "int8"
    if dom.get("act_bits", 16) <= 8:
        updates["kv_cache_dtype"] = "int8"
    if updates:
        cfg = dataclasses.replace(cfg, **updates)
    return cfg, dom


def plan_mapping_execution(params, artifact, interpret=None):
    """Lower ``artifact`` against ``params`` and bind a planned backend.

    Returns (plan, backend).  Raises `repro.runtime.LoweringError` when the
    artifact does not lower onto the model, and `repro.runtime
    .ExecutionError` when the lowered plan cannot bind (e.g. a stacked
    repeat-count mismatch); callers catch both and fall back to
    `apply_mapping_artifact`.
    """
    from repro.runtime import PlannedBackend, lower
    plan = lower(artifact, params=params)
    backend = PlannedBackend(plan, params, interpret=interpret)
    return plan, backend


def build_planset(params, artifacts, default, interpret=None):
    """Lower several mapping artifacts of the SAME weights and bind them as
    one `repro.runtime.PlanSet` precision bank.

    ``artifacts``: {variant_name: MappingArtifact}.  Returns
    (plans, planset) with ``plans`` the per-variant `ExecutionPlan`s.
    Raises `LoweringError` / `ExecutionError` — multi-plan serving has no
    majority-dtype fallback (a bank that cannot bind is an error, not a
    degraded mode)."""
    from repro.runtime import PlanSet, lower
    plans = {v: lower(art, params=params) for v, art in artifacts.items()}
    planset = PlanSet(plans, params, default=default, interpret=interpret)
    return plans, planset


def print_planset_report(tag, plans, planset):
    """Per-variant coverage + the dedup memory accounting of the bank."""
    for v in planset.variant_names:
        hist = " ".join(f"{k}:{n}" for k, n in
                        sorted(plans[v].kernel_histogram().items()))
        bp = planset.variant(v)
        print(f"[{tag}] variant {v!r}: {hist}; {len(bp.bound)}/"
              f"{len(bp.plan.layers)} planned layers bound to weights, "
              f"{len(bp.unbound)} unbound")
    rep = planset.memory_report()
    shared = rep["shared_layers"]
    print(f"[{tag}] planset memory: prepared_bytes={rep['prepared_bytes']} "
          f"sum_variant_bytes={rep['sum_variant_bytes']} "
          f"dedup_saved_bytes={rep['dedup_saved_bytes']} "
          f"shared_layers={len(shared)}")
    diff = planset.coverage_diff()
    for v, missing in sorted(diff.items()):
        print(f"[{tag}] coverage diff: variant {v!r} leaves unbound: "
              f"{missing}")


def print_plan_coverage(tag, plan, backend):
    """Per-layer kernel/coverage report + the greppable summary line.

    Leads with the per-kernel layer histogram and every fp-fallback reason
    (layer names included) so capability fallbacks are visible at a glance
    — not only via ``--require-full-coverage``."""
    hist = " ".join(f"{k}:{v}" for k, v in
                    sorted(plan.kernel_histogram().items()))
    for line in plan.histogram_lines():
        print(f"[{tag}] {line}")
    print(f"[{tag}] per-layer planned execution ({hist}; "
          f"{backend.coverage()})")
    for lp in plan.layers:
        mark = "*" if lp.name in backend.bound else " "
        note = f"  ({lp.note})" if lp.note else ""
        print(f"[{tag}]  {mark} {lp.name}: {lp.kernel} "
              f"counts={lp.counts}{note}")


def check_coverage(tag, backend, require_full: bool):
    """Enforce ``--require-full-coverage``: exit 2 when any planned layer is
    unbound or declined at trace time.  Multi-variant `PlanSet` banks are
    checked variant by variant — the exit names the offending variant and
    its unplanned layer NAMES."""
    declines = backend.runtime_declines or {}
    for name, reason in sorted(declines.items()):
        print(f"[{tag}] declined at trace time: {name}: {reason}")
    if not require_full:
        return
    variants = list(getattr(backend, "variant_names", ()) or ())
    if len(variants) > 1:
        diff = backend.coverage_diff()
        for v in variants:
            problems = list(diff.get(v, [])) + \
                [k.split(":", 1)[1] for k in sorted(declines)
                 if k.startswith(f"{v}:")]
            if problems:
                print(f"[{tag}] ERROR: --require-full-coverage but variant "
                      f"{v!r}: {len(problems)} planned layers did not "
                      f"execute as mapped: {problems}", file=sys.stderr)
                sys.exit(2)
        return
    problems = list(backend.unbound) + sorted(declines)
    if problems:
        print(f"[{tag}] ERROR: --require-full-coverage but "
              f"{len(problems)} planned layers did not execute as mapped: "
              f"{problems}", file=sys.stderr)
        sys.exit(2)


def serve_batch(cfg, params, prompts, gen_len: int, frontend=None,
                backend=None):
    """prompts: (B, P) int32. Returns generated (B, gen_len).

    MIGRATED: this is now a thin wrapper over the `repro.serving.Engine` —
    the B same-length prompts are submitted as B requests with a shared
    generation budget, admitted into B slots at once, and decoded to
    completion (token-identical to the old fixed-shape loop; the engine's
    per-slot machinery degenerates to it for a uniform batch).  Prefill and
    decode run under ``jax.jit`` with or without a matmul ``backend``; use
    the engine directly for mixed lengths / queueing / EOS / TTFT.
    """
    from repro.serving import Engine, Request
    B, P = prompts.shape
    prompts_np = np.asarray(prompts)
    frontend_np = None if frontend is None else np.asarray(frontend)
    reqs = [Request(rid=b, prompt=prompts_np[b], max_new_tokens=gen_len,
                    frontend=(frontend_np[b] if frontend_np is not None
                              else None))
            for b in range(B)]
    engine = Engine(cfg, params, max_batch=B, max_len=P + gen_len,
                    backend=backend, prefill_bucket=P)
    results = engine.run(reqs)
    gen = jnp.asarray(np.stack([r.tokens for r in results]))
    st = engine.stats
    return gen, {"prefill_s": st["prefill_s"], "decode_s": st["decode_s"],
                 "tok_per_s": B * (gen_len - 1) / max(st["decode_s"], 1e-9)}


def serve_engine(args, cfg, params, backend=None):
    """``--engine``: replay a mixed-length request trace through the
    continuous-batching engine and report per-request latency (TTFT,
    decode tok/s) + the run summary.  The trace comes from ``--trace``
    (JSONL, see `repro.serving.trace`) or a seeded synthetic trace sized by
    ``--requests/--prompt-len/--gen-len``.  With ``--speculate`` the run is
    self-speculative (and ``--check-spec-parity`` replays it target-only to
    assert token identity); with ``--slo-variant`` routes each request's
    SLO class to its plan variant."""
    from repro.serving import (Engine, FaultInjector, SamplingParams,
                               Scheduler, ShedResult, load_trace,
                               poisson_arrivals, summarize, synthetic_trace)
    speculate = ("draft", "target") if args.speculate else None
    # the --degrade-to class is bound in the bank but is NOT an SLO route:
    # requests reach it only while the engine is degraded, never by tag
    route_classes = [c for c in getattr(args, "slo_classes", [])
                     if c != args.degrade_to]
    slo_routes = ({cls: cls for cls in route_classes}
                  if route_classes else None)
    sampling = None
    if args.temperature is not None or args.top_p < 1.0:
        sampling = SamplingParams(
            temperature=(args.temperature if args.temperature is not None
                         else 1.0),
            top_p=args.top_p, seed=args.seed)
    if args.trace:
        try:
            trace = load_trace(args.trace, vocab=cfg.vocab)
        except FileNotFoundError:
            print(f"[serve] ERROR: trace file not found: {args.trace}",
                  file=sys.stderr)
            sys.exit(2)
        except (ValueError, OSError) as e:
            print(f"[serve] ERROR: bad trace: {e}", file=sys.stderr)
            sys.exit(2)
        print(f"[serve] trace {args.trace}: {len(trace)} requests")
    else:
        priorities = ([int(p) for p in args.priorities.split(",")]
                      if args.priorities else None)
        deadlines = ([None if d in ("", "none") else float(d)
                      for d in args.deadlines_ms.split(",")]
                     if args.deadlines_ms else None)
        trace = synthetic_trace(
            args.requests, vocab=cfg.vocab,
            min_prompt=max(2, args.prompt_len // 4),
            max_prompt=args.prompt_len,
            min_new=max(2, args.gen_len // 4), max_new=args.gen_len,
            seed=args.seed, shared_prefix=args.shared_prefix,
            slo_classes=(sorted(slo_routes) if slo_routes else None),
            priorities=priorities, deadlines_ms=deadlines)
        print(f"[serve] synthetic trace: {len(trace)} mixed-length requests "
              f"(prompts <= {args.prompt_len}, gen <= {args.gen_len}, "
              f"shared prefix {args.shared_prefix})")
    if args.poisson:
        trace = poisson_arrivals(trace, args.poisson, seed=args.seed)
        print(f"[serve] open-loop arrivals: Poisson at {args.poisson} "
              f"req/step (last arrival step "
              f"{max(r.arrival_step for r in trace)})")
    if cfg.frontend:
        key = jax.random.PRNGKey(args.seed)
        for i, r in enumerate(trace):
            r.frontend = np.asarray(jax.random.normal(
                jax.random.fold_in(key, i),
                (cfg.frontend_tokens, cfg.d_model), jnp.bfloat16))
    max_len = args.max_len or max(r.prompt_len + r.max_new_tokens
                                  for r in trace)
    injector = (FaultInjector.parse(args.fault_spec, seed=args.seed)
                if args.fault_spec else None)
    engine = Engine(cfg, params, max_batch=args.max_batch, max_len=max_len,
                    backend=backend, scheduler=Scheduler(args.policy),
                    kv_layout=args.kv_layout, page_size=args.page_size,
                    num_pages=args.num_pages,
                    prefill_chunk=args.prefill_chunk,
                    speculate=speculate, draft_k=args.draft_k,
                    slo_routes=slo_routes, sampling=sampling,
                    max_queue_depth=args.max_queue_depth,
                    page_watermark=args.page_watermark,
                    request_timeout_s=args.request_timeout,
                    degrade_to=args.degrade_to,
                    ttft_target_s=args.ttft_target_s,
                    injector=injector)
    results = engine.run(trace)
    for r in results:
        if isinstance(r, ShedResult):
            print(f"[serve]  {r.rid}: SHED ({r.reason}) at step "
                  f"{r.shed_step} after {r.waited_s * 1e3:.0f}ms")
            continue
        print(f"[serve]  {r.rid}: prompt={r.prompt_len} "
              f"gen={r.n_tokens} ({r.finish_reason}) "
              f"ttft={r.ttft_s * 1e3:.0f}ms "
              f"decode={r.decode_tok_s:.1f} tok/s")
    summ = summarize(results, engine.stats["wall_s"])
    print(f"[serve] engine[{args.policy}] B={args.max_batch} "
          f"max_len={max_len}: {summ['total_tokens']} tokens in "
          f"{summ['wall_s'] * 1e3:.0f}ms ({summ['total_tok_s']} tok/s, "
          f"ttft p50 {summ['ttft_p50_s'] * 1e3:.0f}ms / "
          f"p95 {summ['ttft_p95_s'] * 1e3:.0f}ms, "
          f"{engine.stats['decode_steps']} decode steps)")
    st = engine.stats
    if (st["preemptions"] or st["shed_requests"] or st["timeouts"]
            or st["faults_injected"] or st["degrade_transitions"]
            or args.policy == "deadline" or injector is not None
            or args.max_queue_depth or args.page_watermark
            or args.request_timeout):
        print(f"[serve] robustness: preemptions={st['preemptions']} "
              f"resumes={st['resumes']} sheds={st['shed_requests']} "
              f"shed_rate={summ['shed_rate']} timeouts={st['timeouts']} "
              f"faults_injected={st['faults_injected']} "
              f"faults_detected={st['faults_detected']} "
              f"heartbeat_trips={st['heartbeat_trips']} "
              f"degrade_transitions={st['degrade_transitions']} "
              f"degrade_rate={summ['degrade_rate']}")
        if "shed_reasons" in summ:
            print(f"[serve] shed reasons: "
                  + " ".join(f"{k}:{v}" for k, v in
                             sorted(summ["shed_reasons"].items())))
        for step_t, kind, p95 in engine.degrade_log:
            print(f"[serve] degrade transition @step {step_t}: {kind} "
                  f"(window p95 ttft {p95 * 1e3:.0f}ms)")
    if args.check_preempt_parity:
        # replay the SAME trace FCFS without preemption/faults/sheds and
        # compare every COMPLETED request's token stream — preemption must
        # be a pure scheduling decision, invisible in the tokens
        ref_engine = Engine(
            cfg, params, max_batch=args.max_batch, max_len=max_len,
            backend=backend, scheduler=Scheduler("continuous"),
            kv_layout=args.kv_layout, page_size=args.page_size,
            num_pages=args.num_pages, prefill_chunk=args.prefill_chunk,
            slo_routes=slo_routes, sampling=sampling)
        ref = {r.rid: r for r in ref_engine.run(trace)}
        # timed-out requests carry a clean PREFIX of the full stream, so
        # every non-shed result must prefix-match its FCFS replay
        done = [r for r in results if not isinstance(r, ShedResult)]
        bad = [r.rid for r in done
               if isinstance(ref.get(r.rid), ShedResult)
               or r.tokens != ref[r.rid].tokens[:len(r.tokens)]]
        print(f"[serve] preemption token parity "
              f"({len(done)} completed requests): {not bad}")
        if bad:
            print(f"[serve] ERROR: preempted serving diverged from FCFS "
                  f"replay on requests {bad}", file=sys.stderr)
            sys.exit(2)
    if args.kv_layout == "paged":
        st = engine.stats
        print(f"[serve] paged kv: page_size={engine.page_size} "
              f"pool={engine.num_pages} pages "
              f"kv_peak_pages={st['kv_peak_pages']} "
              f"kv_peak_bytes={st['kv_peak_bytes']} "
              f"(capacity {st['kv_capacity_bytes']}) "
              f"prefix_hit_tokens={st['prefix_hit_tokens']} "
              f"prefix_hit_requests={st['prefix_hit_requests']} "
              f"(lookups {st['prefix_lookups']}) "
              f"cow_copies={st['cow_copies']} "
              f"evictions={st['page_evictions']}")
    if "by_slo" in summ:
        for cls, rec in sorted(summ["by_slo"].items()):
            variant = (slo_routes or {}).get(cls, "default")
            print(f"[serve] slo {cls!r} -> variant {variant!r}: "
                  f"{rec['requests']} requests "
                  f"ttft p50 {rec['ttft_p50_s'] * 1e3:.0f}ms / "
                  f"p95 {rec['ttft_p95_s'] * 1e3:.0f}ms, "
                  f"decode p50 {rec['decode_tok_s_p50']} tok/s")
    if speculate is not None:
        st = engine.stats
        print(f"[serve] speculative(draft_k={args.draft_k}): "
              f"rounds={st['spec_rounds']} drafted={st['spec_drafted']} "
              f"accepted={st['spec_accepted']} "
              f"acceptance={st['spec_acceptance']} "
              f"tokens_per_round={st['spec_tokens_per_round']}")
        if args.check_spec_parity:
            # replay the SAME trace target-only (the PlanSet default is the
            # target variant) and compare every request's token stream
            ref_engine = Engine(
                cfg, params, max_batch=args.max_batch, max_len=max_len,
                backend=backend, scheduler=Scheduler(args.policy),
                kv_layout=args.kv_layout, page_size=args.page_size,
                num_pages=args.num_pages, prefill_chunk=args.prefill_chunk)
            ref = ref_engine.run(trace)
            identical = all(a.tokens == b.tokens
                            for a, b in zip(results, ref))
            print(f"[serve] spec tokens identical to target-only: "
                  f"{identical}")
            if not identical:
                bad = [a.rid for a, b in zip(results, ref)
                       if a.tokens != b.tokens]
                print(f"[serve] ERROR: speculative decode diverged from "
                      f"target-only on requests {bad}", file=sys.stderr)
                sys.exit(2)
    return results, summ


# --------------------------------------------------------------------------
# CNN serving (arch "cnn:<config>"): batch inference through the planned
# conv/dense kernels
# --------------------------------------------------------------------------

def serve_cnn(args, cnn_name: str):
    """Batch-inference "serving" of a CNN façade, with ``--mapping`` running
    every bound conv/dense through its planned kernel (im2col'd conv
    lowering) under ``jax.jit``."""
    from repro.models import cnn as C
    cfg = C.get_config(cnn_name)
    init_fn, apply_fn, _ = C.get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = init_fn(key, cfg, None)

    backend = None
    if args.mapping:
        from repro.api import MappingArtifact
        from repro.runtime import ExecutionError, LoweringError
        art = MappingArtifact.load(args.mapping)
        try:
            plan, backend = plan_mapping_execution(params, art)
        except (LoweringError, ExecutionError) as e:
            print(f"[serve] mapping {args.mapping} failed to lower/bind "
                  f"({e})", file=sys.stderr)
            sys.exit(2)
        print(f"[serve] mapping {args.mapping}: model={art.model} "
              f"platform={art.platform}")
        print_plan_coverage("serve", plan, backend)

    x = jax.random.normal(key, (args.requests, *cfg.img_hw, cfg.in_ch),
                          jnp.float32)
    fwd = jax.jit(lambda p, xb: apply_fn(p, xb, cfg, None, "fp", 1.0))
    ctx = matmul_backend(backend) if backend is not None \
        else contextlib.nullcontext()
    with ctx:
        t0 = time.monotonic()
        logits = jax.block_until_ready(fwd(params, x))
        dt = time.monotonic() - t0
    assert logits.shape == (args.requests, cfg.n_classes)
    assert np.isfinite(np.asarray(logits)).all()
    if backend is not None:
        check_coverage("serve", backend, args.require_full_coverage)
    print(f"[serve] {cfg.name}: {args.requests} images in {dt*1e3:.0f}ms "
          f"({args.requests / max(dt, 1e-9):.1f} img/s)")
    return logits, {"forward_s": dt}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="LM arch name, or cnn:<config> for CNN façades")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", action="store_true",
                    help="serve through the continuous-batching engine "
                         "(repro.serving): mixed-length trace replay with "
                         "slot admission/retirement + per-request TTFT")
    ap.add_argument("--trace", default=None,
                    help="JSONL request trace for --engine "
                         "(repro.serving.trace format); default: a seeded "
                         "synthetic mixed-length trace")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="engine slot-pool size (concurrent requests)")
    ap.add_argument("--max-len", type=int, default=None,
                    help="engine per-slot sequence capacity (default: "
                         "longest prompt+gen in the trace)")
    ap.add_argument("--policy", default="continuous",
                    choices=["continuous", "static", "deadline"],
                    help="engine admission policy (static = gang batching "
                         "baseline; deadline = priority/slack ordering "
                         "with mid-decode preemption)")
    ap.add_argument("--priorities", default=None,
                    help="synthetic trace: comma-separated ints assigned "
                         "round-robin as request priorities (higher = more "
                         "urgent, used by --policy deadline)")
    ap.add_argument("--deadlines-ms", default=None,
                    help="synthetic trace: comma-separated per-request "
                         "deadlines in ms assigned round-robin ('none' "
                         "for no deadline)")
    ap.add_argument("--poisson", type=float, default=None, metavar="RATE",
                    help="restamp arrivals as a seeded open-loop Poisson "
                         "process at RATE requests per engine step")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="shed the newest waiting requests once the "
                         "admission queue exceeds this depth")
    ap.add_argument("--page-watermark", type=float, default=None,
                    help="paged layout: shed waiting requests when the "
                         "free-page fraction drops below this watermark")
    ap.add_argument("--request-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="per-request wall-clock budget: queued requests "
                         "shed, running requests retire with their partial "
                         "tokens (finish_reason='timeout')")
    ap.add_argument("--fault-spec", default=None,
                    help="inject seeded faults: comma-separated "
                         "kind@step:slot[xN] events and/or kind~rate "
                         "Bernoulli rates (kinds: nonfinite_logits, "
                         "corrupt_page, stuck)")
    ap.add_argument("--degrade-to", default=None, metavar="CLASS",
                    help="graceful degradation: route NEW requests to this "
                         "--slo-variant class while the sliding p95 TTFT "
                         "exceeds --ttft-target-s")
    ap.add_argument("--ttft-target-s", type=float, default=None,
                    help="p95 TTFT target (seconds) driving --degrade-to")
    ap.add_argument("--check-preempt-parity", action="store_true",
                    help="after a --policy deadline run, replay the trace "
                         "FCFS without preemption and exit nonzero unless "
                         "every completed request's tokens prefix-match")
    ap.add_argument("--kv-layout", default="paged",
                    choices=["paged", "dense"],
                    help="KV-cache layout: paged (block-table pool with "
                         "chunked prefill + prefix caching) or dense "
                         "(B x max_len slots, the parity oracle)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged layout: tokens per KV page")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="paged layout: pool capacity in pages (default "
                         "max_batch * ceil(max_len / page_size))")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="paged layout: prompt tokens prefilled per engine "
                         "step (default 2 * page_size)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="synthetic --engine trace: prepend the same "
                         "N-token system prefix to every prompt (exercises "
                         "prefix caching)")
    ap.add_argument("--speculate", default=None, metavar="DRAFT_MAPPING",
                    help="second mapping artifact of the SAME weights bound "
                         "as the 'draft' variant of a PlanSet bank "
                         "(--mapping is the 'target'): self-speculative "
                         "decoding, token-identical to target-only greedy")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="tokens drafted per speculative round")
    ap.add_argument("--check-spec-parity", action="store_true",
                    help="after the speculative run, replay the trace "
                         "target-only and exit nonzero unless every "
                         "request's tokens are identical")
    ap.add_argument("--slo-variant", action="append", default=[],
                    metavar="CLASS=MAPPING",
                    help="route SLO class CLASS to a variant bound from "
                         "this mapping artifact (repeatable; --mapping is "
                         "the default variant for unrouted requests)")
    ap.add_argument("--temperature", type=float, default=None,
                    help="enable non-greedy sampling at this temperature "
                         "(default: greedy argmax)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (enables sampling when "
                         "< 1.0)")
    ap.add_argument("--mapping", default=None,
                    help="mapping artifact JSON (repro.api schema); lowered "
                         "to per-layer ExecutionPlans, with the global "
                         "majority-dtype path as fallback")
    ap.add_argument("--mapping-fallback", action="store_true",
                    help="skip plan lowering and use the legacy global "
                         "majority-dtype path directly")
    ap.add_argument("--require-full-coverage", action="store_true",
                    help="exit nonzero unless every planned layer is bound "
                         "AND executes as mapped (no fp fallbacks, no "
                         "trace-time declines)")
    args = ap.parse_args(argv)

    if args.require_full_coverage and not args.mapping:
        # without an artifact nothing executes as mapped — passing the gate
        # green would be exactly the silent fallback it exists to catch
        ap.error("--require-full-coverage needs --mapping")

    robust_flags = (args.policy == "deadline" or args.poisson
                    or args.max_queue_depth or args.page_watermark
                    or args.request_timeout or args.fault_spec
                    or args.degrade_to or args.check_preempt_parity
                    or args.priorities or args.deadlines_ms)
    if robust_flags and not args.engine:
        ap.error("robustness flags (--policy deadline / --poisson / "
                 "--max-queue-depth / --page-watermark / --request-timeout "
                 "/ --fault-spec / --degrade-to / --check-preempt-parity / "
                 "--priorities / --deadlines-ms) need --engine")
    if args.degrade_to:
        if args.ttft_target_s is None:
            ap.error("--degrade-to needs --ttft-target-s")
        if not any(s.startswith(f"{args.degrade_to}=")
                   for s in args.slo_variant):
            ap.error(f"--degrade-to {args.degrade_to!r} must name a "
                     f"--slo-variant class of the bank")
    elif args.ttft_target_s is not None:
        ap.error("--ttft-target-s needs --degrade-to")
    if args.check_preempt_parity and args.policy != "deadline":
        ap.error("--check-preempt-parity needs --policy deadline")

    args.slo_classes = []
    if args.speculate or args.slo_variant:
        if not args.engine:
            ap.error("--speculate/--slo-variant need --engine")
        if not args.mapping:
            ap.error("--speculate/--slo-variant need --mapping (the "
                     "target/default plan of the bank)")
        if args.mapping_fallback:
            ap.error("--mapping-fallback cannot serve a multi-plan bank")
        if args.speculate and args.slo_variant:
            ap.error("--speculate and --slo-variant are mutually exclusive")
    for spec_arg in args.slo_variant:
        cls, sep, path = spec_arg.partition("=")
        if not cls or not sep or not path:
            ap.error(f"--slo-variant wants CLASS=MAPPING, got {spec_arg!r}")
        args.slo_classes.append(cls)

    if args.arch.startswith("cnn:"):
        if args.engine:
            ap.error("--engine is a decode-loop mode; CNN façades have no "
                     "KV cache to batch continuously")
        return serve_cnn(args, args.arch.split(":", 1)[1])

    cfgbase.load_all()
    cfg = cfgbase.get(args.arch)
    if args.reduce:
        cfg = cfgbase.reduce_for_smoke(cfg)

    art = None
    if args.mapping:
        from repro.api import MappingArtifact
        art = MappingArtifact.load(args.mapping)

    key = jax.random.PRNGKey(args.seed)
    params = T.init_lm(key, cfg)

    backend = None
    if art is not None and (args.speculate or args.slo_variant):
        from repro.api import MappingArtifact
        from repro.runtime import ExecutionError, LoweringError
        if args.speculate:
            arts = {"target": art,
                    "draft": MappingArtifact.load(args.speculate)}
            default = "target"
        else:
            arts = {"default": art}
            for spec_arg in args.slo_variant:
                cls, _, path = spec_arg.partition("=")
                arts[cls] = MappingArtifact.load(path)
            default = "default"
        try:
            plans, backend = build_planset(params, arts, default)
        except (LoweringError, ExecutionError) as e:
            print(f"[serve] multi-plan bank failed to lower/bind ({e})",
                  file=sys.stderr)
            sys.exit(2)
        # KV-cache precision follows the default/target artifact's
        # activation majority, as on the single-plan path
        fractions = art.domain_channel_fractions(searchable_only=True)
        dom = art.domains[int(np.argmax(fractions))]
        if dom.get("act_bits", 16) <= 8:
            cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
        print(f"[serve] planset bank: model={art.model} "
              f"platform={art.platform} "
              f"variants={list(backend.variant_names)} default={default!r} "
              f"kv={cfg.kv_cache_dtype} (jit: prefill+decode)")
        print_planset_report("serve", plans, backend)
    elif art is not None:
        from repro.runtime import ExecutionError, LoweringError
        plan = None
        if not args.mapping_fallback:
            try:
                plan, backend = plan_mapping_execution(params, art)
            except (LoweringError, ExecutionError) as e:
                print(f"[serve] mapping {args.mapping} failed to lower/bind "
                      f"({e}); falling back to majority-dtype serving")
        if backend is not None:
            # KV-cache precision follows the artifact's activation majority
            # even on the planned path (the weight kernels don't cover it)
            fractions = art.domain_channel_fractions(searchable_only=True)
            dom = art.domains[int(np.argmax(fractions))]
            if dom.get("act_bits", 16) <= 8:
                cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
            print(f"[serve] mapping {args.mapping}: model={art.model} "
                  f"platform={art.platform} kv={cfg.kv_cache_dtype} "
                  f"(jit: prefill+decode)")
            print_plan_coverage("serve", plan, backend)
        else:
            cfg, dom = apply_mapping_artifact(cfg, art)
            print(f"[serve] mapping {args.mapping}: model={art.model} "
                  f"platform={art.platform} FALLBACK majority domain="
                  f"{dom['name']} -> weights={cfg.serve_weight_dtype} "
                  f"kv={cfg.kv_cache_dtype}")
            if args.require_full_coverage:
                print("[serve] ERROR: --require-full-coverage but no "
                      "execution plan could be bound", file=sys.stderr)
                sys.exit(2)

    if args.engine:
        results, summ = serve_engine(args, cfg, params, backend=backend)
        if backend is not None:
            check_coverage("serve", backend, args.require_full_coverage)
        return results, summ

    prompts = jax.random.randint(key, (args.requests, args.prompt_len),
                                 0, cfg.vocab)
    frontend = None
    if cfg.frontend:
        frontend = jax.random.normal(
            key, (args.requests, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    gen, stats = serve_batch(cfg, params, prompts, args.gen_len, frontend,
                             backend=backend)
    assert gen.shape == (args.requests, args.gen_len)
    assert np.isfinite(np.asarray(gen)).all()
    if backend is not None:
        check_coverage("serve", backend, args.require_full_coverage)
    print(f"[serve] {cfg.name}: {args.requests} reqs, prefill "
          f"{stats['prefill_s']*1e3:.0f}ms, decode {stats['decode_s']*1e3:.0f}ms "
          f"({stats['tok_per_s']:.1f} tok/s)")
    print("[serve] sample generations:", np.asarray(gen[:2, :8]))
    return gen, stats


if __name__ == "__main__":
    main()
