"""End-to-end training driver.

Runs any registered arch (full or reduced), with:
  * mesh + FSDP/TP shardings (1-device mesh on CPU works transparently)
  * deterministic restart-safe data pipeline
  * atomic async checkpointing + restore (resume with --resume)
  * straggler monitoring + non-finite-step skipping (TrainSupervisor logic)
  * optional int8 gradient compression with error feedback (--compress-grads)

Example (CPU, ~100M-param reduced model, a few hundred steps):
    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduce \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import base as cfgbase
from repro.data.pipeline import ShardedLoader, TokenTaskConfig
from repro.distributed import sharding as sh
from repro.distributed.fault_tolerance import StragglerPolicy
from repro.models import transformer as T
from repro.optim import adamw, compression


def emit_static_mapping(params, cfg, platform, out_path, max_cout=512,
                        stacked_prefixes=("units", "enc_units"),
                        plan_hints=None, act_log_scale=None, bias=None):
    """Write a schema-v2 `repro.api` mapping artifact for the trained
    model's projection weights: per-layer min-cost static channel split
    (paper Sec. IV baselines) under the named platform's cost model, with
    max-abs weight quant scales so the artifact lowers to an executable
    `ExecutionPlan` (``serve.py --mapping`` per-layer planned execution).

    Layer names are params-pytree paths in flatten order (not network
    order).  Three weight layouts are covered:

      * 2-D ``(C_in, C_out)`` dense matrices -> one layer per weight;
      * 3-D ``(R, C_in, C_out)`` scan-stacked dense matrices (leaves under
        a ``stacked_prefixes`` subtree) -> one layer PER REPEAT, named
        ``path@r`` with that repeat's own max-abs scale, so every scanned
        layer binds and executes as mapped (no silent fp fallbacks);
      * 4-D ``(kh, kw, C_in, C_out)`` HWIO conv kernels -> one layer per
        conv, lowered through the im2col execution path.

    ``plan_hints`` — optional ``{name: (LayerGeometry, searchable)}`` from a
    façade's ``plan()`` — supplies the true cost-model geometry (conv output
    maps, groups) and searchability; grouped/depthwise convs are EMITTED
    with their group count (``"groups"`` on the artifact layer) and lower
    block-diagonally onto the im2col'd kernels — mbv1's own artifact passes
    ``--require-full-coverage``.  Without hints, conv geometry falls back to
    the weight shape alone (ox/oy unknown -> 1, groups unknown -> 1).

    ``act_log_scale``: None (default) leaves activation scales null — the
    executors then quantize activations DYNAMICALLY per call with the
    batch's max-abs, which makes planned outputs depend on batch
    composition.  Pass a float to pin a STATIC activation scale on every
    layer instead — required for the serving engine's per-request
    reproducibility guarantee (`repro.serving`: a request's tokens must not
    change with its batch neighbours).  Layers wider than ``max_cout``
    output channels are pinned to domain 0 — the exhaustive per-layer split
    search is O(C_out) cost evaluations.

    ``bias``: optional ``(domain_name, fraction)`` overriding the min-cost
    split on every SEARCHABLE layer: ``fraction`` of each layer's output
    channels are forced into the named domain (the rest stay digital, or
    domain 1 when the biased domain IS digital).  This is how a precision
    BANK is produced from one set of weights — e.g. on diana,
    ``bias=("aimc", 1.0)`` emits a ternary-heavy "draft" artifact and
    ``bias=("digital", 1.0)`` an int8 "target" artifact; both lower against
    the same params and bind as variants of one `repro.runtime.PlanSet`.
    """
    from repro.api import MappingArtifact, Platform
    from repro.core import baselines, quant
    from repro.core.cost_models import LayerGeometry

    plat = Platform.get(platform)
    cm, spec = plat.cost_model(), plat.spec()
    names, geoms, searchable, scales = [], [], [], []
    plan_hints = plan_hints or {}

    def w_scale(w):
        ls = float(quant.init_log_scale(np.asarray(w, dtype=np.float32)))
        return {"w_log_scales": [ls] * spec.n_domains,
                "act_log_scale": (float(act_log_scale)
                                  if act_log_scale is not None else None)}

    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        # dense/conv layers only ({"w": ...} dicts, the repo-wide
        # convention) — other >=2-D leaves (norm scale stacks, ssm params,
        # grouped expert einsums) can never execute as channel-split matmuls
        if not parts or parts[-1] != "w":
            continue
        parts = parts[:-1]               # drop the leaf key: name the layer
        name = "/".join(parts)
        ndim = getattr(leaf, "ndim", 0)
        hint = plan_hints.get(name)
        if ndim == 2:
            names.append(name)
            geoms.append(hint[0] if hint else
                         LayerGeometry(c_in=leaf.shape[0],
                                       c_out=leaf.shape[1]))
            searchable.append((hint[1] if hint else True) and
                              leaf.shape[1] <= max_cout)
            scales.append(w_scale(leaf))
        elif ndim == 3 and parts and parts[0] in stacked_prefixes:
            # scan-stacked dense: one artifact layer per repeat
            for r in range(leaf.shape[0]):
                names.append(f"{name}@{r}")
                geoms.append(LayerGeometry(c_in=leaf.shape[1],
                                           c_out=leaf.shape[2]))
                searchable.append(leaf.shape[2] <= max_cout)
                scales.append(w_scale(leaf[r]))
        elif ndim == 4:
            kh, kw, ci, co = leaf.shape
            names.append(name)
            # façade plan geometry carries the output map (ox/oy) the cost
            # model's latency is nonlinear in; the weight shape alone can't
            geoms.append(hint[0] if hint else
                         LayerGeometry(c_in=ci, c_out=co, fx=kw, fy=kh))
            searchable.append((hint[1] if hint else True) and
                              co <= max_cout)
            scales.append(w_scale(leaf))
    assigns = baselines.min_cost(cm, geoms, "latency", searchable)
    if bias is not None:
        dom_name, frac = bias
        dom_names = [d.name for d in spec.domains]
        if dom_name not in dom_names:
            raise ValueError(f"bias domain {dom_name!r} is not on platform "
                             f"{plat.name} (domains: {dom_names})")
        if not (0.0 <= frac <= 1.0):
            raise ValueError(f"bias fraction must be in [0, 1], got {frac}")
        di = dom_names.index(dom_name)
        other = 0 if di != 0 else min(1, spec.n_domains - 1)
        for li, a in enumerate(assigns):
            if not searchable[li]:
                continue
            k = int(round(frac * a.size))
            forced = np.full(a.size, other, dtype=np.int64)
            forced[:k] = di
            assigns[li] = forced
    counts = baselines.counts_from_assignments(assigns, spec.n_domains)
    plan = [(n, g, s) for n, g, s in zip(names, geoms, searchable)]
    art = MappingArtifact.from_search(cfg.name, spec, plan, assigns, counts,
                                      platform=plat.name, objective="latency",
                                      scales=scales)
    art.save(out_path)
    print(f"[train] wrote mapping artifact ({len(names)} layers, schema v"
          f"{art.schema_version}, platform={plat.name}) -> {out_path}")
    return art


def train_cnn(args, cnn_name: str):
    """Supervised training of a CNN façade (``--arch cnn:<config>``) on the
    synthetic image task, with ``--emit-mapping`` writing the same static
    min-cost artifact the LM path writes — conv weights included, so the
    artifact lowers onto the im2col'd planned kernels
    (``serve.py --arch cnn:... --mapping``)."""
    from repro.data.pipeline import ImageTaskConfig, image_batch
    from repro.models import cnn as C

    cfg = C.get_config(cnn_name)
    init_fn, apply_fn, plan_fn = C.get_model(cfg)
    task = ImageTaskConfig(n_classes=cfg.n_classes, img_hw=cfg.img_hw,
                           in_ch=cfg.in_ch)
    params = init_fn(jax.random.PRNGKey(args.seed), cfg, None)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name} params={n_params/1e6:.2f}M")

    ocfg = adamw.AdamWConfig(lr=args.lr, weight_decay=0.01)
    opt_state = adamw.init(params, ocfg)

    @jax.jit
    def step_fn(params, opt_state, x, y, lr):
        def loss_fn(p):
            logits = apply_fn(p, x, cfg, None, "fp", 1.0)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params,
                                                ocfg, lr=lr)
        return params, opt_state, loss

    losses = []
    for step in range(args.steps):
        lr = float(adamw.warmup_cosine(step, peak_lr=args.lr,
                                       warmup=min(args.warmup, args.steps),
                                       total=args.steps))
        x, y = image_batch(task, step, args.batch)
        params, opt_state, loss = step_fn(params, opt_state, x, y, lr)
        losses.append(float(loss))
        if step % args.log_every == 0:
            print(f"[train] step {step} loss={losses[-1]:.4f} lr={lr:.2e}")
    if args.emit_mapping:
        hints = {n: (g, s) for (n, g, s) in plan_fn(cfg)}
        emit_static_mapping(params, cfg, args.platform, args.emit_mapping,
                            plan_hints=hints, act_log_scale=args.mapping_act_scale,
                            bias=args.bias)
    print(f"[train] done. first loss={losses[0]:.4f} last={losses[-1]:.4f}")
    return losses


def make_step(cfg, ocfg, compress: bool):
    def train_step(params, opt_state, residual, batch, lr):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(p, cfg, batch, remat=True))(params)
        if compress:
            comp, residual = compression.compress_with_feedback(grads, residual)
            grads = compression.decompress(comp)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params,
                                                ocfg, lr=lr)
        return params, opt_state, residual, {"loss": loss, "grad_norm": gnorm}
    return train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="LM arch name, or cnn:<config> for CNN façades "
                         "(e.g. cnn:resnet20_tiny)")
    ap.add_argument("--reduce", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--platform", default="tpu_v5e",
                    help="repro.api platform name for --emit-mapping")
    ap.add_argument("--emit-mapping", default=None,
                    help="write a static min-cost mapping artifact (JSON) "
                         "for the trained weights to this path")
    ap.add_argument("--mapping-bias", default=None,
                    help="bias the emitted mapping toward a platform domain:"
                         " 'DOMAIN[:FRACTION]' forces that fraction "
                         "(default 1.0) of every searchable layer's output "
                         "channels into DOMAIN — emit a draft/target "
                         "precision bank from one set of weights (e.g. "
                         "'aimc' then 'digital' on diana)")
    ap.add_argument("--mapping-act-scale", type=float, default=None,
                    help="pin this STATIC activation log-scale on every "
                         "emitted layer (instead of dynamic per-batch "
                         "max-abs) — required for the serving engine's "
                         "per-request reproducibility and the speculative "
                         "decoder's token-identity guarantee")
    args = ap.parse_args(argv)

    args.bias = None
    if args.mapping_bias:
        if not args.emit_mapping:
            ap.error("--mapping-bias needs --emit-mapping")
        name, _, frac = args.mapping_bias.partition(":")
        args.bias = (name, float(frac) if frac else 1.0)
    if args.emit_mapping:
        from repro.api import Platform
        Platform.get(args.platform)   # unknown name fails before training
    if args.arch.startswith("cnn:"):
        return train_cnn(args, args.arch.split(":", 1)[1])

    cfgbase.load_all()
    cfg = cfgbase.get(args.arch)
    if args.reduce:
        cfg = cfgbase.reduce_for_smoke(cfg)

    ocfg = adamw.AdamWConfig(lr=args.lr, weight_decay=0.01)
    params = T.init_lm(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name} reduced={args.reduce} params={n_params/1e6:.1f}M")

    opt_state = adamw.init(params, ocfg)
    residual = (compression.init_residual(params)
                if args.compress_grads else None)

    data = ShardedLoader("token", TokenTaskConfig(vocab=cfg.vocab),
                         batch=args.batch, seq_len=args.seq)

    step_fn = jax.jit(make_step(cfg, ocfg, args.compress_grads),
                      donate_argnums=(0, 1, 2))

    start = 0
    saver = None
    if args.ckpt_dir:
        saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
        if args.resume:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                state_like = (params, opt_state)
                params, opt_state = ckpt.restore(args.ckpt_dir, latest,
                                                 state_like)
                start = ckpt.restore_extra(args.ckpt_dir, latest)["step"]
                print(f"[train] resumed from step {start}")

    straggler = StragglerPolicy()
    losses = []
    for step in range(start, args.steps):
        lr = float(adamw.warmup_cosine(step, peak_lr=args.lr,
                                       warmup=args.warmup, total=args.steps))
        tokens, targets = data.get(step)
        batch = {"tokens": tokens, "targets": targets}
        if cfg.frontend:
            batch["frontend"] = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(9), step),
                (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        t0 = time.monotonic()
        params, opt_state, residual, metrics = step_fn(
            params, opt_state, residual, batch, lr)
        gn = float(metrics["grad_norm"])
        if not np.isfinite(gn):
            print(f"[train] step {step}: non-finite grad norm, skipped")
            continue
        dt = time.monotonic() - t0
        verdict = straggler.observe(step, dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(f"[train] step {step} loss={losses[-1]:.4f} "
                  f"gnorm={gn:.3f} lr={lr:.2e} dt={dt*1e3:.0f}ms {verdict}")
        if saver and (step + 1) % args.ckpt_every == 0:
            saver.save(step + 1, (params, opt_state), {"step": step + 1})
    if saver:
        saver.save(args.steps, (params, opt_state), {"step": args.steps})
        saver.wait()
    if args.emit_mapping:
        emit_static_mapping(params, cfg, args.platform, args.emit_mapping,
                            act_log_scale=args.mapping_act_scale,
                            bias=args.bias)
    print(f"[train] done. first loss={losses[0]:.4f} last={losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
