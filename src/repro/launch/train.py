"""End-to-end training driver.

Runs any registered arch (full or reduced), with:
  * mesh + FSDP/TP shardings (1-device mesh on CPU works transparently)
  * deterministic restart-safe data pipeline
  * atomic async checkpointing + restore (resume with --resume)
  * straggler monitoring + non-finite-step skipping (TrainSupervisor logic)
  * optional int8 gradient compression with error feedback (--compress-grads)

Example (CPU, ~100M-param reduced model, a few hundred steps):
    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --reduce \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs import base as cfgbase
from repro.data.pipeline import ShardedLoader, TokenTaskConfig
from repro.distributed import sharding as sh
from repro.distributed.fault_tolerance import StragglerPolicy
from repro.models import transformer as T
from repro.optim import adamw, compression


def emit_static_mapping(params, cfg, platform, out_path, max_cout=512):
    """Write a schema-v2 `repro.api` mapping artifact for the trained LM's
    2-D weight matrices: per-layer min-cost static channel split (paper
    Sec. IV baselines) under the named platform's cost model, with max-abs
    weight quant scales so the artifact lowers to an executable
    `ExecutionPlan` (``serve.py --mapping`` per-layer planned execution).

    Layer names are params-pytree paths in flatten order (not network
    order).  Activation scales are left null (the executors quantize with
    dynamic max-abs statistics).  Layers wider than ``max_cout`` output
    channels are pinned to domain 0 — the exhaustive per-layer split search
    is O(C_out) cost evaluations.
    """
    from repro.api import MappingArtifact, Platform
    from repro.core import baselines, quant
    from repro.core.cost_models import LayerGeometry

    plat = Platform.get(platform)
    cm, spec = plat.cost_model(), plat.spec()
    names, geoms, searchable, scales = [], [], [], []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if getattr(leaf, "ndim", 0) != 2:
            continue
        parts = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        # dense layers only ({"w": ...} dicts, the repo-wide convention) —
        # stacked scan params make 1-D leaves (norm scales, ssm params)
        # look 2-D, and those can never execute as channel-split matmuls
        if not parts or parts[-1] != "w":
            continue
        parts = parts[:-1]               # drop the leaf key: name the layer
        name = "/".join(parts)
        names.append(name)
        geoms.append(LayerGeometry(c_in=leaf.shape[0], c_out=leaf.shape[1]))
        searchable.append(leaf.shape[1] <= max_cout)
        ls = float(quant.init_log_scale(np.asarray(leaf, dtype=np.float32)))
        scales.append({"w_log_scales": [ls] * spec.n_domains,
                       "act_log_scale": None})
    assigns = baselines.min_cost(cm, geoms, "latency", searchable)
    counts = baselines.counts_from_assignments(assigns, spec.n_domains)
    plan = [(n, g, s) for n, g, s in zip(names, geoms, searchable)]
    art = MappingArtifact.from_search(cfg.name, spec, plan, assigns, counts,
                                      platform=plat.name, objective="latency",
                                      scales=scales)
    art.save(out_path)
    print(f"[train] wrote mapping artifact ({len(names)} layers, schema v"
          f"{art.schema_version}, platform={plat.name}) -> {out_path}")
    return art


def make_step(cfg, ocfg, compress: bool):
    def train_step(params, opt_state, residual, batch, lr):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(p, cfg, batch, remat=True))(params)
        if compress:
            comp, residual = compression.compress_with_feedback(grads, residual)
            grads = compression.decompress(comp)
        params, opt_state, gnorm = adamw.update(grads, opt_state, params,
                                                ocfg, lr=lr)
        return params, opt_state, residual, {"loss": loss, "grad_norm": gnorm}
    return train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduce", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--platform", default="tpu_v5e",
                    help="repro.api platform name for --emit-mapping")
    ap.add_argument("--emit-mapping", default=None,
                    help="write a static min-cost mapping artifact (JSON) "
                         "for the trained weights to this path")
    args = ap.parse_args(argv)

    cfgbase.load_all()
    cfg = cfgbase.get(args.arch)
    if args.reduce:
        cfg = cfgbase.reduce_for_smoke(cfg)
    if args.emit_mapping:
        from repro.api import Platform
        Platform.get(args.platform)   # unknown name fails before training

    ocfg = adamw.AdamWConfig(lr=args.lr, weight_decay=0.01)
    params = T.init_lm(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name} reduced={args.reduce} params={n_params/1e6:.1f}M")

    opt_state = adamw.init(params, ocfg)
    residual = (compression.init_residual(params)
                if args.compress_grads else None)

    data = ShardedLoader("token", TokenTaskConfig(vocab=cfg.vocab),
                         batch=args.batch, seq_len=args.seq)

    step_fn = jax.jit(make_step(cfg, ocfg, args.compress_grads),
                      donate_argnums=(0, 1, 2))

    start = 0
    saver = None
    if args.ckpt_dir:
        saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
        if args.resume:
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                state_like = (params, opt_state)
                params, opt_state = ckpt.restore(args.ckpt_dir, latest,
                                                 state_like)
                start = ckpt.restore_extra(args.ckpt_dir, latest)["step"]
                print(f"[train] resumed from step {start}")

    straggler = StragglerPolicy()
    losses = []
    for step in range(start, args.steps):
        lr = float(adamw.warmup_cosine(step, peak_lr=args.lr,
                                       warmup=args.warmup, total=args.steps))
        tokens, targets = data.get(step)
        batch = {"tokens": tokens, "targets": targets}
        if cfg.frontend:
            batch["frontend"] = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(9), step),
                (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        t0 = time.monotonic()
        params, opt_state, residual, metrics = step_fn(
            params, opt_state, residual, batch, lr)
        gn = float(metrics["grad_norm"])
        if not np.isfinite(gn):
            print(f"[train] step {step}: non-finite grad norm, skipped")
            continue
        dt = time.monotonic() - t0
        verdict = straggler.observe(step, dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(f"[train] step {step} loss={losses[-1]:.4f} "
                  f"gnorm={gn:.3f} lr={lr:.2e} dt={dt*1e3:.0f}ms {verdict}")
        if saver and (step + 1) % args.ckpt_every == 0:
            saver.save(step + 1, (params, opt_state), {"step": step + 1})
    if saver:
        saver.save(args.steps, (params, opt_state), {"step": args.steps})
        saver.wait()
    if args.emit_mapping:
        emit_static_mapping(params, cfg, args.platform, args.emit_mapping)
    print(f"[train] done. first loss={losses[0]:.4f} last={losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
