import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
# on the production mesh, record memory/cost analysis + collective schedule.
#
# MUST be run as its own process (the XLA_FLAGS lines above precede every jax
# import, since jax locks the device count on first init):
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b \
#         --shape train_4k [--multi-pod] [--out experiments/dryrun]
#
#     PYTHONPATH=src python -m repro.launch.dryrun --all  # everything, serial

import argparse
import json
import re
import time
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfgbase
from repro.distributed import sharding as sh
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh, mesh_axes

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in optimized HLO, separating
    ops inside while-loop bodies (executed once per scanned layer repeat)
    from top-level ops.  Returns {op: {"top": bytes, "loop": bytes}}."""
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                   "s8": 1, "u8": 1, "f64": 8, "s64": 8, "pred": 1,
                   "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}
    result = {c: {"top": 0, "loop": 0} for c in COLLECTIVES}
    counts = {c: {"top": 0, "loop": 0} for c in COLLECTIVES}
    current_comp = ""
    loop_comps = set()
    # first pass: find computations used as while bodies/conditions
    for m in re.finditer(r"while\([^)]*\).*?body=([%\w.\-]+)", hlo_text):
        loop_comps.add(m.group(1).lstrip("%"))
    for line in hlo_text.splitlines():
        mcomp = re.match(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->", line)
        if mcomp and "{" in line or re.match(r"^%?[\w.\-]+ \(", line):
            if mcomp:
                current_comp = mcomp.group(1)
        for coll in COLLECTIVES:
            if f" {coll}(" in line or f"= {coll}(" in line or \
               re.search(rf"\b{coll}(-start)?\(", line):
                # operand bytes: parse result shape, e.g. bf16[2048,512]{...}
                shapes = re.findall(r"(\w+)\[([\d,]*)\]", line)
                if not shapes:
                    continue
                dt, dims = shapes[0]
                if dt not in dtype_bytes:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes = n * dtype_bytes[dt]
                # crude scope attribution: computation named like a loop body
                scope = "loop" if (current_comp in loop_comps or
                                   "body" in current_comp or
                                   "while" in current_comp) else "top"
                result[coll][scope] += nbytes
                counts[coll][scope] += 1
    return {"bytes": result, "counts": counts}


def scanned_repeats(cfg) -> int:
    """Trip count of the layer scan (collectives inside count this many x)."""
    period = len(cfg.pattern)
    r = cfg.n_layers // period
    if cfg.moe_first_dense and period == 1:
        r -= 1
    return r


VARIANTS = {
    "base": {},
    "kvq8": {"kv_cache_dtype": "int8"},
    "wq8": {"serve_weight_dtype": "int8"},
    "kvwq8": {"kv_cache_dtype": "int8", "serve_weight_dtype": "int8"},
}


def mapping_plan_report(cfg, mapping_path: str) -> dict:
    """Lower a mapping artifact against the arch's weight SHAPES (no
    concrete params needed) and report the per-layer kernel selection the
    runtime would execute — the compile-time view of `serve.py --mapping`."""
    import json as _json

    from repro.models import transformer as T
    from repro.runtime import lower

    from repro.runtime import LoweringError

    artifact = _json.loads(Path(mapping_path).read_text())
    pshapes = jax.eval_shape(lambda k: T.init_lm(k, cfg),
                             jax.random.PRNGKey(0))
    try:
        plan = lower(artifact, params=pshapes)
    except LoweringError as e:
        # no traceback: the message IS the diagnostic (main exits 2 on it)
        print(f"[dryrun] mapping {mapping_path} does not lower onto "
              f"{cfg.name}: {e}")
        return {"error": str(e)}
    rec = {"kernels": plan.kernel_histogram(),
           "fallbacks": plan.fallback_reasons(),
           "layers": [{"name": lp.name, "kernel": lp.kernel,
                       "counts": lp.counts,
                       "aligned_boundaries": lp.aligned_boundaries,
                       **({"note": lp.note} if lp.note else {})}
                      for lp in plan.layers]}
    print(f"[dryrun] mapping {mapping_path}: {plan.summary()}")
    for line in plan.histogram_lines():
        print(f"[dryrun] {line}")
    try:  # registry introspection: what CAN this platform's domains fuse?
        from repro.api import Platform
        caps = Platform.get(plan.platform).kernel_capabilities()
        for names, (kernel, note) in caps.items():
            extra = f"  ({note})" if note else ""
            print(f"[dryrun]   capability {'+'.join(names)}: "
                  f"{kernel}{extra}")
    except KeyError:
        pass  # unregistered platform name in the artifact
    for l in rec["layers"]:
        note = f"  ({l['note']})" if "note" in l else ""
        print(f"[dryrun]   {l['name']}: {l['kernel']} "
              f"counts={l['counts']}{note}")
    return rec


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path,
             variant: str = "base", mapping: str | None = None) -> dict:
    import dataclasses as _dc
    cfg = cfgbase.get(arch)
    if VARIANTS[variant]:
        cfg = _dc.replace(cfg, **VARIANTS[variant])
    if not SP.cell_is_runnable(cfg, shape):
        rec = {"arch": arch, "shape": shape, "variant": variant,
               "multi_pod": multi_pod, "status": "skipped",
               "reason": "full-attention arch: long_500k requires "
                         "sub-quadratic sequence mixing (DESIGN.md §4)"}
        _write(out_dir, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp, tp = mesh_axes(mesh)
    sh.set_mesh_axes(dp, tp, mesh)

    meta = SP.SHAPES[shape]
    pspec = SP.param_specs(cfg)
    if meta["kind"] != "train" and cfg.serve_weight_dtype == "int8":
        from repro.models.transformer import quantize_for_serve
        pspec = quantize_for_serve(pspec, cfg)
    psh = sh.shardings_for_params(mesh, pspec, dp, tp)
    inputs = SP.input_specs(cfg, shape)

    t0 = time.time()
    with mesh:
        if meta["kind"] == "train":
            ospec = SP.opt_specs(cfg)
            # moments share the param specs; step counter replicated
            osh = jax.tree_util.tree_map_with_path(
                lambda path, l: NamedSharding(
                    mesh,
                    sh.param_spec(mesh, sh._path_str(path[1:]), l.shape, dp, tp)
                    if len(l.shape) > 1 else P()),
                ospec)
            bsh = jax.tree.map(
                lambda l: NamedSharding(mesh, sh.batch_spec(mesh, l.shape, dp)),
                inputs["batch"])
            step = SP.make_train_step(cfg, grad_shardings=psh)
            jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(pspec, ospec, inputs["batch"])
        elif meta["kind"] == "prefill":
            csh = jax.tree.map(
                lambda l: NamedSharding(
                    mesh, sh.cache_leaf_spec(mesh, l.shape, dp, tp)),
                inputs["caches"])
            tsh = NamedSharding(
                mesh, sh.batch_spec(mesh, inputs["tokens"].shape, dp))
            step = SP.make_prefill_step(cfg)
            if cfg.frontend:
                fsh = NamedSharding(
                    mesh, sh.batch_spec(mesh, inputs["frontend"].shape, dp))
                jitted = jax.jit(step, in_shardings=(psh, tsh, csh, fsh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(pspec, inputs["tokens"],
                                       inputs["caches"], inputs["frontend"])
            else:
                jitted = jax.jit(step, in_shardings=(psh, tsh, csh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(pspec, inputs["tokens"],
                                       inputs["caches"])
        else:  # decode
            csh = jax.tree.map(
                lambda l: NamedSharding(
                    mesh, sh.cache_leaf_spec(mesh, l.shape, dp, tp)),
                inputs["caches"])
            tsh = NamedSharding(
                mesh, sh.batch_spec(mesh, inputs["token"].shape, dp))
            ish = NamedSharding(mesh, P())
            step = SP.make_serve_step(cfg)
            jitted = jax.jit(step, in_shardings=(psh, tsh, csh, ish),
                             donate_argnums=(2,))
            lowered = jitted.lower(pspec, inputs["token"],
                                   inputs["caches"], inputs["index"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older JAX: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)

    mem_rec = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        mem_rec[k] = int(getattr(mem, k, 0) or 0)

    rec = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod,
        "variant": variant,
        "status": "ok",
        "mesh": list(mesh.devices.shape),
        "n_devices": int(np.prod(mesh.devices.shape)),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem_rec,
        "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                          if isinstance(v, (int, float)) and
                          k in ("flops", "bytes accessed",
                                "bytes accessed operand 0 {}",
                                "bytes accessed output {}", "utilization")},
        "collectives": colls,
        "scan_repeats": scanned_repeats(cfg),
    }
    if mapping:
        rec["mapping_plan"] = mapping_plan_report(cfg, mapping)
    print(f"[dryrun] {arch} x {shape} ({'2x16x16' if multi_pod else '16x16'})"
          f" OK  compile={t_compile:.0f}s  temp="
          f"{mem_rec['temp_size_in_bytes']/2**30:.2f}GiB/dev "
          f"args={mem_rec['argument_size_in_bytes']/2**30:.2f}GiB/dev")
    print("  memory_analysis:", mem_rec)
    print("  cost_analysis (per-device, scan bodies counted once):",
          rec["cost_analysis"])
    _write(out_dir, rec)
    sh.clear_mesh_axes()
    return rec


def _write(out_dir: Path, rec: dict):
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = "mp" if rec["multi_pod"] else "sp"
    if rec.get("variant", "base") != "base":
        tag = f"{tag}-{rec['variant']}"
    path = out_dir / f"{rec['arch']}__{rec['shape']}__{tag}.json"
    path.write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SP.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="base", choices=list(VARIANTS))
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mapping", default=None,
                    help="mapping artifact JSON: report the per-layer "
                         "kernel selection the runtime would execute")
    args = ap.parse_args()
    out = Path(args.out)

    cfgbase.load_all()
    if args.all:
        for arch in cfgbase.names():
            for shape in SP.SHAPES:
                for mp in (False, True):
                    run_cell(arch, shape, mp, out)
    else:
        rec = run_cell(args.arch, args.shape, args.multi_pod, out,
                       variant=args.variant, mapping=args.mapping)
        err = (rec or {}).get("mapping_plan", {}).get("error")
        if err:
            import sys
            print(f"[dryrun] ERROR: {err}", file=sys.stderr)
            sys.exit(2)


if __name__ == "__main__":
    main()
