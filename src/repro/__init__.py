"""repro: ODiMO (precision-aware multi-accelerator DNN mapping) as a
production-grade JAX framework. See DESIGN.md."""
__version__ = "0.1.0"
