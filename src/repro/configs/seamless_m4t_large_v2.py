"""SeamlessM4T-large-v2 [arXiv:2308.11596; hf].

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 — encoder-decoder,
multimodal. The speech frontend is a STUB: input_specs supplies precomputed
frame embeddings (B, T_enc, d_model); the text decoder cross-attends.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206, norm="layernorm", act="gelu", gated_ffn=False,
    rope_theta=10000.0, pattern=("dec",),
    encoder_layers=24, frontend="audio", frontend_tokens=1024,
))
