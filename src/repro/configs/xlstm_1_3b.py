"""xLSTM-1.3B [arXiv:2405.04517; unverified].

48L d_model=2048 4H (kv=4) d_ff=0 vocab=50304 — xLSTM[7:1]: 7 mLSTM blocks
per sLSTM block. Linear recurrence => sub-quadratic, long_500k OK.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304, norm="rmsnorm",
    pattern=("mlstm",) * 7 + ("slstm",),
    subquadratic=True,
))
