"""Zamba2-1.2B [arXiv:2411.15242; hf].

38 blocks d_model=2048, Mamba2 backbone (ssm_state=64) with a SHARED
attention+FFN transformer block applied every 6th position (weights shared,
per-use input norms). 32H kv=32, shared-block d_ff=8192.
Mamba2 recurrence => sub-quadratic, long_500k OK.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000, norm="rmsnorm", act="gelu", gated_ffn=True,
    rope_theta=10000.0,
    pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    ssm_state=64, subquadratic=True,
))
