"""H2O-Danube3-4B [arXiv:2401.16818; unverified].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 — llama+mistral mix
with sliding-window attention (window 4096) => sub-quadratic, long_500k OK.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab=32000, norm="rmsnorm", act="silu", gated_ffn=True,
    rope_theta=10000.0, sliding_window=4096, pattern=("attn",),
    subquadratic=True,
))
