"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2
PLUS a dense residual FFN in parallel (dense-MoE hybrid).
Optimizer moments run in bf16 so the 256-chip pod fits (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, register
from repro.models.moe import MoEConfig

CONFIG = register(ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000, norm="rmsnorm", act="silu", gated_ffn=True,
    rope_theta=10000.0, pattern=("attn",),
    moe=MoEConfig(n_experts=128, top_k=2, d_ff=4864),
    moe_dense_residual=True, dense_ff=4864,
))
