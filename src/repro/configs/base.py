"""Architecture config schema + registry for the 10 assigned architectures.

Block kinds used in ``pattern`` (the repeating layer unit):
  attn        self-attention (GQA) + FFN
  mla         multi-head latent attention + FFN (deepseek)
  cross       cross-attention to frontend embeddings + FFN (vlm)
  dec         decoder layer: self-attn + cross-attn + FFN (enc-dec)
  mamba       Mamba2 block (no FFN)
  mlstm/slstm xLSTM blocks (no FFN)
  shared_attn zamba2 shared transformer block (weights shared across uses)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.moe import MoEConfig


@dataclasses.dataclass(frozen=True)
class MLASpec:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|vlm|audio|ssm|moe|hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    norm: str = "rmsnorm"
    act: str = "silu"
    gated_ffn: bool = True
    parallel_block: bool = False     # command-r: attn and FFN in parallel
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False
    pattern: Tuple[str, ...] = ("attn",)
    moe: Optional[MoEConfig] = None
    moe_dense_residual: bool = False # arctic: dense FFN in parallel with MoE
    moe_first_dense: int = 0         # deepseek: first k layers use dense FFN
    dense_ff: int = 0                # hidden of dense residual / first-dense FFN
    mla: Optional[MLASpec] = None
    ssm_state: int = 64
    encoder_layers: int = 0          # seamless enc-dec
    frontend: Optional[str] = None   # "vision"|"audio" — STUB embeddings
    frontend_tokens: int = 0
    subquadratic: bool = False       # eligible for long_500k
    param_dtype: str = "bfloat16"
    # ODiMO-on-TPU serve-time precision domains (DESIGN.md §2):
    kv_cache_dtype: str = "bfloat16"   # "int8": quantized KV/latent cache
    serve_weight_dtype: str = "bfloat16"  # "int8": quantized projections

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def pattern_repeats(self) -> int:
        assert self.n_layers % len(self.pattern) == 0 or self.name.startswith("zamba"), \
            (self.name, self.n_layers, len(self.pattern))
        return self.n_layers // len(self.pattern)


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def names():
    if not _REGISTRY:
        load_all()
    return sorted(_REGISTRY)


def load_all():
    """Import every config module (each self-registers)."""
    from repro.configs import (  # noqa: F401
        command_r_35b, nemotron_4_15b, yi_9b, h2o_danube_3_4b,
        llama_3_2_vision_11b, seamless_m4t_large_v2, xlstm_1_3b,
        arctic_480b, deepseek_v2_lite_16b, zamba2_1_2b,
    )


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Shrink a config to CPU-smoke-test size, preserving the family shape."""
    period = len(cfg.pattern)
    n_layers = period * min(2, max(1, cfg.n_layers // period))
    if cfg.name.startswith("zamba"):
        n_layers = period + 2  # one full period + the remainder mambas
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, n_experts=8,
                                  top_k=min(cfg.moe.top_k, 2), d_ff=64)
    mla = MLASpec(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                  v_head_dim=16) if cfg.mla else None
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=64,
        n_heads=4, n_kv_heads=min(4, max(1, cfg.n_kv_heads)), head_dim=16,
        d_ff=128 if cfg.d_ff else 0, vocab=128, moe=moe, mla=mla,
        dense_ff=96 if cfg.dense_ff else 0,
        encoder_layers=min(2, cfg.encoder_layers),
        frontend_tokens=8 if cfg.frontend_tokens else 0,
        sliding_window=16 if cfg.sliding_window else None)
