"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf].

27L d_model=2048 16H (kv=16 via MLA kv_lora=512) moe_d_ff=1408 vocab=102400,
MoE 64 routed top-6 + 2 shared experts; layer 0 uses a dense FFN (10944).
"""
from repro.configs.base import ArchConfig, MLASpec, register
from repro.models.moe import MoEConfig

CONFIG = register(ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102400, norm="rmsnorm", act="silu", gated_ffn=True,
    rope_theta=10000.0, pattern=("mla",),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared=2),
    moe_first_dense=1, dense_ff=10944,
    mla=MLASpec(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                v_head_dim=128),
))
