"""Architecture registry: from repro.configs import base; base.get(name)."""
from repro.configs.base import ArchConfig, get, names, load_all, reduce_for_smoke
