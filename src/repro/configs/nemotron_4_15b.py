"""Nemotron-4 15B [arXiv:2402.16819; unverified].

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000 — GQA,
squared-ReLU ungated MLP, LayerNorm, RoPE.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=256000, norm="layernorm", act="relu2", gated_ffn=False,
    rope_theta=10000.0, pattern=("attn",),
))
