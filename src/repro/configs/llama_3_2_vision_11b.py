"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256 — text backbone with
cross-attention image layers every 5th layer. The vision tower is a STUB:
input_specs supplies precomputed patch embeddings (B, 1024, d_model).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=128256, norm="rmsnorm", act="silu", gated_ffn=True,
    rope_theta=500000.0,
    pattern=("attn", "attn", "attn", "cross", "attn"),
    frontend="vision", frontend_tokens=1024,
))
