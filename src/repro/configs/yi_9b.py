"""Yi-9B [arXiv:2403.04652; hf].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 — llama-arch GQA,
RMSNorm, SwiGLU.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab=64000, norm="rmsnorm", act="silu", gated_ffn=True,
    rope_theta=10000.0, pattern=("attn",),
))
