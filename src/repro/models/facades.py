"""Small ODiMO-managed façades beyond the paper CNNs: an MLP stack and a
transformer-encoder classifier, both built from managed Dense layers
(``repro.models.managed``) so every weight matrix is channel-wise searchable.

Both follow the standard façade contract consumed by
`repro.api.ModelHandle.from_legacy`:

    init(key, cfg, spec)                      -> params pytree
    apply(params, x, cfg, spec, mode, tau)    -> logits
    plan(cfg)                                 -> [(name, geometry, searchable)]

Plan names are params-pytree paths, so the default managed-layer lookup of
`ModelHandle` works without a custom ``managed_layers``.

Both apply functions take a pluggable matmul ``backend`` (the
`repro.models._backend` protocol): with ``mode="deploy"`` and a
`repro.runtime.PlannedBackend`, every covered dense executes through its
planned split-precision/quant kernel while declined layers fall back to the
discretized fake-quant weights — mapping execution without forking the model.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.cost_models import LayerGeometry
from repro.models import _backend
from repro.models import managed as mg

_null_ctx = contextlib.nullcontext


# --------------------------------------------------------------------------
# MLP over flattened inputs (the TPU-domains example model)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPConfig:
    in_dim: int
    widths: Tuple[int, ...]
    n_classes: int
    name: str = "mlp"


def mlp_init(key, cfg: MLPConfig, spec):
    ks = jax.random.split(key, len(cfg.widths) + 1)
    dims = [cfg.in_dim] + list(cfg.widths)
    layers = [mg.init_dense(ks[i], dims[i], dims[i + 1], spec)
              for i in range(len(cfg.widths))]
    head = mg.init_dense(ks[-1], cfg.widths[-1], cfg.n_classes, spec)
    return {"layers": layers, "head": head}


def mlp_apply(p, x, cfg: MLPConfig, spec=None, mode="fp", tau=1.0,
              backend=None, variant=None):
    with mg.matmul_backend(backend) if backend is not None else \
            _null_ctx():
        with _backend.plan_variant(variant):
            h = x.reshape(x.shape[0], -1)
            for i, lp in enumerate(p["layers"]):
                h = jax.nn.relu(mg.dense(lp, h, spec, mode, tau,
                                         name=f"layers/{i}"))
            return mg.dense(p["head"], h, spec, mode, tau, name="head")


def mlp_plan(cfg: MLPConfig) -> List[Tuple[str, LayerGeometry, bool]]:
    dims = [cfg.in_dim] + list(cfg.widths)
    plan = [(f"layers/{i}", mg.dense_geometry(dims[i], dims[i + 1]), True)
            for i in range(len(cfg.widths))]
    plan.append(("head", mg.dense_geometry(cfg.widths[-1], cfg.n_classes),
                 True))
    return plan


# --------------------------------------------------------------------------
# Transformer-encoder classifier (patchify -> blocks -> mean-pool -> head)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    in_dim: int                 # per-token input dim after patchify
    n_tokens: int
    d_model: int
    n_layers: int
    n_classes: int
    n_heads: int = 4
    ffn_mult: int = 2
    name: str = "encoder"


def _block_init(key, cfg: EncoderConfig, spec):
    ks = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_model * cfg.ffn_mult
    return {
        "qkv": mg.init_dense(ks[0], d, 3 * d, spec),
        "proj": mg.init_dense(ks[1], d, d, spec),
        "ffn1": mg.init_dense(ks[2], d, f, spec),
        "ffn2": mg.init_dense(ks[3], f, d, spec),
    }


def encoder_init(key, cfg: EncoderConfig, spec):
    ks = jax.random.split(key, cfg.n_layers + 2)
    return {
        "embed": mg.init_dense(ks[0], cfg.in_dim, cfg.d_model, spec),
        "blocks": [_block_init(ks[1 + i], cfg, spec)
                   for i in range(cfg.n_layers)],
        "head": mg.init_dense(ks[-1], cfg.d_model, cfg.n_classes, spec),
    }


def _attention(h, qkv, cfg: EncoderConfig):
    B, S, D = h.shape
    hd = D // cfg.n_heads
    q, k, v = jnp.split(qkv, 3, axis=-1)
    shape = (B, S, cfg.n_heads, hd)
    q, k, v = (t.reshape(shape).transpose(0, 2, 1, 3) for t in (q, k, v))
    att = jax.nn.softmax(q @ k.transpose(0, 1, 3, 2) / hd ** 0.5, axis=-1)
    return (att @ v).transpose(0, 2, 1, 3).reshape(B, S, D)


def _tokens(x, cfg: EncoderConfig):
    """Flatten any input into (B, n_tokens, in_dim) patch tokens."""
    return x.reshape(x.shape[0], cfg.n_tokens, cfg.in_dim)


def encoder_apply(p, x, cfg: EncoderConfig, spec=None, mode="fp", tau=1.0,
                  backend=None, variant=None):
    with mg.matmul_backend(backend) if backend is not None else \
            _null_ctx():
        with _backend.plan_variant(variant):
            h = mg.dense(p["embed"], _tokens(x, cfg), spec, mode, tau,
                         name="embed")
            for i, blk in enumerate(p["blocks"]):
                a = _attention(h, mg.dense(blk["qkv"], h, spec, mode, tau,
                                           name=f"blocks/{i}/qkv"), cfg)
                h = h + mg.dense(blk["proj"], a, spec, mode, tau,
                                 name=f"blocks/{i}/proj")
                f = jax.nn.relu(mg.dense(blk["ffn1"], h, spec, mode, tau,
                                         name=f"blocks/{i}/ffn1"))
                h = h + mg.dense(blk["ffn2"], f, spec, mode, tau,
                                 name=f"blocks/{i}/ffn2")
            return mg.dense(p["head"], jnp.mean(h, axis=1), spec, mode, tau,
                            name="head")


def encoder_plan(cfg: EncoderConfig) -> List[Tuple[str, LayerGeometry, bool]]:
    d, f = cfg.d_model, cfg.d_model * cfg.ffn_mult
    plan = [("embed", mg.dense_geometry(cfg.in_dim, d), True)]
    for i in range(cfg.n_layers):
        plan += [(f"blocks/{i}/qkv", mg.dense_geometry(d, 3 * d), True),
                 (f"blocks/{i}/proj", mg.dense_geometry(d, d), True),
                 (f"blocks/{i}/ffn1", mg.dense_geometry(d, f), True),
                 (f"blocks/{i}/ffn2", mg.dense_geometry(f, d), True)]
    plan.append(("head", mg.dense_geometry(d, cfg.n_classes), True))
    return plan
