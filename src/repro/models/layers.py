"""Shared transformer building blocks (pure JAX, pytree params).

Weight layout: every projection is (in_features, out_features) so the ODiMO
output-channel convention (out axis last) holds framework-wide.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import _backend


def init_dense(key, d_in, d_out, dtype=jnp.bfloat16, scale=None, bias=False):
    s = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, name=None):
    """``name`` is the layer's pytree path, forwarded to the pluggable
    matmul backend (`repro.models._backend`); None skips backend dispatch."""
    be = _backend.current()
    if be is not None:
        y = be(name, p, x)
        if y is not None:
            return y  # planned kernel output, bias applied by the backend
    if "w_q" in p:
        # int8-domain weights: HBM stream is int8; dequant fuses into the
        # matmul operand load (per-output-channel scale)
        w = p["w_q"].astype(x.dtype) * p["w_s"].astype(x.dtype)[..., None, :]
        y = x @ w
    else:
        y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_norm(d, dtype=jnp.bfloat16, kind="rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm(p, x, kind="rmsnorm", eps=1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rope(x, positions, theta=10000.0, rotary_dim=None):
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = rotary_dim or x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]   # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:d]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)
    if d < x.shape[-1]:
        out = jnp.concatenate([out, x[..., d:]], axis=-1)
    return out


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "relu2":  # nemotron squared-ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


def init_ffn(key, d_model, d_ff, gated: bool, dtype=jnp.bfloat16, bias=False):
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], d_model, d_ff, dtype, bias=bias),
         "down": init_dense(ks[1], d_ff, d_model, dtype,
                            scale=d_ff ** -0.5, bias=bias)}
    if gated:
        p["gate"] = init_dense(ks[2], d_model, d_ff, dtype, bias=bias)
    return p


def ffn(p, x, act_name="silu", name=None):
    a = act_fn(act_name)
    j = _backend.join
    if "gate" in p:
        h = a(dense(p["gate"], x, j(name, "gate"))) * \
            dense(p["up"], x, j(name, "up"))
    else:
        h = a(dense(p["up"], x, j(name, "up")))
    return dense(p["down"], h, j(name, "down"))
