"""Model zoo: paper CNNs + the 10 assigned LM architectures."""
