"""State-space / recurrent blocks: Mamba2 (zamba2) and xLSTM (mLSTM, sLSTM).

All recurrences are O(T) scans with O(1) per-token state, which is what makes
these archs eligible for the long_500k decode shape (DESIGN.md §4).

State conventions (decode caches):
  mamba2 : {"ssm": (B, H, hd, N), "conv": (B, K-1, conv_dim)}
  mlstm  : {"C": (B, H, hd, hd), "n": (B, H, hd), "m": (B, H)}
  slstm  : {"c","n","h": (B, H, hd), "m": (B, H)}
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models._backend import join as _j


# ===================================================================== Mamba2

@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def n_heads(self):
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self):
        return self.d_inner + 2 * self.d_state


def init_mamba2(key, cfg: Mamba2Config, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    proj_out = 2 * di + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": L.init_dense(ks[0], d, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, cfg.conv_dim))
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": L.init_norm(di, dtype),
        "out_proj": L.init_dense(ks[2], di, d, dtype, scale=di ** -0.5),
    }


def _causal_conv(x, w, b, state=None, lengths=None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). state: (B,K-1,C)|None.

    ``lengths`` (B,) makes the NEW state ragged-aware: with right-padded
    inputs the carried window must hold the last K-1 VALID positions of
    each slot, i.e. ``xp[b, lengths[b] : lengths[b]+K-1]`` (``xp`` prepends
    the K-1 carry rows, so index ``lengths`` is exactly that window; a slot
    with lengths == 0 keeps its state bit-identical)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    if lengths is None:
        new_state = xp[:, -(K - 1):, :]
    else:
        new_state = jax.vmap(
            lambda xb, l: jax.lax.dynamic_slice(
                xb, (l, 0), (K - 1, xb.shape[1])))(xp, lengths)
    return out + b, new_state



SSD_CHUNK = 256


def _ssd_chunked(xs, Bt, Ct, dt, la, h0, chunk=None):
    """Chunkwise-parallel SSD (Mamba2).  xs (B,S,H,P); Bt/Ct (B,S,N);
    dt/la (B,S,H) with la = dt*A <= 0; h0 (B,H,P,N) f32.
    Returns (h_final, y (B,S,H,P) f32).

    Padding steps use dt=0 (=> la=0): exact identity on the state.
    """
    B, S, H, P = xs.shape
    N = Bt.shape[-1]
    Q = min(chunk or SSD_CHUNK, S)
    pad = (-S) % Q
    if pad:
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        xs, Bt, Ct, dt, la = map(zf, (xs, Bt, Ct, dt, la))
    Sp = S + pad
    nc = Sp // Q

    def r(a):  # (B,Sp,...) -> (nc, B, Q, ...)
        return a.reshape(B, nc, Q, *a.shape[2:]).swapaxes(0, 1)

    xs_c, B_c, C_c, dt_c, la_c = map(r, (xs, Bt, Ct, dt, la))

    @jax.checkpoint
    def chunk_fn(h, inp):
        xc, bc, cc, dtc, lac = inp
        xc = xs_f = xc.astype(jnp.float32)
        bc = bc.astype(jnp.float32)
        cc = cc.astype(jnp.float32)
        ca = jnp.cumsum(lac, axis=1)                       # (B,Q,H) inclusive
        # intra-chunk: y_t += sum_{s<=t} exp(ca_t - ca_s) dt_s (C_t.B_s) x_s
        cb = constrain(jnp.einsum("bqn,bsn->bqs", cc, bc), "act")  # (B,Q,Q)
        L = jnp.exp(ca[:, :, None, :] - ca[:, None, :, :])  # (B,Q,S=Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        W = constrain(cb[..., None] * jnp.where(tri, L, 0.0), "act")
        y = jnp.einsum("bqsh,bsh,bshp->bqhp", W, dtc, xs_f)
        # inter-chunk: y_t += exp(ca_t) C_t . h0
        y = y + jnp.einsum("bqn,bhpn->bqhp", cc, h) *             jnp.exp(ca)[..., None]
        # state update: h' = exp(ca_Q) h0 + sum_s exp(ca_Q - ca_s) dt_s B_s x_s
        dlast = jnp.exp(ca[:, -1:, :] - ca)                # (B,Q,H)
        h = jnp.exp(ca[:, -1, :])[:, :, None, None] * h +             jnp.einsum("bsh,bshp,bsn->bhpn", dlast * dtc, xs_f, bc)
        return h, y

    hT, ys = jax.lax.scan(chunk_fn, h0, (xs_c, B_c, C_c, dt_c, la_c))
    y = ys.swapaxes(0, 1).reshape(B, Sp, H, P)[:, :S]
    return hT, y


def mamba2(p, x, cfg: Mamba2Config, state=None, name=None, length_mask=None):
    """x: (B,S,D). Returns (y, new_state). Recurrent selective-state scan.

    ``length_mask`` (B,S) bool marks the VALID positions of right-padded
    ragged inputs (continuous-batching prefill / per-slot decode): padded
    steps run with dt = 0, which is an exact identity on the SSM state
    (see `_ssd_chunked`), and the conv carry keeps each slot's last valid
    window.  Outputs at masked positions are garbage by contract."""
    B, S, D = x.shape
    di, N, H, hd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    zxbcdt = L.dense(p["in_proj"], x, _j(name, "in_proj"))
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + cfg.conv_dim]
    dt_raw = zxbcdt[..., di + cfg.conv_dim:]                    # (B,S,H)

    lengths = (jnp.sum(length_mask.astype(jnp.int32), axis=-1)
               if length_mask is not None else None)
    conv_state = state["conv"] if state is not None else None
    xbc = constrain(xbc, "act")
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state,
                                 lengths=lengths)
    xbc = jax.nn.silu(xbc)
    xs = constrain(xbc[..., :di].reshape(B, S, H, hd), "act")
    Bt = xbc[..., di:di + N]                                    # (B,S,N)
    Ct = xbc[..., di + N:]                                      # (B,S,N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    if length_mask is not None:
        dt = dt * length_mask[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"])                                    # (H,)

    h0 = (state["ssm"] if state is not None
          else jnp.zeros((B, H, hd, N), jnp.float32))

    la = dt * A                                                 # (B,S,H) <= 0
    if S > 1:
        # chunkwise SSD (parallel within chunks, O(S*Q) not O(S) scan steps;
        # backward stores only per-chunk states -> bounded memory)
        hT, y = _ssd_chunked(xs, Bt, Ct, dt, la, h0)
    else:
        dA = jnp.exp(la)
        def step(h, inp):
            xs_t, B_t, C_t, dA_t, dt_t = inp
            dBx = jnp.einsum("bhp,bn,bh->bhpn", xs_t.astype(jnp.float32),
                             B_t.astype(jnp.float32), dt_t)
            h = h * dA_t[..., None, None] + dBx
            yt = jnp.einsum("bhpn,bn->bhp", h, C_t.astype(jnp.float32))
            return h, yt
        inps = (xs.transpose(1, 0, 2, 3), Bt.transpose(1, 0, 2),
                Ct.transpose(1, 0, 2), dA.transpose(1, 0, 2),
                dt.transpose(1, 0, 2))
        hT, ys = jax.lax.scan(step, h0, inps)
        y = ys.transpose(1, 0, 2, 3)                            # (B,S,H,hd)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(z)
    y = L.norm(p["norm"], y)
    out = L.dense(p["out_proj"], y, _j(name, "out_proj"))
    new_state = {"ssm": hT, "conv": new_conv}
    return out, new_state


def mamba2_init_state(B, cfg: Mamba2Config, dtype=jnp.bfloat16):
    return {"ssm": jnp.zeros((B, cfg.n_heads, cfg.head_dim, cfg.d_state),
                             jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_kernel - 1, cfg.conv_dim), dtype)}


# ===================================================================== mLSTM

@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int = 4
    expand: int = 2
    conv_kernel: int = 4

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def head_dim(self):
        return self.d_inner // self.n_heads


def init_mlstm(key, cfg: XLSTMConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 7)
    d, di, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    hd = cfg.head_dim
    bd = lambda k: (jax.random.normal(k, (H, hd, hd)) * hd ** -0.5).astype(dtype)
    return {
        "up": L.init_dense(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, di)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        # block-diagonal per-head q/k/v (xLSTM: di^2/H params each, not di^2)
        "wq_bd": bd(ks[2]),
        "wk_bd": bd(ks[3]),
        "wv_bd": bd(ks[4]),
        "w_if": L.init_dense(ks[5], di, 2 * H, dtype),   # input+forget gates
        "norm": L.init_norm(di, dtype),
        "down": L.init_dense(ks[6], di, d, dtype, scale=di ** -0.5),
    }



MLSTM_CHUNK = 256


def _mlstm_chunked(q, k, v, ig, fg, state, chunk=None):
    """Chunkwise-parallel mLSTM with exact exponential-gating stabilization.

    q/k/v (B,S,H,hd); ig/fg (B,S,H) raw gate pre-activations; state
    (C0 (B,H,hd,hd), n0 (B,H,hd), m0 (B,H)) in the same scaled convention as
    the recurrent step (stored C == true C / exp(m)).
    Returns ((C,n,m), h (B,S,H,hd) f32).

    Derivation (matches the recurrent form exactly): with a = cumsum(logf)
    inclusive and u_s = i_s - a_s,
      m_t     = a_t + M_t,  M_t = max(cummax_{s<=t} u_s, m0)
      h_t     = [ sum_{s<=t} exp(u_s - M_t) (q_t.k_s) v_s
                  + exp(m0 - M_t) q_t.C0 ] / max(|den|, exp(-m_t))
      den     = sum_{s<=t} exp(u_s - M_t) (q_t.k_s) + exp(m0 - M_t) q_t.n0
    Padding steps use f=+inf (logf=0) and i=-inf: exact identity.
    """
    B, S, H, hd = q.shape
    Q = min(chunk or MLSTM_CHUNK, S)
    pad = (-S) % Q
    if pad:
        pf = lambda a, val: jnp.pad(
            a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
            constant_values=val)
        q, k, v = pf(q, 0), pf(k, 0), pf(v, 0)
        ig = pf(ig, -1e30)     # i = -inf: no input
        fg = pf(fg, 80.0)      # sigmoid(80) ~ 1: no decay
    Sp = S + pad
    nc = Sp // Q

    def r(a):
        return a.reshape(B, nc, Q, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, igc, fgc = map(r, (q, k, v, ig, fg))

    @jax.checkpoint
    def chunk_fn(carry, inp):
        C0, n0, m0 = carry
        qt, kt, vt, it, ft = inp
        qt = qt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        logf = jax.nn.log_sigmoid(ft)                    # (B,Q,H)
        a = jnp.cumsum(logf, axis=1)
        u = it - a                                       # (B,Q,H)
        Mt = jnp.maximum(jax.lax.cummax(u, axis=1), m0[:, None, :])
        # intra-chunk scores, gated:  g(t,s) = exp(a_t - a_s + i_s - m_t)
        #                                    = exp(u_s - M_t)  (a_t cancels)
        # NOTE: k arrives pre-scaled by hd**-0.5 (see mlstm()).
        qk = constrain(jnp.einsum("bqhd,bshd->bhqs", qt, kt), "act")
        g = jnp.exp(u.transpose(0, 2, 1)[:, :, None, :] -
                    Mt.transpose(0, 2, 1)[:, :, :, None])  # (B,H,t,s)
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None]
        w = jnp.where(tri, qk * g, 0.0)                  # (B,H,t,s)
        num = jnp.einsum("bhts,bshd->bthd", w, vt)
        den = jnp.sum(w, axis=-1).transpose(0, 2, 1)     # (B,t,H)
        # inter-chunk from carried state
        inter_scale = jnp.exp(m0[:, None, :] - Mt)       # (B,t,H)
        qC = jnp.einsum("bqhk,bhvk->bqhv", qt, C0)
        num = num + inter_scale[..., None] * qC
        den = den + inter_scale * jnp.einsum("bqhk,bhk->bqh", qt, n0)
        m_t = a + Mt
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # end-of-chunk state (scaled convention)
        MQ = Mt[:, -1, :]
        dec = jnp.exp(u - MQ[:, None, :])                # (B,s,H)
        Cn = jnp.einsum("bsh,bshv,bshk->bhvk", dec, vt, kt) + \
            jnp.exp(m0 - MQ)[..., None, None] * C0
        nn = jnp.einsum("bsh,bshk->bhk", dec, kt) + \
            jnp.exp(m0 - MQ)[..., None] * n0
        mn = a[:, -1, :] + MQ
        return (Cn, nn, mn), h

    (CT, nT, mT), hs = jax.lax.scan(chunk_fn, state, (qc, kc, vc, igc, fgc))
    h = hs.swapaxes(0, 1).reshape(B, Sp, H, hd)[:, :S]
    return (CT, nT, mT), h


def mlstm(p, x, cfg: XLSTMConfig, state=None, name=None, length_mask=None):
    """Matrix-memory LSTM with exponential gating (xLSTM), recurrent form.

    ``length_mask`` (B,S) marks valid positions of ragged inputs: masked
    steps reuse the chunked path's padding convention (i = -inf: no input,
    f ~ +inf: no decay) so they are an identity on (C, n, m), and the conv
    carry keeps each slot's last valid window."""
    B, S, D = x.shape
    di, H, hd = cfg.d_inner, cfg.n_heads, cfg.head_dim
    uz = L.dense(p["up"], x, _j(name, "up"))
    u, z = uz[..., :di], uz[..., di:]
    lengths = (jnp.sum(length_mask.astype(jnp.int32), axis=-1)
               if length_mask is not None else None)
    conv_state = state["conv"] if state is not None else None
    uc, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state,
                                lengths=lengths)
    uc = constrain(jax.nn.silu(uc), "act")
    uh = uc.reshape(B, S, H, hd)
    q = constrain(jnp.einsum("bshd,hdk->bshk", uh, p["wq_bd"]), "act")
    k = constrain(jnp.einsum("bshd,hdk->bshk", uh, p["wk_bd"]), "act") * hd ** -0.5
    v = constrain(jnp.einsum("bshd,hdk->bshk", uh, p["wv_bd"]), "act")
    gates = L.dense(p["w_if"], uc, _j(name, "w_if")).astype(jnp.float32)  # (B,S,2H)
    ig, fg = gates[..., :H], gates[..., H:]
    if length_mask is not None:
        ig = jnp.where(length_mask[..., None], ig, -1e30)
        fg = jnp.where(length_mask[..., None], fg, 80.0)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    if S > 1:
        (CT, nT, mT), hs = _mlstm_chunked(q, k, v, ig, fg, (C0, n0, m0))
        h = hs.reshape(B, S, di).astype(x.dtype)
    else:
        def step(carry, inp):
            C, n, m = carry
            q_t, k_t, v_t, i_t, f_t = inp
            # stabilized exponential gating (xLSTM eq. 15-19)
            logf = jax.nn.log_sigmoid(f_t)
            m_new = jnp.maximum(logf + m, i_t)
            fs = jnp.exp(logf + m - m_new)
            is_ = jnp.exp(i_t - m_new)
            kf, vf = k_t.astype(jnp.float32), v_t.astype(jnp.float32)
            C = C * fs[..., None, None] + is_[..., None, None] * \
                jnp.einsum("bhv,bhk->bhvk", vf, kf)
            n = n * fs[..., None] + is_[..., None] * kf
            qf = q_t.astype(jnp.float32)
            num = jnp.einsum("bhvk,bhk->bhv", C, qf)
            den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                              jnp.exp(-m_new))[..., None]
            return (C, n, m_new), num / den

        inps = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
                v.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2),
                fg.transpose(1, 0, 2))
        (CT, nT, mT), hs = jax.lax.scan(step, (C0, n0, m0), inps)
        h = hs.transpose(1, 0, 2, 3).reshape(B, S, di).astype(x.dtype)
    h = L.norm(p["norm"], h) * jax.nn.silu(z)
    out = L.dense(p["down"], h, _j(name, "down"))
    return out, {"C": CT, "n": nT, "m": mT, "conv": new_conv}


def mlstm_init_state(B, cfg: XLSTMConfig, dtype=jnp.bfloat16):
    H, hd = cfg.n_heads, cfg.head_dim
    return {"C": jnp.zeros((B, H, hd, hd), jnp.float32),
            "n": jnp.zeros((B, H, hd), jnp.float32),
            "m": jnp.zeros((B, H), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_kernel - 1, cfg.d_inner), dtype)}


# ===================================================================== sLSTM

def init_slstm(key, cfg: XLSTMConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    d, di, H, hd = cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.head_dim
    return {
        "w_in": L.init_dense(ks[0], d, 4 * di, dtype),          # i,f,z,o pre-acts
        "r": (jax.random.normal(ks[1], (H, hd, 4 * hd)) * hd ** -0.5).astype(dtype),
        "norm": L.init_norm(di, dtype),
        "down": L.init_dense(ks[2], di, d, dtype, scale=di ** -0.5),
    }


def slstm(p, x, cfg: XLSTMConfig, state=None, name=None, length_mask=None):
    """Scalar-memory LSTM with exponential gating + recurrent head mixing.

    ``length_mask`` (B,S) marks valid positions of ragged inputs; masked
    steps carry (c, n, h, m) through unchanged (the recurrent h-mixing
    makes a gate-level identity impossible, so the step SELECTS the old
    carry instead)."""
    B, S, D = x.shape
    di, H, hd = cfg.d_inner, cfg.n_heads, cfg.head_dim
    pre = L.dense(p["w_in"], x, _j(name, "w_in")).reshape(B, S, H, 4 * hd)

    if state is None:
        c0 = jnp.zeros((B, H, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        h0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        c0, n0, h0, m0 = state["c"], state["n"], state["h"], state["m"]

    r = p["r"].astype(jnp.float32)
    mask = (jnp.ones((B, S), bool) if length_mask is None
            else length_mask.astype(bool))

    def step(carry, inp):
        pre_t, m_t_ = inp
        c, n, h, m = carry
        rec = jnp.einsum("bhk,hkj->bhj", h, r)                  # (B,H,4hd)
        g = pre_t.astype(jnp.float32) + rec
        i_t, f_t, z_t, o_t = jnp.split(g, 4, axis=-1)
        i_t, f_t = i_t.mean(-1), f_t.mean(-1)                   # scalar/head gates
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        fs = jnp.exp(logf + m - m_new)[..., None]
        is_ = jnp.exp(i_t - m_new)[..., None]
        c_new = c * fs + is_ * jnp.tanh(z_t)
        n_new = n * fs + is_
        h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
        sel2, sel3 = m_t_[:, None], m_t_[:, None, None]
        carry = (jnp.where(sel3, c_new, c), jnp.where(sel3, n_new, n),
                 jnp.where(sel3, h_new, h), jnp.where(sel2, m_new, m))
        return carry, h_new

    (cT, nT, hT, mT), hs = jax.lax.scan(step, (c0, n0, h0, m0),
                                        (pre.transpose(1, 0, 2, 3),
                                         mask.transpose(1, 0)))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, di).astype(x.dtype)
    out = L.dense(p["down"], L.norm(p["norm"], h), _j(name, "down"))
    return out, {"c": cT, "n": nT, "h": hT, "m": mT}


def slstm_init_state(B, cfg: XLSTMConfig):
    H, hd = cfg.n_heads, cfg.head_dim
    z = lambda *s: jnp.zeros(s, jnp.float32)
    return {"c": z(B, H, hd), "n": z(B, H, hd), "h": z(B, H, hd), "m": z(B, H)}
