"""Mixture-of-Experts: GShard/Mixtral-style grouped dense dispatch.

Tokens are reshaped into G groups (aligned with the data-parallel sharding so
the group axis shards over `data` and the expert axis over `model`; GSPMD
then lowers the dispatch/combine einsums into all-to-alls).  Capacity-style
dropping keeps shapes static.

Supports the two assigned MoE archs:
  arctic-480b        : 128 experts top-2 + parallel dense residual FFN
  deepseek-v2-lite   : 64 routed top-6 + 2 shared experts (+ dense layer 0)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models._backend import join as _j


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden
    n_shared: int = 0            # always-on shared experts (deepseek)
    capacity_factor: float = 1.25
    act: str = "silu"
    gated: bool = True
    router_dtype: str = "float32"


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 6)
    E, F = cfg.n_experts, cfg.d_ff
    s_in = d_model ** -0.5
    s_out = F ** -0.5
    p = {
        "router": L.init_dense(ks[0], d_model, E, jnp.float32),
        "up": (jax.random.normal(ks[1], (E, d_model, F)) * s_in).astype(dtype),
        "down": (jax.random.normal(ks[2], (E, F, d_model)) * s_out).astype(dtype),
    }
    if cfg.gated:
        p["gate"] = (jax.random.normal(ks[3], (E, d_model, F)) * s_in).astype(dtype)
    if cfg.n_shared:
        p["shared"] = L.init_ffn(ks[4], d_model, cfg.n_shared * F,
                                 gated=cfg.gated, dtype=dtype)
    return p


def _capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.top_k)


def moe_ffn(p, x, cfg: MoEConfig, n_groups: int | None = None, name=None,
            length_mask=None):
    """x: (B, S, D) -> (B, S, D), plus aux losses dict.  ``name`` threads
    the block's pytree path into the router/shared-expert dense calls (the
    grouped expert einsums are not dense dicts and stay on their fused
    path).

    ``length_mask`` (B, S) marks the VALID tokens of a ragged/partially
    active batch (continuous-batching serving): masked tokens are dropped
    from dispatch BEFORE the capacity cumsum, so padding tokens and
    retired slots never compete with real tokens for expert capacity —
    with every token valid the result is unchanged."""
    B, S, D = x.shape
    T = B * S
    if n_groups is None:
        # ~4k tokens per group: training/prefill get per-data-shard groups
        # (all-to-all friendly); decode (T=B) collapses to one group so the
        # capacity buffers stay proportional to the actual token count
        # (G=B at decode cost 85x the needed expert compute on arctic;
        # EXPERIMENTS.md §Perf)
        n_groups = max(1, min(256, T // 4096))
    G = n_groups
    assert T % G == 0, (T, G)
    Tg = T // G
    E, K = cfg.n_experts, cfg.top_k
    C = _capacity(Tg, cfg)

    xt = x.reshape(G, Tg, D)
    logits = L.dense(p["router"], xt.astype(jnp.float32),
                     _j(name, "router"))                       # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, K)                       # (G,Tg,K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = {"load_balance": E * jnp.sum(me * ce)}

    # position of each (token, k) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)          # (G,Tg,K,E)
    if length_mask is not None:
        mt = length_mask.reshape(G, Tg).astype(onehot.dtype)
        onehot = onehot * mt[..., None, None]
    flat = onehot.reshape(G, Tg * K, E)
    pos = jnp.cumsum(flat, axis=1) - 1                          # (G,Tg*K,E)
    pos = pos.reshape(G, Tg, K, E)
    pos = jnp.sum(pos * onehot, axis=-1)                        # (G,Tg,K)
    keep = pos < C

    # combine (G,Tg,E,C) and dispatch tensors
    from repro.distributed.sharding import constrain
    pos_oh = jax.nn.one_hot(pos, C, dtype=x.dtype) * keep[..., None]
    comb = jnp.einsum("gtke,gtkc->gtec", onehot.astype(x.dtype),
                      pos_oh * topv[..., None].astype(x.dtype))
    comb = constrain(comb, "moe_grouped")
    disp = (comb > 0).astype(x.dtype)
    disp = constrain(disp, "moe_grouped")

    ein = jnp.einsum("gtec,gtd->gecd", disp, xt)                # (G,E,C,D)
    ein = constrain(ein, "moe_expert")
    a = L.act_fn(cfg.act)
    if cfg.gated:
        h = a(jnp.einsum("gecd,edf->gecf", ein, p["gate"])) * \
            jnp.einsum("gecd,edf->gecf", ein, p["up"])
    else:
        h = a(jnp.einsum("gecd,edf->gecf", ein, p["up"]))
    h = constrain(h, "moe_expert")  # (G,E,C,F): without this the expert
    # hidden replicates on G under ambiguous propagation (125 GiB/dev on
    # deepseek prefill; EXPERIMENTS.md §Perf notes)
    eout = jnp.einsum("gecf,efd->gecd", h, p["down"])           # (G,E,C,D)
    eout = constrain(eout, "moe_expert")
    y = jnp.einsum("gtec,gecd->gtd", comb, eout).reshape(B, S, D)

    if cfg.n_shared:
        y = y + L.ffn(p["shared"], x, cfg.act, _j(name, "shared"))
    return y, aux
