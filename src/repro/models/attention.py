"""Attention variants: GQA (optional sliding window), cross-attention, MLA.

Shapes: hidden (B, S, D); q (B, S, H, hd); kv (B, S, KVH, hd).
GQA is computed grouped — q reshaped to (B, S, KVH, G, hd) — so KV heads are
never materialized H times.  Long sequences use a double-chunked online-
softmax attention (the jnp reference of the Pallas flash kernel in
``repro.kernels.flash_attention``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models._backend import join as _j

# int8 KV-cache quantization step (post-norm k/v live in ~[-8, 8])
KV_QSCALE = 16.0


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    bias: bool = False
    causal: bool = True
    rotary: bool = True


def init_attn(key, cfg: AttnConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    H, KVH, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "wq": L.init_dense(ks[0], d, H * hd, dtype, bias=cfg.bias),
        "wk": L.init_dense(ks[1], d, KVH * hd, dtype, bias=cfg.bias),
        "wv": L.init_dense(ks[2], d, KVH * hd, dtype, bias=cfg.bias),
        "wo": L.init_dense(ks[3], H * hd, d, dtype,
                           scale=(H * hd) ** -0.5, bias=cfg.bias),
    }


# ---------------------------------------------------------------- core math

def _grouped_scores_softmax_out(q, k, v, mask, scale):
    """q (B,Sq,KVH,G,hd); k,v (B,Sk,KVH,hd); mask (Sq,Sk) or (B,Sq,Sk) bool
    or None (the batched form carries per-slot cache lengths)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        if mask.ndim == 3:                  # per-slot: (B,Sq,Sk) over
            mask = mask[:, None, None]      # s (B,KVH,G,Sq,Sk)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)


def full_attention(q, k, v, *, causal, window=None, q_pos0=0, kv_len=None):
    """Unchunked reference path (small S / decode).

    ``q_pos0`` and ``kv_len`` may be scalars (one position for the whole
    batch — the classic path) or ``(B,)`` arrays of PER-SLOT positions /
    cache lengths (the continuous-batching decode path: every slot sits at
    its own sequence length, so the causal/window/length masks must be
    built per slot)."""
    B, Sq, KVH, G, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    mask = None
    per_slot = jnp.ndim(q_pos0) == 1 or jnp.ndim(kv_len) == 1
    if per_slot:                            # (B,Sq,Sk)-shaped index grids
        q0 = jnp.reshape(jnp.asarray(q_pos0), (-1, 1, 1)) \
            if jnp.ndim(q_pos0) == 1 else q_pos0
        qi = q0 + jnp.arange(Sq)[None, :, None]
        ki = jnp.arange(Sk)[None, None, :]
    else:                                   # (Sq,Sk) grids, broadcast over B
        qi = q_pos0 + jnp.arange(Sq)[:, None]
        ki = jnp.arange(Sk)[None, :]
    if causal:
        mask = ki <= qi
    if window is not None:
        wm = ki > qi - window
        mask = wm if mask is None else (mask & wm)
    if kv_len is not None:
        kl = (jnp.reshape(jnp.asarray(kv_len), (-1, 1, 1))
              if jnp.ndim(kv_len) == 1 else kv_len)
        lm = ki < kl
        mask = lm if mask is None else (mask & lm)
    return _grouped_scores_softmax_out(q, k, v, mask, scale)


def chunked_attention(q, k, v, *, causal=True, window=None,
                      q_chunk=512, k_chunk=1024, kv_len=None):
    """Double-chunked online-softmax attention (flash-style, pure jnp).

    Memory per step is O(q_chunk * k_chunk); the causal upper triangle is
    masked (not skipped) to keep scan trip counts static.
    """
    B, Sq, KVH, G, hd = q.shape
    Sk, vd = k.shape[1], v.shape[-1]
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0
    nq, nk = Sq // q_chunk, Sk // k_chunk
    scale = hd ** -0.5

    qc = q.reshape(B, nq, q_chunk, KVH, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, k_chunk, KVH, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, k_chunk, KVH, vd).transpose(1, 0, 2, 3, 4)

    import jax as _jax

    @_jax.checkpoint
    def q_body(_, qi_blk):
        qi, qb = qi_blk  # index, (B, qc, KVH, G, hd)
        m0 = jnp.full((B, KVH, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, q_chunk, KVH, G, vd), jnp.float32)

        @_jax.checkpoint
        def k_body(carry, ki_blk):
            m, l, o = carry
            ki, kb, vb = ki_blk
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb).astype(jnp.float32) * scale
            qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = ki * k_chunk + jnp.arange(k_chunk)[None, :]
            mask = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            if kv_len is not None:
                mask = mask & (kpos < kv_len)
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vb.dtype), vb)
            o_new = o * alpha.transpose(0, 3, 1, 2)[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, o_new), None

        (m, l, o), _ = jax.lax.scan(
            k_body, (m0, l0, o0), (jnp.arange(nk), kc, vc))
        o = o / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return None, o.astype(q.dtype)

    _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qc))
    # out: (nq, B, q_chunk, KVH, G, vd) -> (B, Sq, KVH, G, vd)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KVH, G, vd)


def cache_update(buf, val, index):
    """Write ``val (B, S, ...)`` into ``buf (B, S_max, ...)`` starting at
    sequence position ``index`` — a scalar (whole batch at one position) or
    a ``(B,)`` array of per-slot positions (continuous batching: each slot's
    KV lands at that slot's own cache length)."""
    if jnp.ndim(index) == 1:
        def one(b, v, i):
            start = (i,) + (0,) * (b.ndim - 1)
            return jax.lax.dynamic_update_slice(b, v, start)
        return jax.vmap(one)(buf, val.astype(buf.dtype), index)
    start = (0, index) + (0,) * (buf.ndim - 2)
    return jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype), start)


# ------------------------------------------------------------- paged KV pool

def _paged_rows(pool, val, pages, index, mask):
    """Flat row indices of ``val (B, S, ...)`` in ``pool (P, ps, ...)``.

    ``pages`` is the (B, W) int32 page table; ``index`` the (B,)-or-scalar
    starting logical position.  Row 0 of the pool is the TRASH page: masked
    (padding) writes and any position whose page-table entry is 0 land
    there, so a slot with a zeroed table can never corrupt live pages."""
    B, S = val.shape[:2]
    W, ps = pages.shape[1], pool.shape[1]
    pos = jnp.reshape(jnp.asarray(index), (-1, 1)) + jnp.arange(S)[None, :]
    logical = jnp.clip(pos // ps, 0, W - 1)
    phys = jnp.take_along_axis(pages, logical, axis=1) * ps + pos % ps
    valid = pos < W * ps
    if mask is not None:
        valid = valid & mask
    return jnp.where(valid, phys, 0)


def paged_update(pool, val, pages, index, mask=None):
    """Scatter ``val (B, S, ...)`` into the shared page pool ``pool
    (P, ps, ...)`` at per-slot logical positions ``index`` under page table
    ``pages (B, W)``; ``mask (B, S)`` suppresses padding writes (they hit
    the trash page, row 0)."""
    rows = _paged_rows(pool, val, pages, index, mask)
    B, S = val.shape[:2]
    feat = pool.shape[2:]
    flat = pool.reshape((pool.shape[0] * pool.shape[1],) + feat)
    flat = flat.at[rows.reshape(-1)].set(
        val.astype(pool.dtype).reshape((B * S,) + feat))
    return flat.reshape(pool.shape)


def paged_gather(pool, pages):
    """Gather each slot's pages into a dense (B, W*ps, ...) sequence view —
    `full_attention`'s q_pos0/kv_len masking then applies unchanged (the
    tail beyond kv_len, including any trash-page rows, is masked out)."""
    B, W = pages.shape
    ps = pool.shape[1]
    return pool[pages].reshape((B, W * ps) + pool.shape[2:])


# ---------------------------------------------------------------- GQA layer

def gqa(p, x, positions, cfg: AttnConfig, *, cache=None, cache_index=None,
        chunked=False, kv_override=None, pages=None, write_mask=None,
        name=None):
    """Grouped-query attention.

    cache: optional dict {"k","v"} of (B, S_max, KVH, hd) + writes at
    ``cache_index`` — a scalar, or a ``(B,)`` array of per-slot positions
    (the continuous-batching decode path; masks then build per slot);
    decode passes S==1 inputs.  kv_override supplies precomputed (k, v) for
    cross-attention.  With ``pages`` (a (B, W) page table) the cache leaves
    are the SHARED (num_pages, page_size, ...) pool instead: writes scatter
    through the table (`paged_update`, padding suppressed by ``write_mask``)
    and reads attend over the gathered per-slot view (`paged_gather`) under
    the same q_pos0/kv_len masks.  ``name``: this block's pytree path,
    threaded into the projections' matmul-backend calls.
    """
    B, S, D = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KVH
    q = L.dense(p["wq"], x, _j(name, "wq")).reshape(B, S, H, hd)
    if kv_override is None:
        k = L.dense(p["wk"], x, _j(name, "wk")).reshape(B, S, KVH, hd)
        v = L.dense(p["wv"], x, _j(name, "wv")).reshape(B, S, KVH, hd)
        if cfg.rotary:
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override

    if pages is not None:
        upd = partial(paged_update, pages=pages, index=cache_index,
                      mask=write_mask)
        view = partial(paged_gather, pages=pages)
    else:
        upd = partial(cache_update, index=cache_index)
        view = lambda buf: buf

    kv_len = None
    if cache is not None:
        if cache["k"].dtype == jnp.int8:
            # int8 KV-cache domain: codes = round(x * KV_QSCALE); the cache
            # HBM stream halves vs bf16 (EXPERIMENTS.md §Perf)
            enc = lambda t: jnp.clip(jnp.round(t.astype(jnp.float32) *
                                               KV_QSCALE), -127, 127
                                     ).astype(jnp.int8)
            kc = upd(cache["k"], enc(k))
            vc = upd(cache["v"], enc(v))
            new_cache = {"k": kc, "v": vc}
            k = view(kc).astype(x.dtype) * (1.0 / KV_QSCALE)
            v = view(vc).astype(x.dtype) * (1.0 / KV_QSCALE)
        else:
            kc = upd(cache["k"], k)
            vc = upd(cache["v"], v)
            new_cache = {"k": kc, "v": vc}
            k, v = view(kc), view(vc)
        kv_len = cache_index + S
    else:
        new_cache = None

    qg = q.reshape(B, S, KVH, G, hd)
    if chunked and S > 1:
        # long prefill: chunked flash attention directly over the (updated)
        # cache buffers; cache-backed prefill starts at position 0
        out = chunked_attention(qg, k, v, causal=cfg.causal,
                                window=cfg.sliding_window, kv_len=kv_len)
    else:
        q_pos0 = cache_index if cache is not None else 0
        out = full_attention(qg, k, v, causal=cfg.causal,
                             window=cfg.sliding_window,
                             q_pos0=q_pos0, kv_len=kv_len)
    out = out.reshape(B, S, H * hd)
    return L.dense(p["wo"], out, _j(name, "wo")), new_cache


# ---------------------------------------------------------------- MLA layer

@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0


def init_mla(key, cfg: MLAConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    H = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": L.init_dense(ks[0], cfg.d_model, H * qd, dtype),
        "kv_a": L.init_dense(ks[1], cfg.d_model,
                             cfg.kv_lora_rank + cfg.qk_rope_dim, dtype),
        "kv_norm": L.init_norm(cfg.kv_lora_rank, dtype),
        "kv_b": L.init_dense(ks[2], cfg.kv_lora_rank,
                             H * (cfg.qk_nope_dim + cfg.v_head_dim), dtype),
        "wo": L.init_dense(ks[3], H * cfg.v_head_dim, cfg.d_model, dtype,
                           scale=(H * cfg.v_head_dim) ** -0.5),
    }


def mla(p, x, positions, cfg: MLAConfig, *, cache=None, cache_index=None,
        chunked=False, pages=None, write_mask=None, name=None):
    """Multi-head Latent Attention (DeepSeek-V2). Cache holds the compressed
    latent + shared rope key: (B, S_max, kv_lora_rank + qk_rope_dim) — or,
    with ``pages``, the shared (num_pages, page_size, r + rd) pool read
    through the per-slot page table (see `gqa`)."""
    B, S, D = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    q = L.dense(p["wq"], x, _j(name, "wq")).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)

    kv = L.dense(p["kv_a"], x, _j(name, "kv_a"))
    latent, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    latent = L.norm(p["kv_norm"], latent)
    k_rope = L.rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    if pages is not None:
        upd = partial(paged_update, pages=pages, index=cache_index,
                      mask=write_mask)
        view = partial(paged_gather, pages=pages)
    else:
        upd = partial(cache_update, index=cache_index)
        view = lambda buf: buf

    kv_len = None
    if cache is not None:
        packed = jnp.concatenate([latent, k_rope], axis=-1)
        if cache["latent"].dtype == jnp.int8:
            codes = jnp.clip(jnp.round(packed.astype(jnp.float32) *
                                       KV_QSCALE), -127, 127).astype(jnp.int8)
            buf = upd(cache["latent"], codes)
            new_cache = {"latent": buf}
            deq = view(buf).astype(x.dtype) * (1.0 / KV_QSCALE)
            latent = deq[..., :cfg.kv_lora_rank]
            k_rope = deq[..., cfg.kv_lora_rank:]
        else:
            buf = upd(cache["latent"], packed)
            new_cache = {"latent": buf}
            seq = view(buf)
            latent = seq[..., :cfg.kv_lora_rank]
            k_rope = seq[..., cfg.kv_lora_rank:]
        kv_len = cache_index + S
    else:
        new_cache = None

    if cache is not None and S == 1:
        # ABSORBED decode path (DeepSeek-V2 Appendix): fold kv_b's
        # up-projections into the query / output sides so attention runs
        # directly against the compressed latent cache — O(H*r) per token
        # instead of re-expanding K/V for the whole cache (~100x fewer
        # FLOPs at 32k context; see EXPERIMENTS.md §Perf).
        r = cfg.kv_lora_rank
        kvb_p = p["kv_b"]
        if "w_q" in kvb_p:  # int8 serve domain: dequant for the absorb fold
            w_kvb_flat = kvb_p["w_q"].astype(x.dtype) * \
                kvb_p["w_s"].astype(x.dtype)[..., None, :]
        else:
            w_kvb_flat = kvb_p["w"]
        w_kvb = w_kvb_flat.reshape(r, H, nd + vd)
        w_uk, w_uv = w_kvb[..., :nd], w_kvb[..., nd:]
        q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
        scale = (nd + rd) ** -0.5
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_abs.astype(jnp.float32),
                             latent.astype(jnp.float32)) +
                  jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                             k_rope.astype(jnp.float32))) * scale
        kl = (jnp.reshape(jnp.asarray(kv_len), (-1, 1, 1, 1))
              if jnp.ndim(kv_len) == 1 else kv_len)
        mask = jnp.arange(latent.shape[1])[None, None, None, :] < kl
        scores = jnp.where(mask, scores, -1e30)
        pw = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhqs,bsr->bqhr", pw,
                           latent.astype(jnp.float32))
        out = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(x.dtype), w_uv)
        out = out.reshape(B, S, H * vd)
        return L.dense(p["wo"], out, _j(name, "wo")), new_cache

    kvb = L.dense(p["kv_b"], latent,
                  _j(name, "kv_b")).reshape(B, latent.shape[1], H, nd + vd)
    k_nope, v = kvb[..., :nd], kvb[..., nd:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], rd))], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)

    # MLA is MHA (KVH == H, G == 1) on the concatenated features.
    qg = qfull.reshape(B, S, H, 1, nd + rd)
    if chunked and S > 1:
        out = chunked_attention(qg, k, v, causal=True, kv_len=kv_len)
    else:
        q_pos0 = cache_index if cache is not None else 0
        out = full_attention(qg, k, v, causal=True, q_pos0=q_pos0, kv_len=kv_len)
    out = out.reshape(B, S, H * vd)
    return L.dense(p["wo"], out, _j(name, "wo")), new_cache
