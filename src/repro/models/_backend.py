"""Pluggable matmul backend shared by every dense primitive in the repo.

A backend is any callable ``backend(name, p, x, **meta) -> y | None`` where

  * ``name`` is the layer's pytree path (``"units/0/attn/wq"``, ``"head"``,
    ``"blocks/3/c1"``) — a STATIC Python string, so a backend can resolve its
    per-layer plan at trace time and the whole forward pass stays
    ``jax.jit``-compatible.  Call sites that cannot name their layer pass
    ``name=None``; backends must decline those (return ``None``).
  * ``p`` is the dense param dict (``{"w": ..., "b"?: ...}``) and ``x`` the
    input activations.  Under ``jax.jit`` both may be tracers — backends must
    NOT key on them (see migration note below).
  * ``meta``: conv call sites pass ``conv={"stride", "padding", "groups"}``
    (see `repro.models.managed.conv2d`); dense call sites pass nothing.

Returning ``None`` declines the call and the primitive runs its default
path.  `repro.models.managed.dense`/`conv2d`/`conv2d_linear`,
`repro.models.layers.dense` and the LM head projection all consult the
active backend, so installing one swaps the execution of every covered
matmul WITHOUT forking model code — this is how `repro.runtime
.PlannedBackend` slots per-layer split-precision kernels into serving.

Scan-stacked layers: weights that only exist stacked inside a
``jax.lax.scan`` (leading repeat axis R) are addressed as ``name`` plus the
current repeat index.  The scan body publishes its (traced) loop index with
``scan_slot``; backends read it via ``current_scan_index()`` and index their
per-repeat state dynamically — `repro.models.transformer.backbone` does this
for the LM layer scan.

Migration from the ``backend(p, x)`` signature (PR 2): the old protocol
matched weight leaves by ``id()``, which silently failed for any weight that
only exists as a tracer (every jitted call, every scan-stacked layer) — the
layer fell back to the default path with no diagnostic.  The name-keyed
protocol resolves plans statically instead; update custom backends by adding
the leading ``name`` parameter and keying on it.

Deliberately dependency-free (both `layers` and `managed` import it).
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

MatmulBackend = Callable[..., object]

_ACTIVE: Optional[MatmulBackend] = None
_SCAN_INDEX = None
_PLAN_VARIANT: Optional[str] = None


def current() -> Optional[MatmulBackend]:
    """The backend dense primitives should consult (None = default path)."""
    return _ACTIVE


@contextlib.contextmanager
def use(backend: Optional[MatmulBackend]):
    """Install ``backend`` for the duration of the context."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = backend
    try:
        yield backend
    finally:
        _ACTIVE = prev


@contextlib.contextmanager
def scan_slot(index):
    """Publish the current scan repeat index (an int or a traced scalar) for
    the duration of the context — layers called inside a ``lax.scan`` body
    carry a base ``name`` shared by all repeats, and backends combine it with
    this index to select the repeat's prepared state."""
    global _SCAN_INDEX
    prev = _SCAN_INDEX
    _SCAN_INDEX = index
    try:
        yield index
    finally:
        _SCAN_INDEX = prev


def current_scan_index():
    """The repeat index published by the innermost ``scan_slot`` (None when
    not inside a scan body)."""
    return _SCAN_INDEX


@contextlib.contextmanager
def plan_variant(name: Optional[str]):
    """Publish the active plan-variant key for the duration of the context.

    Multi-plan backends (`repro.runtime.PlanSet`) bind several
    ``ExecutionPlan`` variants against one params pytree and select among
    them by this key.  ``name`` must be a STATIC Python string (never a
    tracer): the variant decides which prepared kernels are traced into the
    computation, so callers that jit must make it a static argument
    (``jax.jit(f, static_argnames=("variant",))``) — otherwise jax would
    reuse a trace cached for a different variant.

    ``plan_variant(None)`` is a no-op that keeps any surrounding selection,
    so call sites can thread an optional ``variant=None`` kwarg without
    clobbering an outer context.  Single-plan backends ignore the key.
    """
    global _PLAN_VARIANT
    if name is None:
        yield None
        return
    if not isinstance(name, str):
        raise TypeError(
            f"plan variant must be a static str, got {type(name).__name__} "
            "(a traced variant would silently reuse another variant's trace)"
        )
    prev = _PLAN_VARIANT
    _PLAN_VARIANT = name
    try:
        yield name
    finally:
        _PLAN_VARIANT = prev


def current_plan_variant() -> Optional[str]:
    """The variant key published by the innermost ``plan_variant`` (None =
    let the backend use its default variant)."""
    return _PLAN_VARIANT


def join(prefix: Optional[str], leaf: str) -> Optional[str]:
    """``"a/b" + "c" -> "a/b/c"``; None prefix stays None (unnamed call
    sites never consult a name-keyed backend)."""
    return None if prefix is None else f"{prefix}/{leaf}"
