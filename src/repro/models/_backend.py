"""Pluggable matmul backend shared by every dense primitive in the repo.

A backend is any callable ``backend(p, x) -> y | None`` where ``p`` is a
dense param dict (``{"w": ..., "b"?: ...}``) and ``x`` the input activations;
returning ``None`` declines the call and the primitive runs its default
path.  `repro.models.managed.dense`/`conv2d`, `repro.models.layers.dense`
and the LM head projection all consult the active backend, so installing one
swaps the execution of every covered matmul WITHOUT forking model code —
this is how `repro.runtime.PlannedBackend` slots per-layer split-precision
kernels into serving.

Deliberately dependency-free (both `layers` and `managed` import it).
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional

MatmulBackend = Callable[[dict, object], object]

_ACTIVE: Optional[MatmulBackend] = None


def current() -> Optional[MatmulBackend]:
    """The backend dense primitives should consult (None = default path)."""
    return _ACTIVE


@contextlib.contextmanager
def use(backend: Optional[MatmulBackend]):
    """Install ``backend`` for the duration of the context."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = backend
    try:
        yield backend
    finally:
        _ACTIVE = prev
