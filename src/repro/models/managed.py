"""ODiMO-managed layer primitives shared by the CNN repro and the LM zoo.

A *managed* layer is a Conv/Dense whose weight passes through the ODiMO
mixing (search mode), the discretized per-channel quantization (finetune
mode), or plain floats (fp32 mode).  Activations are fake-quantized at the
spec's worst-case bit-width in the quantized modes (paper Sec. III-B).

Mode "deploy" is the mapping-execution path: with a matmul backend installed
(``with matmul_backend(planned): ...`` — see `repro.runtime.PlannedBackend`)
covered layers run through their planned Pallas kernels; layers the backend
declines fall back to the discretized fake-quant weights, so a partially
lowered plan still executes the searched mapping end to end.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import odimo, quant
from repro.core.cost_models import LayerGeometry
from repro.core.odimo import ODiMOSpec
from repro.models import _backend

Mode = Literal["fp", "search", "finetune", "deploy"]

# Re-exported context manager installing a pluggable matmul backend for every
# dense primitive in the repo (managed + LM layers).
matmul_backend = _backend.use


def init_conv(key, kh, kw, c_in, c_out, spec: ODiMOSpec | None, groups=1):
    kw_, ko = jax.random.split(key)
    fan_in = kh * kw * (c_in // groups)
    w = jax.random.normal(kw_, (kh, kw, c_in // groups, c_out)) * (2.0 / fan_in) ** 0.5
    p = {"w": w, "b": jnp.zeros(c_out)}
    if spec is not None:
        p["odimo"] = odimo.init_layer_state(ko, w, spec)
        p["act_log_scale"] = jnp.asarray(1.0)
    return p


def init_dense(key, c_in, c_out, spec: ODiMOSpec | None, bias: bool = True,
               scale: float | None = None):
    kw_, ko = jax.random.split(key)
    s = scale if scale is not None else (1.0 / c_in) ** 0.5
    w = jax.random.normal(kw_, (c_in, c_out)) * s
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros(c_out)
    if spec is not None:
        p["odimo"] = odimo.init_layer_state(ko, w, spec)
        p["act_log_scale"] = jnp.asarray(1.0)
    return p


def _weight(p: dict, spec: ODiMOSpec | None, mode: Mode, tau: float):
    w = p["w"]
    if spec is None or mode == "fp" or "odimo" not in p:
        return w
    if mode == "search":
        return odimo.effective_weight(w, p["odimo"], spec, tau)
    return odimo.discretized_weight(w, p["odimo"], spec)


def _maybe_quant_act(x, p, spec: ODiMOSpec | None, mode: Mode):
    if spec is None or mode == "fp" or "act_log_scale" not in p:
        return x
    return quant.fake_quant_act(x, p["act_log_scale"], spec.act_bits)


def conv2d(p: dict, x: jax.Array, spec: ODiMOSpec | None = None,
           mode: Mode = "fp", tau: float = 1.0, stride: int = 1,
           padding: str = "SAME", groups: int = 1,
           name: str | None = None) -> jax.Array:
    """NHWC conv with HWIO weights; ODiMO-managed when spec is given.

    ``name`` (the layer's pytree path) routes the call through the pluggable
    matmul backend; conv geometry travels as the ``conv`` meta kwarg so a
    planned backend can im2col the input.  A backend returns the LINEAR conv
    output (bias applied) — the ReLU + activation fake-quant run here either
    way."""
    be = _backend.current()
    if be is not None and mode in ("fp", "deploy"):
        y = be(name, p, x, conv={"stride": stride, "padding": padding,
                                 "groups": groups})
        if y is not None:
            return _maybe_quant_act(jax.nn.relu(y), p, spec, mode)
    w = _weight(p, spec, mode, tau).astype(x.dtype)
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=groups)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return _maybe_quant_act(jax.nn.relu(y), p, spec, mode)


def conv2d_linear(p: dict, x: jax.Array, spec=None, mode: Mode = "fp",
                  tau: float = 1.0, stride: int = 1, padding="SAME",
                  groups: int = 1, name: str | None = None) -> jax.Array:
    """Conv without activation (residual branches); backend-routable like
    `conv2d` so planned execution covers projection shortcuts too."""
    be = _backend.current()
    if be is not None and mode in ("fp", "deploy"):
        y = be(name, p, x, conv={"stride": stride, "padding": padding,
                                 "groups": groups})
        if y is not None:
            return y
    w = _weight(p, spec, mode, tau).astype(x.dtype)
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=groups)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def dense(p: dict, x: jax.Array, spec: ODiMOSpec | None = None,
          mode: Mode = "fp", tau: float = 1.0,
          name: str | None = None) -> jax.Array:
    be = _backend.current()
    if be is not None and mode in ("fp", "deploy"):
        y = be(name, p, x)
        if y is not None:
            return y  # planned kernel output, bias applied by the backend
    w = _weight(p, spec, mode, tau).astype(x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def get_by_path(params, path: str):
    """Resolve ``"a/0/b"`` into ``params["a"][0]["b"]`` (plan-name lookup)."""
    node = params
    for part in path.split("/"):
        node = node[int(part)] if isinstance(node, list) else node[part]
    return node


def conv_geometry(kh, kw, c_in, c_out, out_hw, groups=1) -> LayerGeometry:
    return LayerGeometry(c_in=c_in, c_out=c_out, fx=kw, fy=kh,
                         ox=out_hw[1], oy=out_hw[0], groups=groups)


def dense_geometry(c_in, c_out) -> LayerGeometry:
    return LayerGeometry(c_in=c_in, c_out=c_out)
