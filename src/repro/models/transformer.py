"""Config-driven LM assembly for all 10 assigned architectures.

Layers are organized as ``pattern_repeats`` repeats of the config's block
``pattern`` and executed with ``jax.lax.scan`` over the repeats (stacked
params, leading axis R) — this keeps HLO size and compile time bounded for
40-layer x 8k-wide archs (DESIGN.md §5).  zamba2's two remainder blocks run
unscanned; its shared transformer block's weights are closure-captured by the
scan body (shared across repeats), with per-use input norms stacked.

Public API (cfg: ArchConfig is static/hashable):
  init_lm(key, cfg)                          -> params
  lm_loss(params, cfg, batch)                -> scalar loss   (training)
  prefill(params, cfg, tokens, cache, ...)   -> (last_logits, cache)
  decode_step(params, cfg, token, cache, i)  -> (logits, cache)
  init_cache(cfg, B, S_max)                  -> cache pytree  (concrete)
  cache_specs(cfg, B, S_max)                 -> cache pytree  (ShapeDtypeStruct)
  scatter_cache(caches, sub, slots)          -> caches with sub written at slots

Continuous-batching serving (`repro.serving`) drives the same entry points
with per-slot state: ``prefill(..., lengths=)`` ragged-prefills right-padded
prompts, ``decode_step(..., index=(B,), active=)`` writes and masks the KV
cache at each slot's own length, and `scatter_cache` admits freshly
prefilled requests into freed slots of the cache pool.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import _backend
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

_j = _backend.join


# --------------------------------------------------------------- kind specs

def _attn_cfg(cfg: ArchConfig, causal=True, cross=False) -> A.AttnConfig:
    return A.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta,
        sliding_window=None if cross else cfg.sliding_window,
        causal=causal and not cross, rotary=not cross)


def _mla_cfg(cfg: ArchConfig) -> A.MLAConfig:
    m = cfg.mla
    return A.MLAConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                       kv_lora_rank=m.kv_lora_rank, qk_nope_dim=m.qk_nope_dim,
                       qk_rope_dim=m.qk_rope_dim, v_head_dim=m.v_head_dim,
                       rope_theta=cfg.rope_theta)


def _mamba_cfg(cfg: ArchConfig) -> S.Mamba2Config:
    return S.Mamba2Config(d_model=cfg.d_model, d_state=cfg.ssm_state)


def _xlstm_cfg(cfg: ArchConfig) -> S.XLSTMConfig:
    return S.XLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads)


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.param_dtype == "bfloat16" else jnp.float32


# --------------------------------------------------------------- block init

def init_block(key, cfg: ArchConfig, kind: str, layer_idx: int = 0):
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict = {"norm1": L.init_norm(d, dt, cfg.norm)}
    if kind == "attn":
        p["attn"] = A.init_attn(ks[0], _attn_cfg(cfg), dt)
        if cfg.moe is not None and layer_idx >= cfg.moe_first_dense:
            p["moe"] = M.init_moe(ks[1], d, cfg.moe, dt)
            if cfg.moe_dense_residual:
                p["ffn"] = L.init_ffn(ks[2], d, cfg.dense_ff, cfg.gated_ffn, dt)
                p["norm2"] = L.init_norm(d, dt, cfg.norm)
        elif cfg.moe is not None:  # first-dense MoE layer
            p["ffn"] = L.init_ffn(ks[2], d, cfg.dense_ff, cfg.gated_ffn, dt)
            p["norm2"] = L.init_norm(d, dt, cfg.norm)
        elif cfg.d_ff:
            p["ffn"] = L.init_ffn(ks[2], d, cfg.d_ff, cfg.gated_ffn, dt)
            if not cfg.parallel_block:
                p["norm2"] = L.init_norm(d, dt, cfg.norm)
    elif kind == "mla":
        p["attn"] = A.init_mla(ks[0], _mla_cfg(cfg), dt)
        if layer_idx < cfg.moe_first_dense:
            p["ffn"] = L.init_ffn(ks[2], d, cfg.dense_ff, cfg.gated_ffn, dt)
        else:
            p["moe"] = M.init_moe(ks[1], d, cfg.moe, dt)
        p["norm2"] = L.init_norm(d, dt, cfg.norm)
    elif kind == "cross":
        p["attn"] = A.init_attn(ks[0], _attn_cfg(cfg, cross=True), dt)
        p["gate"] = jnp.zeros((), dt)  # llama-vision gated cross-attn
        p["ffn"] = L.init_ffn(ks[2], d, cfg.d_ff, cfg.gated_ffn, dt)
        p["norm2"] = L.init_norm(d, dt, cfg.norm)
    elif kind == "dec":
        p["attn"] = A.init_attn(ks[0], _attn_cfg(cfg), dt)
        p["xattn"] = A.init_attn(ks[3], _attn_cfg(cfg, cross=True), dt)
        p["normx"] = L.init_norm(d, dt, cfg.norm)
        p["ffn"] = L.init_ffn(ks[2], d, cfg.d_ff, cfg.gated_ffn, dt)
        p["norm2"] = L.init_norm(d, dt, cfg.norm)
    elif kind == "mamba":
        p["mamba"] = S.init_mamba2(ks[0], _mamba_cfg(cfg), dt)
    elif kind == "mlstm":
        p["core"] = S.init_mlstm(ks[0], _xlstm_cfg(cfg), dt)
    elif kind == "slstm":
        p["core"] = S.init_slstm(ks[0], _xlstm_cfg(cfg), dt)
    elif kind == "shared_attn":
        # per-use input norm only; weights live in params["shared"]
        pass
    else:
        raise ValueError(kind)
    return p


def init_shared_block(key, cfg: ArchConfig):
    """zamba2 shared transformer block (one copy)."""
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    return {
        "attn": A.init_attn(ks[0], _attn_cfg(cfg), dt),
        "ffn": L.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_ffn, dt),
        "norm2": L.init_norm(cfg.d_model, dt, cfg.norm),
    }


# --------------------------------------------------------------- block apply

def block_apply(p, x, kind, cfg: ArchConfig, positions, *, cache=None,
                cache_index=None, cross_kv=None, chunked=False, shared=None,
                name=None, length_mask=None, pages=None):
    """One block. Returns (x, new_cache, aux_loss).

    ``name`` is the block's params-pytree path prefix (``"units/3"``,
    ``"rem/0"``, ``"first_dense"``); it is threaded into every projection's
    matmul-backend call so a name-keyed planned backend (see
    `repro.models._backend`) resolves the layer statically — including under
    `jax.jit` and inside the layer scan.  Shared-block weights always use the
    fixed ``"shared/..."`` names (one copy, many call sites).

    ``cache_index`` may be a scalar (whole batch at one position) or a
    ``(B,)`` array of per-slot cache positions, and ``length_mask`` (B, S)
    marks the valid tokens of a ragged batch — together these are the
    continuous-batching serving path: recurrent/MoE layers suppress masked
    tokens exactly, attention writes and masks the KV cache per slot.

    ``pages`` (B, W) switches the attention KV leaves to the paged layout
    (shared page pool + per-slot page table, `attention.paged_update` /
    `paged_gather`); per-slot state (recurrent, encoder memory) is O(1) per
    slot and stays slot-indexed either way."""
    aux = 0.0
    if kind in ("attn", "mla"):
        h = L.norm(p["norm1"], x, cfg.norm)
        if kind == "attn":
            ao, nc = A.gqa(p["attn"], h, positions, _attn_cfg(cfg),
                           cache=cache, cache_index=cache_index,
                           chunked=chunked, pages=pages,
                           write_mask=length_mask, name=_j(name, "attn"))
        else:
            ao, nc = A.mla(p["attn"], h, positions, _mla_cfg(cfg),
                           cache=cache, cache_index=cache_index,
                           chunked=chunked, pages=pages,
                           write_mask=length_mask, name=_j(name, "attn"))
        if cfg.parallel_block and "ffn" in p:
            x = x + ao + L.ffn(p["ffn"], h, cfg.act, _j(name, "ffn"))
        else:
            x = x + ao
            if "moe" in p:
                h2 = L.norm(p.get("norm2", p["norm1"]), x, cfg.norm)
                mo, ml = M.moe_ffn(p["moe"], h2, cfg.moe, name=_j(name, "moe"),
                                   length_mask=length_mask)
                if "ffn" in p:  # arctic dense residual in parallel with MoE
                    mo = mo + L.ffn(p["ffn"], h2, cfg.act, _j(name, "ffn"))
                x = x + mo
                aux = aux + ml["load_balance"]
            elif "ffn" in p:
                x = x + L.ffn(p["ffn"], L.norm(p["norm2"], x, cfg.norm),
                              cfg.act, _j(name, "ffn"))
        return x, nc, aux
    if kind == "cross":
        h = L.norm(p["norm1"], x, cfg.norm)
        new_cache = None
        if cache is not None and cross_kv is not None:      # prefill: store
            new_cache = {"ck": cross_kv[0].astype(jnp.bfloat16),
                         "cv": cross_kv[1].astype(jnp.bfloat16)}
        elif cache is not None:                              # decode: reuse
            cross_kv = (cache["ck"], cache["cv"])
            new_cache = cache
        ao, _ = A.gqa(p["attn"], h, positions, _attn_cfg(cfg, cross=True),
                      kv_override=cross_kv, name=_j(name, "attn"))
        x = x + jnp.tanh(p["gate"]) * ao
        x = x + L.ffn(p["ffn"], L.norm(p["norm2"], x, cfg.norm), cfg.act,
                      _j(name, "ffn"))
        return x, new_cache, aux
    if kind == "dec":
        h = L.norm(p["norm1"], x, cfg.norm)
        self_cache = None
        if cache is not None:
            self_cache = {"k": cache["k"], "v": cache["v"]}
        ao, nc = A.gqa(p["attn"], h, positions, _attn_cfg(cfg),
                       cache=self_cache, cache_index=cache_index,
                       chunked=chunked, pages=pages, write_mask=length_mask,
                       name=_j(name, "attn"))
        x = x + ao
        hx = L.norm(p["normx"], x, cfg.norm)
        if cache is not None and cross_kv is not None:      # prefill: store
            nc = dict(nc or {}, ck=cross_kv[0].astype(jnp.bfloat16),
                      cv=cross_kv[1].astype(jnp.bfloat16))
        elif cache is not None:                              # decode: reuse
            cross_kv = (cache["ck"], cache["cv"])
            nc = dict(nc or {}, ck=cache["ck"], cv=cache["cv"])
        xo, _ = A.gqa(p["xattn"], hx, positions, _attn_cfg(cfg, cross=True),
                      kv_override=cross_kv, name=_j(name, "xattn"))
        x = x + xo
        x = x + L.ffn(p["ffn"], L.norm(p["norm2"], x, cfg.norm), cfg.act,
                      _j(name, "ffn"))
        return x, nc, aux
    if kind == "mamba":
        h = L.norm(p["norm1"], x, cfg.norm)
        mo, ns = S.mamba2(p["mamba"], h, _mamba_cfg(cfg), state=cache,
                          name=_j(name, "mamba"), length_mask=length_mask)
        return x + mo, ns, aux
    if kind == "mlstm":
        h = L.norm(p["norm1"], x, cfg.norm)
        mo, ns = S.mlstm(p["core"], h, _xlstm_cfg(cfg), state=cache,
                         name=_j(name, "core"), length_mask=length_mask)
        return x + mo, ns, aux
    if kind == "slstm":
        h = L.norm(p["norm1"], x, cfg.norm)
        mo, ns = S.slstm(p["core"], h, _xlstm_cfg(cfg), state=cache,
                         name=_j(name, "core"), length_mask=length_mask)
        return x + mo, ns, aux
    if kind == "shared_attn":
        h = L.norm(p["norm1"], x, cfg.norm)
        ao, nc = A.gqa(shared["attn"], h, positions, _attn_cfg(cfg),
                       cache=cache, cache_index=cache_index, chunked=chunked,
                       pages=pages, write_mask=length_mask,
                       name="shared/attn")
        x = x + ao
        x = x + L.ffn(shared["ffn"],
                      L.norm(shared["norm2"], x, cfg.norm), cfg.act,
                      "shared/ffn")
        return x, nc, aux
    raise ValueError(kind)


# --------------------------------------------------------------- model init

def _zamba_remainder(cfg: ArchConfig) -> int:
    period = len(cfg.pattern)
    return cfg.n_layers - (cfg.n_layers // period) * period


def init_lm(key, cfg: ArchConfig):
    dt = _dtype(cfg)
    keys = jax.random.split(key, 16)
    params: dict = {
        "emb": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02
                ).astype(dt),
        "final_norm": L.init_norm(cfg.d_model, dt, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["head"] = L.init_dense(keys[1], cfg.d_model, cfg.vocab, dt,
                                      scale=cfg.d_model ** -0.5)

    period = len(cfg.pattern)
    repeats = cfg.n_layers // period
    if cfg.moe_first_dense and period == 1:
        repeats -= 1  # layer 0 lives in params["first_dense"], unscanned

    def init_unit(k):
        uks = jax.random.split(k, period)
        return tuple(init_block(uks[i], cfg, cfg.pattern[i], layer_idx=1)
                     for i in range(period))

    unit_keys = jax.random.split(keys[2], repeats)
    params["units"] = jax.vmap(init_unit)(unit_keys)

    rem = _zamba_remainder(cfg)
    if rem:
        rks = jax.random.split(keys[3], rem)
        params["rem"] = [init_block(rks[i], cfg, cfg.pattern[i % period])
                         for i in range(rem)]
    if "shared_attn" in cfg.pattern:
        params["shared"] = init_shared_block(keys[4], cfg)
    if cfg.moe_first_dense:
        # deepseek: layer 0 replaced by a dense-FFN copy, unscanned
        params["first_dense"] = init_block(keys[5], cfg, cfg.pattern[0],
                                           layer_idx=0)
    if cfg.encoder_layers:
        enc_keys = jax.random.split(keys[6], cfg.encoder_layers)
        enc_cfg = dataclasses.replace(cfg, moe=None, parallel_block=False,
                                      pattern=("attn",))
        params["enc_units"] = jax.vmap(
            lambda k: (init_block(k, enc_cfg, "attn"),))(enc_keys)
        params["enc_norm"] = L.init_norm(cfg.d_model, dt, cfg.norm)
    return params


# ------------------------------------------------------------- cross kv prep

def _frontend_kv(params_attn, cross_source, cfg: ArchConfig, name=None):
    """Project frontend embeddings to (k, v) for cross-attention."""
    B, T, _ = cross_source.shape
    KVH, hd = cfg.n_kv_heads, cfg.hd
    k = L.dense(params_attn["wk"], cross_source,
                _j(name, "wk")).reshape(B, T, KVH, hd)
    v = L.dense(params_attn["wv"], cross_source,
                _j(name, "wv")).reshape(B, T, KVH, hd)
    return k, v


def encode(params, cfg: ArchConfig, frames):
    """seamless encoder: frames (B, T, D) -> memory (B, T, D)."""
    enc_cfg = dataclasses.replace(cfg, moe=None, parallel_block=False)
    positions = jnp.arange(frames.shape[1])[None, :]

    def body(x, xs):
        ridx, unit = xs
        (blk,) = unit
        with _backend.scan_slot(ridx):
            h = L.norm(blk["norm1"], x, cfg.norm)
            acfg = dataclasses.replace(_attn_cfg(enc_cfg), causal=False)
            ao, _ = A.gqa(blk["attn"], h, positions, acfg,
                          name="enc_units/0/attn")
            x = x + ao
            x = x + L.ffn(blk["ffn"], L.norm(blk["norm2"], x, cfg.norm),
                          cfg.act, "enc_units/0/ffn")
        return x, None

    enc_repeats = jax.tree.leaves(params["enc_units"])[0].shape[0]
    x, _ = jax.lax.scan(body, frames,
                        (jnp.arange(enc_repeats), params["enc_units"]))
    return L.norm(params["enc_norm"], x, cfg.norm)


# --------------------------------------------------------------- full stack

def backbone(params, cfg: ArchConfig, x, positions, *, caches=None,
             cache_index=None, cross_source=None, chunked=False,
             remat=False, length_mask=None, pages=None):
    """Run all layers. caches: None or pytree matching cache_specs.
    Returns (hidden, new_caches, aux).

    ``cache_index`` scalar or (B,) per-slot positions, ``length_mask``
    (B, S) valid-token mask, ``pages`` (B, W) paged-KV page table — see
    `block_apply`."""
    from repro.distributed.sharding import constrain
    period = len(cfg.pattern)
    shared = params.get("shared")

    def unit_fn(carry, xs):
        x, aux = carry
        ridx, unit_params, unit_cache = xs
        new_cache = []
        # publish the (traced) repeat index: scan-stacked layers are named by
        # their base path (e.g. "units/0/mamba/in_proj") and a name-keyed
        # backend selects the repeat's prepared kernels with this index
        with _backend.scan_slot(ridx):
            for i, kind in enumerate(cfg.pattern):
                blk = unit_params[i]
                c = unit_cache[i] if unit_cache is not None else None
                ckv = None
                if kind in ("cross", "dec") and cross_source is not None:
                    att = blk["attn"] if kind == "cross" else blk["xattn"]
                    sub = "attn" if kind == "cross" else "xattn"
                    ckv = _frontend_kv(att, cross_source, cfg,
                                       name=f"units/{i}/{sub}")
                x, nc, a = block_apply(
                    blk, x, kind, cfg, positions, cache=c,
                    cache_index=cache_index, cross_kv=ckv, chunked=chunked,
                    shared=shared, name=f"units/{i}",
                    length_mask=length_mask, pages=pages)
                aux = aux + a
                new_cache.append(nc)
        x = constrain(x, "act")
        return (x, aux), tuple(new_cache)

    unit_caches = caches["units"] if caches is not None else None
    if params.get("first_dense") is not None:
        fd_cache = caches["first"] if caches is not None else None
        x, nfc, a0 = block_apply(params["first_dense"], x, cfg.pattern[0], cfg,
                                 positions, cache=fd_cache,
                                 cache_index=cache_index, chunked=chunked,
                                 shared=shared, name="first_dense",
                                 length_mask=length_mask, pages=pages)
        units = params["units"]  # init_lm already excluded layer 0
    else:
        x, nfc, a0 = x, None, 0.0
        units = params["units"]

    repeats = jax.tree.leaves(units)[0].shape[0]
    xs = (jnp.arange(repeats), units, unit_caches)
    body = jax.checkpoint(unit_fn, prevent_cse=False) if remat else unit_fn
    (x, aux), new_unit_caches = jax.lax.scan(body, (x, a0), xs)

    new_rem = []
    if params.get("rem"):
        rem_caches = caches["rem"] if caches is not None else None
        for i, blk in enumerate(params["rem"]):
            kind = cfg.pattern[i % period]
            c = rem_caches[i] if rem_caches is not None else None
            x, nc, a = block_apply(blk, x, kind, cfg, positions, cache=c,
                                   cache_index=cache_index, chunked=chunked,
                                   shared=shared, name=f"rem/{i}",
                                   length_mask=length_mask, pages=pages)
            aux = aux + a
            new_rem.append(nc)

    x = L.norm(params["final_norm"], x, cfg.norm)
    new_caches = None
    if caches is not None:
        new_caches = {"units": new_unit_caches}
        if params.get("rem"):
            new_caches["rem"] = new_rem
        if nfc is not None:
            new_caches["first"] = nfc
    return x, new_caches, aux


def _head_weight(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["emb"].T
    h = params["head"]
    if "w_q" in h:  # int8 serve domain
        return h["w_q"].astype(params["emb"].dtype) * \
            h["w_s"].astype(params["emb"].dtype)[..., None, :]
    return h["w"]


def _project_logits(params, cfg: ArchConfig, h):
    """Vocab projection of the last hidden states, routed through the
    pluggable matmul backend when one is installed (per-layer planned
    execution of the head; see repro.models._backend)."""
    be = _backend.current()
    if be is not None and not cfg.tie_embeddings and "head" in params:
        y = be("head", params["head"], h)
        if y is not None:
            return y.astype(jnp.float32)
    return (h @ _head_weight(params, cfg)).astype(jnp.float32)


def chunked_ce(h, w, targets, chunk=512):
    """Cross-entropy with the vocab projection computed per sequence chunk
    (rematerialized in backward) — avoids materializing (B,S,V) logits."""
    B, Sq, D = h.shape
    chunk = min(chunk, Sq)
    n = Sq // chunk
    hc = h[:, :n * chunk].reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    tc = targets[:, :n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(hb, tb):
        logits = (hb @ w).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(logp, tb[..., None], axis=-1)[..., 0]

    def body(acc, xs):
        hb, tb = xs
        return acc + jnp.sum(one(hb, tb)), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, tc))
    return tot / (B * n * chunk)


def lm_loss(params, cfg: ArchConfig, batch, remat=False):
    """batch: {"tokens","targets"[, "frontend"]} -> scalar loss."""
    tokens = batch["tokens"]
    B, Sq = tokens.shape
    x = params["emb"][tokens]
    positions = jnp.arange(Sq)[None, :]
    cross_source = None
    if cfg.frontend == "vision":
        cross_source = batch["frontend"]
    elif cfg.frontend == "audio":
        cross_source = encode(params, cfg, batch["frontend"])
    h, _, aux = backbone(params, cfg, x, positions,
                         cross_source=cross_source, chunked=Sq > 2048,
                         remat=remat)
    loss = chunked_ce(h, _head_weight(params, cfg), batch["targets"])
    return loss + 0.01 * aux


# --------------------------------------------------------------- serving

def _block_cache_spec(cfg: ArchConfig, kind: str, B: int, S_max: int):
    dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16
    if kind in ("attn", "dec", "shared_attn"):
        spec = {"k": ((B, S_max, cfg.n_kv_heads, cfg.hd), dt),
                "v": ((B, S_max, cfg.n_kv_heads, cfg.hd), dt)}
        if kind == "dec":
            # encoder memory KV, computed once at prefill (decode reuses)
            spec["ck"] = ((B, cfg.frontend_tokens, cfg.n_kv_heads, cfg.hd), dt)
            spec["cv"] = ((B, cfg.frontend_tokens, cfg.n_kv_heads, cfg.hd), dt)
        return spec
    if kind == "mla":
        m = cfg.mla
        return {"latent": ((B, S_max, m.kv_lora_rank + m.qk_rope_dim), dt)}
    if kind == "cross":
        return {"ck": ((B, cfg.frontend_tokens, cfg.n_kv_heads, cfg.hd), dt),
                "cv": ((B, cfg.frontend_tokens, cfg.n_kv_heads, cfg.hd), dt)}
    if kind == "mamba":
        mc = _mamba_cfg(cfg)
        return {"ssm": ((B, mc.n_heads, mc.head_dim, mc.d_state), jnp.float32),
                "conv": ((B, mc.conv_kernel - 1, mc.conv_dim), dt)}
    if kind == "mlstm":
        xc = _xlstm_cfg(cfg)
        return {"C": ((B, xc.n_heads, xc.head_dim, xc.head_dim), jnp.float32),
                "n": ((B, xc.n_heads, xc.head_dim), jnp.float32),
                "m": ((B, xc.n_heads), jnp.float32),
                "conv": ((B, xc.conv_kernel - 1, xc.d_inner), dt)}
    if kind == "slstm":
        xc = _xlstm_cfg(cfg)
        z = lambda *s: (s, jnp.float32)
        return {"c": z(B, xc.n_heads, xc.head_dim),
                "n": z(B, xc.n_heads, xc.head_dim),
                "h": z(B, xc.n_heads, xc.head_dim),
                "m": z(B, xc.n_heads)}
    raise ValueError(kind)


def _materialize(spec, make):
    if spec is None:
        return None
    if isinstance(spec, dict):
        return {k: _materialize(v, make) for k, v in spec.items()}
    shape, dt = spec
    return make(shape, dt)


def _assemble_caches(cfg: ArchConfig, block_fn, stacked, plain):
    """Shared layout walk for every cache-shaped pytree: ``block_fn(kind)``
    emits one block's leaf dict; ``stacked(leaf, repeats)`` builds the
    scan-stacked "units" version of a leaf, ``plain(leaf)`` the unstacked
    "rem"/"first" version."""
    period = len(cfg.pattern)
    repeats = cfg.n_layers // period
    if cfg.moe_first_dense and period == 1:
        repeats -= 1  # layer 0 cache lives under "first"

    def walk(spec, leaf):
        if spec is None:
            return None
        if isinstance(spec, dict):
            return {k: walk(v, leaf) for k, v in spec.items()}
        return leaf(spec)

    caches = {"units": tuple(
        walk(block_fn(kind), lambda sp: stacked(sp, repeats))
        for kind in cfg.pattern)}
    rem = _zamba_remainder(cfg)
    if rem:
        caches["rem"] = [walk(block_fn(cfg.pattern[i % period]), plain)
                         for i in range(rem)]
    if cfg.moe_first_dense:
        caches["first"] = walk(block_fn(cfg.pattern[0]), plain)
    return caches


def cache_specs(cfg: ArchConfig, B: int, S_max: int, concrete=False):
    make = (lambda s, d: jnp.zeros(s, d)) if concrete else \
        (lambda s, d: jax.ShapeDtypeStruct(s, d))
    return _assemble_caches(
        cfg, lambda kind: _block_cache_spec(cfg, kind, B, S_max),
        stacked=lambda sp, r: make((r, *sp[0]), sp[1]),
        plain=lambda sp: make(*sp))


def init_cache(cfg: ArchConfig, B: int, S_max: int):
    return cache_specs(cfg, B, S_max, concrete=True)


# ------------------------------------------------------------ paged KV pool

# Sequence-indexed attention-KV leaves — the ones a paged layout moves from
# per-slot (B, S_max, ...) buffers into the shared page pool.  Everything
# else (recurrent state, conv tails, encoder memory) is O(1)-per-slot and
# stays slot-indexed in both layouts.
_PAGED_KEYS = frozenset({"k", "v", "latent"})
_SEQ_KINDS = frozenset({"attn", "dec", "shared_attn", "mla"})


def _paged_block_cache_spec(cfg: ArchConfig, kind: str, B: int,
                            pool_rows: int, page_size: int):
    spec = _block_cache_spec(cfg, kind, B, 1)
    if kind not in _SEQ_KINDS:
        return spec
    for key in spec:
        if key in _PAGED_KEYS:
            (_, _, *feat), dt = spec[key]
            spec[key] = ((pool_rows, page_size, *feat), dt)
    return spec


def paged_cache_specs(cfg: ArchConfig, B: int, pool_rows: int,
                      page_size: int, concrete=False):
    """Cache pytree for the PAGED layout: attention-KV leaves become the
    shared ``(pool_rows, page_size, ...)`` page pool (``pool_rows`` includes
    the trash row 0 — pass num_pages + 1); per-slot state keeps its dense
    shape.  Same tree structure as `cache_specs`."""
    make = (lambda s, d: jnp.zeros(s, d)) if concrete else \
        (lambda s, d: jax.ShapeDtypeStruct(s, d))
    return _assemble_caches(
        cfg, lambda kind: _paged_block_cache_spec(cfg, kind, B, pool_rows,
                                                  page_size),
        stacked=lambda sp, r: make((r, *sp[0]), sp[1]),
        plain=lambda sp: make(*sp))


def init_paged_cache(cfg: ArchConfig, B: int, pool_rows: int, page_size: int):
    return paged_cache_specs(cfg, B, pool_rows, page_size, concrete=True)


def cache_kv_axes(cfg: ArchConfig):
    """Marker pytree (same structure as `cache_specs`/`paged_cache_specs`):
    ``"page"`` for sequence-indexed attention-KV leaves, ``"slot"`` for
    per-slot state, with the count of leading scan-stack axes appended —
    ``"page1"`` means "KV leaf whose pool/batch axis sits at axis 1 under
    the stacked repeats".  This is what the engine's jitted slot-reset /
    page-copy helpers and the KV-byte accounting use to address leaves of
    either layout."""
    def roles(kind):
        spec = _block_cache_spec(cfg, kind, 1, 1)
        return {key: ("page" if kind in _SEQ_KINDS and key in _PAGED_KEYS
                      else "slot") for key in spec}
    return _assemble_caches(cfg, roles,
                            stacked=lambda role, r: role + "1",
                            plain=lambda role: role + "0")


def cache_batch_axes(caches):
    """Pytree (matching ``caches``) of each leaf's BATCH axis: ``"units"``
    leaves are scan-stacked with a leading repeats axis (batch is axis 1),
    everything else carries batch first.  This is the layout knowledge
    `scatter_cache` needs to address slots."""
    axes = {"units": jax.tree.map(lambda _: 1, caches["units"])}
    for k in ("rem", "first"):
        if k in caches:
            axes[k] = jax.tree.map(lambda _: 0, caches[k])
    return axes


def scatter_cache(caches, sub, slots):
    """Write a k-request cache pytree ``sub`` into the B-slot pool
    ``caches`` at slot indices ``slots`` (k,) — the continuous-batching
    admission step: freshly prefilled per-request caches land in the slots
    the scheduler assigned, replacing whatever a retired request left
    there.  Jit-safe (``slots`` may be traced)."""
    slots = jnp.asarray(slots)

    def put(buf, s, axis):
        if axis == 0:
            return buf.at[slots].set(s.astype(buf.dtype))
        return buf.at[:, slots].set(s.astype(buf.dtype))

    return jax.tree.map(put, caches, sub, cache_batch_axes(caches))


def prefill(params, cfg: ArchConfig, tokens, caches, cross_source=None,
            lengths=None, variant=None):
    """Process the prompt, fill caches, return (last_logits, caches).

    ``lengths`` (B,) enables RAGGED prefill of right-padded prompts: valid
    tokens occupy positions ``[0, lengths[b])`` of each row.  Recurrent
    (SSM/xLSTM) states and MoE dispatch suppress the padded tail exactly;
    attention KV written at padded positions is garbage by contract — every
    subsequent read masks the cache by per-slot length (`decode_step` with
    a (B,) index).  The returned logits are taken at each slot's LAST VALID
    position (``lengths - 1``), not at the padded row end.

    ``variant`` selects the plan variant of a multi-plan backend
    (`repro.runtime.PlanSet`) for this call — a STATIC string (make it a
    ``static_argnames`` entry when jitting); None keeps any surrounding
    ``plan_variant`` selection / the backend default."""
    with _backend.plan_variant(variant):
        return _prefill_body(params, cfg, tokens, caches, cross_source,
                             lengths)


def _prefill_body(params, cfg, tokens, caches, cross_source, lengths):
    B, Sq = tokens.shape
    x = params["emb"][tokens]
    positions = jnp.arange(Sq)[None, :]
    length_mask = None
    if lengths is not None:
        length_mask = jnp.arange(Sq)[None, :] < jnp.asarray(lengths)[:, None]
    if cfg.frontend == "audio" and cross_source is not None:
        cross_source = encode(params, cfg, cross_source)
    h, caches, _ = backbone(params, cfg, x, positions, caches=caches,
                            cache_index=0, cross_source=cross_source,
                            chunked=Sq > 2048, length_mask=length_mask)
    h_last = (h[:, -1] if lengths is None
              else jnp.take_along_axis(
                  h, (jnp.asarray(lengths) - 1)[:, None, None], axis=1)[:, 0])
    logits = _project_logits(params, cfg, h_last)
    return logits, caches


def prefill_chunk(params, cfg: ArchConfig, tokens, caches, index, valid,
                  pages, cross_source=None, variant=None,
                  full_logits: bool = False):
    """One fixed-size chunk of a paged CHUNKED prefill.

    ``tokens`` (B, C) holds the next (up to C) prompt tokens of every
    currently-prefilling slot, left-aligned; ``index`` (B,) is each slot's
    prefill progress (tokens already in its pages) and ``valid`` (B,) how
    many of this chunk's tokens are real — 0 for slots that are decoding or
    idle, whose rows are fully masked: attention writes land in the trash
    page and recurrent state carries through unchanged (`ssm` masked steps
    are exact identities), so interleaving chunks with decode steps cannot
    perturb other slots.  Recurrent state accumulated in ``caches`` across
    calls IS the carried chunk boundary state.  Returns (logits at each
    slot's last valid token — the slot's first generated token once its
    whole prompt is in, garbage before that — and the updated caches).

    ``variant`` selects the plan variant of a multi-plan backend (STATIC —
    see `prefill`).  ``full_logits=True`` returns logits at EVERY chunk
    position ``(B, C, V)`` instead of the last valid one — the speculative
    verify step reads the target model's prediction after each drafted
    token from one chunk call (rows past ``valid`` are garbage by the same
    masking contract)."""
    with _backend.plan_variant(variant):
        B, C = tokens.shape
        index = jnp.asarray(index)
        valid = jnp.asarray(valid)
        x = params["emb"][tokens]
        positions = index[:, None] + jnp.arange(C)[None, :]
        length_mask = jnp.arange(C)[None, :] < valid[:, None]
        if cfg.frontend == "audio" and cross_source is not None:
            cross_source = encode(params, cfg, cross_source)
        h, caches, _ = backbone(params, cfg, x, positions, caches=caches,
                                cache_index=index, cross_source=cross_source,
                                length_mask=length_mask, pages=pages)
        if full_logits:
            return _project_logits(params, cfg, h), caches
        last = jnp.clip(valid - 1, 0, C - 1)
        h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]
        logits = _project_logits(params, cfg, h_last)
        return logits, caches


def decode_step(params, cfg: ArchConfig, token, caches, index,
                cross_source=None, active=None, pages=None, variant=None):
    """One decode step. token (B,), index: position of the new token — a
    scalar (classic same-length batch) or a ``(B,)`` array of PER-SLOT cache
    lengths (continuous batching: each slot's token lands at that slot's own
    position and attention masks the cache per slot).  ``active`` (B,) bool
    marks live slots: retired/empty slots are suppressed in cross-slot
    coupling (MoE capacity) and their recurrent states carry through
    unchanged — their logits are garbage by contract.  ``pages`` (B, W)
    switches attention KV to the paged pool layout (see `block_apply`).
    Cross-attention KV (frontend/encoder memory) is read from the cache
    written at prefill — cross_source is ignored here.  ``variant`` selects
    the plan variant of a multi-plan backend (STATIC — see `prefill`)."""
    with _backend.plan_variant(variant):
        x = params["emb"][token][:, None, :]
        B = x.shape[0]
        positions = (jnp.asarray(index)[:, None] if jnp.ndim(index) == 1
                     else jnp.full((B, 1), index))
        length_mask = None if active is None else jnp.asarray(active)[:, None]
        h, caches, _ = backbone(params, cfg, x, positions, caches=caches,
                                cache_index=index, cross_source=None,
                                length_mask=length_mask, pages=pages)
        logits = _project_logits(params, cfg, h[:, -1])
        return logits, caches


# ------------------------------------------------- serve-time quantization

def quantize_for_serve(params, cfg: ArchConfig):
    """Replace projection weights with int8 codes + per-out-channel scales
    (the TPU int8 precision domain of DESIGN.md §2).  Embedding, norms and
    small vectors stay bf16.  Works on concrete params or on
    ShapeDtypeStructs (for the dry-run)."""
    if cfg.serve_weight_dtype != "int8":
        return params

    def walk(node):
        if isinstance(node, dict):
            if "w" in node and hasattr(node["w"], "ndim") and \
                    node["w"].ndim >= 2:
                w = node["w"]
                rest = {k: walk(v) for k, v in node.items() if k != "w"}
                # per-out-channel scale; stacked scan params (R, in, out)
                # keep their leading axes: scale shape = (*lead, out)
                s_shape = w.shape[:-2] + (w.shape[-1],)
                if isinstance(w, jax.ShapeDtypeStruct):
                    rest["w_q"] = jax.ShapeDtypeStruct(w.shape, jnp.int8)
                    rest["w_s"] = jax.ShapeDtypeStruct(s_shape, jnp.float32)
                else:
                    s_ = jnp.maximum(jnp.max(jnp.abs(w.astype(jnp.float32)),
                                             axis=-2), 1e-8) / 127.0
                    rest["w_q"] = jnp.clip(
                        jnp.round(w.astype(jnp.float32) / s_[..., None, :]),
                        -127, 127).astype(jnp.int8)
                    rest["w_s"] = s_.astype(jnp.float32)
                return rest
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node

    # keep the embedding (and tied head) in bf16: vocab-gather accuracy
    out = walk({k: v for k, v in params.items() if k != "emb"})
    out["emb"] = params["emb"]
    return out
