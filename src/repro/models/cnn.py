"""Paper-faithful CNN benchmarks: ResNet20 (CIFAR-10), ResNet18
(Tiny-ImageNet), MobileNetV1-0.25x (VWW) — Sec. IV-A.

BatchNorm is assumed folded into the convolutions (the paper folds BN before
quantization since DIANA has no BN hardware); layers are conv+bias.

Each model exposes:
    init(key, cfg)              -> params pytree
    apply(params, x, mode, tau) -> logits
    plan(cfg)                   -> list of (name, LayerGeometry, searchable)
    managed_paths(cfg)          -> list of param-dict key paths, forward order

``searchable=False`` layers (depthwise convs on DIANA) are pinned to the
digital domain and excluded from the DNAS (paper Sec. IV-A).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.cost_models import LayerGeometry
from repro.core.odimo import ODiMOSpec
from repro.models import managed as mg


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    img_hw: Tuple[int, int]
    in_ch: int
    n_classes: int
    width_mult: float = 1.0


RESNET20_CFG = CNNConfig("resnet20", (32, 32), 3, 10)
RESNET18_CFG = CNNConfig("resnet18", (64, 64), 3, 200)
RESNET18_SMALL = CNNConfig("resnet18_small", (32, 32), 3, 50)
MBV1_CFG = CNNConfig("mobilenetv1_025", (96, 96), 3, 2, width_mult=0.25)

# Reduced configs for CI-speed tests
RESNET20_TINY = CNNConfig("resnet20_tiny", (16, 16), 3, 10)
MBV1_TINY = CNNConfig("mobilenetv1_tiny", (32, 32), 3, 2, width_mult=0.25)

CONFIGS = {c.name: c for c in (RESNET20_CFG, RESNET18_CFG, RESNET18_SMALL,
                               MBV1_CFG, RESNET20_TINY, MBV1_TINY)}


def get_config(name: str) -> CNNConfig:
    """Named CNN config (the ``cnn:<name>`` arch convention of the launch
    drivers)."""
    try:
        return CONFIGS[name]
    except KeyError:
        raise ValueError(f"unknown CNN config {name!r} "
                         f"(known: {sorted(CONFIGS)})") from None


# --------------------------------------------------------------------------
# ResNet (pre-BN-folded basic blocks)
# --------------------------------------------------------------------------

def _resnet_stages(name: str):
    if "20" in name:
        return [(16, 3, 1), (32, 3, 2), (64, 3, 2)], 16        # (width, blocks, stride)
    return [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)], 64


def resnet_init(key, cfg: CNNConfig, spec: ODiMOSpec | None):
    stages, stem_w = _resnet_stages(cfg.name)
    keys = jax.random.split(key, 512)
    ki = iter(range(512))
    p = {"stem": mg.init_conv(keys[next(ki)], 3, 3, cfg.in_ch, stem_w, spec)}
    blocks = []
    c_prev = stem_w
    for (w, n, s) in stages:
        for b in range(n):
            stride = s if b == 0 else 1
            blk = {
                "c1": mg.init_conv(keys[next(ki)], 3, 3, c_prev, w, spec),
                "c2": mg.init_conv(keys[next(ki)], 3, 3, w, w, spec),
            }
            if stride != 1 or c_prev != w:
                blk["proj"] = mg.init_conv(keys[next(ki)], 1, 1, c_prev, w, spec)
            blocks.append(blk)
            c_prev = w
    p["blocks"] = blocks
    p["head"] = mg.init_dense(keys[next(ki)], c_prev, cfg.n_classes, spec)
    return p


def resnet_apply(p, x, cfg: CNNConfig, spec=None, mode="fp", tau=1.0):
    stages, _ = _resnet_stages(cfg.name)
    x = mg.conv2d(p["stem"], x, spec, mode, tau, name="stem")
    bi = 0
    c_prev_w = None
    for (w, n, s) in stages:
        for b in range(n):
            stride = s if b == 0 else 1
            blk = p["blocks"][bi]
            h = mg.conv2d(blk["c1"], x, spec, mode, tau, stride=stride,
                          name=f"blocks/{bi}/c1")
            h = mg.conv2d_linear(blk["c2"], h, spec, mode, tau,
                                 name=f"blocks/{bi}/c2")
            sc = x
            if "proj" in blk:
                sc = mg.conv2d_linear(blk["proj"], x, spec, mode, tau,
                                      stride=stride, name=f"blocks/{bi}/proj")
            x = jax.nn.relu(h + sc)
            x = mg._maybe_quant_act(x, blk["c2"], spec, mode)
            bi += 1
    x = jnp.mean(x, axis=(1, 2))
    return mg.dense(p["head"], x, spec, mode, tau, name="head")


def resnet_plan(cfg: CNNConfig) -> List[Tuple[str, LayerGeometry, bool]]:
    stages, stem_w = _resnet_stages(cfg.name)
    hw = cfg.img_hw
    plan = [("stem", mg.conv_geometry(3, 3, cfg.in_ch, stem_w, hw), True)]
    c_prev = stem_w
    bi = 0
    for (w, n, s) in stages:
        for b in range(n):
            stride = s if b == 0 else 1
            hw = (hw[0] // stride, hw[1] // stride)
            plan.append((f"blocks/{bi}/c1", mg.conv_geometry(3, 3, c_prev, w, hw), True))
            plan.append((f"blocks/{bi}/c2", mg.conv_geometry(3, 3, w, w, hw), True))
            if stride != 1 or c_prev != w:
                plan.append((f"blocks/{bi}/proj", mg.conv_geometry(1, 1, c_prev, w, hw), True))
            c_prev = w
            bi += 1
    plan.append(("head", mg.dense_geometry(c_prev, cfg.n_classes), True))
    return plan


# --------------------------------------------------------------------------
# MobileNetV1 (depthwise separable; depthwise convs NOT searchable on DIANA)
# --------------------------------------------------------------------------

MBV1_LAYERS = [  # (stride, c_out at 1.0x) for the 13 separable blocks
    (1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
    (1, 512), (1, 512), (1, 512), (1, 512), (1, 512), (2, 1024), (1, 1024),
]


def _mb_w(c, mult):  # width multiplier with 8-divisibility like the reference
    return max(8, int(c * mult))


def mbv1_init(key, cfg: CNNConfig, spec: ODiMOSpec | None):
    keys = jax.random.split(key, 64)
    ki = iter(range(64))
    c0 = _mb_w(32, cfg.width_mult)
    p = {"stem": mg.init_conv(keys[next(ki)], 3, 3, cfg.in_ch, c0, spec)}
    blocks = []
    c_prev = c0
    for (s, c) in MBV1_LAYERS:
        cw = _mb_w(c, cfg.width_mult)
        blocks.append({
            # depthwise: pinned (searchable=False), still quantized 8-bit
            "dw": mg.init_conv(keys[next(ki)], 3, 3, c_prev, c_prev, spec, groups=c_prev),
            "pw": mg.init_conv(keys[next(ki)], 1, 1, c_prev, cw, spec),
        })
        c_prev = cw
    p["blocks"] = blocks
    p["head"] = mg.init_dense(keys[next(ki)], c_prev, cfg.n_classes, spec)
    return p


def mbv1_apply(p, x, cfg: CNNConfig, spec=None, mode="fp", tau=1.0):
    x = mg.conv2d(p["stem"], x, spec, mode, tau, stride=2, name="stem")
    c_prev = _mb_w(32, cfg.width_mult)
    for i, (blk, (s, c)) in enumerate(zip(p["blocks"], MBV1_LAYERS)):
        x = mg.conv2d(blk["dw"], x, spec, mode, tau, stride=s, groups=c_prev,
                      name=f"blocks/{i}/dw")
        x = mg.conv2d(blk["pw"], x, spec, mode, tau, name=f"blocks/{i}/pw")
        c_prev = _mb_w(c, cfg.width_mult)
    x = jnp.mean(x, axis=(1, 2))
    return mg.dense(p["head"], x, spec, mode, tau, name="head")


def mbv1_plan(cfg: CNNConfig) -> List[Tuple[str, LayerGeometry, bool]]:
    hw = (cfg.img_hw[0] // 2, cfg.img_hw[1] // 2)
    c0 = _mb_w(32, cfg.width_mult)
    plan = [("stem", mg.conv_geometry(3, 3, cfg.in_ch, c0, hw), True)]
    c_prev = c0
    for i, (s, c) in enumerate(MBV1_LAYERS):
        hw = (hw[0] // s, hw[1] // s)
        cw = _mb_w(c, cfg.width_mult)
        plan.append((f"blocks/{i}/dw",
                     mg.conv_geometry(3, 3, c_prev, c_prev, hw, groups=c_prev), False))
        plan.append((f"blocks/{i}/pw", mg.conv_geometry(1, 1, c_prev, cw, hw), True))
        c_prev = cw
    plan.append(("head", mg.dense_geometry(c_prev, cfg.n_classes), True))
    return plan


# --------------------------------------------------------------------------
# Uniform façade
# --------------------------------------------------------------------------

def get_model(cfg: CNNConfig):
    if cfg.name.startswith("resnet"):
        return resnet_init, resnet_apply, resnet_plan
    if cfg.name.startswith("mobilenet"):
        return mbv1_init, mbv1_apply, mbv1_plan
    raise ValueError(cfg.name)


def get_by_path(params, path: str):
    return mg.get_by_path(params, path)


def managed_layer_dicts(params, cfg: CNNConfig):
    """Param dicts of all managed layers, in plan order."""
    _, _, plan_fn = get_model(cfg)
    return [get_by_path(params, name) for (name, _, _) in plan_fn(cfg)]
