"""Roofline analysis: three terms per (arch x shape x mesh) cell.

    compute term    = HLO_FLOPs / (chips * peak)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Methodology note (IMPORTANT, see EXPERIMENTS.md §Roofline): the model stacks
layers with ``lax.scan``, and XLA's ``cost_analysis`` counts a while-loop
body ONCE (verified experimentally: a scan of 8 matmuls reports 1/8 the
flops of the unrolled version).  Therefore:
  * FLOPs and HBM bytes are computed by an exact ANALYTIC enumerator over
    the architecture's tensor ops (what the compiled program executes,
    including full-square masked attention, MoE capacity overcompute and
    the remat re-forward) — cross-checked against cost_analysis on the
    scan body (see check_against_hlo);
  * collective bytes come from the compiled HLO text, with collectives
    inside while bodies multiplied by the layer-scan trip count (recorded
    per cell by dryrun.py);
  * memory fit comes from compiled.memory_analysis() directly.

MODEL_FLOPS follows the assignment: 6*N*D (dense) / 6*N_active*D (MoE) for
training; 2*N_active per generated token for decode.  The ratio
MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is "useful".
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict

import jax
import numpy as np

from repro.configs.base import ArchConfig

# TPU v5e hardware constants (per chip)
PEAK_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9              # B/s
LINK_BW = 50e9              # B/s per ICI link

SHAPE_META = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


# --------------------------------------------------------- parameter counts

def param_counts(cfg: ArchConfig) -> Dict[str, float]:
    """(total, active) parameter counts from the real init (eval_shape)."""
    from repro.models import transformer as T
    shapes = jax.eval_shape(lambda k: T.init_lm(k, cfg), jax.random.PRNGKey(0))
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
    active = total
    if cfg.moe is not None:
        E, K, F, D = (cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.d_ff,
                      cfg.d_model)
        n_moe_layers = cfg.n_layers - cfg.moe_first_dense
        per_layer_experts = E * (3 if cfg.moe.gated else 2) * D * F
        active_frac = K / E
        active = total - n_moe_layers * per_layer_experts * (1 - active_frac)
    return {"total": float(total), "active": float(active)}


# --------------------------------------------------------- FLOPs enumerator

def _attn_flops(B, Sq, Sk, H, hd_qk, hd_v):
    """Full-square masked attention as implemented (scores + PV)."""
    return 2 * B * Sq * Sk * H * hd_qk + 2 * B * Sq * Sk * H * hd_v


def _block_fwd_flops(cfg: ArchConfig, kind: str, B: int, S: int,
                     cache_len: int | None) -> float:
    """Forward FLOPs of one block on (B, S) tokens (cache_len for decode)."""
    D = cfg.d_model
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    lin = lambda i, o: 2.0 * B * S * i * o
    Sk = cache_len if cache_len is not None else S
    if cfg.sliding_window and kind in ("attn",):
        Sk = min(Sk, cfg.sliding_window) if cache_len is not None else Sk
    f = 0.0
    if kind in ("attn", "shared_attn", "dec"):
        f += lin(D, H * hd) + 2 * lin(D, KVH * hd) + lin(H * hd, D)
        f += _attn_flops(B, S, Sk, H, hd, hd)
        if kind == "dec":  # + cross attention to T_f frontend tokens
            Tf = cfg.frontend_tokens
            f += lin(D, H * hd) + lin(H * hd, D)
            f += _attn_flops(B, S, Tf, H, hd, hd)
        # FFN
        if cfg.moe is not None and kind == "attn":
            f += _moe_flops(cfg, B, S)
            if cfg.moe_dense_residual:
                f += (3 if cfg.gated_ffn else 2) * lin(D, cfg.dense_ff)
        elif cfg.d_ff:
            f += (3 if cfg.gated_ffn else 2) * lin(D, cfg.d_ff)
    elif kind == "mla":
        m = cfg.mla
        qd = m.qk_nope_dim + m.qk_rope_dim
        r = m.kv_lora_rank
        f += lin(D, H * qd) + lin(D, r + m.qk_rope_dim)
        if cache_len is not None:
            # ABSORBED decode: q/out folded through kv_b, attention over the
            # compressed latent (r) + rope dims
            f += 2.0 * B * H * m.qk_nope_dim * r       # q absorb
            f += 2.0 * B * H * Sk * (r + m.qk_rope_dim)  # scores
            f += 2.0 * B * H * Sk * r                  # latent-weighted sum
            f += 2.0 * B * H * r * m.v_head_dim        # output absorb
        else:
            f += lin(r, H * (m.qk_nope_dim + m.v_head_dim))
            f += _attn_flops(B, S, Sk, H, qd, m.v_head_dim)
        f += lin(H * m.v_head_dim, D)
        f += _moe_flops(cfg, B, S)
    elif kind == "cross":
        Tf = cfg.frontend_tokens
        f += lin(D, H * hd) + lin(H * hd, D)
        if cache_len is None:  # decode reuses prefill-cached cross KV
            f += 2 * 2.0 * B * Tf * D * KVH * hd        # kv projections
        f += _attn_flops(B, S, Tf, H, hd, hd)
        f += (3 if cfg.gated_ffn else 2) * lin(D, cfg.d_ff)
    elif kind == "mamba":
        di, N = 2 * D, cfg.ssm_state
        Hm, P = di // 64, 64
        f += lin(D, 2 * di + 2 * N + Hm) + lin(di, D)
        Q = min(256, S)
        nchunks = max(1, S // Q)
        # SSD chunk math: CB (2BQ^2N), W-apply (2BQ^2 Hm P), state io
        f += nchunks * (2.0 * B * Q * Q * N + 2.0 * B * Q * Q * Hm * P +
                        4.0 * B * Q * Hm * P * N)
    elif kind in ("mlstm", "slstm"):
        di = 2 * D
        Hx, hx = cfg.n_heads, di // cfg.n_heads
        if kind == "mlstm":
            # block-diagonal qkv: di*hd per matrix (not di^2)
            f += lin(D, 2 * di) + 3 * lin(di, hx) + lin(di, 2 * Hx) + lin(di, D)
            Q = min(256, S)
            nchunks = max(1, S // Q)
            f += nchunks * (4.0 * B * Q * Q * Hx * hx +       # qk + wv
                            4.0 * B * Q * Hx * hx * hx)       # state update
        else:
            f += lin(D, 4 * di) + lin(di, D)
            f += 2.0 * B * S * Hx * hx * 4 * hx               # recurrent mix
    else:
        raise ValueError(kind)
    return f


def _moe_flops(cfg: ArchConfig, B, S) -> float:
    """Dense-dispatch MoE as implemented (capacity buffers, not just top-k)."""
    m = cfg.moe
    D = cfg.d_model
    T = B * S
    G = max(1, min(256, T // 4096))  # matches moe_ffn's grouping heuristic
    Tg = T // G
    C = max(int(Tg * m.top_k * m.capacity_factor / m.n_experts), m.top_k)
    nmat = 3 if m.gated else 2
    f = 2.0 * T * D * m.n_experts                     # router
    f += 2 * 2.0 * G * Tg * m.n_experts * C * D       # dispatch + combine
    f += nmat * 2.0 * G * m.n_experts * C * D * m.d_ff  # expert FFNs
    if m.n_shared:
        f += nmat * 2.0 * T * D * (m.n_shared * m.d_ff)
    return f


def hlo_flops(cfg: ArchConfig, shape: str) -> Dict[str, float]:
    """Analytic 'as-implemented' FLOPs for the cell (fwd/total/model)."""
    meta = SHAPE_META[shape]
    B, S = meta["batch"], meta["seq"]
    kind = meta["kind"]
    counts = param_counts(cfg)
    N, Na = counts["total"], counts["active"]

    cache_len = S if kind == "decode" else None
    s_eff = 1 if kind == "decode" else S

    fwd = 0.0
    period = len(cfg.pattern)
    reps = cfg.n_layers // period
    for k in cfg.pattern:
        fwd += reps * _block_fwd_flops(cfg, k, B, s_eff, cache_len)
    rem = cfg.n_layers - reps * period
    for i in range(rem):
        fwd += _block_fwd_flops(cfg, cfg.pattern[i % period], B, s_eff,
                                cache_len)
    if cfg.encoder_layers and kind != "decode":  # decode reuses enc memory
        Tf = cfg.frontend_tokens
        enc_cfg_ff = (2 if not cfg.gated_ffn else 3) * 2.0 * B * Tf * \
            cfg.d_model * cfg.d_ff
        enc_attn = (2 * 2.0 * B * Tf * cfg.d_model * cfg.n_heads * cfg.hd +
                    2 * 2.0 * B * Tf * cfg.d_model * cfg.n_kv_heads * cfg.hd +
                    _attn_flops(B, Tf, Tf, cfg.n_heads, cfg.hd, cfg.hd))
        fwd += cfg.encoder_layers * (enc_attn + enc_cfg_ff)
    # LM head
    fwd += 2.0 * B * s_eff * cfg.d_model * cfg.vocab

    if kind == "train":
        tokens = B * S
        total = fwd * 4.0            # fwd + 2x bwd + 1x remat re-forward
        model = 6.0 * Na * tokens
    elif kind == "prefill":
        total = fwd
        model = 2.0 * Na * B * S
    else:
        total = fwd
        model = 2.0 * Na * B
    return {"fwd": fwd, "total": total, "model": model,
            "params": N, "params_active": Na}


# --------------------------------------------------------- bytes enumerator

def hlo_bytes(cfg: ArchConfig, shape: str) -> float:
    """NOTE: weight-byte width follows cfg.serve_weight_dtype and cache
    width follows cfg.kv_cache_dtype (the int8 precision-domain variants)."""
    """Idealized HBM traffic per step (reads+writes), global across chips."""
    meta = SHAPE_META[shape]
    B, S = meta["batch"], meta["seq"]
    kind = meta["kind"]
    counts = param_counts(cfg)
    N, Na = counts["total"], counts["active"]
    D = cfg.d_model
    F_eff = cfg.d_ff if cfg.d_ff else 2 * D
    if cfg.moe is not None:
        m = cfg.moe
        C_frac = m.top_k * m.capacity_factor  # capacity compute per token
        F_eff = m.d_ff * C_frac + (cfg.dense_ff if cfg.moe_dense_residual
                                   else 0) + m.n_shared * m.d_ff

    if kind == "train":
        opt_b = 2 if cfg.name == "arctic-480b" else 4   # moment dtype
        # params read (fwd+bwd+remat ~3x), grads w+r (f32), opt m,v r+w,
        # param write
        wb = N * (2 * 3 + 4 * 2 + 2 * opt_b * 2 + 2)
        act = cfg.n_layers * B * S * 2.0 * (8 * D + 3 * F_eff)
        return wb + act
    wbyte = 1.0 if cfg.serve_weight_dtype == "int8" else 2.0
    if kind == "prefill":
        wb = wbyte * N
        act = cfg.n_layers * B * S * 2.0 * (6 * D + 2 * F_eff)
        cache = _cache_bytes(cfg, B, S)
        return wb + act + cache
    # decode: all weights + full cache read + small activations
    wb = wbyte * N
    cache = _cache_bytes(cfg, B, S)
    act = cfg.n_layers * B * 2.0 * (6 * D + 2 * F_eff)
    return wb + cache + act


def _cache_bytes(cfg: ArchConfig, B, S) -> float:
    """KV-cache / state bytes (as allocated by cache_specs)."""
    from repro.launch import specs as SP
    from repro.models import transformer as T
    caches = T.cache_specs(cfg, B, S)
    return float(sum(np.prod(l.shape) * l.dtype.itemsize
                     for l in jax.tree.leaves(caches)))


# --------------------------------------------------------- the three terms

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float
    collective_bytes: float
    note: str = ""

    @property
    def terms(self):
        return {"compute": self.t_compute, "memory": self.t_memory,
                "collective": self.t_collective}


def collective_bytes_from_record(rec: dict) -> float:
    """Scan-aware total: top-level once + loop-scope x scan repeats."""
    tot = 0.0
    R = rec.get("scan_repeats", 1)
    for op, scopes in rec["collectives"]["bytes"].items():
        tot += scopes["top"] + R * scopes["loop"]
    return tot


def analyze_cell(rec: dict, peak=PEAK_BF16, hbm=HBM_BW, link=LINK_BW):
    import dataclasses as _dc
    from repro.configs import base as cfgbase
    from repro.launch.dryrun import VARIANTS
    cfg = cfgbase.get(rec["arch"])
    var = rec.get("variant", "base")
    if VARIANTS.get(var):
        cfg = _dc.replace(cfg, **VARIANTS[var])
    shape = rec["shape"]
    chips = rec["n_devices"]
    fl = hlo_flops(cfg, shape)
    by = hlo_bytes(cfg, shape)
    cb = collective_bytes_from_record(rec)
    t_c = fl["total"] / (chips * peak)
    t_m = by / (chips * hbm)
    t_l = cb / (chips * link)
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_l)),
              key=lambda kv: kv[1])[0]
    return Roofline(
        arch=rec["arch"], shape=shape, chips=chips,
        t_compute=t_c, t_memory=t_m, t_collective=t_l, dominant=dom,
        model_flops=fl["model"], hlo_flops=fl["total"],
        useful_ratio=fl["model"] / max(fl["total"], 1.0),
        collective_bytes=cb)


def load_records(dryrun_dir: str | Path, tag="sp"):
    recs = []
    for f in sorted(Path(dryrun_dir).glob(f"*__{tag}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def markdown_table(rooflines, records_by_key=None) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | HLO_FLOPs | useful | action on dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rooflines:
        act = {
            "compute": "raise useful ratio (cut full-square attn waste / "
                       "capacity overcompute; int8 domains 2x peak)",
            "memory": "cut HBM traffic (int8/ternary weights via ODiMO "
                      "domains, fuse, larger arithmetic intensity)",
            "collective": "re-shard to cut resharding collectives / overlap "
                          "with compute",
        }[r.dominant]
        lines.append(
            f"| {r.arch} | {r.shape} | {r.t_compute:.3e} | {r.t_memory:.3e} "
            f"| {r.t_collective:.3e} | **{r.dominant}** | "
            f"{r.model_flops:.3e} | {r.hlo_flops:.3e} | "
            f"{r.useful_ratio:.2f} | {act} |")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="sp")
    args = ap.parse_args()
    from repro.configs import base as cfgbase
    cfgbase.load_all()
    out = []
    for rec in load_records(args.dryrun_dir, args.tag):
        if rec.get("status") != "ok":
            print(f"| {rec['arch']} | {rec['shape']} | — skipped: "
                  f"{rec.get('reason','')[:60]} |")
            continue
        out.append(analyze_cell(rec))
    print(markdown_table(out))


if __name__ == "__main__":
    main()
