"""Deterministic synthetic data pipelines (images + LM tokens).

Real datasets (CIFAR-10, Tiny-ImageNet, MSCOCO/VWW) are not available in
this offline container, so tasks are replaced by *learnable* synthetic
distributions of identical geometry:

  images: class-conditional Gaussian prototypes + structured noise — a CNN
          must learn the prototypes to classify (accuracy is meaningful and
          degrades monotonically with quantization noise, which is what the
          paper's accuracy axis measures).
  tokens: a hidden-Markov-ish next-token process driven by a fixed random
          permutation + noise, so an LM's loss improves with capacity.

Every batch is a pure function of (seed, step, shard) => checkpoint/restart
and elastic re-sharding reproduce the exact stream (fault-tolerance
substrate; see distributed/fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageTaskConfig:
    n_classes: int
    img_hw: tuple
    in_ch: int = 3
    noise: float = 0.35
    seed: int = 1234


def _prototypes(cfg: ImageTaskConfig) -> jax.Array:
    key = jax.random.PRNGKey(cfg.seed)
    return jax.random.normal(key, (cfg.n_classes, *cfg.img_hw, cfg.in_ch)) * 0.7


def image_batch(cfg: ImageTaskConfig, step: int, batch: int,
                shard: int = 0, n_shards: int = 1):
    """Deterministic labeled image batch for (step, shard)."""
    protos = _prototypes(cfg)
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(cfg.seed + 1), step), shard)
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (batch,), 0, cfg.n_classes)
    noise = cfg.noise * jax.random.normal(k2, (batch, *cfg.img_hw, cfg.in_ch))
    # mild random gain so the task is not linearly separable from one pixel
    gain = 1.0 + 0.1 * jax.random.normal(k3, (batch, 1, 1, 1))
    x = protos[labels] * gain + noise
    return x, labels


@dataclasses.dataclass(frozen=True)
class TokenTaskConfig:
    vocab: int
    seed: int = 4321


def token_batch(cfg: TokenTaskConfig, step: int, batch: int, seq_len: int,
                shard: int = 0, n_shards: int = 1):
    """Deterministic LM batch: tokens follow x_{t+1} = perm[x_t] with 10%
    uniform corruption; returns (tokens, targets) of shape (batch, seq).
    """
    rng = np.random.default_rng(cfg.seed)
    perm = rng.permutation(cfg.vocab)
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(cfg.seed + 7), step), shard)
    k0, kc, ku = jax.random.split(key, 3)
    x0 = jax.random.randint(k0, (batch,), 0, cfg.vocab)
    perm_j = jnp.asarray(perm)

    def stepf(x, k):
        nxt = perm_j[x]
        corrupt = jax.random.bernoulli(jax.random.fold_in(kc, k), 0.1, (batch,))
        rand = jax.random.randint(jax.random.fold_in(ku, k), (batch,), 0, cfg.vocab)
        return jnp.where(corrupt, rand, nxt), None

    def scan_body(carry, k):
        nxt, _ = stepf(carry, k)
        return nxt, nxt

    _, seq = jax.lax.scan(scan_body, x0, jnp.arange(seq_len))
    tokens = jnp.concatenate([x0[None, :], seq[:-1]], axis=0).T  # (B, T)
    targets = seq.T
    return tokens, targets


class ShardedLoader:
    """Host-side loader: yields the global batch's shard for this process.

    Deterministic in (step) — after a restart at step k, iteration resumes
    with bit-identical batches. ``reshard(n_shards, shard)`` supports elastic
    rescaling without replaying data.
    """

    def __init__(self, kind: str, cfg, batch: int, seq_len: int | None = None,
                 shard: int = 0, n_shards: int = 1):
        self.kind, self.cfg, self.batch = kind, cfg, batch
        self.seq_len = seq_len
        self.shard, self.n_shards = shard, n_shards

    def reshard(self, shard: int, n_shards: int):
        self.shard, self.n_shards = shard, n_shards

    def get(self, step: int):
        local = self.batch // self.n_shards
        if self.kind == "image":
            return image_batch(self.cfg, step, local, self.shard, self.n_shards)
        return token_batch(self.cfg, step, local, self.seq_len,
                           self.shard, self.n_shards)
