"""Paper experiments: Fig. 4 (Pareto sweep), Fig. 5 (abstract HW models),
Table I (deployment accounting), plus a cross-platform Pareto row
(DIANA vs the 3-domain gap9_like SoC vs the TPU v5e roofline).

Real datasets are offline-unavailable; tasks are learnable synthetic
distributions of identical geometry (see data/pipeline.py), so accuracy
deltas between mappings are meaningful and the latency/energy numbers —
which come from the paper's ANALYTICAL models — are exact.

Scale knobs: --preset quick (CI, minutes) | medium (EXPERIMENTS.md numbers)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.api import Platform, SearchConfig, SearchPipeline, cnn_handle
from repro.core import baselines as BL
from repro.data.pipeline import ImageTaskConfig, image_batch
from repro.models import cnn

PRESETS = {
    "quick": dict(pretrain=80, search=100, finetune=80, batch=32, evalb=4,
                  lambdas=(1e-8, 3e-7, 3e-6), models=("resnet20_tiny",)),
    # medium: full resnet20 geometry, CPU-budget steps (the quick preset
    # uses the reduced-geometry model; EXPERIMENTS.md records both)
    "medium": dict(pretrain=150, search=200, finetune=150, batch=48, evalb=6,
                   lambdas=(1e-7, 1e-6, 1e-5),
                   models=("resnet20",)),
    "full": dict(pretrain=250, search=300, finetune=250, batch=64, evalb=8,
                 lambdas=(1e-8, 1e-7, 5e-7, 2e-6, 1e-5),
                 models=("resnet20", "mobilenetv1_025", "resnet18_small")),
}

MODEL_CFGS = {
    "resnet20": cnn.RESNET20_CFG,
    "resnet20_tiny": cnn.RESNET20_TINY,
    "resnet18": cnn.RESNET18_CFG,
    "resnet18_small": cnn.RESNET18_SMALL,   # full-geometry resnet18 is
    "mobilenetv1_025": cnn.MBV1_CFG,        # CPU-infeasible; same family
}


def _task_for(cfg):
    # noise 0.8: hard enough that aggressive quantization visibly costs
    # accuracy (the paper's accuracy axis)
    return ImageTaskConfig(n_classes=cfg.n_classes, img_hw=cfg.img_hw,
                           noise=0.8)


def _data_fn(cfg):
    task = _task_for(cfg)
    return lambda step, batch: image_batch(task, step, batch)


def _scfg(preset, lam, objective):
    p = PRESETS[preset]
    return SearchConfig(
        lam=lam, objective=objective, pretrain_steps=p["pretrain"],
        search_steps=p["search"], finetune_steps=p["finetune"],
        batch=p["batch"], eval_batches=p["evalb"])


def run_baselines(model_name: str, preset: str, platform, out: list):
    cfg = MODEL_CFGS[model_name]
    handle = cnn_handle(cfg)
    geoms, searchable = handle.geometries(), handle.searchable()
    cost_model = Platform.get(platform).cost_model()
    data_fn = _data_fn(cfg)
    scfg = _scfg(preset, 0.0, "latency")
    base_defs = {
        "all_8bit": BL.all_8bit(geoms),
        "all_ternary": BL.all_ternary(geoms),
        "io8_backbone_ternary": BL.io8_backbone_ternary(geoms),
        "min_cost_lat": BL.min_cost(cost_model, geoms, "latency", searchable),
        "min_cost_en": BL.min_cost(cost_model, geoms, "energy", searchable),
    }
    for name, assigns in base_defs.items():
        # pinned layers (depthwise) stay digital regardless of the baseline
        for li, s in enumerate(searchable):
            if not s:
                assigns[li][:] = 0
        t0 = time.time()
        res = SearchPipeline.fixed_mapping(handle, assigns, platform,
                                           config=scfg, data_fn=data_fn).run()
        rec = dict(kind="baseline", model=model_name, name=name,
                   accuracy=res.accuracy, latency=res.latency,
                   energy=res.energy,
                   aimc_ch=_aimc_frac(res.counts), wall_s=time.time() - t0)
        out.append(rec)
        print(f"  [baseline {name}] acc={res.accuracy:.4f} "
              f"lat={res.latency:.3e} en={res.energy:.3e} "
              f"A.Ch={rec['aimc_ch']:.1%}")


def _aimc_frac(counts):
    tot = sum(int(c.sum()) for c in counts)
    aimc = sum(int(c[1]) for c in counts)
    return aimc / max(tot, 1)


def run_odimo_sweep(model_name: str, preset: str, platform, objective: str,
                    out: list, tag: str):
    cfg = MODEL_CFGS[model_name]
    handle = cnn_handle(cfg)
    data_fn = _data_fn(cfg)
    for lam in PRESETS[preset]["lambdas"]:
        t0 = time.time()
        scfg = _scfg(preset, lam, objective)
        res = SearchPipeline(handle, platform, config=scfg,
                             data_fn=data_fn).run()
        rec = dict(kind=f"odimo_{tag}", model=model_name, objective=objective,
                   lam=lam, accuracy=res.accuracy, latency=res.latency,
                   energy=res.energy, aimc_ch=_aimc_frac(res.counts),
                   counts=[c.tolist() for c in res.counts],
                   wall_s=time.time() - t0)
        out.append(rec)
        print(f"  [odimo {tag} {objective} lam={lam:.1e}] "
              f"acc={res.accuracy:.4f} lat={res.latency:.3e} "
              f"en={res.energy:.3e} A.Ch={rec['aimc_ch']:.1%}")


def fig4(preset: str, results: list):
    """Accuracy vs latency + accuracy vs energy Pareto fronts on DIANA."""
    for m in PRESETS[preset]["models"]:
        print(f"[fig4] {m}")
        run_baselines(m, preset, "diana", results)
        for obj in ("latency", "energy"):
            run_odimo_sweep(m, preset, "diana", obj, results, tag="diana")


def fig5(preset: str, results: list):
    """Abstract HW models: P_idle = P_act and P_idle = 0 (HW independence)."""
    m = PRESETS[preset]["models"][0]
    for platform, tag in (("diana_abstract", "abs_noshut"),
                          ("diana_ideal_shutdown", "abs_shut")):
        print(f"[fig5] {m} platform={platform}")
        run_odimo_sweep(m, preset, platform, "energy", results, tag=tag)


def crossplat(preset: str, results: list):
    """Cross-platform Pareto row: the same model and lambda searched on each
    registered target — DIANA (2 domains), the 3-domain gap9_like SoC, the
    TPU v5e roofline and the gpu_tc_like tensor-core pair — reporting the
    per-domain channel fractions the search settles on under each
    platform's cost structure."""
    m = PRESETS[preset]["models"][0]
    cfg = MODEL_CFGS[m]
    handle = cnn_handle(cfg)
    data_fn = _data_fn(cfg)
    lambdas = PRESETS[preset]["lambdas"]
    lam = lambdas[len(lambdas) // 2]
    for platform in ("diana", "gap9_like", "tpu_v5e", "gpu_tc_like"):
        t0 = time.time()
        scfg = _scfg(preset, lam, "latency")
        res = SearchPipeline(handle, platform, config=scfg,
                             data_fn=data_fn).run()
        art = res.artifact
        fracs = {d["name"]: float(f) for d, f in
                 zip(art.domains, art.domain_channel_fractions())}
        rec = dict(kind="crossplat", model=m, platform=platform, lam=lam,
                   objective="latency", accuracy=res.accuracy,
                   latency=res.latency, energy=res.energy,
                   domain_fractions=fracs,
                   counts=[c.tolist() for c in res.counts],
                   wall_s=time.time() - t0)
        results.append(rec)
        frac_s = " ".join(f"{k}={v:.1%}" for k, v in fracs.items())
        print(f"  [crossplat {platform} lam={lam:.1e}] "
              f"acc={res.accuracy:.4f} lat={res.latency:.3e} "
              f"en={res.energy:.3e} {frac_s}")


def table1(results: list):
    """Deployment accounting (Table I): utilization per accelerator and
    AIMC-channel fraction, from the discretized mappings of fig4."""
    cm = Platform.get("diana").cost_model()
    rows = []
    for r in results:
        if r["kind"] != "odimo_diana" or "counts" not in r:
            continue
        geoms = cnn_handle(MODEL_CFGS[r["model"]]).geometries()
        lat_dig = lat_aimc = lat_tot = 0.0
        for geom, counts in zip(geoms, r["counts"]):
            lat = cm.latency(geom, np.asarray(counts, np.float32))
            lat_dig += float(lat[0])
            lat_aimc += float(lat[1])
            lat_tot += float(max(lat))
        rows.append(dict(
            kind="table1", model=r["model"], objective=r["objective"],
            lam=r["lam"], acc=r["accuracy"],
            lat_ms=float(cm.cycles_to_ms(r["latency"])),
            energy=r["energy"],
            dig_util=lat_dig / max(lat_tot, 1e-9),
            aimc_util=lat_aimc / max(lat_tot, 1e-9),
            aimc_ch=r["aimc_ch"]))
    for row in rows:
        print(f"  [table1 {row['model']} {row['objective']} "
              f"lam={row['lam']:.0e}] acc={row['acc']:.4f} "
              f"lat={row['lat_ms']:.3f}ms D/A util="
              f"{row['dig_util']:.0%}/{row['aimc_util']:.0%} "
              f"A.Ch={row['aimc_ch']:.1%}")
    results.extend(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="quick", choices=list(PRESETS))
    ap.add_argument("--out", default="experiments/paper")
    ap.add_argument("--only", default=None,
                    choices=[None, "fig4", "fig5", "table1", "crossplat"])
    args = ap.parse_args(argv)
    results: list = []
    t0 = time.time()
    if args.only in (None, "fig4"):
        fig4(args.preset, results)
    if args.only in (None, "fig5"):
        fig5(args.preset, results)
    if args.only in (None, "table1"):
        table1(results)
    if args.only in (None, "crossplat"):
        crossplat(args.preset, results)
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / f"results_{args.preset}.json").write_text(
        json.dumps(results, indent=1))
    print(f"[paper_experiments] wrote {len(results)} records "
          f"in {time.time()-t0:.0f}s -> {outdir}/results_{args.preset}.json")
    return results


if __name__ == "__main__":
    main()
