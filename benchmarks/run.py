"""Benchmark harness: one function per paper table/figure + kernel and
roofline benches.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src:. python -m benchmarks.run [--preset quick]

Paper-experiment functions reuse experiments/paper/results_<preset>.json if
present (produced by benchmarks.paper_experiments), else run the quick
preset inline.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

ROOT = Path(__file__).resolve().parents[1]


def _load_or_run_paper(preset: str):
    f = ROOT / "experiments/paper" / f"results_{preset}.json"
    if f.exists():
        return json.loads(f.read_text())
    from benchmarks import paper_experiments
    return paper_experiments.main(["--preset", preset])


def _csv(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def bench_fig4_pareto(preset: str):
    """Fig. 4: accuracy vs modeled latency/energy Pareto fronts (DIANA)."""
    res = _load_or_run_paper(preset)
    odimo = [r for r in res if r["kind"] == "odimo_diana"]
    base = {r["name"]: r for r in res if r["kind"] == "baseline"}
    for r in odimo:
        _csv(f"fig4/{r['model']}/{r['objective']}/lam{r['lam']:.0e}",
             r["wall_s"] * 1e6,
             f"acc={r['accuracy']:.4f};lat={r['latency']:.4e};"
             f"energy={r['energy']:.4e};aimc_ch={r['aimc_ch']:.3f}")
    # headline paper claim: energy/latency reduction vs All-8bit at small drop
    a8 = base.get("all_8bit")
    if a8 and odimo:
        for obj, key in (("latency", "latency"), ("energy", "energy")):
            cands = [r for r in odimo if r["objective"] == obj and
                     r["accuracy"] >= a8["accuracy"] - 0.01]
            if cands:
                best = min(cands, key=lambda r: r[key])
                red = 1 - best[key] / a8[key]
                _csv(f"fig4/headline/{obj}_reduction_vs_all8bit", 0.0,
                     f"reduction={red:.1%};acc_drop="
                     f"{a8['accuracy']-best['accuracy']:+.4f}")


def bench_fig5_abstract(preset: str):
    """Fig. 5: HW-independence — abstract proportional cost models."""
    res = _load_or_run_paper(preset)
    for tag in ("abs_noshut", "abs_shut"):
        for r in [r for r in res if r["kind"] == f"odimo_{tag}"]:
            _csv(f"fig5/{tag}/lam{r['lam']:.0e}", r["wall_s"] * 1e6,
                 f"acc={r['accuracy']:.4f};energy={r['energy']:.4e};"
                 f"aimc_ch={r['aimc_ch']:.3f}")


def bench_table1_deployment(preset: str):
    """Table I: per-mapping deployment accounting (utilization, A.Ch.%)."""
    res = _load_or_run_paper(preset)
    for r in [r for r in res if r["kind"] == "table1"]:
        _csv(f"table1/{r['model']}/{r['objective']}/lam{r['lam']:.0e}", 0.0,
             f"acc={r['acc']:.4f};lat_ms={r['lat_ms']:.4f};"
             f"dig_util={r['dig_util']:.3f};aimc_util={r['aimc_util']:.3f};"
             f"aimc_ch={r['aimc_ch']:.3f}")


def bench_kernels():
    """Pallas kernels (interpret mode on CPU -> correctness + relative cost;
    us_per_call is CPU-interpret time, NOT TPU time)."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    M, K, N = 256, 512, 256

    xq = jax.random.randint(key, (M, K), -127, 128, jnp.int8)
    wq = jax.random.randint(jax.random.fold_in(key, 1), (K, N), -127, 128,
                            jnp.int8)
    sx = jnp.asarray(0.01, jnp.float32)
    sw = jnp.ones((N,), jnp.float32)

    def timeit(fn, *a, reps=3):
        fn(*a)  # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*a))
        return (time.perf_counter() - t0) / reps * 1e6

    flops = 2 * M * K * N
    us = timeit(lambda *a: ops.quant_matmul_op(*a, interpret=True),
                xq, wq, sx, sw)
    _csv("kernels/quant_matmul_w8a8", us, f"gflop={flops/1e9:.2f}")
    wt = jax.random.randint(jax.random.fold_in(key, 2), (K, N), -1, 2, jnp.int8)
    us = timeit(lambda *a: ops.ternary_matmul_op(*a, interpret=True),
                xq, wt, sx, sw)
    _csv("kernels/ternary_matmul", us, f"gflop={flops/1e9:.2f}")
    from repro.kernels.ternary_packed import pack_ternary, ternary_packed_matmul
    wp = pack_ternary(wt)
    us = timeit(lambda: ternary_packed_matmul(xq, wp, sx, sw, interpret=True))
    _csv("kernels/ternary_matmul_2bit_packed", us,
         f"gflop={flops/1e9:.2f};weight_bytes={wp.size}(4x-less)")

    x = jax.random.normal(key, (M, K), jnp.bfloat16)
    wb = jax.random.normal(jax.random.fold_in(key, 3), (K, N), jnp.bfloat16)
    us = timeit(lambda: ops.split_precision_op(x, xq, sx, wb, wq, sw, N // 2,
                                               interpret=True))
    _csv("kernels/split_precision_fused", us, f"boundary={N//2}")

    q = jax.random.normal(key, (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 4), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.fold_in(key, 5), (1, 2, 256, 64))
    us = timeit(lambda: ops.flash_attention_op(q, k, v, interpret=True))
    _csv("kernels/flash_attention_gqa", us, "shape=1x4x256x64;G=2")


def bench_roofline():
    """Dry-run roofline terms per (arch x shape) on the single-pod mesh."""
    from repro.configs import base as cfgbase
    from repro.roofline import analysis as RA
    cfgbase.load_all()
    recs = RA.load_records(ROOT / "experiments/dryrun", "sp")
    if not recs:
        print("roofline/none,0,run launch/dryrun first")
        return
    for rec in recs:
        if rec.get("status") != "ok":
            _csv(f"roofline/{rec['arch']}/{rec['shape']}", 0.0,
                 "status=skipped")
            continue
        r = RA.analyze_cell(rec)
        _csv(f"roofline/{r.arch}/{r.shape}", 0.0,
             f"t_compute={r.t_compute:.4e};t_memory={r.t_memory:.4e};"
             f"t_collective={r.t_collective:.4e};dominant={r.dominant};"
             f"useful={r.useful_ratio:.3f}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="quick")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    benches = {
        "fig4": lambda: bench_fig4_pareto(args.preset),
        "fig5": lambda: bench_fig5_abstract(args.preset),
        "table1": lambda: bench_table1_deployment(args.preset),
        "kernels": bench_kernels,
        "roofline": bench_roofline,
    }
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        fn()


if __name__ == "__main__":
    main()
