"""Planned-serving runtime benchmark: artifact -> lower -> bind -> jit'd
prefill/decode, timed end to end, written to ``BENCH_runtime.json``.

This is the repo's perf baseline for the mapping-execution hot path.  Legs:

  * ``lm:zamba2``        the ci reduced zamba2 loop (diana platform — mixed
                         ternary+int8 layers lower to the fused
                         split_ternary kernel, zero fp fallbacks; its layer
                         stacks carry ONE repeat each, so no dispatch
                         comparison — tok/s + lowering/bind cost only)
  * ``lm:yi9b_homog``    yi-9b reduced, layer stack deepened to R=6 repeats
                         sharing ONE mapping: the grouped dispatch runs a
                         single stacked gather
  * ``lm:yi9b_grouped``  same model, repeats alternating TWO mappings: the
                         grouped dispatch switches over G=2 groups where the
                         PR 3 baseline switched over R=6 branches
  * ``cnn:resnet20_tiny`` conv artifact through the im2col planned kernels
  * ``engine:yi9b_trace`` the `repro.serving` continuous-batching engine
                         replaying one mixed-length trace under the
                         "continuous" vs "static" (gang batching) admission
                         policies: total token throughput ratio + per-policy
                         p50/p95 TTFT (warmed jit caches; same greedy
                         tokens under both policies by construction)
  * ``engine:yi9b_spec`` self-speculative decoding over a two-variant
                         `repro.runtime.PlanSet` precision bank (ternary-
                         tinted draft + all-int8 target of the SAME
                         weights): acceptance rate, tokens per round, and
                         decode throughput vs target-only serving of the
                         identical trace — with token IDENTITY between the
                         two asserted (the speculative loop is an exact
                         rewrite of greedy target decoding), plus the
                         bank's prepared-weight dedup accounting
  * ``engine:yi9b_openloop`` timed OPEN-LOOP load sweep: seeded Poisson
                         arrivals at three offered loads (under / near /
                         over capacity) through a bounded admission queue —
                         TTFT p50/p95/p99, token throughput and shed rate
                         per point — plus a repeat of the overload point
                         with graceful precision degradation (p95-TTFT
                         breach routes new requests to the cheaper PlanSet
                         variant), asserting the degraded run's p95 TTFT
                         does not exceed the undegraded one
  * ``engine:yi9b_paged`` paged vs dense KV layout on the SAME engine:
                         (a) a skewed-length trace (one long prompt among
                         short ones) where the paged pool's peak in-use KV
                         bytes must undercut the dense B x max_len pool
                         while producing IDENTICAL tokens (asserted, every
                         mode — the quick run is the CI parity gate), and
                         (b) a shared-prefix trace through the prefix cache
                         recording hit counts + TTFT

The yi-9b legs run twice — ``stack_mode="grouped"`` (current) vs
``stack_mode="switch"`` (the PR 3 one-branch-per-repeat baseline) — and
record cold (trace+compile included) and warm decode throughput for both,
plus plan-lowering/bind wall time and the per-kernel layer histogram.
``decode_total_tok_s`` (tokens over cold-start + steady decode — serving
startup latency is exactly what fewer traced branches buy) is the headline;
``decode_warm_tok_s`` isolates the steady state.  Timed rounds interleave
the two modes and keep the best so machine drift cancels.

    PYTHONPATH=src python benchmarks/bench_runtime.py [--quick] \
        [--out BENCH_runtime.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def _ms(t0: float) -> float:
    return round((time.monotonic() - t0) * 1e3, 1)


def _lm_setup(arch: str, platform: str, n_layers: int | None = None):
    """(cfg, params, artifact) for a reduced LM arch with a static min-cost
    mapping emitted against its concrete weights."""
    from repro.configs import base as cfgbase
    from repro.launch.train import emit_static_mapping
    from repro.models import transformer as T

    cfgbase.load_all()
    cfg = cfgbase.reduce_for_smoke(cfgbase.get(arch))
    if n_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as td:
        art = emit_static_mapping(params, cfg, platform,
                                  Path(td) / "mapping.json")
    return cfg, params, art


def _alternate_stacked_mappings(art) -> dict:
    """Rewrite ODD repeats of every scan-stacked layer to a half/half
    digital+ternary split (the fused split_ternary shape): the stack then
    carries TWO distinct mappings tiled across the depth — G=2 groups for
    the grouped dispatch, R branches for the switch baseline."""
    doc = art.to_dict()
    for layer in doc["layers"]:
        base, _, rep = layer["name"].partition("@")
        if not rep or int(rep) % 2 == 0:
            continue
        c = len(layer["assignment"])
        layer["assignment"] = [0] * (c // 2) + [1] * (c - c // 2)
        layer["counts"] = [c // 2, c - c // 2]
    return doc


def _bench_lm(leg: str, cfg, params, artifact, *, requests: int,
              prompt_len: int, gen_len: int,
              compare=("grouped", "switch")) -> dict:
    """Lower + bind + jit'd prefill/decode, per stack mode in ``compare``
    (a single-mode leg skips the grouped-vs-switch ratios — e.g. reduced
    zamba2, whose layer stacks carry one repeat each, has no dispatch to
    compare)."""
    from repro.models import transformer as T
    from repro.models.managed import matmul_backend
    from repro.runtime import PlannedBackend, lower

    t0 = time.monotonic()
    plan = lower(artifact, params=params)
    plan_lower_ms = _ms(t0)

    rec = {"leg": leg, "model": plan.model, "platform": plan.platform,
           "layers": len(plan.layers),
           "kernel_histogram": plan.kernel_histogram(),
           "fallbacks": plan.fallback_reasons(),
           "plan_lower_ms": plan_lower_ms, "modes": {}}

    key = jax.random.PRNGKey(0)
    prompts = jax.random.randint(key, (requests, prompt_len), 0, cfg.vocab)
    steps, rounds = gen_len - 1, 3
    budget = 1 + 2 + rounds * steps               # cold + warmup + timed
    modes = {}

    class _State:                                  # per-mode decode state
        pass

    def _make_state(mode):
        st = _State()
        t0 = time.monotonic()
        st.backend = PlannedBackend(plan, params, stack_mode=mode)
        st.bind_ms = _ms(t0)
        st.caches = T.init_cache(cfg, requests, prompt_len + budget)
        st.prefill = jax.jit(lambda p, t, c: T.prefill(p, cfg, t, c,
                                                       cross_source=None))
        st.decode = jax.jit(lambda p, t, c, i: T.decode_step(p, cfg, t, c,
                                                             i))
        st.pos = prompt_len
        st.best_s = float("inf")
        return st

    def _step(st):
        logits, st.caches = st.decode(params, st.tok, st.caches, st.pos)
        st.tok = jnp.argmax(logits, axis=-1)
        st.pos += 1

    def _cold(st):
        with matmul_backend(st.backend):
            t0 = time.monotonic()
            logits, st.caches = st.prefill(params, prompts, st.caches)
            st.tok = jax.block_until_ready(jnp.argmax(logits, axis=-1))
            st.prefill_cold_ms = _ms(t0)
            t0 = time.monotonic()
            _step(st)
            jax.block_until_ready(st.tok)
            st.decode_cold_ms = _ms(t0)            # traces + compiles
            for _ in range(2):                     # settle allocator
                _step(st)
            jax.block_until_ready(st.tok)

    # throwaway pass: whatever compiles first in the process also pays
    # first-touch jit/XLA/Pallas-interpret initialization — absorb it here
    # (every leg, single-mode included) so cold/bind numbers stay
    # comparable across legs, leg order, and --legs subsets
    _cold(_make_state(compare[-1]))

    for mode in compare:
        modes[mode] = _make_state(mode)
    for mode, st in modes.items():                 # cold: trace + compile
        _cold(st)
    for _ in range(rounds):                        # timed rounds INTERLEAVE
        for mode, st in modes.items():             # modes so machine drift
            with matmul_backend(st.backend):       # cancels; keep the best
                t0 = time.monotonic()
                for _ in range(steps):
                    _step(st)
                jax.block_until_ready(st.tok)
                st.best_s = min(st.best_s, time.monotonic() - t0)

    for mode, st in modes.items():
        total_s = st.best_s + st.decode_cold_ms / 1e3
        rec["modes"][mode] = {
            "bind_ms": st.bind_ms,
            "prefill_cold_ms": st.prefill_cold_ms,
            "decode_cold_ms": st.decode_cold_ms,
            "decode_warm_tok_s": round(requests * steps
                                       / max(st.best_s, 1e-9), 2),
            "decode_total_tok_s": round(requests * (steps + 1)
                                        / max(total_s, 1e-9), 2),
        }
    g = rec["modes"]["grouped"]
    if "switch" in rec["modes"]:
        s = rec["modes"]["switch"]
        rec["grouped_vs_switch_total"] = round(
            g["decode_total_tok_s"] / max(s["decode_total_tok_s"], 1e-9), 3)
        rec["grouped_vs_switch_warm"] = round(
            g["decode_warm_tok_s"] / max(s["decode_warm_tok_s"], 1e-9), 3)
        print(f"[bench] {leg}: lower {plan_lower_ms}ms, "
              f"hist={rec['kernel_histogram']}, grouped "
              f"{g['decode_total_tok_s']} tok/s vs switch "
              f"{s['decode_total_tok_s']} tok/s "
              f"(x{rec['grouped_vs_switch_total']} total, "
              f"x{rec['grouped_vs_switch_warm']} warm)")
    else:
        print(f"[bench] {leg}: lower {plan_lower_ms}ms, "
              f"hist={rec['kernel_histogram']}, "
              f"{g['decode_total_tok_s']} tok/s total "
              f"({g['decode_warm_tok_s']} warm)")
    return rec


def _bench_cnn(leg: str, cnn_name: str, platform: str, *,
               requests: int) -> dict:
    from repro.launch.train import emit_static_mapping
    from repro.models import cnn as C
    from repro.models.managed import matmul_backend
    from repro.runtime import PlannedBackend, lower

    cfg = C.get_config(cnn_name)
    init_fn, apply_fn, plan_fn = C.get_model(cfg)
    params = init_fn(jax.random.PRNGKey(0), cfg, None)
    hints = {n: (g, s) for (n, g, s) in plan_fn(cfg)}
    with tempfile.TemporaryDirectory() as td:
        art = emit_static_mapping(params, cfg, platform,
                                  Path(td) / "mapping.json",
                                  plan_hints=hints)
    t0 = time.monotonic()
    plan = lower(art, params=params)
    plan_lower_ms = _ms(t0)
    t0 = time.monotonic()
    backend = PlannedBackend(plan, params)
    bind_ms = _ms(t0)

    x = jax.random.normal(jax.random.PRNGKey(1),
                          (requests, *cfg.img_hw, cfg.in_ch), jnp.float32)
    fwd = jax.jit(lambda p, xb: apply_fn(p, xb, cfg, None, "fp", 1.0))
    with matmul_backend(backend):
        t0 = time.monotonic()
        jax.block_until_ready(fwd(params, x))
        cold_ms = _ms(t0)
        t0 = time.monotonic()
        jax.block_until_ready(fwd(params, x))
        warm_s = time.monotonic() - t0
    rec = {"leg": leg, "model": cfg.name, "platform": platform,
           "layers": len(plan.layers),
           "kernel_histogram": plan.kernel_histogram(),
           "fallbacks": plan.fallback_reasons(),
           "plan_lower_ms": plan_lower_ms, "bind_ms": bind_ms,
           "forward_cold_ms": cold_ms,
           "forward_warm_img_s": round(requests / max(warm_s, 1e-9), 2)}
    print(f"[bench] {leg}: lower {plan_lower_ms}ms, "
          f"hist={rec['kernel_histogram']}, "
          f"{rec['forward_warm_img_s']} img/s warm")
    return rec


def _bench_engine(leg: str, *, requests: int, max_batch: int,
                  max_prompt: int, max_new: int) -> dict:
    """Continuous vs static batching over ONE mixed-length trace
    (`repro.serving` engine, yi-9b reduced, no mapping bound — the planned
    hot path is covered by the zamba2 leg; here interpret-mode Pallas would
    swamp the scheduling signal this leg measures).  Each policy serves the
    same trace twice on one engine — the first pass warms every
    (group-size, prompt-bucket) prefill trace, the second is timed — so the
    throughput ratio compares steady-state batching policy, not compile
    luck.  Headline: ``continuous_vs_static_total`` (total token throughput
    ratio) plus per-policy p50/p95 TTFT.

    The trace is DECODE-dominated by construction: prompts fit one prefill
    bucket and generation lengths are high-variance (min_new << max_new).
    That is the regime continuous batching exists for — static gangs burn
    ``max_gen - gen_i`` idle slot-steps per member, continuous refills the
    slot immediately.  (At this toy scale, per-call prefill dispatch is
    comparable to a decode step, so a prefill-dominated trace would measure
    Python/XLA call overhead — continuous does ~R single-request prefills
    where static does R/B gang prefills — not scheduling.)"""
    from repro.configs import base as cfgbase
    from repro.models import transformer as T
    from repro.serving import Engine, Scheduler, summarize, synthetic_trace

    cfgbase.load_all()
    cfg = cfgbase.reduce_for_smoke(cfgbase.get("yi-9b"))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    trace = synthetic_trace(requests, vocab=cfg.vocab, min_prompt=4,
                            max_prompt=max_prompt, min_new=2,
                            max_new=max_new, seed=7)
    max_len = max(r.prompt_len + r.max_new_tokens for r in trace)
    rec = {"leg": leg, "model": cfg.name, "requests": requests,
           "max_batch": max_batch, "max_len": max_len, "policies": {}}
    token_sets = {}
    for policy in ("static", "continuous"):
        eng = Engine(cfg, params, max_batch=max_batch, max_len=max_len,
                     scheduler=Scheduler(policy))
        eng.run(trace)                        # warm every prefill bucket
        results = eng.run(trace)              # timed pass
        summ = summarize(results, eng.stats["wall_s"])
        summ["decode_steps"] = eng.stats["decode_steps"]
        rec["policies"][policy] = summ
        token_sets[policy] = [r.tokens for r in results]
        print(f"[bench] {leg}[{policy}]: {summ['total_tok_s']} tok/s, "
              f"ttft p50 {summ['ttft_p50_s'] * 1e3:.0f}ms / "
              f"p95 {summ['ttft_p95_s'] * 1e3:.0f}ms, "
              f"{summ['decode_steps']} decode steps")
    assert token_sets["static"] == token_sets["continuous"], \
        "batching policy changed greedy tokens"
    c, s = rec["policies"]["continuous"], rec["policies"]["static"]
    rec["continuous_vs_static_total"] = round(
        c["total_tok_s"] / max(s["total_tok_s"], 1e-9), 3)
    rec["continuous_vs_static_ttft_p95"] = round(
        s["ttft_p95_s"] / max(c["ttft_p95_s"], 1e-9), 3)
    print(f"[bench] {leg}: continuous x{rec['continuous_vs_static_total']} "
          f"total throughput vs static "
          f"(p95 TTFT x{rec['continuous_vs_static_ttft_p95']} lower)")
    return rec


def _bench_engine_paged(leg: str, *, quick: bool) -> dict:
    """Paged-vs-dense KV layout on the serving engine (yi-9b reduced).

    Skewed trace: one ``long_prompt`` request among short ones — the dense
    layout allocates B x max_len up front (peak == capacity) while the
    paged pool's peak tracks tokens actually in flight.  Token parity
    between the layouts is ASSERTED in every mode (the --quick run is the
    CI gate for it); the full run additionally asserts the >= 2x peak-KV
    reduction the skew buys.  Shared-prefix trace: every prompt opens with
    the same system prefix — later admissions map the first request's
    pages (cold pass records the hit counts; a second, fully-resident pass
    records warmed TTFT)."""
    from repro.configs import base as cfgbase
    from repro.models import transformer as T
    from repro.serving import Engine, summarize, synthetic_trace

    cfgbase.load_all()
    cfg = cfgbase.reduce_for_smoke(cfgbase.get("yi-9b"))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    n, B = (6, 2) if quick else (12, 4)
    long_prompt = 32 if quick else 128
    skew = synthetic_trace(n, vocab=cfg.vocab, min_prompt=4, max_prompt=8,
                           min_new=2, max_new=6, seed=13,
                           long_every=n, long_prompt=long_prompt)
    max_len = max(r.prompt_len + r.max_new_tokens for r in skew)
    rec = {"leg": leg, "model": cfg.name, "requests": n, "max_batch": B,
           "max_len": max_len, "page_size": 8, "layouts": {}}

    mk = lambda layout: Engine(cfg, params, max_batch=B, max_len=max_len,
                               kv_layout=layout, page_size=8)
    token_sets = {}
    for layout in ("dense", "paged"):
        eng = mk(layout)
        eng.run(skew)                         # warm the jitted steps
        results = eng.run(skew)               # timed pass
        summ = summarize(results, eng.stats["wall_s"])
        summ["kv_peak_bytes"] = eng.stats["kv_peak_bytes"]
        summ["kv_capacity_bytes"] = eng.stats["kv_capacity_bytes"]
        rec["layouts"][layout] = summ
        token_sets[layout] = [r.tokens for r in results]
        print(f"[bench] {leg}[{layout}]: {summ['total_tok_s']} tok/s, "
              f"peak kv {summ['kv_peak_bytes']} / "
              f"capacity {summ['kv_capacity_bytes']} bytes")
    assert token_sets["paged"] == token_sets["dense"], \
        "paged layout changed greedy tokens vs dense"
    rec["paged_token_parity"] = True
    rec["dense_vs_paged_peak_kv"] = round(
        rec["layouts"]["dense"]["kv_peak_bytes"]
        / max(rec["layouts"]["paged"]["kv_peak_bytes"], 1), 3)
    if not quick:
        assert rec["dense_vs_paged_peak_kv"] >= 2.0, rec
    print(f"[bench] {leg}: token parity ok, paged peak KV "
          f"x{rec['dense_vs_paged_peak_kv']} below dense on the skewed "
          f"trace")

    shared = 24
    pre = synthetic_trace(n, vocab=cfg.vocab, min_prompt=4, max_prompt=8,
                          min_new=2, max_new=6, seed=17,
                          shared_prefix=shared)
    eng = mk("paged")
    eng.run(pre)                              # cold: first sharer populates
    cold = {k: eng.stats[k] for k in
            ("prefix_lookups", "prefix_hit_requests", "prefix_hit_tokens",
             "cow_copies", "page_evictions")}
    prompt_tokens = sum(r.prompt_len for r in pre)
    results = eng.run(pre)                    # warmed: fully resident
    summ = summarize(results, eng.stats["wall_s"])
    rec["prefix"] = {
        "shared_prefix": shared, "prompt_tokens": prompt_tokens,
        "cold": cold,
        "cold_hit_rate": round(cold["prefix_hit_tokens"] / prompt_tokens, 3),
        # pool stats are cumulative across runs on one engine
        "warm_hit_tokens": eng.stats["prefix_hit_tokens"]
        - cold["prefix_hit_tokens"],
        "warm_ttft_p50_s": summ["ttft_p50_s"],
        "warm_ttft_p95_s": summ["ttft_p95_s"],
        "warm_total_tok_s": summ["total_tok_s"],
    }
    assert cold["prefix_hit_tokens"] > 0, "shared-prefix trace missed cache"
    print(f"[bench] {leg}[prefix]: prefix_hit_tokens="
          f"{cold['prefix_hit_tokens']}/{prompt_tokens} cold "
          f"({cold['prefix_hit_requests']} requests, "
          f"{cold['cow_copies']} cow), warm ttft p50 "
          f"{summ['ttft_p50_s'] * 1e3:.0f}ms")
    return rec


def _bench_engine_spec(leg: str, *, quick: bool) -> dict:
    """Self-speculative decoding vs target-only serving on ONE PlanSet
    precision bank (yi-9b reduced, diana).

    The bank binds two variants of the same weights: an all-int8 "target"
    and a 5%-ternary "draft" (`emit_static_mapping` ``bias``).  The
    speculative engine drafts ``draft_k`` tokens per round under the draft
    variant and verifies them in one target-variant chunk; the target-only
    engine decodes the same trace sequentially under the same bank.  Token
    identity between the two is ASSERTED every run (speculation is an
    exact rewrite of greedy target decoding, not an approximation);
    recorded: acceptance rate, committed tokens per round, per-engine
    decode throughput and their ratio, and the bank's prepared-weight
    dedup accounting."""
    from repro.configs import base as cfgbase
    from repro.launch.train import emit_static_mapping
    from repro.models import transformer as T
    from repro.runtime import PlanSet, lower
    from repro.serving import Engine, summarize, synthetic_trace

    cfgbase.load_all()
    cfg = cfgbase.reduce_for_smoke(cfgbase.get("yi-9b"))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as td:
        target = emit_static_mapping(params, cfg, "diana",
                                     Path(td) / "target.json",
                                     act_log_scale=2.0,
                                     bias=("digital", 1.0))
    # draft: the target mapping with 5% of every FFN layer's channels
    # pushed to the ternary aimc domain — attention stacks and the head
    # stay byte-identical, so the bank dedups their prepared buffers
    draft = target.to_dict()
    for layer in draft["layers"]:
        if "/ffn/" not in layer["name"]:
            continue
        a = list(layer["assignment"])
        k = max(1, round(0.05 * len(a)))
        layer["assignment"] = [1] * k + a[k:]
        layer["counts"] = [len(a) - k, k]
    bank = PlanSet({"target": lower(target, params=params),
                    "draft": lower(draft, params=params)},
                   params, default="target")
    mem = bank.memory_report()

    n, B = (4, 2) if quick else (8, 4)
    draft_k = 4
    trace = synthetic_trace(n, vocab=cfg.vocab, min_prompt=4, max_prompt=8,
                            min_new=4, max_new=(8 if quick else 16), seed=23)
    max_len = max(r.prompt_len + r.max_new_tokens for r in trace)
    rec = {"leg": leg, "model": cfg.name, "requests": n, "max_batch": B,
           "max_len": max_len, "draft_k": draft_k,
           "planset_memory": {k: mem[k] for k in
                              ("prepared_bytes", "sum_variant_bytes",
                               "dedup_saved_bytes")},
           "modes": {}}
    rec["planset_memory"]["shared_layers"] = len(mem["shared_layers"])

    mk = {
        "target_only": lambda: Engine(cfg, params, max_batch=B,
                                      max_len=max_len, backend=bank,
                                      kv_layout="paged"),
        "speculative": lambda: Engine(cfg, params, max_batch=B,
                                      max_len=max_len, backend=bank,
                                      kv_layout="paged",
                                      speculate=("draft", "target"),
                                      draft_k=draft_k),
    }
    token_sets = {}
    for mode, make in mk.items():
        eng = make()
        eng.run(trace)                        # warm the jitted steps
        results = eng.run(trace)              # timed pass
        summ = summarize(results, eng.stats["wall_s"])
        if mode == "speculative":
            for k in ("spec_rounds", "spec_acceptance",
                      "spec_tokens_per_round"):
                summ[k] = eng.stats[k]
        rec["modes"][mode] = summ
        token_sets[mode] = [r.tokens for r in results]
        print(f"[bench] {leg}[{mode}]: {summ['total_tok_s']} tok/s")
    assert token_sets["speculative"] == token_sets["target_only"], \
        "speculative decoding changed greedy tokens vs target-only"
    rec["spec_token_parity"] = True
    sp = rec["modes"]["speculative"]
    rec["spec_vs_target_total"] = round(
        sp["total_tok_s"]
        / max(rec["modes"]["target_only"]["total_tok_s"], 1e-9), 3)
    assert sp["spec_acceptance"] > 0, "draft never agreed with target"
    print(f"[bench] {leg}: token parity ok, acceptance="
          f"{sp['spec_acceptance']} tokens/round="
          f"{sp['spec_tokens_per_round']} "
          f"(x{rec['spec_vs_target_total']} vs target-only), bank saved "
          f"{rec['planset_memory']['dedup_saved_bytes']} prepared bytes "
          f"({rec['planset_memory']['shared_layers']} shared layers)")
    return rec


def _bench_engine_openloop(leg: str, *, quick: bool) -> dict:
    """Open-loop Poisson load sweep + graceful-degradation comparison
    (yi-9b reduced, gpu_tc_like two-variant bank).

    Arrivals are a seeded Poisson process at a FIXED offered load
    (req/engine-step) — the open-loop discipline where overload shows up
    as queue growth, not back-pressured arrivals.  Three load points
    (under capacity, near capacity, overload) record the TTFT tail
    (p50/p95/p99), token throughput, and shed rate under a bounded
    admission queue (``max_queue_depth`` — overload SHEDS instead of
    queueing forever; the CI smoke leg asserts the overload point sheds).

    At the overload point the run is repeated with graceful PRECISION
    DEGRADATION enabled: a breached sliding-p95 TTFT target routes new
    requests to the bank's cheaper variant until the tail recovers.  The
    record asserts the degraded run's p95 TTFT does not exceed the
    undegraded one — the paper's precision/latency trade applied as a
    serving-time control loop.

    HONEST CAVEAT on which variant is "cheap": on real tensor cores the
    int8 domain is the fast one, but this benchmark runs Pallas kernels
    in CPU interpret mode, where the fp16 domain lowers to KERNEL_FP — a
    plain XLA matmul — and is therefore the wall-clock-cheap variant,
    while the int8 quant kernels pay interpret-mode overhead.  So the
    bank here serves ``default`` = all-int8 (expensive on this host) and
    ``cheap`` = all-fp16; the control loop being measured (breach ->
    route to the cheaper variant -> p95 bounded -> recover) is the same
    one a GPU deployment would run with the roles reversed."""
    from repro.configs import base as cfgbase
    from repro.launch.train import emit_static_mapping
    from repro.models import transformer as T
    from repro.runtime import PlanSet, lower
    from repro.serving import (Engine, ShedResult, poisson_arrivals,
                               summarize, synthetic_trace)

    cfgbase.load_all()
    cfg = cfgbase.reduce_for_smoke(cfgbase.get("yi-9b"))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as td:
        int8 = emit_static_mapping(params, cfg, "gpu_tc_like",
                                   Path(td) / "int8.json",
                                   act_log_scale=2.0,
                                   bias=("tc_int8", 1.0))
        fp16 = emit_static_mapping(params, cfg, "gpu_tc_like",
                                   Path(td) / "fp16.json",
                                   act_log_scale=2.0,
                                   bias=("tc_fp16", 1.0))
    bank = PlanSet({"default": lower(int8, params=params),
                    "cheap": lower(fp16, params=params)},
                   params, default="default")

    n, B = (12, 2) if quick else (20, 2)
    max_new = 8 if quick else 12
    rates = (0.1, 0.4, 2.0)
    depth = 6   # deep enough that overload queues (and so benefits from
    #             faster drain under degradation) before it sheds
    base = synthetic_trace(n, vocab=cfg.vocab, min_prompt=4, max_prompt=8,
                           min_new=4, max_new=max_new, seed=31)
    max_len = max(r.prompt_len + r.max_new_tokens for r in base)

    def run_point(rate, degrade=None):
        trace = poisson_arrivals(base, rate, seed=31)
        kw = dict(max_batch=B, max_len=max_len, backend=bank,
                  kv_layout="paged", page_size=8, max_queue_depth=depth,
                  prefix_cache=False)   # same cache policy in every mode
        if degrade is not None:   # degrade = TTFT target (seconds)
            kw.update(degrade_to="cheap", ttft_target_s=degrade,
                      degrade_window=4)
        eng = Engine(cfg, params, **kw)
        eng.run(trace)                   # warm the jitted steps
        results = eng.run(trace)         # timed pass
        summ = summarize(results, eng.stats["wall_s"])
        summ["offered_load_req_per_step"] = rate
        summ["degrade_transitions"] = eng.stats["degrade_transitions"]
        assert not any(isinstance(r, ShedResult) and r.reason == "fault"
                       for r in results)
        return summ

    rec = {"leg": leg, "model": cfg.name, "requests": n, "max_batch": B,
           "max_len": max_len, "max_queue_depth": depth,
           "variants": {"default": "tc_int8 (interpret-mode quant kernels)",
                        "cheap": "tc_fp16 (KERNEL_FP plain matmul)"},
           "load_sweep": [], "degradation": {}}
    for rate in rates:
        summ = run_point(rate)
        rec["load_sweep"].append(summ)
        print(f"[bench] {leg}[load={rate}]: "
              f"ttft p50/p95/p99 {summ['ttft_p50_s']}/{summ['ttft_p95_s']}"
              f"/{summ['ttft_p99_s']}s shed_rate={summ['shed_rate']} "
              f"({summ['total_tok_s']} tok/s)")
    overload = rec["load_sweep"][-1]
    assert overload["shed"] > 0, \
        "overload point shed nothing: the queue bound is not binding"

    # the TTFT target to defend: half the overloaded median (adaptive —
    # absolute tails are host-dependent).  Well above the unloaded TTFT
    # (no spurious degradation at sane load) yet breached EARLY in the
    # overload run, so most of its tail is served on the cheap variant.
    target_s = max(0.5 * overload["ttft_p50_s"], 1e-3)
    rec["degrade_ttft_target_s"] = target_s
    degraded = run_point(rates[-1], degrade=target_s)
    rec["degradation"] = {"no_degrade": overload, "degrade": degraded}
    rec["degradation"]["p95_ttft_ratio"] = round(
        degraded["ttft_p95_s"] / max(overload["ttft_p95_s"], 1e-9), 3)
    assert degraded["degrade_transitions"] >= 1 and degraded["degraded"] > 0, \
        "degradation never engaged at the overload point"
    assert degraded["ttft_p95_s"] <= overload["ttft_p95_s"], (
        f"degradation failed to bound p95 TTFT: "
        f"{degraded['ttft_p95_s']} > {overload['ttft_p95_s']}")
    print(f"[bench] {leg}: degradation bounds p95 ttft "
          f"{overload['ttft_p95_s']}s -> {degraded['ttft_p95_s']}s "
          f"(x{rec['degradation']['p95_ttft_ratio']}, "
          f"{degraded['degraded']} requests served degraded, "
          f"shed {overload['shed_rate']} -> {degraded['shed_rate']})")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller batch/seq/gen (the ci_smoke.sh leg)")
    ap.add_argument("--out", default="BENCH_runtime.json")
    ap.add_argument("--legs", default="all",
                    help="comma list: zamba2,yi9b,cnn,engine,paged,spec,"
                         "openloop (default all)")
    args = ap.parse_args(argv)

    requests, prompt_len, gen_len = (2, 8, 4) if args.quick else (4, 16, 12)
    legs = (["zamba2", "yi9b", "cnn", "engine", "paged", "spec", "openloop"]
            if args.legs == "all" else args.legs.split(","))
    results = []

    if "zamba2" in legs:
        cfg, params, art = _lm_setup("zamba2-1.2b", "diana")
        results.append(_bench_lm("lm:zamba2", cfg, params, art,
                                 requests=requests, prompt_len=prompt_len,
                                 gen_len=gen_len, compare=("grouped",)))
    if "yi9b" in legs:
        cfg, params, art = _lm_setup("yi-9b", "diana", n_layers=6)
        results.append(_bench_lm("lm:yi9b_homog", cfg, params, art,
                                 requests=requests, prompt_len=prompt_len,
                                 gen_len=gen_len))
        results.append(_bench_lm("lm:yi9b_grouped", cfg, params,
                                 _alternate_stacked_mappings(art),
                                 requests=requests, prompt_len=prompt_len,
                                 gen_len=gen_len))
    if "cnn" in legs:
        results.append(_bench_cnn("cnn:resnet20_tiny", "resnet20_tiny",
                                  "diana", requests=requests))
    if "engine" in legs:
        results.append(_bench_engine(
            "engine:yi9b_trace",
            requests=(6 if args.quick else 16),
            max_batch=(2 if args.quick else 4),
            max_prompt=8,
            max_new=(12 if args.quick else 24)))
    if "paged" in legs:
        results.append(_bench_engine_paged("engine:yi9b_paged",
                                           quick=args.quick))
    if "spec" in legs:
        results.append(_bench_engine_spec("engine:yi9b_spec",
                                          quick=args.quick))
    if "openloop" in legs:
        results.append(_bench_engine_openloop("engine:yi9b_openloop",
                                              quick=args.quick))

    doc = {
        "bench": "runtime_planned_serving",
        "quick": bool(args.quick),
        "settings": {"requests": requests, "prompt_len": prompt_len,
                     "gen_len": gen_len},
        "env": {"jax": jax.__version__,
                "backend": jax.default_backend(),
                "interpret_pallas": jax.default_backend() == "cpu"},
        "legs": results,
    }
    out = Path(args.out)
    out.write_text(json.dumps(doc, indent=1))
    print(f"[bench] wrote {out}")
    return doc


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
