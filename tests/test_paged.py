"""Paged KV-cache tests: allocator/prefix-cache mechanics, the paged
update/gather primitives, and the engine-level acceptance criteria of the
paged-KV ISSUE — paged-vs-dense token parity on mixed-length traces (fp and
planned), chunked prefill of prompts longer than the chunk, prefix-cache
hits with copy-on-write, bounded retrace counts, peak-KV savings on skewed
traffic, and deterministic trace replay.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models import attention as A
from repro.models import transformer as T
from repro.serving import Engine, Request, save_trace, synthetic_trace
from repro.serving.paged import PagePool


@pytest.fixture(scope="module", autouse=True)
def _load():
    cfgbase.load_all()


def _reduced(arch):
    return cfgbase.reduce_for_smoke(cfgbase.get(arch))


def _mixed_reqs(cfg, shapes, seed=9, prefix=0):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab, size=prefix) if prefix else None
    reqs = []
    for i, (plen, new) in enumerate(shapes):
        p = rng.integers(0, cfg.vocab, size=plen)
        if pre is not None:
            p = np.concatenate([pre, p])
        reqs.append(Request(rid=i, prompt=p, max_new_tokens=new))
    return reqs


# --------------------------------------------------------------------------
# PagePool mechanics (no model)
# --------------------------------------------------------------------------

def test_pagepool_alloc_refcount_exhaustion():
    pool = PagePool(num_pages=4, page_size=8)
    assert pool.available() == 4 and pool.pages_for(17) == 3
    a = pool.alloc(3)
    assert len(set(a)) == 3 and 0 not in a        # never hands out trash
    assert pool.in_use == 3 and pool.available() == 1
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(2)
    pool.release(a[:2])
    assert pool.available() == 3 and pool.in_use == 1
    with pytest.raises(RuntimeError, match="unreferenced"):
        pool.decref(a[0])                         # double free
    assert pool.stats["peak_pages"] == 3


def test_pagepool_lru_cache_survives_release_then_evicts():
    pool = PagePool(num_pages=2, page_size=4)
    prompt = np.arange(4, dtype=np.int32)
    (pg,) = pool.alloc(1)
    (key, end) = pool.prompt_keys(prompt)[0]
    assert end == 4
    pool.register(pg, key)
    pool.release([pg])
    # hashed page parks in the LRU (still matchable), not the free list
    assert pool.available() == 2 and pool.in_use == 0
    hit_len, shared, cow = pool.match(prompt)
    assert hit_len == 3 and shared == [] and cow == pg   # capped at plen-1
    pool.release_cow(cow)
    pool.release([])  # no-op
    # exhausting the free list evicts the cached page and drops its key
    both = pool.alloc(2)
    assert pg in both and pool.stats["evictions"] == 1
    assert pool.match(prompt)[0] == 0


def test_pagepool_match_chain_shared_plus_cow():
    pool = PagePool(num_pages=8, page_size=4)
    prompt = np.arange(8, dtype=np.int32)
    pages = pool.alloc(2)
    for (key, end), pg in zip(pool.prompt_keys(prompt), pages):
        pool.register(pg, key)
    hit_len, shared, cow = pool.match(prompt)     # identical second prompt
    assert hit_len == 7                           # plen-1: one token redone
    assert shared == [pages[0]] and cow == pages[1]
    assert pool.ref[pages[0]] == 2 and pool.ref[pages[1]] == 2
    pool.release_cow(cow)
    s = pool.stats
    assert (s["hit_requests"], s["hit_tokens"], s["cow_copies"]) == (1, 7, 1)
    # a prompt diverging inside page 0 matches nothing
    other = prompt.copy()
    other[1] += 1
    assert pool.match(other)[0] == 0


def test_pagepool_partial_tail_key():
    pool = PagePool(num_pages=4, page_size=4)
    prompt = np.arange(6, dtype=np.int32)         # 1 full + 1 partial page
    keys = pool.prompt_keys(prompt)
    assert [end for _, end in keys] == [4, 6]
    assert keys[1][0][0] == "p"
    pages = pool.alloc(2)
    for (key, _), pg in zip(keys, pages):
        pool.register(pg, key)
    hit_len, shared, cow = pool.match(prompt)
    assert hit_len == 5 and shared == [pages[0]] and cow == pages[1]
    pool.release_cow(cow)


# --------------------------------------------------------------------------
# paged_update / paged_gather primitives
# --------------------------------------------------------------------------

def test_paged_update_gather_roundtrip_and_trash():
    ps, W, B, F = 4, 3, 2, 2
    rows = 5                                       # 4 real pages + trash
    pool = jnp.zeros((rows, ps, F))
    # slot 0 -> pages [1,2,3], slot 1 -> pages [4, unmapped, unmapped]
    pages = jnp.asarray([[1, 2, 3], [4, 0, 0]], jnp.int32)
    val = jnp.arange(B * 3 * F, dtype=jnp.float32).reshape(B, 3, F) + 1.0
    mask = jnp.asarray([[True, True, True], [True, True, False]])
    out = A.paged_update(pool, val, pages, jnp.asarray([2, 0]), mask=mask)
    got = A.paged_gather(out, pages)               # (B, W*ps, F)
    assert got.shape == (B, W * ps, F)
    np.testing.assert_array_equal(np.asarray(got[0, 2:5]),
                                  np.asarray(val[0]))
    np.testing.assert_array_equal(np.asarray(got[1, 0:2]),
                                  np.asarray(val[1, :2]))
    # masked write landed in the trash page, not the slot's view
    assert float(jnp.abs(got[1, 2]).sum()) == 0.0
    # slot 1's unmapped tail reads the (all-zero after masked writes only
    # partially dirty it) trash page — positions >= kv_len are masked by
    # attention anyway; here just check the writes didn't cross slots
    assert float(jnp.abs(got[0, :2]).sum()) == 0.0


def test_paged_update_out_of_table_positions_go_to_trash():
    ps, F = 2, 1
    pool = jnp.zeros((3, ps, F))
    pages = jnp.asarray([[1, 2]], jnp.int32)       # W*ps = 4 capacity
    val = jnp.ones((1, 3, F))
    out = A.paged_update(pool, val, pages, jnp.asarray([3]))  # pos 3,4,5
    got = A.paged_gather(out, pages)
    np.testing.assert_array_equal(np.asarray(got[0, :, 0]),
                                  [0, 0, 0, 1])    # only pos 3 in range


# --------------------------------------------------------------------------
# engine: paged vs dense token parity (the acceptance criterion)
# --------------------------------------------------------------------------

SHAPES = [(6, 3), (2, 6), (9, 2), (4, 4), (3, 3)]  # PR-5 parity shapes


@pytest.mark.parametrize("arch", ["yi-9b", "zamba2-1.2b"])
def test_engine_paged_vs_dense_parity_fp(arch):
    """Paged chunked-prefill serving is token-identical to the dense ragged
    layout on the PR-5 mixed-length parity trace — attention-only AND
    hybrid recurrent archs (chunk boundaries cross recurrent state)."""
    cfg = _reduced(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_reqs(cfg, SHAPES)
    dense = Engine(cfg, params, max_batch=2, max_len=16, kv_layout="dense")
    res_d = dense.run(reqs)
    paged = Engine(cfg, params, max_batch=2, max_len=16, kv_layout="paged",
                   page_size=4, prefill_chunk=4)
    res_p = paged.run(reqs)
    assert [r.tokens for r in res_p] == [r.tokens for r in res_d]
    assert [r.finish_reason for r in res_p] == \
        [r.finish_reason for r in res_d]


@pytest.mark.slow
def test_engine_paged_vs_dense_parity_planned(tmp_path):
    """Same parity with the planned diana backend bound (zero fp
    fallbacks): paging must not perturb planned kernel execution."""
    from repro.launch.serve import plan_mapping_execution
    from repro.launch.train import emit_static_mapping
    cfg = _reduced("zamba2-1.2b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    art = emit_static_mapping(params, cfg, "diana", tmp_path / "m.json",
                              act_log_scale=2.0)
    plan, backend = plan_mapping_execution(params, art)
    assert "fp" not in plan.kernel_histogram()
    reqs = _mixed_reqs(cfg, [(7, 4), (3, 5), (8, 3), (5, 4)], seed=5)
    dense = Engine(cfg, params, max_batch=2, max_len=16, kv_layout="dense",
                   backend=backend)
    res_d = dense.run(reqs)
    paged = Engine(cfg, params, max_batch=2, max_len=16, kv_layout="paged",
                   page_size=4, backend=backend)
    res_p = paged.run(reqs)
    assert [r.tokens for r in res_p] == [r.tokens for r in res_d]
    assert not backend.runtime_declines


def test_engine_chunked_prefill_long_prompt_interleaves():
    """A prompt much longer than prefill_chunk streams in over several
    steps and still matches per-request generation; short requests admitted
    alongside decode while it streams."""
    from repro.launch.serve import serve_batch
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    long_p = rng.integers(0, cfg.vocab, size=21)
    short_p = rng.integers(0, cfg.vocab, size=3)
    reqs = [Request(rid="long", prompt=long_p, max_new_tokens=3),
            Request(rid="short", prompt=short_p, max_new_tokens=4)]
    eng = Engine(cfg, params, max_batch=2, max_len=32, kv_layout="paged",
                 page_size=4, prefill_chunk=4)
    res = {r.rid: r for r in eng.run(reqs)}
    # 21 tokens / chunk 4 -> 6 chunk steps for the long prompt
    assert eng.stats["prefill_calls"] >= 6
    for r in reqs:
        gen, _ = serve_batch(cfg, params, jnp.asarray(r.prompt)[None],
                             gen_len=r.max_new_tokens)
        assert res[r.rid].tokens == list(np.asarray(gen)[0]), r.rid
    # the chunk step traced ONCE despite variable fill positions
    assert eng.trace_counts["chunk"] == 1
    assert eng.trace_counts["decode"] == 1


def test_engine_paged_admits_prompt_beyond_dense_max_len():
    """Admission is page-capacity based: a prompt dense rejects
    (prompt_len >= max_len) is servable when the page-rounded slot capacity
    covers it."""
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    req = Request(rid=0, prompt=np.arange(10, dtype=np.int32) % cfg.vocab,
                  max_new_tokens=2)
    dense = Engine(cfg, params, max_batch=1, max_len=10, kv_layout="dense")
    with pytest.raises(ValueError, match="max_len"):
        dense.run([req])
    paged = Engine(cfg, params, max_batch=1, max_len=10, kv_layout="paged",
                   page_size=4)                    # slot capacity 12
    res = paged.run([req])
    assert len(res[0].tokens) == 2


# --------------------------------------------------------------------------
# prefix caching through the engine
# --------------------------------------------------------------------------

def test_engine_prefix_cache_hits_cow_and_parity():
    """Two requests sharing a system prefix: the second's prefill reuses
    the first's pages (nonzero hit tokens, one COW tail copy) and tokens
    are identical to a prefix-cache-off run."""
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_reqs(cfg, [(5, 4), (6, 4)], seed=1, prefix=10)
    assert np.array_equal(reqs[0].prompt[:10], reqs[1].prompt[:10])
    reqs.append(Request(rid=2, prompt=reqs[0].prompt.copy(),
                        max_new_tokens=3))
    mk = lambda pc: Engine(cfg, params, max_batch=1, max_len=32,
                           kv_layout="paged", page_size=4,
                           prefix_cache=pc)
    on = mk(True)
    assert on.prefix_cache
    res_on = on.run(reqs)
    # req1 shares the 10-token prefix's 2 FULL pages (8 tokens); req2 is
    # token-identical to req0, so it hits all but the last prompt token
    # (14 of 15) — the partially covered tail page arrives via one COW copy
    assert on.stats["prefix_hit_requests"] == 2
    assert on.stats["prefix_hit_tokens"] == 8 + 14
    assert on.stats["cow_copies"] == 1
    off = mk(False)
    res_off = off.run(reqs)
    assert off.stats["prefix_hit_tokens"] == 0
    assert [r.tokens for r in res_on] == [r.tokens for r in res_off]


def test_engine_prefix_cache_survives_non_overlapping_requests():
    """max_batch=1 forces the sharers to never overlap in time: the LRU
    parking of hashed pages still yields hits for the second request."""
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_reqs(cfg, [(4, 2), (4, 2)], seed=2, prefix=8)
    eng = Engine(cfg, params, max_batch=1, max_len=16, kv_layout="paged",
                 page_size=4)
    eng.run(reqs)
    assert eng.stats["prefix_hit_tokens"] >= 8


def test_engine_prefix_cache_gated_off_for_recurrent_archs():
    """Hybrid/recurrent archs carry non-page-resident state — prefix
    sharing is auto-disabled even when requested."""
    cfg = _reduced("zamba2-1.2b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_batch=2, max_len=16, kv_layout="paged",
                 prefix_cache=True)
    assert not eng.prefix_cache
    cfg2 = _reduced("yi-9b")
    params2 = T.init_lm(jax.random.PRNGKey(0), cfg2)
    assert Engine(cfg2, params2, kv_layout="paged").prefix_cache


# --------------------------------------------------------------------------
# retrace bounds / memory accounting / determinism (satellites)
# --------------------------------------------------------------------------

def test_dense_prefill_retraces_are_bucket_bounded():
    """Dense admission pads prompt length AND group size to powers of two:
    a mixed trace may retrace prefill at most (log2 length buckets x log2
    group buckets) times, decode exactly once."""
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    trace = synthetic_trace(10, vocab=cfg.vocab, min_prompt=2, max_prompt=14,
                            min_new=2, max_new=5, seed=7)
    eng = Engine(cfg, params, max_batch=4, max_len=16, kv_layout="dense",
                 prefill_bucket=4)
    eng.run(trace)
    n_len_buckets = 3                           # 4, 8, 16
    n_group_buckets = 3                         # 1, 2, 4
    assert 1 <= eng.trace_counts["prefill"] <= n_len_buckets * n_group_buckets
    assert eng.trace_counts["decode"] == 1


def test_paged_peak_kv_drops_on_skewed_trace():
    """Skewed-length traffic (one long prompt among short ones): the paged
    pool's peak in-use bytes stay well under the dense B x max_len
    capacity, at identical tokens."""
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    trace = synthetic_trace(6, vocab=cfg.vocab, min_prompt=2, max_prompt=6,
                            min_new=2, max_new=4, seed=4,
                            long_every=6, long_prompt=40)
    dense = Engine(cfg, params, max_batch=4, max_len=48, kv_layout="dense")
    res_d = dense.run(trace)
    paged = Engine(cfg, params, max_batch=4, max_len=48, kv_layout="paged",
                   page_size=4)
    res_p = paged.run(trace)
    assert [r.tokens for r in res_p] == [r.tokens for r in res_d]
    assert paged.stats["kv_capacity_bytes"] == dense.stats[
        "kv_capacity_bytes"]                     # same worst-case pool
    assert paged.stats["kv_peak_bytes"] * 2 <= dense.stats["kv_peak_bytes"]


def test_trace_replay_deterministic_and_byte_identical(tmp_path):
    """Satellite: a fixed-seed synthetic trace serializes byte-identically
    across runs, and replaying it through the engine twice produces
    identical tokens and finish reasons."""
    mk = lambda: synthetic_trace(6, vocab=97, min_prompt=3, max_prompt=9,
                                 min_new=2, max_new=5, seed=11,
                                 arrival_every=1, shared_prefix=4)
    p1 = save_trace(tmp_path / "a.jsonl", mk())
    p2 = save_trace(tmp_path / "b.jsonl", mk())
    assert p1.read_bytes() == p2.read_bytes()
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    trace = mk()
    runs = [Engine(cfg, params, max_batch=2, max_len=16).run(trace)
            for _ in range(2)]
    assert [r.tokens for r in runs[0]] == [r.tokens for r in runs[1]]
    assert [r.finish_reason for r in runs[0]] == \
        [r.finish_reason for r in runs[1]]
