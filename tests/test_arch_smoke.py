"""Per-architecture smoke tests: REDUCED configs, one forward + train-grad +
prefill/decode step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only via the dry-run (launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import transformer as T

ARCHS = [
    "command-r-35b", "nemotron-4-15b", "yi-9b", "h2o-danube-3-4b",
    "llama-3.2-vision-11b", "seamless-m4t-large-v2", "xlstm-1.3b",
    "arctic-480b", "deepseek-v2-lite-16b", "zamba2-1.2b",
]

B, S = 2, 16


def _batch(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab),
    }
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            k3, (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def rcfgs():
    base.load_all()
    return {n: base.reduce_for_smoke(base.get(n)) for n in ARCHS}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch, rcfgs):
    cfg = rcfgs[arch]
    key = jax.random.PRNGKey(0)
    params = T.init_lm(key, cfg)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(
        lambda p: T.lm_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode(arch, rcfgs):
    cfg = rcfgs[arch]
    key = jax.random.PRNGKey(1)
    params = T.init_lm(key, cfg)
    batch = _batch(cfg, key)
    S_max = S + 4
    caches = T.init_cache(cfg, B, S_max)
    cross = batch.get("frontend")
    logits, caches = T.prefill(params, cfg, batch["tokens"], caches,
                               cross_source=cross)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    tok = jnp.argmax(logits, -1)
    for i in range(2):
        logits, caches = T.decode_step(params, cfg, tok, caches, S + i)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), (arch, i)
        tok = jnp.argmax(logits, -1)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill_continuation(arch, rcfgs):
    """Decoding token S given a prefill of S tokens must equal prefilling
    S+1 tokens (cache correctness)."""
    cfg = rcfgs[arch]
    if cfg.name == "xlstm-1.3b":
        pytest.skip("xLSTM denominator clamp differs at exact boundary; "
                    "covered by dedicated test in test_ssm.py")
    if cfg.moe is not None:
        # capacity dropping differs between batched prefill and single-token
        # decode (MoE semantics, not a bug); raise capacity so nothing drops
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(2)
    params = T.init_lm(key, cfg)
    k1, k3 = jax.random.split(key)
    toks = jax.random.randint(k1, (B, S + 1), 0, cfg.vocab)
    cross = None
    if cfg.frontend:
        cross = jax.random.normal(
            k3, (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)

    c1 = T.init_cache(cfg, B, S + 1)
    ref, _ = T.prefill(params, cfg, toks, c1, cross_source=cross)

    c2 = T.init_cache(cfg, B, S + 1)
    _, c2 = T.prefill(params, cfg, toks[:, :S], c2, cross_source=cross)
    got, _ = T.decode_step(params, cfg, toks[:, S], c2, S)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               atol=0.65, rtol=0.1)
