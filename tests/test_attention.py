"""Attention invariants: chunked flash == full attention, SWA masking,
GQA grouping, MLA absorbed decode == naive decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.models import attention as A


def _qkv(key, B, Sq, Sk, KVH, G, hd, vd=None):
    vd = vd or hd
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, KVH, G, hd))
    k = jax.random.normal(ks[1], (B, Sk, KVH, hd))
    v = jax.random.normal(ks[2], (B, Sk, KVH, vd))
    return q, k, v


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16),
       S=st.sampled_from([64, 128, 256]),
       qc=st.sampled_from([32, 64]),
       kc=st.sampled_from([32, 128]),
       G=st.sampled_from([1, 4]))
def test_chunked_equals_full_causal(seed, S, qc, kc, G):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 2, S, S, 2, G, 16)
    full = A.full_attention(q, k, v, causal=True)
    chunk = A.chunked_attention(q, k, v, causal=True, q_chunk=qc, k_chunk=kc)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_chunked_equals_full_sliding_window():
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 128, 128, 2, 2, 16)
    full = A.full_attention(q, k, v, causal=True, window=32)
    chunk = A.chunked_attention(q, k, v, causal=True, window=32,
                                q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_masks_old_tokens():
    """A token far outside the window must not influence the output."""
    key = jax.random.PRNGKey(1)
    q, k, v = _qkv(key, 1, 64, 64, 1, 1, 8)
    out1 = A.full_attention(q, k, v, causal=True, window=8)
    k2 = k.at[:, 0].set(100.0)  # poison a token outside every window >8
    v2 = v.at[:, 0].set(-100.0)
    out2 = A.full_attention(q, k2, v2, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(out1[:, 16:]),
                               np.asarray(out2[:, 16:]), atol=1e-5)


def test_gqa_grouping_matches_repeated_kv():
    """Grouped einsum == expanding KV heads G times."""
    B, S, KVH, G, hd = 1, 32, 2, 3, 8
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, S, KVH, G, hd)
    grouped = A.full_attention(q, k, v, causal=True)
    # expand kv: (B,S,KVH,hd) -> (B,S,KVH*G,hd); q -> (B,S,KVH*G,1,hd)
    kx = jnp.repeat(k, G, axis=2)
    vx = jnp.repeat(v, G, axis=2)
    qx = q.reshape(B, S, KVH * G, 1, hd)
    expanded = A.full_attention(qx, kx, vx, causal=True)
    np.testing.assert_allclose(
        np.asarray(grouped.reshape(B, S, KVH * G, hd)),
        np.asarray(expanded.reshape(B, S, KVH * G, hd)), rtol=1e-4, atol=1e-5)


def test_mla_absorbed_decode_equals_naive():
    """The absorbed decode path must equal the naive (expand-KV) path."""
    cfg = A.MLAConfig(d_model=32, n_heads=2, kv_lora_rank=16, qk_nope_dim=8,
                      qk_rope_dim=4, v_head_dim=8)
    key = jax.random.PRNGKey(3)
    p = A.init_mla(key, cfg, jnp.float32)
    B, S = 2, 9
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S + 1, 32))
    S_max = S + 1

    def run(use_absorbed):
        cache = {"latent": jnp.zeros(
            (B, S_max, cfg.kv_lora_rank + cfg.qk_rope_dim), jnp.float32)}
        pos = jnp.arange(S)[None, :]
        _, cache = A.mla(p, x[:, :S], pos, cfg, cache=cache, cache_index=0)
        if use_absorbed:
            out, _ = A.mla(p, x[:, S:], jnp.full((B, 1), S), cfg,
                           cache=cache, cache_index=S)
            return out
        # naive: process all S+1 tokens with cache (S+1 > 1 -> naive path)
        cache2 = {"latent": jnp.zeros(
            (B, S_max, cfg.kv_lora_rank + cfg.qk_rope_dim), jnp.float32)}
        out, _ = A.mla(p, x, jnp.arange(S + 1)[None, :], cfg,
                       cache=cache2, cache_index=0)
        return out[:, -1:]

    np.testing.assert_allclose(np.asarray(run(True)), np.asarray(run(False)),
                               rtol=2e-4, atol=2e-4)


def test_rope_rotation_preserves_norm():
    from repro.models import layers as L
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32))
    pos = jnp.arange(16)[None, :]
    xr = L.rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(xr, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-4)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    from repro.models import layers as L
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))

    def dot_at(i, j):
        qr = L.rope(q, jnp.asarray([[i]]))
        kr = L.rope(k, jnp.asarray([[j]]))
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3
    assert abs(dot_at(0, 0) - dot_at(11, 11)) < 1e-3


def test_chunked_kv_len_masks_padded_cache():
    """Prefill against a larger cache: padded KV slots must be ignored."""
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 64, 128, 2, 2, 16)
    # only first 64 kv entries valid
    full = A.full_attention(q, k[:, :64], v[:, :64], causal=True)
    chunk = A.chunked_attention(q, k, v, causal=True, q_chunk=32, k_chunk=32,
                                kv_len=64)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(full),
                               rtol=2e-3, atol=2e-3)
