"""Domain->submesh planning: exact channel tiling, device conservation,
latency-balanced sizing."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import partition as P
from repro.core.cost_models import LayerGeometry, TPUCostModel


@settings(max_examples=20, deadline=None)
@given(c0=st.integers(0, 512), tp=st.sampled_from([4, 8, 16]),
       c_out=st.sampled_from([256, 512, 1024]))
def test_plan_layer_invariants(c0, tp, c_out):
    c0 = min(c0, c_out)
    counts = [c0, c_out - c0]
    geom = LayerGeometry(c_in=512, c_out=c_out, ox=64)
    plan = P.plan_layer(TPUCostModel(), geom, counts, tp)
    plan.check(tp)  # tiling + device conservation
    for s, c in zip(plan.shards, counts):
        assert s.col_end - s.col_start == c
        if c > 0:
            assert s.devices >= 1


def test_balanced_split_gets_more_devices_for_slower_domain():
    """bf16 domain (half peak) should get ~2x the devices of int8 at equal
    channel counts — finishing times equalize."""
    geom = LayerGeometry(c_in=4096, c_out=4096, ox=4096)
    devs = P.size_subgroups(TPUCostModel(), geom, [2048, 2048], 12)
    assert devs[1] > devs[0]          # bf16 slower per chip -> more chips
    assert sum(devs) == 12


def test_all_one_domain():
    geom = LayerGeometry(c_in=64, c_out=128)
    plan = P.plan_layer(TPUCostModel(), geom, [128, 0], 8)
    assert plan.shards[0].devices == 8
    assert plan.shards[1].devices == 0


def test_plan_network_runs_over_odimo_counts():
    geoms = [LayerGeometry(c_in=64, c_out=128, ox=32),
             LayerGeometry(c_in=128, c_out=256, ox=16)]
    counts = [[100, 28], [0, 256]]
    plans = P.plan_network(TPUCostModel(), geoms, counts, 16)
    assert len(plans) == 2
    for p in plans:
        p.check(16)
