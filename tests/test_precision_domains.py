"""Serve-time precision domains (the ODiMO technique applied to the LM
serving path): int8 KV cache and int8 projection weights must preserve
decode outputs within quantization tolerance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import transformer as T

ARCHS = ["yi-9b", "deepseek-v2-lite-16b", "seamless-m4t-large-v2"]
B, S = 2, 12


def _setup(arch, **over):
    base.load_all()
    cfg = base.reduce_for_smoke(base.get(arch))
    cfg = dataclasses.replace(cfg, **over)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    cross = None
    if cfg.frontend:
        cross = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_tokens, cfg.d_model),
            jnp.bfloat16)
    return cfg, params, toks, cross


def _decode_logits(cfg, params, toks, cross):
    caches = T.init_cache(cfg, B, S + 1)
    _, caches = T.prefill(params, cfg, toks[:, :S], caches, cross_source=cross)
    logits, _ = T.decode_step(params, cfg, toks[:, S], caches, S)
    return np.asarray(logits)


@pytest.mark.parametrize("arch", ARCHS)
def test_int8_kv_cache_close_to_bf16(arch):
    cfg, params, toks, cross = _setup(arch)
    ref = _decode_logits(cfg, params, toks, cross)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    got = _decode_logits(cfg8, params, toks, cross)
    # correlation of logits survives cache quantization
    r = np.corrcoef(ref.ravel(), got.ravel())[0, 1]
    assert r > 0.98, (arch, r)


def test_int8_weights_close_to_bf16():
    cfg, params, toks, cross = _setup("yi-9b",
                                      serve_weight_dtype="int8")
    ref = _decode_logits(dataclasses.replace(cfg, serve_weight_dtype="bfloat16"),
                         params, toks, cross)
    qparams = T.quantize_for_serve(params, cfg)
    got = _decode_logits(cfg, qparams, toks, cross)
    r = np.corrcoef(ref.ravel(), got.ravel())[0, 1]
    assert r > 0.98, r


def test_quantize_for_serve_structure():
    cfg, params, _, _ = _setup("yi-9b", serve_weight_dtype="int8")
    q = T.quantize_for_serve(params, cfg)
    # projections replaced, embedding untouched
    leaves = jax.tree_util.tree_flatten_with_path(q)[0]
    has_wq = any("w_q" in str(p) for p, _ in leaves)
    assert has_wq
    assert q["emb"].dtype == jnp.bfloat16
    # spec version mirrors the transform
    specs = jax.eval_shape(lambda k: T.init_lm(k, cfg), jax.random.PRNGKey(0))
    qspecs = T.quantize_for_serve(specs, cfg)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(qspecs)[0],
            jax.tree_util.tree_flatten_with_path(q)[0]):
        assert a.shape == b.shape and a.dtype == b.dtype, (pa, a, b)
