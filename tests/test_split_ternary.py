"""Fused ternary+int8 kernel (`kernels.split_ternary`) tests: ops-level
parity against the pure-jnp oracle across boundary edge cases, prepared-
layer execution parity (Pallas interpret vs `ref.py` vs the fp path), jit
parity through the name-keyed backend, kernel block-size tuning threading,
and the end-to-end DIANA artifact that lowered to fp before the kernel
existed."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import MappingArtifact, Platform, lower
from repro.core import baselines as BL
from repro.kernels import ops, ref
from repro.kernels.ternary_packed import pack_ternary
from repro.runtime import (ExecutionPlan, KERNEL_SPLIT_TERNARY, LayerPlan,
                           PlannedBackend, execute_layer, prepare_layer,
                           reference_layer)


def _codes(rng, M, K, N, boundary):
    """(x_q, w_q, w_packed, wt_full, sx, sw): int8 codes below ``boundary``,
    ternary codes at/above, packed stream for the ternary side."""
    x_q = jnp.asarray(rng.integers(-127, 128, (M, K)), jnp.int8)
    w_q = np.asarray(rng.integers(-127, 128, (K, N)), np.int8)
    t = rng.integers(-1, 2, (K, N)).astype(np.int8)
    cols = np.arange(N)[None, :]
    w_q = np.where(cols >= boundary, t, w_q).astype(np.int8)
    wt_full = np.where(cols >= boundary, t, 0).astype(np.int8)
    k4 = -(-K // 4) * 4
    wt_pad = np.zeros((k4, N), np.int8)
    wt_pad[:K] = wt_full
    sx = jnp.float32(0.01)
    sw = jnp.asarray(rng.uniform(0.001, 0.01, (N,)), jnp.float32)
    return (x_q, jnp.asarray(w_q), pack_ternary(jnp.asarray(wt_pad)),
            jnp.asarray(wt_full), sx, sw)


@pytest.mark.parametrize("boundary", [0, 100, 128, 256, 300])
def test_split_ternary_op_matches_ref(boundary):
    """Pallas (interpret) vs the pure-jnp oracle at boundary=0 (all
    ternary), boundary=N (all int8), block-aligned and NON-aligned
    boundaries, K not a multiple of 4."""
    rng = np.random.default_rng(0)
    M, K, N = 16, 45, 300
    x_q, w_q, w_p, wt, sx, sw = _codes(rng, M, K, N, boundary)
    y = ops.split_ternary_op(x_q, w_q, w_p, sx, sw, boundary, interpret=True)
    b_al = ops.align_boundary(boundary, 128)
    y_ref = ref.split_ternary_matmul_ref(x_q, w_q, wt, sx, sw, b_al)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def _diana_prepared(rng, m=16, k=64, n=256, n_int8=100, tuning=None):
    """A DIANA-shaped prepared layer: first ``n_int8`` permuted columns on
    the digital int8 domain, the rest on the ternary AIMC array (NON-block-
    aligned by default — `ops.align_boundary` rounds inside the op)."""
    lp = LayerPlan(
        name="l", kernel=KERNEL_SPLIT_TERNARY, c_in=k, c_out=n,
        perm=np.arange(n), counts=[n_int8, n - n_int8],
        boundaries=[n_int8, n],
        aligned_boundaries=[ops.align_boundary(n_int8, 128), n],
        # int8 scale covers max|w| (no clipping); the ternary scale is the
        # AIMC array's own coarse step
        w_log_scales=[0.2, -2.0], act_log_scale=None, tuning=tuning)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.25, jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    return prepare_layer(lp, w, b, domain_bits=[8, 2]), x


def test_prepared_split_ternary_parity_and_packing():
    prep, x = _diana_prepared(np.random.default_rng(1))
    assert prep.w_t_packed is not None and prep.w_t_packed.dtype == jnp.uint8
    assert prep.w_t_packed.shape == (16, 256)      # K/4 packed rows
    # ternary columns carry ternary codes with the AIMC domain's step
    wq = np.asarray(prep.w_q)
    assert set(np.unique(wq[:, 100:])) <= {-1, 0, 1}
    assert np.asarray(prep.sw)[100:].max() == pytest.approx(np.exp(-2.0))
    y_kernel = execute_layer(prep, x, interpret=True)
    y_oracle = execute_layer(prep, x, reference=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                               rtol=1e-4, atol=1e-4)
    # vs the fp path: the int8 (digital) half is within int8 quant
    # tolerance; the ternary (AIMC) half carries the inherent 2-bit
    # ternarization error — lossy but correlated, never garbage
    y = np.asarray(y_kernel, np.float64)
    y_fp = np.asarray(reference_layer(prep, x), np.float64)
    rel_lo = (np.linalg.norm(y[:, :100] - y_fp[:, :100])
              / np.linalg.norm(y_fp[:, :100]))
    assert rel_lo < 0.05, rel_lo
    rel_hi = (np.linalg.norm(y[:, 100:] - y_fp[:, 100:])
              / np.linalg.norm(y_fp[:, 100:]))
    assert rel_hi < 0.9, rel_hi
    corr = np.corrcoef(y[:, 100:].ravel(), y_fp[:, 100:].ravel())[0, 1]
    assert corr > 0.8, corr


@pytest.mark.parametrize("n_int8", [1, 128, 255])
def test_prepared_split_ternary_boundary_edges(n_int8):
    """Boundaries that round to 128 / N and straddle blocks all stay at
    parity with the oracle (straddling columns execute on the int8 path
    with their own ternary codes + step)."""
    prep, x = _diana_prepared(np.random.default_rng(2), n_int8=n_int8)
    y_kernel = execute_layer(prep, x, interpret=True)
    y_oracle = execute_layer(prep, x, reference=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                               rtol=1e-4, atol=1e-4)


def test_tuning_threads_block_sizes_and_serializes():
    """`LayerPlan.tuning` reaches the kernel call (bm/bn/bk) and round-trips
    through plan JSON; split_ternary rejects a bk the 2-bit packing cannot
    tile."""
    tuning = {"bm": 8, "bn": 128, "bk": 64}
    prep, x = _diana_prepared(np.random.default_rng(3), tuning=tuning)
    assert prep.blocks == (8, 128, 64)
    y = execute_layer(prep, x, interpret=True)
    prep0, _ = _diana_prepared(np.random.default_rng(3), tuning=None)
    assert prep0.blocks == (128, 128, 512)
    y0 = execute_layer(prep0, x, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)
    plan = ExecutionPlan(model="t", domains=[{"weight_bits": 8},
                                             {"weight_bits": 2}],
                         layers=[prep.plan])
    loaded = ExecutionPlan.from_json(plan.to_json())
    assert loaded.layers[0].tuning == tuning
    from repro.runtime import ExecutionError
    bad = LayerPlan(**{**prep.plan.to_dict(), "tuning": {"bk": 30}})
    with pytest.raises(ExecutionError, match="bk % 4"):
        prepare_layer(bad, jnp.zeros((64, 256)), domain_bits=[8, 2])


def test_lower_threads_tuning_to_layers():
    doc = {
        "schema_version": 2, "model": "tuned",
        "domains": [{"name": "digital", "weight_bits": 8, "act_bits": 8},
                    {"name": "aimc", "weight_bits": 2, "act_bits": 7}],
        "layers": [{"name": "a", "searchable": True,
                    "assignment": [0] * 8 + [1] * 8, "counts": [8, 8]},
                   {"name": "b", "searchable": True,
                    "assignment": [0] * 16, "counts": [16, 0]}],
    }
    plan = lower(doc, tuning={"a": {"bm": 8, "bk": 128}})
    assert plan["a"].tuning == {"bm": 8, "bk": 128}
    assert plan["b"].tuning is None
    plan = lower(doc, tuning={"*": {"bk": 256}})
    assert plan["a"].tuning == plan["b"].tuning == {"bk": 256}


def _diana_mixed_artifact(rng, n_layers=2, K=32, N=192):
    """A diana-platform artifact whose every layer splits channels across
    digital int8 + ternary AIMC — the exact shape that fell back to fp
    before the fused kernel existed."""
    spec = Platform.get("diana").spec()
    assigns = [np.array(([0] * 2 + [1]) * (N // 3)) for _ in range(n_layers)]
    counts = BL.counts_from_assignments(assigns, 2)
    plan_list = [(f"l{i}", None, True) for i in range(n_layers)]
    scales = [{"w_log_scales": [0.3, -1.5], "act_log_scale": None}
              for _ in range(n_layers)]
    art = MappingArtifact.from_search("diana_mixed", spec, plan_list,
                                      assigns, counts, platform="diana",
                                      scales=scales)
    params = {}
    dims = [K] + [N] * n_layers
    for i in range(n_layers):
        params[f"l{i}"] = {
            "w": jnp.asarray(rng.normal(size=(dims[i], N)) * 0.3,
                             jnp.float32),
            "b": jnp.asarray(rng.normal(size=(N,)) * 0.1, jnp.float32)}
    return art, params


def test_diana_artifact_lowers_and_executes_split_ternary_under_jit():
    """End to end for the paper's platform: a mixed-layer diana artifact
    lowers every layer to split_ternary (strict mode passes — zero fp
    capability fallbacks), binds, and the jitted planned execution matches
    eager planned execution and stays within quant tolerance of fp."""
    rng = np.random.default_rng(4)
    art, params = _diana_mixed_artifact(rng)
    plan = lower(art, params=params, strict=True)
    assert plan.kernel_histogram() == {KERNEL_SPLIT_TERNARY: 2}
    backend = PlannedBackend(plan, params, interpret=True)
    assert backend.bound == ["l0", "l1"] and backend.fully_covered

    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    y_eager = backend("l0", params["l0"], x)
    y_jit = jax.jit(lambda p, xx: backend("l0", p, xx))(params["l0"], x)
    np.testing.assert_allclose(np.asarray(y_jit), np.asarray(y_eager),
                               rtol=1e-5, atol=1e-5)
    # digital (int8) columns are within int8 quant tolerance of fp; ternary
    # columns carry the inherent 2-bit loss (and prove this is genuinely
    # the planned path, not fp)
    y_fp = x @ params["l0"]["w"] + params["l0"]["b"]
    lo = np.asarray(art.assignments()[0]) == 0
    rel_lo = float(jnp.linalg.norm(y_jit[:, lo] - y_fp[:, lo])
                   / jnp.linalg.norm(y_fp[:, lo]))
    assert rel_lo < 0.05, rel_lo
    assert not np.allclose(np.asarray(y_jit), np.asarray(y_fp),
                           rtol=1e-6, atol=1e-6)


def test_single_repeat_stack_executes_direct_without_fp_weights():
    """R=1 stacks (every reduced-config layer stack) bind to the direct
    `_SingleRepeat` fast path — no stack axis, no per-iteration gather —
    and drop the dead fp32 weight copy like the other stack containers."""
    from repro.models import _backend
    from repro.runtime.execute import _SingleRepeat
    rng = np.random.default_rng(6)
    K, N = 16, 192
    spec = Platform.get("diana").spec()
    a = np.array(([0] * 2 + [1]) * (N // 3))
    art = MappingArtifact.from_search(
        "single", spec, [("units/0/proj@0", None, True)], [a],
        BL.counts_from_assignments([a], 2))
    params = {"units": ({"proj": {
        "w": jnp.asarray(rng.normal(size=(1, K, N)) * 0.25, jnp.float32),
        "b": jnp.asarray(rng.normal(size=(1, N)) * 0.1, jnp.float32)}},)}
    backend = PlannedBackend(lower(art, params=params), params,
                             interpret=True)
    entry = backend._by_name["units/0/proj"]
    assert isinstance(entry, _SingleRepeat)
    assert entry.prep.w_perm is None and entry.prep.w_t_packed is not None
    x = jnp.asarray(rng.normal(size=(2, K)), jnp.float32)
    with _backend.scan_slot(0):
        y = backend("units/0/proj", None, x)
    w, b = params["units"][0]["proj"]["w"][0], params["units"][0]["proj"]["b"][0]
    lo = np.asarray(a) == 0
    y_fp = x @ w + b
    rel = float(jnp.linalg.norm(y[:, lo] - y_fp[:, lo])
                / jnp.linalg.norm(y_fp[:, lo]))
    assert rel < 0.06, rel


def test_stacked_split_ternary_repeats_group_without_fp_weights():
    """Scan-stacked diana mixed layers stack codes + packed streams only
    (no R fp weight copies) and execute at parity inside a jitted scan."""
    from repro.models import _backend
    from repro.runtime.execute import _StackedPrepared
    rng = np.random.default_rng(5)
    R, K, N = 3, 16, 192
    spec = Platform.get("diana").spec()
    a = np.array(([0] * 2 + [1]) * (N // 3))
    counts = BL.counts_from_assignments([a] * R, 2)
    art = MappingArtifact.from_search(
        "stacked_diana", spec, [(f"units/0/proj@{r}", None, True)
                                for r in range(R)],
        [a] * R, counts, platform="diana")
    params = {"units": ({"proj": {
        "w": jnp.asarray(rng.normal(size=(R, K, N)) * 0.25, jnp.float32),
        "b": jnp.asarray(rng.normal(size=(R, N)) * 0.1, jnp.float32)}},)}
    plan = lower(art, params=params, strict=True)
    backend = PlannedBackend(plan, params, interpret=True)
    assert backend.unbound == []
    entry = backend._by_name["units/0/proj"]
    assert isinstance(entry, _StackedPrepared)
    assert entry._w_perm is None and entry._w_t_packed is not None

    x = jnp.asarray(rng.normal(size=(2, K)), jnp.float32)

    def body(carry, ridx):
        with _backend.scan_slot(ridx):
            y = backend("units/0/proj", None, x)
        return carry, y

    ys = jax.jit(lambda: jax.lax.scan(body, 0, jnp.arange(R))[1])()
    for r in range(R):
        with _backend.scan_slot(r):
            y_eager = backend("units/0/proj", None, x)
        np.testing.assert_allclose(np.asarray(ys[r]), np.asarray(y_eager),
                                   rtol=1e-5, atol=1e-5)
