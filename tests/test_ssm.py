"""SSM correctness: chunked-parallel forms vs recurrent references, and
decode-state continuity (prefill -> decode equals one long prefill)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.models import ssm as S


def _mamba_rec(xs, Bt, Ct, dt, la, h0):
    dA = jnp.exp(la)

    def step(h, i):
        dBx = jnp.einsum("bhp,bn,bh->bhpn", xs[:, i], Bt[:, i], dt[:, i])
        h = h * dA[:, i][..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", h, Ct[:, i])
        return h, y

    hT, ys = jax.lax.scan(step, h0, jnp.arange(xs.shape[1]))
    return hT, ys.transpose(1, 0, 2, 3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), S_len=st.sampled_from([5, 16, 33, 64]),
       chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_equals_recurrent(seed, S_len, chunk):
    key = jax.random.PRNGKey(seed)
    B, H, hd, N = 2, 3, 8, 5
    ks = jax.random.split(key, 6)
    xs = jax.random.normal(ks[0], (B, S_len, H, hd))
    Bt = jax.random.normal(ks[1], (B, S_len, N))
    Ct = jax.random.normal(ks[2], (B, S_len, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S_len, H)))
    la = dt * -1.0
    h0 = jax.random.normal(ks[4], (B, H, hd, N))
    hT_r, y_r = _mamba_rec(xs, Bt, Ct, dt, la, h0)
    hT_c, y_c = S._ssd_chunked(xs, Bt, Ct, dt, la, h0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(hT_c), np.asarray(hT_r),
                               rtol=3e-4, atol=3e-4)


def _mlstm_rec(q, k, v, ig, fg, carry):
    def step(carry, i):
        C, n, m = carry
        logf = jax.nn.log_sigmoid(fg[:, i])
        m_new = jnp.maximum(logf + m, ig[:, i])
        fs = jnp.exp(logf + m - m_new)
        is_ = jnp.exp(ig[:, i] - m_new)
        C = C * fs[..., None, None] + is_[..., None, None] * \
            jnp.einsum("bhv,bhk->bhvk", v[:, i], k[:, i])
        n = n * fs[..., None] + is_[..., None] * k[:, i]
        num = jnp.einsum("bhvk,bhk->bhv", C, q[:, i])
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q[:, i])),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    (CT, nT, mT), hs = jax.lax.scan(step, carry, jnp.arange(q.shape[1]))
    return (CT, nT, mT), hs.transpose(1, 0, 2, 3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), S_len=st.sampled_from([5, 16, 33]),
       chunk=st.sampled_from([4, 8]))
def test_mlstm_chunked_equals_recurrent(seed, S_len, chunk):
    key = jax.random.PRNGKey(seed)
    B, H, hd = 2, 3, 8
    ks = jax.random.split(key, 6)
    q = jax.random.normal(ks[0], (B, S_len, H, hd))
    k = jax.random.normal(ks[1], (B, S_len, H, hd)) * hd ** -0.5
    v = jax.random.normal(ks[2], (B, S_len, H, hd))
    ig = jax.random.normal(ks[3], (B, S_len, H))
    fg = jax.random.normal(ks[4], (B, S_len, H)) + 2.0
    C0 = jnp.zeros((B, H, hd, hd))
    n0 = jnp.zeros((B, H, hd))
    m0 = jnp.zeros((B, H))
    (CT_r, nT_r, mT_r), h_r = _mlstm_rec(q, k, v, ig, fg, (C0, n0, m0))
    (CT_c, nT_c, mT_c), h_c = S._mlstm_chunked(q, k, v, ig, fg, (C0, n0, m0),
                                               chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(CT_c), np.asarray(CT_r),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(mT_c), np.asarray(mT_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mod", ["mamba2", "mlstm", "slstm"])
def test_block_decode_continuity(mod):
    """prefill(S) then decode(1) == prefill(S+1), per block type."""
    key = jax.random.PRNGKey(0)
    B, S_len, d = 2, 12, 16
    x = jax.random.normal(key, (B, S_len + 1, d), jnp.float32)
    if mod == "mamba2":
        cfg = S.Mamba2Config(d_model=d, d_state=4, head_dim=8)
        p = S.init_mamba2(jax.random.fold_in(key, 1), cfg, jnp.float32)
        fn, init_state = S.mamba2, lambda: S.mamba2_init_state(B, cfg, jnp.float32)
    elif mod == "mlstm":
        cfg = S.XLSTMConfig(d_model=d, n_heads=2)
        p = S.init_mlstm(jax.random.fold_in(key, 1), cfg, jnp.float32)
        fn, init_state = S.mlstm, lambda: S.mlstm_init_state(B, cfg, jnp.float32)
    else:
        cfg = S.XLSTMConfig(d_model=d, n_heads=2)
        p = S.init_slstm(jax.random.fold_in(key, 1), cfg, jnp.float32)
        fn, init_state = S.slstm, lambda: S.slstm_init_state(B, cfg)

    y_full, _ = fn(p, x, cfg, state=init_state())
    _, st1 = fn(p, x[:, :S_len], cfg, state=init_state())
    y_step, _ = fn(p, x[:, S_len:], cfg, state=st1)
    np.testing.assert_allclose(np.asarray(y_step[:, 0]),
                               np.asarray(y_full[:, S_len]),
                               rtol=2e-3, atol=2e-3)
