"""Engine robustness layer: deadline scheduling + preemption, overload
shedding, timeouts, graceful precision degradation, and fault containment.

The load-bearing invariant throughout is TOKEN IDENTITY: preemption,
fault-recovery requeues and resumption-by-prefill are scheduling decisions
that must be invisible in the output stream.  A preempted (or faulted)
request resumes by prefilling ``original prompt + committed tokens``, and
prefill's last-position logits equal the decode-step logits for the same
prefix — so the resumed stream continues exactly where it stopped.  The
engine tests here pin that for the fp backend and (slow) a planned diana
backend; the unit tests cover the queue/metrics/fault-injector mechanics
that make the engine paths deterministic.
"""
import math
import zlib

import jax
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models import transformer as T
from repro.serving import (Engine, FaultEvent, FaultInjector, Request,
                           RequestQueue, RequestResult, Scheduler,
                           ShedResult, load_trace, percentile,
                           poisson_arrivals, save_trace, summarize,
                           synthetic_trace, urgency)
from repro.serving.engine import _DegradeController


@pytest.fixture(scope="module", autouse=True)
def _load():
    cfgbase.load_all()


def _reduced(arch):
    return cfgbase.reduce_for_smoke(cfgbase.get(arch))


def _req(rid, plen=4, new=4, arrival=0, priority=0, deadline=None):
    return Request(rid=rid,
                   prompt=(np.arange(plen) + zlib.crc32(str(rid).encode()))
                   % 7,
                   max_new_tokens=new, arrival_step=arrival,
                   priority=priority, deadline_ms=deadline)


# --------------------------------------------------------------------------
# Request validation (hardened __post_init__)
# --------------------------------------------------------------------------

def test_request_validation_names_the_rid():
    with pytest.raises(ValueError, match="'neg'.*arrival_step"):
        Request(rid="neg", prompt=np.zeros(3), max_new_tokens=2,
                arrival_step=-1)
    with pytest.raises(ValueError, match="'fl'.*arrival_step"):
        Request(rid="fl", prompt=np.zeros(3), max_new_tokens=2,
                arrival_step=1.5)
    with pytest.raises(ValueError, match="'eos'.*eos_id"):
        Request(rid="eos", prompt=np.zeros(3), max_new_tokens=2,
                eos_id="stop")
    with pytest.raises(ValueError, match="'eosb'.*eos_id"):
        Request(rid="eosb", prompt=np.zeros(3), max_new_tokens=2,
                eos_id=True)
    with pytest.raises(ValueError, match="'pri'.*priority"):
        Request(rid="pri", prompt=np.zeros(3), max_new_tokens=2,
                priority="high")
    with pytest.raises(ValueError, match="'dnan'.*deadline_ms"):
        Request(rid="dnan", prompt=np.zeros(3), max_new_tokens=2,
                deadline_ms=float("nan"))
    with pytest.raises(ValueError, match="'dneg'.*deadline_ms"):
        Request(rid="dneg", prompt=np.zeros(3), max_new_tokens=2,
                deadline_ms=-5.0)
    with pytest.raises(ValueError, match="'dbad'.*deadline_ms"):
        Request(rid="dbad", prompt=np.zeros(3), max_new_tokens=2,
                deadline_ms="soon")
    # numpy ints and float-coercible deadlines are fine
    r = Request(rid="ok", prompt=np.zeros(3), max_new_tokens=2,
                arrival_step=np.int64(3), eos_id=np.int32(5),
                priority=np.int64(1), deadline_ms=50)
    assert r.arrival_step == 3 and r.deadline_ms == 50.0


def test_urgency_ordering():
    now = 10.0
    hi = _req("hi", priority=5)
    lo_tight = _req("lo1", deadline=20.0)
    lo_loose = _req("lo2", deadline=500.0)
    lo_none = _req("lo3")
    keys = {r.rid: urgency(r, now) for r in (hi, lo_tight, lo_loose,
                                             lo_none)}
    ranked = sorted(keys, key=keys.get)
    assert ranked == ["hi", "lo1", "lo2", "lo3"]
    # slack shrinks as time passes for a fixed t_ready
    early = urgency(lo_tight, 10.0, t_ready=10.0)
    late = urgency(lo_tight, 10.019, t_ready=10.0)
    assert late < early
    assert urgency(lo_none, now)[1] == math.inf


# --------------------------------------------------------------------------
# RequestQueue.pop_ready edge cases
# --------------------------------------------------------------------------

def test_pop_ready_hol_blocking_with_interleaved_future_arrivals():
    """A non-fitting visible request blocks everything behind it, while
    not-yet-visible requests interleaved in the queue keep their slots."""
    q = RequestQueue()
    a, future, big, c = (_req("a"), _req("future", arrival=10),
                         _req("big", plen=64), _req("c"))
    for r in (a, future, big, c):
        q.push(r)
    got = q.pop_ready(0, 4, fits=lambda r: r.prompt_len <= 8)
    assert [r.rid for r in got] == ["a"]          # big blocks c
    assert [r.rid for r in q] == ["future", "big", "c"]
    # once the blocker fits, order is preserved — big before c
    got = q.pop_ready(0, 4, fits=lambda r: True)
    assert [r.rid for r in got] == ["big", "c"]
    assert [r.rid for r in q] == ["future"]


def test_pop_ready_fits_flapping_preserves_fcfs():
    """fits() flipping False->True->False across calls never reorders the
    queue: head-of-line blocking is re-evaluated from scratch each call."""
    q = RequestQueue()
    for rid in "abcd":
        q.push(_req(rid))
    flap = {"ok": False}
    fits = lambda r: flap["ok"]
    for _ in range(3):                             # repeated full blocking
        assert q.pop_ready(0, 4, fits=fits) == []
        assert [r.rid for r in q] == list("abcd")  # order untouched
    flap["ok"] = True
    assert [r.rid for r in q.pop_ready(0, 2, fits=fits)] == ["a", "b"]
    flap["ok"] = False
    assert q.pop_ready(0, 2, fits=fits) == []
    assert [r.rid for r in q] == ["c", "d"]


def test_pop_ready_ordered_most_urgent_blocks():
    """Under a deadline order the MOST URGENT candidate failing fits()
    blocks cheaper work — urgency must not be starved by admissible
    low-priority requests."""
    q = RequestQueue()
    small = _req("small", plen=4)
    urgent_big = _req("urgent", plen=64, priority=9)
    q.push(small)
    q.push(urgent_big)
    order = lambda r: urgency(r, 0.0)
    got = q.pop_ready(0, 2, fits=lambda r: r.prompt_len <= 8, order=order)
    assert got == []                              # urgent blocks small
    assert len(q) == 2
    got = q.pop_ready(0, 2, fits=lambda r: True, order=order)
    assert [r.rid for r in got] == ["urgent", "small"]


def test_pop_ready_order_stable_fcfs_tiebreak():
    q = RequestQueue()
    for rid in ("x", "y", "z"):
        q.push(_req(rid, priority=1))
    order = lambda r: urgency(r, 0.0)
    assert [r.rid for r in q.pop_ready(0, 3, order=order)] == ["x", "y", "z"]


def test_queue_push_front_and_remove():
    q = RequestQueue()
    a, b = _req("a"), _req("b")
    q.push(a)
    q.push_front(b)
    assert [r.rid for r in q] == ["b", "a"]
    assert q.remove(a) and not q.remove(a)
    assert [r.rid for r in q] == ["b"]


# --------------------------------------------------------------------------
# metrics guards
# --------------------------------------------------------------------------

def test_summarize_empty_and_all_shed():
    assert summarize([], 0.0)["total_tok_s"] == 0.0
    assert summarize([], 1.0)["ttft_p95_s"] == 0.0
    sheds = [ShedResult(rid=i, reason="queue_depth", shed_step=0,
                        waited_s=0.1) for i in range(3)]
    s = summarize(sheds, 1.0)
    assert s["shed"] == 3 and s["shed_rate"] == 1.0
    assert s["completed"] == 0 and s["ttft_p50_s"] == 0.0
    assert s["degrade_rate"] == 0.0
    assert s["shed_reasons"] == {"queue_depth": 3}
    assert sheds[0].n_tokens == 0


def test_summarize_zero_duration_decode_window():
    r = RequestResult(rid=0, prompt_len=4, tokens=[1, 2, 3],
                      finish_reason="max_new_tokens", ttft_s=0.5,
                      finish_s=0.5, admitted_step=0, finished_step=2)
    assert r.decode_tok_s == 0.0
    s = summarize([r], 1.0)
    assert s["decode_tok_s_p50"] == 0.0 and s["completed"] == 1


def test_summarize_by_slo_includes_shed_counts():
    done = RequestResult(rid=0, prompt_len=4, tokens=[1], slo="interactive",
                         finish_reason="eos", ttft_s=0.1, finish_s=0.2,
                         admitted_step=0, finished_step=1)
    shed = ShedResult(rid=1, reason="timeout", shed_step=3, waited_s=2.0,
                      slo="interactive")
    s = summarize([done, shed], 1.0)
    assert s["by_slo"]["interactive"]["requests"] == 1
    assert s["by_slo"]["interactive"]["shed"] == 1


def test_percentile_drops_nonfinite():
    assert percentile([1.0, float("nan"), 2.0, float("inf")], 100) == 2.0
    assert percentile([float("nan")], 50) == 0.0


# --------------------------------------------------------------------------
# degrade controller
# --------------------------------------------------------------------------

def test_degrade_controller_hysteresis():
    c = _DegradeController(target_s=1.0, window=8, min_samples=4,
                           recover_frac=0.5)
    for _ in range(3):
        c.observe(5.0)
    assert not c.update(0)                       # below min_samples
    c.observe(5.0)
    assert c.update(1) and c.active              # p95 over target
    # window cleared at the transition: staying degraded, no flapping
    assert c.update(2) and len(c.transitions) == 1
    for _ in range(4):
        c.observe(0.1)                           # p95 under recover_frac
    assert not c.update(3) and not c.active
    assert [(s, k) for s, k, _ in c.transitions] == \
        [(1, "degrade"), (3, "recover")]
    c.reset()
    assert not c.transitions and not c.active


def test_degrade_controller_validation():
    with pytest.raises(ValueError, match="ttft_target_s"):
        _DegradeController(target_s=0.0)
    with pytest.raises(ValueError, match="recover_frac"):
        _DegradeController(target_s=1.0, recover_frac=1.5)


# --------------------------------------------------------------------------
# fault injector
# --------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError, match="fault kind"):
        FaultEvent("meteor", 0, 0)
    with pytest.raises(ValueError, match="bad fault event"):
        FaultEvent("stuck", -1, 0)
    with pytest.raises(ValueError, match="bad fault event"):
        FaultEvent("stuck", 0, 0, duration=0)


def test_fault_injector_parse():
    inj = FaultInjector.parse(
        "nonfinite_logits@3:0, stuck@5:1x20, corrupt_page~0.25", seed=7)
    assert inj.events == [FaultEvent("nonfinite_logits", 3, 0),
                          FaultEvent("stuck", 5, 1, duration=20)]
    assert inj.rates == {"corrupt_page": 0.25}
    with pytest.raises(ValueError, match="bad fault spec"):
        FaultInjector.parse("nonfinite_logits@oops")
    with pytest.raises(ValueError, match="fault kind"):
        FaultInjector.parse("meteor@1:0")


def test_fault_injector_draw_planned_and_rates():
    inj = FaultInjector(events=[FaultEvent("stuck", 2, 1)])
    assert inj.draw(1, [0, 1]) == []
    assert inj.draw(2, [0]) == []                 # slot 1 not occupied
    assert [e.kind for e in inj.draw(2, [0, 1])] == ["stuck"]
    assert inj.fired == [(2, 1, "stuck")]
    # seeded Bernoulli rates are deterministic
    one = FaultInjector(rates={"nonfinite_logits": 0.2}, seed=3)
    two = FaultInjector(rates={"nonfinite_logits": 0.2}, seed=3)
    seq1 = [len(one.draw(s, [0, 1])) for s in range(60)]
    seq2 = [len(two.draw(s, [0, 1])) for s in range(60)]
    assert seq1 == seq2 and sum(seq1) > 0


# --------------------------------------------------------------------------
# traces: poisson arrivals + priority/deadline round-trip + malformed input
# --------------------------------------------------------------------------

def test_poisson_arrivals_deterministic_and_monotonic():
    base = synthetic_trace(16, vocab=64, seed=1)
    a = poisson_arrivals(base, 0.5, seed=9)
    b = poisson_arrivals(base, 0.5, seed=9)
    assert [r.arrival_step for r in a] == [r.arrival_step for r in b]
    steps = [r.arrival_step for r in a]
    assert steps == sorted(steps) and steps[-1] > 0
    assert all(r0.arrival_step == 0 for r0 in base)   # inputs not mutated
    with pytest.raises(ValueError, match="offered load"):
        poisson_arrivals(base, 0.0)


def test_trace_priority_deadline_roundtrip(tmp_path):
    t = synthetic_trace(6, vocab=64, seed=2, priorities=[0, 3],
                        deadlines_ms=[None, 40.0])
    assert [r.priority for r in t] == [0, 3, 0, 3, 0, 3]
    assert [r.deadline_ms for r in t] == [None, 40.0] * 3
    p = save_trace(tmp_path / "t.jsonl", t)
    back = load_trace(p)
    assert [r.priority for r in back] == [r.priority for r in t]
    assert [r.deadline_ms for r in back] == [r.deadline_ms for r in t]
    # defaults stay byte-identical to pre-knob traces
    t0 = synthetic_trace(6, vocab=64, seed=2)
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(t, t0))


def test_load_trace_malformed_lines_name_path_and_line(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"id": "a", "prompt": [1, 2]}\nnot json{{\n')
    with pytest.raises(ValueError, match=r"bad\.jsonl:2.*malformed"):
        load_trace(p)
    p.write_text('[1, 2, 3]\n')
    with pytest.raises(ValueError, match=r"bad\.jsonl:1.*JSON\s+object"):
        load_trace(p)
    p.write_text('{"id": "a", "prompt": [1], "deadline_ms": -4}\n')
    with pytest.raises(ValueError, match=r"bad\.jsonl:1.*deadline_ms"):
        load_trace(p)


# --------------------------------------------------------------------------
# engine integration: preemption, shedding, timeouts, faults
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def yi(tmp_path_factory):
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk_reqs(cfg, spec):
    """spec: [(rid, plen, new, arrival, priority, deadline_ms), ...] with
    seed-deterministic prompts (same rid -> same prompt)."""
    out = []
    for rid, plen, new, arrival, priority, deadline in spec:
        rng = np.random.default_rng(zlib.crc32(str(rid).encode()))
        out.append(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab, plen),
            max_new_tokens=new, arrival_step=arrival, priority=priority,
            deadline_ms=deadline))
    return out


_PREEMPT_SPEC = [("low0", 8, 12, 0, 0, None), ("low1", 8, 12, 0, 0, None),
                 ("hi", 6, 4, 3, 5, 10.0)]


def _preemption_parity(cfg, params, backend=None):
    eng = Engine(cfg, params, max_batch=2, max_len=48, page_size=8,
                 backend=backend, scheduler=Scheduler("deadline"))
    res = eng.run(_mk_reqs(cfg, _PREEMPT_SPEC))
    assert eng.stats["preemptions"] >= 1 and eng.stats["resumes"] >= 1
    assert sum(r.preemptions for r in res) >= 1
    ref = Engine(cfg, params, max_batch=2, max_len=48, page_size=8,
                 backend=backend)
    ref_res = ref.run(_mk_reqs(cfg, _PREEMPT_SPEC))
    assert ref.stats["preemptions"] == 0
    a = {r.rid: r.tokens for r in res}
    b = {r.rid: r.tokens for r in ref_res}
    assert a == b                     # preemption invisible in the tokens
    return eng


def test_preemption_token_parity_fp(yi):
    """Deadline preemption round-trip (fp backend, paged layout): the
    preempted request's resumed stream is identical to an unpreempted FCFS
    run, and its parked pages serve the resume prefill."""
    cfg, params = yi
    eng = _preemption_parity(cfg, params)
    assert eng.stats["prefix_hit_tokens"] > 0     # resume hit parked pages


@pytest.mark.slow
def test_preemption_token_parity_planned_diana(yi, tmp_path):
    """Same invariant with every projection running its planned diana
    kernel — preemption must also be invisible under quantized execution
    (static act scales make the planned numerics batch-independent)."""
    from repro.launch.serve import plan_mapping_execution
    from repro.launch.train import emit_static_mapping
    cfg, params = yi
    art = emit_static_mapping(params, cfg, "diana", tmp_path / "m.json",
                              act_log_scale=2.0)
    _, backend = plan_mapping_execution(params, art)
    _preemption_parity(cfg, params, backend=backend)


def test_queue_depth_and_watermark_shed(yi):
    cfg, params = yi
    reqs = _mk_reqs(cfg, [(f"q{i}", 6, 4, 0, 0, None) for i in range(6)])
    eng = Engine(cfg, params, max_batch=1, max_len=48, page_size=8,
                 max_queue_depth=2)
    res = eng.run(reqs)
    sheds = [r for r in res if isinstance(r, ShedResult)]
    assert len(sheds) == 3 and {s.reason for s in sheds} == {"queue_depth"}
    assert len([r for r in res if isinstance(r, RequestResult)]) == 3
    # page watermark: a nearly-full pool sheds the backlog instead of
    # letting it wait forever
    eng2 = Engine(cfg, params, max_batch=2, max_len=48, page_size=8,
                  num_pages=12, page_watermark=0.9)
    res2 = eng2.run(_mk_reqs(cfg, [(f"w{i}", 8, 4, 0, 0, None)
                                   for i in range(4)]))
    sheds2 = [r for r in res2 if isinstance(r, ShedResult)]
    assert sheds2 and {s.reason for s in sheds2} == {"page_watermark"}
    assert eng2.stats["shed_requests"] == len(sheds2)


def test_request_timeouts_queued_and_running(yi):
    """A microscopic wall-clock budget times out RUNNING requests (partial
    tokens, finish_reason='timeout') and sheds QUEUED ones (structured
    ShedResult) — the run always terminates."""
    cfg, params = yi
    reqs = _mk_reqs(cfg, [(f"t{i}", 6, 16, 0, 0, None) for i in range(3)])
    eng = Engine(cfg, params, max_batch=1, max_len=48, page_size=8,
                 request_timeout_s=1e-6)
    res = eng.run(reqs)
    assert len(res) == 3
    running = [r for r in res if isinstance(r, RequestResult)]
    queued = [r for r in res if isinstance(r, ShedResult)]
    assert running and all(r.finish_reason == "timeout" for r in running)
    assert all(1 <= r.n_tokens < 16 for r in running)  # partial but clean
    assert queued and all(s.reason == "timeout" for s in queued)
    assert eng.stats["timeouts"] == len(res)


def _clean_tokens(cfg, params, rid="f0", new=10):
    eng = Engine(cfg, params, max_batch=1, max_len=48, page_size=8)
    [r] = eng.run(_mk_reqs(cfg, [(rid, 8, new, 0, 0, None)]))
    return r.tokens


@pytest.mark.parametrize("kind", ["nonfinite_logits", "corrupt_page",
                                  "stuck"])
def test_fault_detected_quarantined_requeued_token_parity(yi, kind):
    """Each fault kind is detected, the slot quarantined, the request
    requeued once — and the final token stream is IDENTICAL to a clean
    run (committed tokens are never corrupted)."""
    cfg, params = yi
    inj = FaultInjector(events=[FaultEvent(kind, step=4, slot=0,
                                           duration=100)])
    eng = Engine(cfg, params, max_batch=1, max_len=48, page_size=8,
                 injector=inj, heartbeat_steps=4)
    [r] = eng.run(_mk_reqs(cfg, [("f0", 8, 10, 0, 0, None)]))
    assert isinstance(r, RequestResult) and r.requeues == 1
    assert eng.stats["faults_injected"] == 1
    if kind == "stuck":
        assert eng.stats["heartbeat_trips"] >= 1
    else:
        assert eng.stats["faults_detected"] >= 1
    assert r.tokens == _clean_tokens(cfg, params)
    assert inj.fired == [(4, 0, kind)]


def test_double_fault_sheds_structured_never_hangs(yi):
    cfg, params = yi
    inj = FaultInjector(events=[FaultEvent("nonfinite_logits", 3, 0),
                                FaultEvent("nonfinite_logits", 8, 0)])
    eng = Engine(cfg, params, max_batch=1, max_len=48, page_size=8,
                 injector=inj, quarantine_steps=1)
    [r] = eng.run(_mk_reqs(cfg, [("d0", 8, 10, 0, 0, None)]))
    assert isinstance(r, ShedResult) and r.reason == "fault"
    assert eng.stats["faults_detected"] == 2
    assert eng.stats["shed_requests"] == 1


def test_corrupted_page_purged_from_prefix_cache(yi):
    """After a corrupt_page fault the slot's pages must not be matchable:
    a second identical prompt re-prefills from scratch (no poisoned hit)."""
    cfg, params = yi
    inj = FaultInjector(events=[FaultEvent("corrupt_page", 4, 0)])
    eng = Engine(cfg, params, max_batch=1, max_len=48, page_size=8,
                 injector=inj)
    spec = [("p0", 16, 6, 0, 0, None), ("p1", 16, 6, 0, 0, None)]
    r0_req, r1_req = _mk_reqs(cfg, spec)
    r1_req.prompt = r0_req.prompt.copy()          # identical prompt
    res = {r.rid: r for r in eng.run([r0_req, r1_req])}
    clean = Engine(cfg, params, max_batch=1, max_len=48, page_size=8)
    ref = {r.rid: r for r in clean.run(
        [Request(rid=s[0], prompt=r0_req.prompt.copy(), max_new_tokens=6)
         for s in spec])}
    for rid in res:
        assert res[rid].tokens == ref[rid].tokens


def test_engine_robustness_validation(yi):
    cfg, params = yi
    with pytest.raises(ValueError, match="max_queue_depth"):
        Engine(cfg, params, max_queue_depth=0)
    with pytest.raises(ValueError, match="page_watermark"):
        Engine(cfg, params, page_watermark=1.5)
    with pytest.raises(ValueError, match="degrade_to"):
        Engine(cfg, params, degrade_to="cheap")   # no ttft target / bank
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, params, kv_layout="dense",
               injector=FaultInjector())


@pytest.mark.slow
def test_degradation_bounds_routing_and_recovers(yi, tmp_path):
    """With a 2-variant bank and an unreachable TTFT target, the engine
    flips new admissions to the degrade variant (degraded=True, variant
    pinned per request across its whole lifetime) and the transition is
    recorded in degrade_log."""
    from repro.launch.serve import build_planset
    from repro.launch.train import emit_static_mapping
    cfg, params = yi
    default = emit_static_mapping(params, cfg, "diana", tmp_path / "a.json",
                                  act_log_scale=2.0, bias=("digital", 1.0))
    cheap = emit_static_mapping(params, cfg, "diana", tmp_path / "b.json",
                                act_log_scale=2.0, bias=("aimc", 1.0))
    _, bank = build_planset(params, {"default": default, "cheap": cheap},
                            "default")
    trace = synthetic_trace(8, vocab=cfg.vocab, seed=4, min_prompt=4,
                            max_prompt=8, min_new=3, max_new=6,
                            arrival_every=2)
    eng = Engine(cfg, params, max_batch=2, max_len=48, page_size=8,
                 backend=bank, degrade_to="cheap", ttft_target_s=1e-9,
                 degrade_window=4)
    res = eng.run(trace)
    assert eng.stats["degrade_transitions"] >= 1
    assert eng.degrade_log and eng.degrade_log[0][1] == "degrade"
    degraded = [r for r in res if isinstance(r, RequestResult)
                and r.degraded]
    assert degraded and all(r.variant == "cheap" for r in degraded)
    undegraded = [r for r in res if isinstance(r, RequestResult)
                  and not r.degraded]
    assert all(r.variant in (None, "default") for r in undegraded)
