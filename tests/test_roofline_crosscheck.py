"""Cross-check the analytic FLOPs enumerator against XLA cost_analysis on a
single UNSCANNED block (no while-loop undercounting), full-size dims.

Compile-only (ShapeDtypeStructs): nothing is allocated, so full-width layers
compile fine on the 1-CPU test runner.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import transformer as T
from repro.roofline import analysis as RA


@pytest.fixture(scope="module", autouse=True)
def _load():
    base.load_all()


def _flops(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older JAX: one dict per device
        ca = ca[0]
    return float(ca["flops"])


def _block_hlo_flops(cfg, kind, B, S):
    """Compile one block (forward) and return cost_analysis flops."""
    pshape = jax.eval_shape(
        lambda k: T.init_block(k, cfg, kind, layer_idx=1), jax.random.PRNGKey(0))
    x = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    pos = jax.ShapeDtypeStruct((1, S), jnp.int32)

    def f(p, x, pos):
        out, _, _ = T.block_apply(p, x, kind, cfg, pos, chunked=False)
        return out

    return _flops(jax.jit(f).lower(pshape, x, pos).compile())


@pytest.mark.parametrize("arch,kind", [
    ("yi-9b", "attn"),
    ("nemotron-4-15b", "attn"),
    ("h2o-danube-3-4b", "attn"),
])
def test_enumerator_matches_hlo_dense_block(arch, kind):
    """Analytic block FLOPs within 20% of compiled HLO FLOPs (HLO includes
    softmax/norm/rope element-wise ops the matmul enumerator omits)."""
    cfg = base.get(arch)
    B, S = 1, 128
    hlo = _block_hlo_flops(cfg, kind, B, S)
    analytic = RA._block_fwd_flops(cfg, kind, B, S, None)
    ratio = analytic / hlo
    assert 0.8 <= ratio <= 1.2, (arch, analytic, hlo, ratio)


def test_enumerator_matches_hlo_mla_block():
    cfg = base.get("deepseek-v2-lite-16b")
    B, S = 1, 128
    hlo = _block_hlo_flops(cfg, "mla", B, S)
    analytic = RA._block_fwd_flops(cfg, "mla", B, S, None)
    ratio = analytic / hlo
    # MoE routing one-hots/cumsums add non-matmul HLO flops -> wider band
    assert 0.6 <= ratio <= 1.3, (analytic, hlo, ratio)


def test_scan_undercount_reproduction():
    """The methodology premise: cost_analysis counts a scan body once."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f_scan(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    fl = _flops(jax.jit(f_scan).lower(x, ws).compile())
    one_mm = 2 * 64 * 64 * 64
    assert fl < 2.5 * one_mm  # ~1 body, NOT 8 bodies
