"""Multi-plan precision bank tests (`repro.runtime.PlanSet` + the serving
features it powers).

Covers: prepared-buffer dedup accounting (identical variants cost one bind;
divergent variants share only coinciding layers; a two-variant bank stays
strictly below two independent binds when any layer coincides), variant
selection parity against a single-plan bind, per-variant coverage diffs by
layer NAME, self-speculative decoding token identity vs target-only greedy
serving on mixed-length traces (attention-only yi-9b AND hybrid zamba2,
whose recurrent state exercises the replay path), SLO-routed serving parity
+ per-class metrics, jit-safe non-greedy sampling (off by default,
seed-deterministic), and the engine's multi-plan validation errors.
"""
import jax
import numpy as np
import pytest

from repro.api import MappingArtifact
from repro.configs import base as cfgbase
from repro.models import transformer as T
from repro.models._backend import plan_variant
from repro.runtime import PlannedBackend, PlanSet, lower
from repro.serving import Engine, SamplingParams, synthetic_trace

jnp = jax.numpy


@pytest.fixture(scope="module", autouse=True)
def _load():
    cfgbase.load_all()


def _reduced(arch):
    return cfgbase.reduce_for_smoke(cfgbase.get(arch))


def _artifact(cfg, params, tmp_path, bias=None, name="m.json"):
    """Static diana artifact (static act scales — the engine's per-request
    reproducibility precondition) with an optional precision-bank bias."""
    from repro.launch.train import emit_static_mapping
    return emit_static_mapping(params, cfg, "diana", tmp_path / name,
                               act_log_scale=2.0, bias=bias)


def _flip_layer(art, layer_name, domain=1):
    """A copy of ``art`` with one layer's channels forced to ``domain`` —
    the minimal divergent variant (every other layer coincides)."""
    doc = art.to_dict()
    hit = False
    for layer in doc["layers"]:
        if layer["name"] == layer_name:
            n = len(layer["assignment"])
            layer["assignment"] = [domain] * n
            counts = [0] * len(doc["domains"])
            counts[domain] = n
            layer["counts"] = counts
            hit = True
    assert hit, f"no layer named {layer_name!r}"
    return MappingArtifact.from_dict(doc)


@pytest.fixture(scope="module")
def yi(tmp_path_factory):
    """Reduced yi-9b + params + a fully-digital target artifact."""
    tmp = tmp_path_factory.mktemp("planset_yi")
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    target = _artifact(cfg, params, tmp, bias=("digital", 1.0))
    return cfg, params, target, tmp


def _tokens(results):
    return {r.rid: list(r.tokens) for r in results}


# --------------------------------------------------------------------------
# dedup accounting
# --------------------------------------------------------------------------

def test_identical_variants_cost_one_bind(yi):
    cfg, params, target, _ = yi
    plan = lower(target, params=params)
    single = PlannedBackend(plan, params)
    bank = PlanSet({"a": lower(target, params=params),
                    "b": lower(target, params=params)}, params, default="a")
    rep = bank.memory_report()
    # two identical variants hold ONE set of prepared buffers
    assert rep["prepared_bytes"] == single.prepared_bytes()
    assert rep["sum_variant_bytes"] == 2 * rep["prepared_bytes"]
    assert rep["dedup_saved_bytes"] == rep["prepared_bytes"]
    # every prepared entry (plain layer or whole scan stack) is shared
    n_entries = len(bank.variant("a").by_name)
    assert n_entries > 0 and len(rep["shared_layers"]) == n_entries
    assert all(set(vs) == {"a", "b"}
               for vs in rep["shared_layers"].values())


def test_divergent_variants_share_only_coinciding_layers(yi):
    cfg, params, target, _ = yi
    draft_art = _flip_layer(target, "head", domain=1)
    bank = PlanSet({"target": lower(target, params=params),
                    "draft": lower(draft_art, params=params)},
                   params, default="target")
    assert bank.fully_covered
    rep = bank.memory_report()
    shared = rep["shared_layers"]
    # everything except the flipped head coincides and is shared once
    assert "head" not in shared
    n_entries = len(bank.variant("target").by_name)
    assert len(shared) == n_entries - 1
    # the bank is STRICTLY below two independent binds (ISSUE criterion)
    two_binds = (PlannedBackend(lower(target, params=params),
                                params).prepared_bytes() +
                 PlannedBackend(lower(draft_art, params=params),
                                params).prepared_bytes())
    assert 0 < rep["prepared_bytes"] < two_binds
    assert rep["dedup_saved_bytes"] == two_binds - rep["prepared_bytes"]


def test_fully_divergent_variants_share_nothing(yi):
    cfg, params, target, tmp = yi
    draft_art = _artifact(cfg, params, tmp, bias=("aimc", 1.0),
                          name="allaimc.json")
    bank = PlanSet({"target": lower(target, params=params),
                    "draft": lower(draft_art, params=params)},
                   params, default="target")
    rep = bank.memory_report()
    assert rep["shared_layers"] == {}
    assert rep["dedup_saved_bytes"] == 0


# --------------------------------------------------------------------------
# variant selection + coverage diff
# --------------------------------------------------------------------------

def test_variant_selection_matches_single_plan_bind(yi):
    cfg, params, target, tmp = yi
    draft_art = _artifact(cfg, params, tmp, bias=("aimc", 1.0),
                          name="sel.json")
    bank = PlanSet({"target": lower(target, params=params),
                    "draft": lower(draft_art, params=params)},
                   params, default="target")
    draft_only = PlannedBackend(lower(draft_art, params=params), params)
    tokens = jnp.arange(12, dtype=jnp.int32).reshape(1, 12) % cfg.vocab
    caches = T.init_cache(cfg, 1, 16)
    from repro.models.managed import matmul_backend

    def prefill_logits(backend, variant):
        with matmul_backend(backend):
            logits, _ = T.prefill(params, cfg, tokens, caches,
                                  variant=variant)
        return np.asarray(logits)

    # default variant == the target plan; the draft variant under the bank
    # is bit-identical to binding the draft plan alone
    np.testing.assert_array_equal(prefill_logits(bank, "draft"),
                                  prefill_logits(draft_only, None))
    assert not np.array_equal(prefill_logits(bank, None),
                              prefill_logits(bank, "draft"))
    # the context-manager route publishes the same trace-static key
    with matmul_backend(bank), plan_variant("draft"):
        logits, _ = T.prefill(params, cfg, tokens, caches)
    np.testing.assert_array_equal(np.asarray(logits),
                                  prefill_logits(bank, "draft"))


def test_coverage_diff_names_layers_per_variant(yi):
    cfg, params, target, _ = yi
    doc = target.to_dict()
    kept = doc["layers"][0]["name"]
    doc["layers"] = [l for l in doc["layers"] if l["name"] == kept]
    partial = MappingArtifact.from_dict(doc)
    bank = PlanSet({"full": lower(target, params=params),
                    "partial": lower(partial, params=params)},
                   params, default="full")
    assert bank.coverage_diff() == {}          # nothing unbound anywhere
    assert bank.fully_covered

    # an artifact naming a layer the params don't have leaves it UNBOUND
    # on that variant only, and the diff reports the NAME, not a count
    # (lowered without params — WITH params the name mismatch is already a
    # LoweringError; bind-time resolution is what coverage_diff audits)
    doc = target.to_dict()
    doc["layers"][0] = dict(doc["layers"][0], name="units/9/no_such")
    ghost = MappingArtifact.from_dict(doc)
    bank = PlanSet({"full": lower(target, params=params),
                    "ghost": lower(ghost)},
                   params, default="full")
    diff = bank.coverage_diff()
    assert list(diff) == ["ghost"]
    assert diff["ghost"] == ["units/9/no_such"]
    assert not bank.fully_covered


def test_unknown_variant_fails_loud(yi):
    cfg, params, target, _ = yi
    from repro.runtime import ExecutionError
    bank = PlanSet({"only": lower(target, params=params)}, params)
    with plan_variant("nope"), pytest.raises(ExecutionError,
                                             match="unknown plan variant"):
        bank("head", None, None)      # resolution fails before execution


# --------------------------------------------------------------------------
# self-speculative decoding
# --------------------------------------------------------------------------

def _spec_bank(cfg, params, tmp, draft_bias):
    target = _artifact(cfg, params, tmp, bias=("digital", 1.0),
                       name="spec_t.json")
    draft = _artifact(cfg, params, tmp, bias=draft_bias, name="spec_d.json")
    return PlanSet({"target": lower(target, params=params),
                    "draft": lower(draft, params=params)},
                   params, default="target")


def _run_spec_vs_target(cfg, params, bank, *, draft_k=4):
    trace = synthetic_trace(4, vocab=cfg.vocab, seed=3, min_prompt=4,
                            max_prompt=10, min_new=4, max_new=10)
    spec = Engine(cfg, params, max_batch=2, max_len=64, backend=bank,
                  kv_layout="paged", speculate=("draft", "target"),
                  draft_k=draft_k)
    ref = Engine(cfg, params, max_batch=2, max_len=64, backend=bank,
                 kv_layout="paged")
    return spec, _tokens(spec.run(trace)), _tokens(ref.run(trace))


def test_speculative_token_identity_attention_only(yi, tmp_path):
    """yi-9b (attention-only, replay-free): a genuinely divergent ternary-
    tinted draft must still yield TOKEN-IDENTICAL output — acceptance only
    controls speed."""
    cfg, params, _, _ = yi
    bank = _spec_bank(cfg, params, tmp_path, ("aimc", 0.05))
    spec, got, want = _run_spec_vs_target(cfg, params, bank)
    assert got == want
    st = spec.stats
    assert st["spec_rounds"] > 0
    assert 0 <= st["spec_acceptance"] <= 1.0
    assert st["spec_committed"] == sum(len(t) - 1 for t in got.values())


def test_speculative_token_identity_hybrid_replay(tmp_path):
    """zamba2 (hybrid SSM+attention): partial accepts must REPLAY the
    committed tokens over the snapshot recurrent state — token identity
    here pins the rollback machinery."""
    cfg = _reduced("zamba2-1.2b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    bank = _spec_bank(cfg, params, tmp_path, ("aimc", 0.05))
    spec, got, want = _run_spec_vs_target(cfg, params, bank)
    assert got == want
    assert spec._has_recurrent            # the replay path is in play
    assert spec.stats["spec_rounds"] > 0


def test_speculative_identical_draft_accepts_everything(yi, tmp_path):
    """draft == target: every commit-eligible draft token must be accepted
    (acceptance exactly 1.0) and rounds retire whole k+1 blocks."""
    cfg, params, target, _ = yi
    bank = PlanSet({"target": lower(target, params=params),
                    "draft": lower(target, params=params)},
                   params, default="target")
    spec, got, want = _run_spec_vs_target(cfg, params, bank)
    assert got == want
    assert spec.stats["spec_acceptance"] == 1.0
    assert spec.stats["spec_tokens_per_round"] > 1.0


# --------------------------------------------------------------------------
# SLO routing
# --------------------------------------------------------------------------

def test_slo_routing_parity_and_metrics(yi, tmp_path):
    """Routed requests get their class's variant with numerics identical
    to serving them ALONE under that variant, and `summarize` breaks out
    per-class tails."""
    cfg, params, target, _ = yi
    draft_art = _artifact(cfg, params, tmp_path, bias=("aimc", 1.0),
                          name="slo.json")
    bank = PlanSet({"default": lower(target, params=params),
                    "cheap": lower(draft_art, params=params)},
                   params, default="default")
    trace = synthetic_trace(4, vocab=cfg.vocab, seed=5, min_prompt=4,
                            max_prompt=8, min_new=3, max_new=6,
                            slo_classes=["batch", "interactive"])
    eng = Engine(cfg, params, max_batch=2, max_len=64, backend=bank,
                 kv_layout="paged",
                 slo_routes={"interactive": "cheap", "batch": "default"})
    got = eng.run(trace)
    tokens = _tokens(got)
    # oracle: each request served ALONE under its routed variant
    for req in trace:
        variant = {"interactive": "cheap", "batch": "default"}[req.slo]
        solo_bank = PlanSet(
            {"v": lower(draft_art if variant == "cheap" else target,
                        params=params)}, params)
        solo = Engine(cfg, params, max_batch=1, max_len=64,
                      backend=solo_bank, kv_layout="paged")
        want = _tokens(solo.run([req]))
        assert tokens[req.rid] == want[req.rid], req.rid
    from repro.serving import summarize
    summary = summarize(got, eng.stats["wall_s"])
    assert set(summary["by_slo"]) == {"batch", "interactive"}
    for cls in ("batch", "interactive"):
        assert summary["by_slo"][cls]["requests"] == 2


def test_slo_unrouted_class_fails_loud(yi):
    cfg, params, target, _ = yi
    bank = PlanSet({"default": lower(target, params=params)}, params)
    eng = Engine(cfg, params, max_batch=2, max_len=64, backend=bank,
                 kv_layout="paged", slo_routes={"gold": "default"})
    trace = synthetic_trace(2, vocab=cfg.vocab, slo_classes=["silver"])
    with pytest.raises(ValueError, match="no route"):
        eng.run(trace)


# --------------------------------------------------------------------------
# non-greedy sampling
# --------------------------------------------------------------------------

def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=0.0)
    with pytest.raises(ValueError):
        SamplingParams(temperature=1.0, top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(temperature=1.0, top_p=1.5)


def test_sampling_off_by_default_and_seed_deterministic(yi):
    """No `sampling` -> greedy (the historical engine output); with
    sampling, the SAME seed reproduces the run and a different seed
    diverges — per-slot PRNG state survives continuous batching."""
    cfg, params, _, _ = yi
    trace = synthetic_trace(3, vocab=cfg.vocab, seed=7, min_prompt=4,
                            max_prompt=8, min_new=4, max_new=6)

    def run(sampling):
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     kv_layout="paged", sampling=sampling)
        return _tokens(eng.run(trace))

    greedy = run(None)
    hot = SamplingParams(temperature=5.0, top_p=0.9, seed=11)
    a, b = run(hot), run(hot)
    assert a == b                                 # seed-deterministic
    assert run(SamplingParams(temperature=5.0, top_p=0.9, seed=12)) != a
    assert a != greedy                            # it actually samples


# --------------------------------------------------------------------------
# engine validation
# --------------------------------------------------------------------------

def test_engine_multiplan_validation_errors(yi):
    cfg, params, target, _ = yi
    bank = PlanSet({"target": lower(target, params=params),
                    "draft": lower(target, params=params)},
                   params, default="target")
    mk = lambda **kw: Engine(cfg, params, max_batch=2, max_len=64, **kw)
    with pytest.raises(ValueError, match="pair of variant names"):
        mk(backend=bank, speculate="draft")
    with pytest.raises(ValueError, match="requires kv_layout='paged'"):
        mk(backend=bank, kv_layout="dense",
           speculate=("draft", "target"))
    with pytest.raises(ValueError, match="greedy-only"):
        mk(backend=bank, kv_layout="paged", speculate=("draft", "target"),
           sampling=SamplingParams(temperature=1.0))
    with pytest.raises(ValueError, match="mutually exclusive"):
        mk(backend=bank, kv_layout="paged", speculate=("draft", "target"),
           slo_routes={"x": "draft"})
    with pytest.raises(ValueError, match="draft_k"):
        mk(backend=bank, kv_layout="paged", speculate=("draft", "target"),
           draft_k=0)
    with pytest.raises(ValueError, match="is not bound"):
        mk(backend=bank, kv_layout="paged", speculate=("tiny", "target"))
    with pytest.raises(ValueError, match="multi-variant PlanSet"):
        mk(backend=None, kv_layout="paged", speculate=("draft", "target"))
    with pytest.raises(ValueError, match="is not bound"):
        mk(backend=bank, kv_layout="paged", slo_routes={"gold": "nope"})
    with pytest.raises(ValueError, match="requires kv_layout='paged'"):
        mk(backend=bank, kv_layout="dense", slo_routes={"gold": "draft"})
