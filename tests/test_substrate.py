"""Substrate tests: checkpointing (atomicity, corruption, resharding),
fault-tolerance logic, data-pipeline determinism, optimizer, compression."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import (ImageTaskConfig, ShardedLoader,
                                 TokenTaskConfig, image_batch, token_batch)
from repro.distributed import fault_tolerance as ft
from repro.distributed import sharding as sh
from repro.optim import adamw, compression


# ---------------------------------------------------------------- checkpoint
def _tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones(5, jnp.bfloat16)},
            "step": jnp.asarray(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t, extra={"step": 3})
    assert ckpt.latest_step(tmp_path) == 3
    out = ckpt.restore(tmp_path, 3, jax.tree.map(lambda x: x, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.restore_extra(tmp_path, 3)["step"] == 3


def test_checkpoint_atomicity_torn_write_ignored(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    # simulate a crash mid-write: step dir without _COMMITTED
    torn = tmp_path / "step_00000002"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert ckpt.latest_step(tmp_path) == 1  # torn write invisible


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    d = ckpt.save(tmp_path, 1, t)
    data = np.load(d / "arrays.npz")
    arrays = {k: data[k].copy() for k in data.files}
    arrays["leaf_0"] = (arrays["leaf_0"] + 1).astype(np.uint8)
    np.savez(d / "arrays.npz", **arrays)
    with pytest.raises(IOError, match="corruption"):
        ckpt.restore(tmp_path, 1, t)


def test_checkpoint_resharding_restore(tmp_path):
    """Save unsharded, restore with an explicit target sharding (elastic)."""
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(tmp_path, 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    shd = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    out = ckpt.restore(tmp_path, 1, t, shardings=shd)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))
    assert out["w"].sharding.spec == jax.sharding.PartitionSpec("data", None)


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(tmp_path)
    c.save(5, _tree(), {"step": 5})
    c.wait()
    assert ckpt.latest_step(tmp_path) == 5


def test_prune_old(tmp_path):
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, {"x": jnp.zeros(1)})
    ckpt.prune_old(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 4
    assert not (tmp_path / "step_00000001").exists()
    assert (tmp_path / "step_00000003").exists()


# ------------------------------------------------------------ fault tolerance
def test_heartbeat_monitor():
    clock = [0.0]
    hb = ft.HeartbeatMonitor(["a", "b"], deadline_s=10,
                             clock=lambda: clock[0])
    clock[0] = 5.0
    hb.beat("a")
    clock[0] = 12.0
    assert hb.dead_hosts() == ["b"]


def test_straggler_policy_escalates():
    p = ft.StragglerPolicy(threshold=2.0, tolerance=2)
    assert p.observe(0, 1.0) == "ok"
    assert p.observe(1, 1.0) == "ok"
    assert p.observe(2, 5.0) == "straggler"
    assert p.observe(3, 5.0) == "escalate"


def test_elastic_plan():
    plan = ft.ElasticPlan(old_shape=(16, 16), new_hosts=48, chips_per_host=4)
    assert plan.propose() == (12, 16)       # model axis preserved
    assert plan.needs_reshard


def test_supervisor_crash_restart(tmp_path):
    """Simulated node failure: supervisor restarts from the last committed
    checkpoint and completes, with bit-identical data (step-keyed loader)."""
    store = {}

    def save_fn(step, state):
        store["ckpt"] = (step, float(state))

    def restore_fn():
        return store.get("ckpt", (0, 0.0))

    def step_fn(state, step):
        return state + 1.0, {"grad_norm": 1.0}

    sup = ft.TrainSupervisor(step_fn, save_fn, restore_fn, ckpt_every=10,
                             inject_crash_at=25)
    final_step, state = sup.run(40)
    assert final_step == 40
    assert any(e["event"] == "crash" for e in sup.log)
    # state advanced exactly (40 - lost steps rerun deterministically)
    assert state == 40.0 - 20.0 or state >= 20.0


def test_supervisor_skips_nonfinite():
    calls = {"n": 0}

    def step_fn(state, step):
        calls["n"] += 1
        gn = float("nan") if step == 3 else 1.0
        return state + 1, {"grad_norm": gn}

    sup = ft.TrainSupervisor(step_fn, lambda s, st: None, lambda: (0, 0),
                             ckpt_every=100)
    final, state = sup.run(6)
    assert final == 6
    assert state == 5  # one skipped update
    assert any(e["event"] == "skip_nonfinite" for e in sup.log)


# ---------------------------------------------------------------- data
def test_data_determinism_and_resharding():
    cfg = TokenTaskConfig(vocab=97)
    a1, b1 = token_batch(cfg, step=5, batch=8, seq_len=16)
    a2, b2 = token_batch(cfg, step=5, batch=8, seq_len=16)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    a3, _ = token_batch(cfg, step=6, batch=8, seq_len=16)
    assert not np.array_equal(np.asarray(a1), np.asarray(a3))

    ld = ShardedLoader("token", cfg, batch=8, seq_len=16, shard=0, n_shards=2)
    x0, _ = ld.get(5)
    assert x0.shape == (4, 16)
    ld.reshard(shard=1, n_shards=4)
    x1, _ = ld.get(5)
    assert x1.shape == (2, 16)


def test_image_task_learnable_structure():
    cfg = ImageTaskConfig(n_classes=4, img_hw=(8, 8))
    x, y = image_batch(cfg, 0, 64)
    assert x.shape == (64, 8, 8, 3) and y.shape == (64,)
    # same-class images correlate more than cross-class
    xv = np.asarray(x).reshape(64, -1)
    yv = np.asarray(y)
    same, diff = [], []
    for i in range(20):
        for j in range(i + 1, 20):
            c = np.dot(xv[i], xv[j]) / (np.linalg.norm(xv[i]) *
                                        np.linalg.norm(xv[j]))
            (same if yv[i] == yv[j] else diff).append(c)
    if same and diff:
        assert np.mean(same) > np.mean(diff)


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, clip_norm=None)
    params = {"x": jnp.asarray(5.0)}
    state = adamw.init(params, cfg)
    for _ in range(200):
        grads = jax.grad(lambda p: (p["x"] - 2.0) ** 2)(params)
        params, state, _ = adamw.update(grads, state, params, cfg)
    assert abs(float(params["x"]) - 2.0) < 1e-2


def test_adamw_bf16_moments():
    cfg = adamw.AdamWConfig(lr=0.01, moment_dtype=jnp.bfloat16)
    params = {"x": jnp.ones(4)}
    state = adamw.init(params, cfg)
    assert state.mu["x"].dtype == jnp.bfloat16
    grads = {"x": jnp.ones(4)}
    params, state, gn = adamw.update(grads, state, params, cfg)
    assert np.isfinite(float(gn))


def test_warmup_cosine_schedule():
    lr0 = float(adamw.warmup_cosine(0, peak_lr=1.0, warmup=10, total=100))
    lrw = float(adamw.warmup_cosine(10, peak_lr=1.0, warmup=10, total=100))
    lre = float(adamw.warmup_cosine(100, peak_lr=1.0, warmup=10, total=100))
    assert lr0 == 0.0 and abs(lrw - 1.0) < 1e-6 and abs(lre - 0.1) < 1e-6


# ------------------------------------------------------------- compression
def test_compression_error_feedback_converges():
    """Compressed-gradient descent with error feedback reaches the optimum."""
    x = jnp.asarray([5.0, -3.0])
    residual = {"x": jnp.zeros(2)}
    for _ in range(300):
        g = {"x": 2 * (x - jnp.asarray([1.0, 2.0]))}
        comp, residual = compression.compress_with_feedback(g, residual)
        g = compression.decompress(comp)
        x = x - 0.05 * g["x"]
    np.testing.assert_allclose(np.asarray(x), [1.0, 2.0], atol=5e-2)


def test_compression_is_4x_smaller():
    g = {"w": jnp.ones((256, 256))}
    comp, _ = compression.compress_with_feedback(
        g, compression.init_residual(g))
    assert compression.compressed_bytes(comp) < 256 * 256 * 4 / 3.5


# ---------------------------------------------------------------- sharding
def test_param_rules_and_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    P = jax.sharding.PartitionSpec
    dp, tp = ("data",), "model"
    s = sh.param_spec(mesh, "units/0/attn/wq/w", (64, 128), dp, tp)
    assert s == P(("data",), "model")
    s = sh.param_spec(mesh, "units/0/attn/wo/w", (128, 64), dp, tp)
    assert s == P("model", ("data",))
    s = sh.param_spec(mesh, "units/0/moe/up", (8, 64, 128), dp, tp)
    assert s == P("model", ("data",), None)
    s = sh.param_spec(mesh, "units/0/norm1/scale", (64,), dp, tp)
    assert s == P()
    # leading stacked dim gets None
    s = sh.param_spec(mesh, "units/attn/wq/w", (6, 64, 128), dp, tp)
    assert s == P(None, ("data",), "model")


def test_divisibility_fallback_replicates():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 7 not divisible by model size (1 divides everything => use fake check)
    assert sh._divides(mesh, "model", 7)  # size-1 axis divides all
