"""Unit + property tests for the ODiMO core (quant, mixing, costs, reorg)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import (
    ODiMOSpec, DianaCostModel, AbstractCostModel, TPUCostModel, LayerGeometry,
    fake_quant, fake_quant_act, smooth_max, latency_loss, energy_loss,
    exact_latency, exact_energy, baselines,
)
from repro.core import odimo, quant, discretize, losses


# ----------------------------------------------------------- quantization
@settings(max_examples=25, deadline=None)
@given(n_bits=st.sampled_from([2, 3, 4, 8]),
       seed=st.integers(0, 2**16))
def test_fake_quant_levels(n_bits, seed):
    """Fake-quantized values lie on the symmetric grid and within scale."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (9, 13))
    ls = quant.init_log_scale(w)
    wq = np.asarray(fake_quant(w, ls, n_bits))
    scale = float(jnp.exp(ls))
    lv = quant.qlevels(n_bits)
    grid = np.round(wq / scale * lv)
    np.testing.assert_allclose(grid, wq / scale * lv, atol=1e-4)
    assert np.abs(wq).max() <= scale * (1 + 1e-6)


def test_ternary_is_three_valued():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    ls = quant.init_log_scale(w)
    wq = np.asarray(fake_quant(w, ls, 2)) / float(jnp.exp(ls))
    assert set(np.round(np.unique(wq), 5)) <= {-1.0, 0.0, 1.0}


def test_fake_quant_8bit_small_error():
    w = jax.random.normal(jax.random.PRNGKey(1), (128,))
    ls = quant.init_log_scale(w)
    err = jnp.max(jnp.abs(fake_quant(w, ls, 8) - w))
    assert float(err) <= float(jnp.exp(ls)) / quant.qlevels(8)


def test_int_roundtrip_matches_fake_quant():
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    ls = quant.init_log_scale(w)
    deq = quant.dequantize_int(quant.quantize_int(w, ls, 8), ls, 8)
    np.testing.assert_allclose(np.asarray(deq),
                               np.asarray(fake_quant(w, ls, 8)), atol=1e-6)


def test_ste_gradient_flows():
    w = jax.random.normal(jax.random.PRNGKey(3), (8, 8))
    ls = quant.init_log_scale(w)
    g = jax.grad(lambda w: jnp.sum(fake_quant(w, ls, 8) ** 2))(w)
    assert float(jnp.linalg.norm(g)) > 0


# ----------------------------------------------------------- ODiMO mixing
def test_effective_weight_convex_combination():
    spec = ODiMOSpec()
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 24))
    st_ = odimo.init_layer_state(jax.random.PRNGKey(1), w, spec)
    we = odimo.effective_weight(w, st_, spec, tau=1.0)
    wq = [fake_quant(w, st_["log_scales"][i], d.weight_bits)
          for i, d in enumerate(spec.domains)]
    lo = jnp.minimum(*wq) - 1e-6
    hi = jnp.maximum(*wq) + 1e-6
    assert bool(jnp.all((we >= lo) & (we <= hi)))


def test_low_tau_recovers_argmax_domain():
    spec = ODiMOSpec()
    w = jax.random.normal(jax.random.PRNGKey(0), (16, 24))
    st_ = odimo.init_layer_state(jax.random.PRNGKey(1), w, spec)
    st_["alpha"] = jnp.asarray(np.random.default_rng(0).normal(size=(2, 24)) * 3)
    we = odimo.effective_weight(w, st_, spec, tau=1e-4)
    wd = odimo.discretized_weight(w, st_, spec)
    np.testing.assert_allclose(np.asarray(we), np.asarray(wd), atol=1e-4)


def test_expected_counts_sum_to_cout():
    spec = ODiMOSpec()
    w = jax.random.normal(jax.random.PRNGKey(0), (4, 37))
    st_ = odimo.init_layer_state(jax.random.PRNGKey(1), w, spec)
    ec = odimo.expected_counts(st_, tau=0.7)
    assert abs(float(jnp.sum(ec)) - 37) < 1e-4


def test_tau_schedule_endpoints():
    spec = ODiMOSpec(init_tau=2.0, final_tau=0.1)
    assert abs(float(odimo.tau_schedule(0, 100, spec)) - 2.0) < 1e-5
    assert abs(float(odimo.tau_schedule(100, 100, spec)) - 0.1) < 1e-5


# ----------------------------------------------------------- cost models
def test_smooth_max_bounds():
    x = jnp.asarray([1.0, 5.0, 3.0])
    sm = float(smooth_max(x, beta=0.01))
    assert 5.0 <= sm <= 5.0 + 0.01 * np.log(3) + 1e-6


def test_diana_latency_monotone_in_channels():
    cm = DianaCostModel()
    g = LayerGeometry(c_in=64, c_out=128, fx=3, fy=3, ox=16, oy=16)
    lat_small = cm.latency(g, jnp.asarray([16.0, 16.0]))
    lat_big = cm.latency(g, jnp.asarray([128.0, 128.0]))
    assert np.all(np.asarray(lat_big) >= np.asarray(lat_small))


def test_diana_zero_channels_zero_latency():
    cm = DianaCostModel()
    g = LayerGeometry(c_in=64, c_out=128, fx=3, fy=3, ox=16, oy=16)
    lat = np.asarray(cm.latency(g, jnp.asarray([0.0, 128.0])))
    assert lat[0] == 0.0 and lat[1] > 0


def test_abstract_model_energy_equals_latency_objective():
    """Fig. 5 corner case: P_idle = P_act makes Eq.4 == Eq.3 * const."""
    cm = AbstractCostModel(ideal_shutdown=False)
    g = [LayerGeometry(c_in=32, c_out=64, fx=3, fy=3, ox=8, oy=8)]
    for counts in ([64, 0], [32, 32], [0, 64], [10, 54]):
        lat = np.asarray(cm.latency(g[0], jnp.asarray(counts, jnp.float32)))
        m = lat.max()
        en = float(exact_energy(cm, g, [counts]))
        # Eq.4 with P_idle=P_act: sum_i P_i * M  (independent of split!)
        assert abs(en - float(np.sum(np.asarray(cm.p_act())) * m)) < 1e-3


def test_tpu_cost_model_int8_faster_when_compute_bound():
    cm = TPUCostModel()
    g = LayerGeometry(c_in=4096, c_out=4096, ox=512, oy=1)  # high intensity
    lat = np.asarray(cm.latency(g, jnp.asarray([2048.0, 2048.0])))
    assert lat[0] < lat[1]  # int8 domain faster at equal channels


def test_ste_ceil_forward_exact():
    from repro.core.cost_models import ste_ceil
    x = jnp.asarray([0.1, 1.0, 1.5, 2.999])
    np.testing.assert_allclose(np.asarray(ste_ceil(x)), [1, 1, 2, 3])
    g = jax.grad(lambda x: jnp.sum(ste_ceil(x)))(x)
    np.testing.assert_allclose(np.asarray(g), 1.0)


# ----------------------------------------------------------- baselines
def _geoms():
    return [LayerGeometry(c_in=16, c_out=32, fx=3, fy=3, ox=16, oy=16),
            LayerGeometry(c_in=32, c_out=64, fx=3, fy=3, ox=8, oy=8),
            LayerGeometry(c_in=64, c_out=10)]


def test_baseline_shapes_and_values():
    gs = _geoms()
    for fn, dom in [(baselines.all_8bit, 0), (baselines.all_ternary, 1)]:
        a = fn(gs)
        assert all((x == dom).all() for x in a)
    io = baselines.io8_backbone_ternary(gs)
    assert (io[0] == 0).all() and (io[-1] == 0).all() and (io[1] == 1).all()


def test_min_cost_beats_or_ties_trivial_mappings():
    cm = DianaCostModel()
    gs = _geoms()
    mc = baselines.min_cost(cm, gs, "latency")
    def lat_of(assigns):
        counts = baselines.counts_from_assignments(assigns, 2)
        return float(exact_latency(cm, gs, counts))
    assert lat_of(mc) <= lat_of(baselines.all_8bit(gs)) + 1e-6
    assert lat_of(mc) <= lat_of(baselines.all_ternary(gs)) + 1e-6


def test_min_cost_respects_pinned_layers():
    cm = DianaCostModel()
    gs = _geoms()
    mc = baselines.min_cost(cm, gs, "latency", searchable=[False, True, True])
    assert (mc[0] == 0).all()


# ----------------------------------------------------------- reorg pass
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), c1=st.integers(4, 24), c2=st.integers(4, 24))
def test_reorg_preserves_mlp_function(seed, c1, c2):
    """Fig. 3 pass: permuting out+next-in channels preserves the network."""
    rng = np.random.default_rng(seed)
    w1 = jnp.asarray(rng.normal(size=(8, c1)))
    b1 = jnp.asarray(rng.normal(size=(c1,)))
    w2 = jnp.asarray(rng.normal(size=(c1, c2)))
    b2 = jnp.asarray(rng.normal(size=(c2,)))
    w3 = jnp.asarray(rng.normal(size=(c2, 5)))
    a1 = rng.integers(0, 2, size=c1)
    a2 = rng.integers(0, 2, size=c2)
    layers = [
        discretize.ReorgLayer(w=w1, b=b1, assign=a1, in_axis=0),
        discretize.ReorgLayer(w=w2, b=b2, assign=a2, in_axis=0),
        discretize.ReorgLayer(w=w3, b=None, assign=np.zeros(5, np.int64), in_axis=0),
    ]
    x = jnp.asarray(rng.normal(size=(3, 8)))

    def fwd(ls):
        h = jax.nn.relu(x @ ls[0].w + ls[0].b)
        h = jax.nn.relu(h @ ls[1].w + ls[1].b)
        return h @ ls[2].w

    y_ref = fwd(layers)
    new_layers, bounds = discretize.reorg_chain(layers, n_domains=2)
    y_new = fwd(new_layers)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_new), atol=1e-5)
    # channels grouped contiguously per domain
    for nl in new_layers[:-1]:
        assert (np.diff(nl.assign) >= 0).all()


def test_reorg_conv_chain_preserves_function():
    rng = np.random.default_rng(0)
    w1 = jnp.asarray(rng.normal(size=(3, 3, 3, 12)) * 0.2)
    w2 = jnp.asarray(rng.normal(size=(3, 3, 12, 8)) * 0.2)
    a1 = rng.integers(0, 2, size=12)
    layers = [
        discretize.ReorgLayer(w=w1, b=jnp.zeros(12), assign=a1, in_axis=2),
        discretize.ReorgLayer(w=w2, b=jnp.zeros(8),
                              assign=np.zeros(8, np.int64), in_axis=2),
    ]
    x = jnp.asarray(rng.normal(size=(2, 8, 8, 3)))

    def fwd(ls):
        h = jax.lax.conv_general_dilated(x, ls[0].w, (1, 1), "SAME",
                                         dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + ls[0].b)
        h = jax.lax.conv_general_dilated(h, ls[1].w, (1, 1), "SAME",
                                         dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return h + ls[1].b

    y_ref = fwd(layers)
    new_layers, _ = discretize.reorg_chain(layers, n_domains=2)
    y_new = fwd(new_layers)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_new), atol=1e-5)


def test_sublayer_slices():
    sl = discretize.sublayer_slices([3, 10])
    assert sl == [(0, 3), (3, 10)]
