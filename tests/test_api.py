"""Tests for the first-class mapping API (`repro.api`): Platform registry
round-trip, pipeline-vs-legacy bit-for-bit equivalence, mapping-artifact
serialization and its consumers."""
import json

import jax
import numpy as np
import pytest

from repro.api import (MappingArtifact, ModelHandle, Platform, SearchConfig,
                       SearchPipeline, cnn_handle, mlp_handle,
                       transformer_handle)
from repro.core import baselines as BL
from repro.core import discretize, engine
from repro.core.cost_models import AbstractCostModel
from repro.data.pipeline import ImageTaskConfig, image_batch
from repro.models import cnn

TINY = SearchConfig(lam=1e-6, objective="latency", pretrain_steps=4,
                    search_steps=6, finetune_steps=3, batch=8, eval_batches=2)


def _data_fn(cfg):
    task = ImageTaskConfig(n_classes=cfg.n_classes, img_hw=cfg.img_hw)
    return lambda step, batch: image_batch(task, step, batch)


# --------------------------------------------------------------------------
# Platform registry
# --------------------------------------------------------------------------

def test_platform_registry_roundtrip():
    from repro.core.quant import DIANA_DOMAINS
    plat = Platform(name="_test_soc", domains=tuple(DIANA_DOMAINS),
                    cost_model_factory=lambda: AbstractCostModel(True))
    try:
        Platform.register(plat)
        assert Platform.get("_test_soc") is plat
        assert "_test_soc" in Platform.names()
        spec = plat.spec()
        assert spec.domains == tuple(DIANA_DOMAINS)
        assert spec.act_bits == 7  # worst case of (8, 7)
        assert plat.cost_model().ideal_shutdown
        # duplicate registration must be an explicit error...
        with pytest.raises(ValueError, match="already registered"):
            Platform.register(plat)
        # ...unless overwrite is requested
        Platform.register(plat, overwrite=True)
    finally:
        Platform.unregister("_test_soc")
    assert "_test_soc" not in Platform.names()
    with pytest.raises(KeyError, match="unknown platform"):
        Platform.get("_test_soc")


def test_builtin_platforms_present():
    for name in ("diana", "diana_abstract", "diana_ideal_shutdown",
                 "tpu_v5e"):
        plat = Platform.get(name)
        assert plat.cost_model().latency is not None
        assert plat.spec().n_domains == len(plat.domains)


# --------------------------------------------------------------------------
# Pipeline vs legacy engine: bit-for-bit
# --------------------------------------------------------------------------

def _legacy_run_odimo(model, cfg_model, spec, cost_model, scfg, data_fn):
    """Verbatim copy of the pre-refactor `engine.run_odimo` loop (seed
    revision), kept here so the equivalence test pins the HISTORICAL
    semantics independently of the pipeline implementation."""
    import jax.numpy as jnp
    from functools import partial
    from repro.core import losses, odimo
    from repro.optim import adamw

    init_fn, apply_raw, plan_fn = model
    plan = plan_fn(cfg_model)
    geoms = [g for (_, g, _) in plan]
    searchable = [s for (_, _, s) in plan]
    managed_paths_fn = lambda p: cnn.managed_layer_dicts(p, cfg_model)
    apply_fn = lambda p, x, mode, tau: apply_raw(p, x, cfg_model, spec, mode,
                                                 tau)
    key = jax.random.PRNGKey(scfg.seed)
    params = init_fn(key, cfg_model, spec)
    ocfg = adamw.AdamWConfig(lr=scfg.lr)

    def loss_fn(params, batch, tau, mode):
        x, y = batch
        logits = apply_fn(params, x, mode=mode, tau=tau)
        task = losses.cross_entropy(logits, y)
        if mode != "search":
            return task, (task, 0.0)
        layer_dicts = managed_paths_fn(params)
        abars, g_s = [], []
        for d, geom, s in zip(layer_dicts, geoms, searchable):
            if not s or "odimo" not in d:
                continue
            abars.append(odimo.alpha_bar(d["odimo"]["alpha"], tau))
            g_s.append(geom)
        if scfg.objective == "latency":
            reg = losses.latency_loss(cost_model, g_s, abars)
        else:
            reg = losses.energy_loss(cost_model, g_s, abars)
        return task + scfg.lam * reg, (task, reg)

    @partial(jax.jit, static_argnames=("mode",))
    def train_step(params, opt, batch, tau, lr, mode):
        (l, (task, reg)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, tau, mode)
        ratio = scfg.alpha_lr / scfg.lr

        def scale(path, g):
            if any(getattr(p, "key", None) == "alpha" for p in path):
                return g * ratio
            return g
        grads = jax.tree_util.tree_map_with_path(scale, grads)
        params, opt, gn = adamw.update(grads, opt, params, ocfg, lr=lr)
        return params, opt, l, task, reg

    @partial(jax.jit, static_argnames=("mode",))
    def eval_step(params, batch, tau, mode):
        x, y = batch
        logits = apply_fn(params, x, mode=mode, tau=tau)
        return jnp.mean(jnp.argmax(logits, -1) == y)

    opt = adamw.init(params, ocfg)
    for step in range(scfg.pretrain_steps):
        params, opt, *_ = train_step(params, opt, data_fn(step, scfg.batch),
                                     1.0, scfg.lr, "fp")
    opt = adamw.init(params, ocfg)
    for step in range(scfg.search_steps):
        tau = float(odimo.tau_schedule(step, scfg.search_steps, spec))
        params, opt, *_ = train_step(params, opt,
                                     data_fn(10_000 + step, scfg.batch),
                                     tau, scfg.lr, "search")
    layer_dicts = managed_paths_fn(params)
    assignments, counts = [], []
    for d, s in zip(layer_dicts, searchable):
        if s and "odimo" in d:
            a = np.asarray(odimo.assignment(d["odimo"]))
        else:
            a = np.zeros(d["w"].shape[-1], dtype=np.int64)
        assignments.append(a)
        counts.append(np.asarray([int((a == i).sum())
                                  for i in range(spec.n_domains)]))
    opt = adamw.init(params, ocfg)
    for step in range(scfg.finetune_steps):
        params, opt, *_ = train_step(params, opt,
                                     data_fn(20_000 + step, scfg.batch),
                                     1.0, scfg.lr * 0.3, "finetune")
    accs = [float(eval_step(params, data_fn(90_000 + b, scfg.batch), 1.0,
                            "finetune"))
            for b in range(scfg.eval_batches)]
    lat = float(losses.exact_latency(cost_model, geoms, counts))
    en = float(losses.exact_energy(cost_model, geoms, counts))
    return assignments, float(np.mean(accs)), lat, en


@pytest.mark.slow
def test_pipeline_reproduces_legacy_run_odimo():
    """`SearchPipeline` must agree bit-for-bit (assignments, accuracy,
    latency, energy) with the pre-refactor engine loop on a fixed seed."""
    cfg = cnn.RESNET20_TINY
    data_fn = _data_fn(cfg)
    plat = Platform.get("diana")

    res_pipe = SearchPipeline(cnn_handle(cfg), "diana", config=TINY,
                              data_fn=data_fn).run()
    assigns, acc, lat, en = _legacy_run_odimo(
        cnn.get_model(cfg), cfg, plat.spec(), plat.cost_model(), TINY,
        data_fn)

    assert len(res_pipe.assignments) == len(assigns)
    for a, b in zip(res_pipe.assignments, assigns):
        np.testing.assert_array_equal(a, b)
    assert res_pipe.accuracy == acc
    assert res_pipe.latency == lat
    assert res_pipe.energy == en
    # the pipeline additionally emits the serializable artifact
    assert res_pipe.artifact is not None
    assert res_pipe.artifact.metrics["accuracy"] == res_pipe.accuracy
    # and the back-compat wrapper routes through the same pipeline
    res_wrap = engine.run_odimo(cnn.get_model(cfg), cfg, plat.spec(),
                                plat.cost_model(), TINY, data_fn)
    assert res_wrap.accuracy == acc and res_wrap.latency == lat


@pytest.mark.slow
def test_fixed_mapping_matches_legacy_wrapper():
    cfg = cnn.RESNET20_TINY
    data_fn = _data_fn(cfg)
    handle = cnn_handle(cfg)
    assigns = BL.io8_backbone_ternary(handle.geometries())
    plat = Platform.get("diana")
    scfg = SearchConfig(pretrain_steps=2, finetune_steps=2, batch=8,
                        eval_batches=2)

    res_pipe = SearchPipeline.fixed_mapping(handle, assigns, "diana",
                                            config=scfg,
                                            data_fn=data_fn).run()
    res_legacy = engine.evaluate_fixed_mapping(cnn.get_model(cfg), cfg,
                                               plat.spec(), plat.cost_model(),
                                               scfg, data_fn, assigns)
    assert res_pipe.accuracy == res_legacy.accuracy
    assert res_pipe.latency == res_legacy.latency
    assert res_pipe.energy == res_legacy.energy


def test_with_assignments_is_functional():
    """Alpha injection must not mutate the input pytree (the old code relied
    on dict aliasing and hardcoded the CNN path)."""
    cfg = cnn.RESNET20_TINY
    handle = cnn_handle(cfg)
    spec = Platform.get("diana").spec()
    params = handle.init(jax.random.PRNGKey(0), spec)
    before = np.asarray(handle.layers(params)[0]["odimo"]["alpha"]).copy()
    assigns = BL.all_ternary(handle.geometries())
    mapped = handle.with_assignments(params, assigns, spec.n_domains)
    np.testing.assert_array_equal(
        np.asarray(handle.layers(params)[0]["odimo"]["alpha"]), before)
    a0 = np.asarray(handle.layers(mapped)[0]["odimo"]["alpha"])
    np.testing.assert_array_equal(a0.argmax(axis=0), assigns[0])
    # a partial assignment list is an explicit error, not silent truncation
    with pytest.raises(ValueError, match="assignments"):
        handle.with_assignments(params, assigns[:-1], spec.n_domains)


# --------------------------------------------------------------------------
# Handles
# --------------------------------------------------------------------------

def test_legacy_tuple_handle_path_lookup():
    """Default managed-layer lookup resolves plan names as pytree paths — no
    CNN-specific fallback anywhere."""
    cfg = cnn.RESNET20_TINY
    handle = ModelHandle.from_legacy(cnn.get_model(cfg), cfg)
    spec = Platform.get("diana").spec()
    params = handle.init(jax.random.PRNGKey(0), spec)
    layers = handle.layers(params)
    assert len(layers) == len(handle.plan())
    assert all("w" in d for d in layers)
    expected = cnn.managed_layer_dicts(params, cfg)
    assert all(a is b for a, b in zip(layers, expected))


@pytest.mark.parametrize("make_handle", [
    lambda: mlp_handle(in_dim=768, widths=(16, 16), n_classes=10),
    lambda: transformer_handle(in_dim=48, n_tokens=16, d_model=16,
                               n_layers=1, n_classes=10, n_heads=2),
])
def test_facade_handles_run_end_to_end(make_handle):
    cfg = cnn.RESNET20_TINY  # only used for the synthetic image geometry
    handle = make_handle()
    res = SearchPipeline(handle, "tpu_v5e", config=TINY,
                         data_fn=_data_fn(cfg)).run()
    assert len(res.assignments) == len(handle.plan())
    assert res.artifact.platform == "tpu_v5e"
    assert 0.0 <= res.accuracy <= 1.0 and res.latency > 0


# --------------------------------------------------------------------------
# Mapping artifact + consumers
# --------------------------------------------------------------------------

def _tiny_artifact():
    handle = mlp_handle(in_dim=8, widths=(6, 4), n_classes=3)
    spec = Platform.get("diana").spec()
    assigns = [np.array([0, 1, 0, 1, 0, 1]), np.array([1, 1, 0, 0]),
               np.array([0, 0, 0])]
    counts = BL.counts_from_assignments(assigns, 2)
    return handle, MappingArtifact.from_search(
        "tiny_mlp", spec, handle.plan(), assigns, counts, platform="diana",
        objective="latency", lam=1e-6, seed=0,
        metrics=dict(accuracy=0.9, latency=1.0, energy=2.0))


def test_artifact_json_roundtrip(tmp_path):
    _, art = _tiny_artifact()
    p = art.save(tmp_path / "mapping.json")
    loaded = MappingArtifact.load(p)
    assert loaded.to_dict() == art.to_dict()
    doc = json.loads(p.read_text())
    assert doc["schema_version"] == 2
    assert doc["layers"][0]["assignment"] == [0, 1, 0, 1, 0, 1]
    assert doc["domains"][0]["name"] == "digital"
    for a, b in zip(loaded.assignments(), art.assignments()):
        np.testing.assert_array_equal(a, b)
    # future schema versions are rejected, not silently misread
    doc["schema_version"] = 99
    with pytest.raises(ValueError, match="newer"):
        MappingArtifact.from_dict(doc)


def test_discretize_consumes_artifact():
    """`reorg_chain_from_artifact` runs the Fig. 3 pass off the stored
    assignment: same-domain channels become contiguous and the next layer's
    input axis is permuted consistently."""
    handle, art = _tiny_artifact()
    spec = Platform.get("diana").spec()
    params = handle.init(jax.random.PRNGKey(0), spec)
    dicts = handle.layers(params)
    layers = [discretize.ReorgLayer(w=d["w"], b=d.get("b"),
                                    assign=np.zeros(d["w"].shape[-1],
                                                    dtype=np.int64))
              for d in dicts]
    new_layers, bounds = discretize.reorg_chain_from_artifact(layers,
                                                              art.to_dict())
    # first layer's channels are now grouped (0,0,0, 1,1,1)
    np.testing.assert_array_equal(new_layers[0].assign,
                                  np.array([0, 0, 0, 1, 1, 1]))
    assert bounds[0] == [3, 6]
    # the reorg is a pure permutation: forward pass is preserved
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    def fwd(ls):
        h = x
        for l in ls:
            h = h @ l.w + l.b
        return h
    np.testing.assert_allclose(np.asarray(fwd(layers)),
                               np.asarray(fwd(new_layers)), rtol=1e-5)
    # length mismatch is an explicit error
    with pytest.raises(ValueError, match="layers"):
        discretize.reorg_chain_from_artifact(layers[:-1], art.to_dict())


def test_serve_consumes_artifact():
    from repro.configs import base as cfgbase
    from repro.launch import serve
    cfgbase.load_all()
    cfg = cfgbase.reduce_for_smoke(cfgbase.get("yi-9b"))
    _, art = _tiny_artifact()
    # majority domain of the tiny artifact is digital 8-bit/8-bit acts
    new_cfg, dom = serve.apply_mapping_artifact(cfg, art)
    assert dom["name"] == "digital"
    assert new_cfg.serve_weight_dtype == "int8"
    assert new_cfg.kv_cache_dtype == "int8"
