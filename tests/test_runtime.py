"""Mapping-execution runtime tests (`repro.runtime`): artifact -> plan ->
artifact round trips, per-layer planned execution parity against the fp
reference (interpret mode), jit parity of the name-keyed backend, scan-
stacked binding/execution, conv im2col lowering, per-domain quant scales,
lowering validation, kernel capability selection, the serve fallback vote,
pipeline stage checkpointing, and the 3-domain gap9_like platform."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (MappingArtifact, Platform, SearchConfig,
                       SearchPipeline, lower, mlp_handle)
from repro.core import baselines as BL
from repro.data.pipeline import ImageTaskConfig, image_batch
from repro.models import _backend
from repro.runtime import (ExecutionError, ExecutionPlan, KERNEL_FP,
                           KERNEL_QUANT, KERNEL_SPLIT, KERNEL_SPLIT_TERNARY,
                           KERNEL_TERNARY, LayerPlan, LoweringError,
                           PlannedBackend, execute_conv_layer, execute_layer,
                           prepare_layer, reference_layer)
from repro.runtime.lower import select_kernel

TINY = SearchConfig(lam=1e-6, objective="latency", pretrain_steps=3,
                    search_steps=5, finetune_steps=2, batch=8, eval_batches=2)


def _data_fn(n_classes=10, img_hw=(4, 4)):
    task = ImageTaskConfig(n_classes=n_classes, img_hw=img_hw)
    return lambda step, batch: image_batch(task, step, batch)


def _toy_artifact(rng=None):
    """2-layer TPU-domain artifact + matching concrete params."""
    rng = rng or np.random.default_rng(0)
    spec = Platform.get("tpu_v5e").spec()
    a0 = np.array(([0] * 3 + [1]) * 16)            # 64 cols, mixed
    a1 = np.zeros(48, dtype=np.int64)              # all int8
    assigns = [a0, a1]
    counts = BL.counts_from_assignments(assigns, 2)
    plan_list = [("l0", None, True), ("l1", None, False)]
    art = MappingArtifact.from_search("toy", spec, plan_list, assigns,
                                      counts, platform="tpu_v5e")
    params = {
        "l0": {"w": jnp.asarray(rng.normal(size=(32, 64)) * 0.3, jnp.float32),
               "b": jnp.asarray(rng.normal(size=(64,)) * 0.1, jnp.float32)},
        "l1": {"w": jnp.asarray(rng.normal(size=(64, 48)) * 0.2,
                                jnp.float32)},
    }
    return art, params


# --------------------------------------------------------------------------
# (a) artifact -> plan -> artifact round trip
# --------------------------------------------------------------------------

def test_artifact_plan_artifact_roundtrip(tmp_path):
    art, params = _toy_artifact()
    plan = lower(art, params=params)
    assert [lp.name for lp in plan.layers] == ["l0", "l1"]
    for lp, a in zip(plan.layers, art.assignments()):
        # the permutation groups channels by domain, stably
        sorted_assign = a[lp.perm]
        assert (np.diff(sorted_assign) >= 0).all()
        np.testing.assert_array_equal(lp.perm, np.argsort(a, kind="stable"))
        # boundaries are the counts' cumulative sums; aligned ones are
        # block-multiples covering them
        np.testing.assert_array_equal(lp.boundaries, np.cumsum(lp.counts))
        for raw, al in zip(lp.boundaries, lp.aligned_boundaries):
            assert al % plan.block_n == 0 or al >= lp.c_out
            assert al >= min(raw, al)
        # plan -> artifact: counts and assignment are recoverable
        assert lp.counts == [int((a == i).sum()) for i in range(2)]
        rebuilt = np.empty_like(a)
        rebuilt[lp.perm] = sorted_assign
        np.testing.assert_array_equal(rebuilt, a)
    # searchability survives lowering
    assert plan["l0"].searchable and not plan["l1"].searchable
    # JSON round trip, to disk and back
    p = plan.save(tmp_path / "plan.json")
    loaded = ExecutionPlan.load(p)
    assert loaded.to_dict() == plan.to_dict()
    assert loaded.summary() == plan.summary()
    # future plan schemas are rejected, not misread
    doc = json.loads(p.read_text())
    doc["schema_version"] = 99
    with pytest.raises(ValueError, match="newer"):
        ExecutionPlan.from_dict(doc)


def test_v1_artifact_lowers_without_scales():
    """Migration: v1 documents (no scales) load and lower; executors fall
    back to max-abs scales of the bound weights."""
    art, params = _toy_artifact()
    doc = art.to_dict()
    doc["schema_version"] = 1
    for l in doc["layers"]:
        l.pop("scales", None)
    v1 = MappingArtifact.from_dict(doc)
    assert v1.schema_version == 1
    plan = lower(v1, params=params)
    lp = plan["l0"]
    w = params["l0"]["w"]
    assert lp.act_log_scale is None
    assert lp.w_log_scales == pytest.approx(
        [float(np.log(np.max(np.abs(np.asarray(w)))))] * 2)
    backend = PlannedBackend(plan, params)
    x = jnp.ones((4, 32), jnp.float32)
    assert backend("l0", params["l0"], x).shape == (4, 64)


# --------------------------------------------------------------------------
# kernel capability selection
# --------------------------------------------------------------------------

def test_select_kernel_capability_matrix():
    bits2 = [8, 16]
    assert select_kernel([10, 0], bits2) == (KERNEL_QUANT, "")
    assert select_kernel([0, 10], bits2) == (KERNEL_FP, "")
    assert select_kernel([5, 5], bits2) == (KERNEL_SPLIT, "")
    assert select_kernel([4, 0], [2, 16]) == (KERNEL_TERNARY, "")
    # ternary + int8 (DIANA mixed layer): the fused split_ternary kernel
    assert select_kernel([5, 5], [8, 2]) == (KERNEL_SPLIT_TERNARY, "")
    # quant domain ordered after the identity domain: split layout impossible
    k, note = select_kernel([5, 5], [16, 8])
    assert k == KERNEL_FP and "ordered before" in note
    # same for the ternary pairing: the int8 domain owns the low columns
    k, note = select_kernel([5, 5], [2, 8])
    assert k == KERNEL_FP and "ordered before" in note
    # ternary + identity has no fused kernel registered
    k, note = select_kernel([5, 5], [2, 16])
    assert k == KERNEL_FP and "no fused kernel" in note
    # three active domains exceed the fused kernels
    k, note = select_kernel([3, 3, 3], [8, 2, 16])
    assert k == KERNEL_FP and "3 active domains" in note


def test_kernel_registry_round_trip():
    """New pairings are ONE registration; bad registrations are rejected."""
    from repro.runtime import registry
    assert registry.kernel_for([8, 2]) == (KERNEL_SPLIT_TERNARY, "")
    with pytest.raises(ValueError, match="already registered"):
        registry.register_kernel(("q", "t"), KERNEL_SPLIT)
    with pytest.raises(ValueError, match="unknown kernel"):
        registry.register_kernel(("t", "f"), "nope")
    with pytest.raises(ValueError, match="unknown bit class"):
        registry.register_kernel(("x",), KERNEL_FP)
    try:  # a fresh pairing routes immediately, without touching lower.py
        registry.register_kernel(("t", "f"), KERNEL_SPLIT, "test-only")
        assert registry.kernel_for([2, 16]) == (KERNEL_SPLIT, "")
    finally:
        registry.unregister_kernel(("t", "f"))
    k, note = registry.kernel_for([2, 16])
    assert k == KERNEL_FP and "no fused kernel" in note


def test_platform_kernel_capabilities_introspection():
    caps = Platform.get("diana").kernel_capabilities()
    assert caps[("digital", "aimc")] == (KERNEL_SPLIT_TERNARY, "")
    assert caps[("digital",)] == (KERNEL_QUANT, "")
    assert caps[("aimc",)] == (KERNEL_TERNARY, "")
    g9 = Platform.get("gap9_like").kernel_capabilities()
    assert g9[("ne16", "analog")] == (KERNEL_SPLIT_TERNARY, "")
    assert g9[("ne16", "cluster_fp16")] == (KERNEL_SPLIT, "")
    k, note = g9[("analog", "cluster_fp16")]
    assert k == KERNEL_FP and note


def test_strict_lowering_rejects_capability_fallbacks():
    # ternary + identity has no fused kernel -> fp fallback, note carries
    # the layer name and the bits pair
    doc = {
        "schema_version": 2, "model": "mixed",
        "domains": [{"name": "aimc", "weight_bits": 2, "act_bits": 7},
                    {"name": "fp16", "weight_bits": 16, "act_bits": 16}],
        "layers": [{"name": "l", "searchable": True,
                    "assignment": [0, 1] * 8, "counts": [8, 8]}],
    }
    plan = lower(doc)                     # non-strict: fp fallback + note
    assert plan["l"].kernel == KERNEL_FP
    assert "l: " in plan["l"].note and "2-bit + 16-bit" in plan["l"].note
    assert plan.fallback_reasons() == {
        "no fused kernel for 2-bit + 16-bit domains": ["l"]}
    assert any("fallback x1" in line for line in plan.histogram_lines())
    with pytest.raises(LoweringError, match="no fused kernel"):
        lower(doc, strict=True)


def test_diana_mixed_layer_lowers_to_split_ternary():
    """The paper's headline platform: a digital+AIMC mixed layer lowers to
    the fused split_ternary kernel — no fp fallback, strict mode passes."""
    spec = Platform.get("diana").spec()   # digital int8 + ternary AIMC
    a = np.array([0, 1] * 8)
    art = MappingArtifact.from_search(
        "mixed", spec, [("l", None, True)], [a],
        BL.counts_from_assignments([a], 2))
    plan = lower(art, strict=True)        # strict: would raise on fallback
    assert plan["l"].kernel == KERNEL_SPLIT_TERNARY and not plan["l"].note


# --------------------------------------------------------------------------
# (b) planned execution parity (interpret mode)
# --------------------------------------------------------------------------

def _split_prepared(rng, m=16, k=64, n=256, boundary=128):
    assign = np.array([0] * boundary + [1] * (n - boundary))
    lp = LayerPlan(
        name="l", kernel=KERNEL_SPLIT, c_in=k, c_out=n,
        perm=np.arange(n), counts=[boundary, n - boundary],
        boundaries=[boundary, n], aligned_boundaries=[128, 256],
        w_log_scales=None, act_log_scale=None)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.25, jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    return prepare_layer(lp, w, b, domain_bits=[8, 16]), x


def test_planned_execution_matches_quantized_reference():
    """Pallas (interpret) vs the pure-jnp oracle: bit-tolerance parity on a
    layer wide enough that BOTH split domains execute."""
    prep, x = _split_prepared(np.random.default_rng(1))
    y_kernel = execute_layer(prep, x, interpret=True)
    y_oracle = execute_layer(prep, x, reference=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                               rtol=1e-4, atol=1e-4)


def test_block_n_agrees_between_plan_and_execution():
    """Plans lowered with a non-default block_n align boundaries with the
    SAME effective N-block the ops execute with (the ops clamp bn to
    min(bn, max(128, n)))."""
    art, params = _toy_artifact()
    for bn, expect_eff in ((256, 128), (128, 128)):   # c_out = 64 -> eff 128
        plan = lower(art, params=params, block_n=bn)
        lp = plan["l0"]
        assert lp.aligned_boundaries == [128, 128]
        backend = PlannedBackend(plan, params)
        prep = backend._by_name["l0"]
        assert prep.block_n == bn
        x = jnp.asarray(np.random.default_rng(3).normal(size=(4, 32)),
                        jnp.float32)
        y_kernel = execute_layer(prep, x, interpret=True)
        y_oracle = execute_layer(prep, x, reference=True)
        np.testing.assert_allclose(np.asarray(y_kernel),
                                   np.asarray(y_oracle),
                                   rtol=1e-4, atol=1e-4)


def test_planned_execution_vs_fp_reference_within_quant_tolerance():
    prep, x = _split_prepared(np.random.default_rng(2))
    y = np.asarray(execute_layer(prep, x, interpret=True), np.float64)
    y_fp = np.asarray(reference_layer(prep, x), np.float64)
    rel = np.linalg.norm(y - y_fp) / np.linalg.norm(y_fp)
    assert rel < 0.05, rel
    # the bf16 (identity) half must be much tighter than int8 quant error
    rel_hi = (np.linalg.norm(y[:, 128:] - y_fp[:, 128:])
              / np.linalg.norm(y_fp[:, 128:]))
    assert rel_hi < 0.01, rel_hi


def test_planned_model_execution_parity_mlp():
    """End-to-end deploy mode: a fixed-mapping search artifact lowered and
    executed through the façade's pluggable backend stays within quant
    tolerance of the fp forward pass."""
    handle = mlp_handle(in_dim=48, widths=(160, 144), n_classes=10)
    data_fn = _data_fn()
    assigns = [np.array([0] * 96 + [1] * 64),
               np.array([0] * 80 + [1] * 64),
               np.zeros(10, np.int64)]
    res = SearchPipeline.fixed_mapping(handle, assigns, "tpu_v5e",
                                       train_steps=2, config=TINY,
                                       data_fn=data_fn).run()
    art = res.artifact
    assert art.schema_version == 2
    assert art.layers[0]["scales"]["w_log_scales"] is not None
    plan = lower(art, params=res.params, handle=handle)
    assert plan.kernel_histogram() == {KERNEL_SPLIT: 2, KERNEL_QUANT: 1}
    backend = PlannedBackend(plan, res.params, handle=handle)
    assert backend.bound == [lp.name for lp in plan.layers]

    from repro.models import facades
    spec = Platform.get("tpu_v5e").spec()
    x, _ = data_fn(0, 8)
    y_dep = facades.mlp_apply(res.params, x, handle.config, spec,
                              mode="deploy", backend=backend)
    y_fp = facades.mlp_apply(res.params, x, handle.config, spec, mode="fp")
    rel = float(jnp.linalg.norm(y_dep - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.15, rel
    # without a backend the deploy mode still runs (discretized fallback)
    y_fb = facades.mlp_apply(res.params, x, handle.config, spec,
                             mode="deploy")
    assert np.isfinite(np.asarray(y_fb)).all()


def test_backend_declines_uncovered_layers():
    art, params = _toy_artifact()
    plan = lower(art, params=params)
    backend = PlannedBackend(plan, params)
    other = {"w": jnp.ones((32, 64), jnp.float32)}
    # unknown and unnamed layers decline; covered names execute
    assert backend("nope", other, jnp.ones((2, 32))) is None
    assert backend(None, other, jnp.ones((2, 32))) is None
    from repro.models import layers as L
    from repro.models.managed import matmul_backend
    with matmul_backend(backend):
        y = L.dense(other, jnp.ones((2, 32), jnp.float32))  # default path
        y2 = L.dense(other, jnp.ones((2, 32), jnp.float32), name="nope")
    np.testing.assert_allclose(np.asarray(y), 32.0)
    np.testing.assert_allclose(np.asarray(y2), 32.0)


def test_handle_plan_count_mismatch_is_execution_error():
    """Binding-phase failures are ExecutionErrors, not LoweringErrors."""
    art, params = _toy_artifact()
    plan = lower(art, params=params)

    class TwoLayerHandle:
        def layers(self, p):
            return [p["l0"]]  # one node for a two-layer plan

    with pytest.raises(ExecutionError, match="resolves 1 managed layers"):
        PlannedBackend(plan, params, handle=TwoLayerHandle())


# --------------------------------------------------------------------------
# jit parity: the name-keyed backend executes planned kernels INSIDE a trace
# --------------------------------------------------------------------------

def _single_layer_backend(kernel, rng, k=32, n=64):
    """(backend, params, name) with one layer lowered to ``kernel``."""
    domains = {
        KERNEL_QUANT: ([0] * n, [8, 16]),
        KERNEL_TERNARY: ([0] * n, [2, 16]),
        KERNEL_SPLIT: ([0] * (n // 2) + [1] * (n // 2), [8, 16]),
        KERNEL_FP: ([1] * n, [8, 16]),
    }
    assign, bits = domains[kernel]
    doc = {
        "schema_version": 2, "model": "jitparity",
        "domains": [{"name": f"d{i}", "weight_bits": b, "act_bits": 8}
                    for i, b in enumerate(bits)],
        "layers": [{"name": "l", "searchable": True,
                    "assignment": assign,
                    "counts": [assign.count(0), assign.count(1)]}],
    }
    params = {"l": {"w": jnp.asarray(rng.normal(size=(k, n)) * 0.3,
                                     jnp.float32),
                    "b": jnp.asarray(rng.normal(size=(n,)) * 0.1,
                                     jnp.float32)}}
    plan = lower(doc, params=params)
    assert plan["l"].kernel == kernel
    return PlannedBackend(plan, params, interpret=True), params, "l"


@pytest.mark.parametrize("kernel", [KERNEL_QUANT, KERNEL_TERNARY,
                                    KERNEL_SPLIT, KERNEL_FP])
def test_backend_jit_parity_per_kernel(kernel):
    """The planned output under jax.jit equals the eager planned output —
    the backend resolves by static name, so nothing falls back to the
    default path inside the trace."""
    rng = np.random.default_rng(7)
    backend, params, name = _single_layer_backend(kernel, rng)
    x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    y_eager = backend(name, params[name], x)
    y_jit = jax.jit(lambda p, xx: backend(name, p, xx))(params[name], x)
    np.testing.assert_allclose(np.asarray(y_jit), np.asarray(y_eager),
                               rtol=1e-5, atol=1e-5)
    # and the jitted output is genuinely the PLANNED one, not the fp path
    if kernel != KERNEL_FP:
        y_fp = x @ params[name]["w"] + params[name]["b"]
        assert not np.allclose(np.asarray(y_jit), np.asarray(y_fp),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# scan-stacked plans: base@r names bind and execute inside the layer scan
# --------------------------------------------------------------------------

def _stacked_artifact(rng, assigns_per_repeat, scales=True):
    """R-repeat stacked dense artifact + params {"units": ({"proj": ...},)}."""
    R = len(assigns_per_repeat)
    K = 16
    spec = Platform.get("tpu_v5e").spec()
    counts = BL.counts_from_assignments(assigns_per_repeat, 2)
    plan_list = [(f"units/0/proj@{r}", None, True) for r in range(R)]
    sc = None
    if scales:
        sc = [{"w_log_scales": [float(np.log(0.4 + 0.2 * r))] * 2,
               "act_log_scale": None} for r in range(R)]
    art = MappingArtifact.from_search("stacked", spec, plan_list,
                                      assigns_per_repeat, counts,
                                      platform="tpu_v5e", scales=sc)
    N = len(assigns_per_repeat[0])
    params = {"units": ({"proj": {
        "w": jnp.asarray(rng.normal(size=(R, K, N)) * 0.25, jnp.float32),
        "b": jnp.asarray(rng.normal(size=(R, N)) * 0.1, jnp.float32)}},)}
    return art, params, R, K


def _scan_planned(backend, x, R):
    """Execute ``units/0/proj`` for every repeat inside a jitted lax.scan
    (the transformer.backbone pattern: scan_slot publishes the index)."""
    def body(carry, ridx):
        with _backend.scan_slot(ridx):
            y = backend("units/0/proj", None, x)
        return carry, y

    @jax.jit
    def run():
        _, ys = jax.lax.scan(body, 0, jnp.arange(R))
        return ys
    return run()


def test_scan_stacked_plans_bind_and_execute_homogeneous():
    """All repeats bind (none silently fp) and the stacked execution inside
    a jitted scan matches per-repeat eager execution."""
    rng = np.random.default_rng(11)
    a = np.array(([0] * 3 + [1]) * 16)           # same split every repeat
    art, params, R, K = _stacked_artifact(rng, [a] * 3)
    plan = lower(art, params=params)
    backend = PlannedBackend(plan, params, interpret=True)
    assert backend.unbound == []
    assert backend.bound == [f"units/0/proj@{r}" for r in range(R)]
    from repro.runtime.execute import _StackedPrepared
    assert isinstance(backend._by_name["units/0/proj"], _StackedPrepared)

    x = jnp.asarray(rng.normal(size=(2, K)), jnp.float32)
    ys = _scan_planned(backend, x, R)
    for r in range(R):
        with _backend.scan_slot(r):
            y_eager = backend("units/0/proj", None, x)
        np.testing.assert_allclose(np.asarray(ys[r]), np.asarray(y_eager),
                                   rtol=1e-5, atol=1e-5)


def test_scan_stacked_heterogeneous_kernels_grouped():
    """Repeats with different kernels (split / quant / fp) still all bind;
    a traced scan index dispatches through lax.switch over the GROUPS."""
    rng = np.random.default_rng(12)
    N = 64
    assigns = [np.array([0] * 32 + [1] * 32),    # split_precision
               np.zeros(N, np.int64),            # quant_matmul
               np.ones(N, np.int64)]             # fp
    art, params, R, K = _stacked_artifact(rng, assigns)
    plan = lower(art, params=params)
    assert [lp.kernel for lp in plan.layers] == \
        [KERNEL_SPLIT, KERNEL_QUANT, KERNEL_FP]
    backend = PlannedBackend(plan, params, interpret=True)
    assert backend.unbound == []
    from repro.runtime.execute import _GroupedPrepared
    entry = backend._by_name["units/0/proj"]
    assert isinstance(entry, _GroupedPrepared) and entry.n_groups == 3

    x = jnp.asarray(rng.normal(size=(2, K)), jnp.float32)
    ys = _scan_planned(backend, x, R)
    for r in range(R):
        with _backend.scan_slot(r):
            y_eager = backend("units/0/proj", None, x)
        np.testing.assert_allclose(np.asarray(ys[r]), np.asarray(y_eager),
                                   rtol=1e-5, atol=1e-5)
    # outside any scan_slot the stacked plan fails LOUDLY, never silently fp
    with pytest.raises(ExecutionError, match="outside a scan_slot"):
        backend("units/0/proj", None, x)


def test_scan_stacked_repeating_pattern_groups_not_switches():
    """The common heterogeneous case — a few distinct mappings tiled across
    the depth — groups into G stacked gathers (G=2 here for R=6), and the
    grouped execution matches both eager per-repeat execution and the
    one-branch-per-repeat ``stack_mode="switch"`` baseline."""
    rng = np.random.default_rng(15)
    N = 64
    a_split = np.array([0] * 32 + [1] * 32)
    a_quant = np.zeros(N, np.int64)
    assigns = [a_split, a_quant] * 3                  # R=6, 2 distinct keys
    art, params, R, K = _stacked_artifact(rng, assigns)
    plan = lower(art, params=params)
    grouped = PlannedBackend(plan, params, interpret=True)
    switch = PlannedBackend(plan, params, interpret=True,
                            stack_mode="switch")
    from repro.runtime.execute import _GroupedPrepared, _SwitchPrepared
    g_entry = grouped._by_name["units/0/proj"]
    assert isinstance(g_entry, _GroupedPrepared) and g_entry.n_groups == 2
    assert isinstance(switch._by_name["units/0/proj"], _SwitchPrepared)

    x = jnp.asarray(rng.normal(size=(2, K)), jnp.float32)
    ys_grouped = _scan_planned(grouped, x, R)
    ys_switch = _scan_planned(switch, x, R)
    np.testing.assert_allclose(np.asarray(ys_grouped),
                               np.asarray(ys_switch), rtol=1e-5, atol=1e-5)
    for r in range(R):
        with _backend.scan_slot(r):
            y_eager = grouped("units/0/proj", None, x)
        np.testing.assert_allclose(np.asarray(ys_grouped[r]),
                                   np.asarray(y_eager),
                                   rtol=1e-5, atol=1e-5)


def test_scan_stacked_quant_stack_skips_fp_weights():
    """Homogeneous quant stacks don't hold R full-precision weight copies
    (the quant kernel only reads the int8 codes) and still execute within
    quant tolerance."""
    rng = np.random.default_rng(14)
    a = np.zeros(64, np.int64)                    # all int8 -> quant_matmul
    art, params, R, K = _stacked_artifact(rng, [a] * 3, scales=False)
    backend = PlannedBackend(lower(art, params=params), params,
                             interpret=True)
    entry = backend._by_name["units/0/proj"]
    assert entry._w_perm is None
    x = jnp.asarray(rng.normal(size=(2, K)), jnp.float32)
    for r in range(R):
        with _backend.scan_slot(r):
            y = backend("units/0/proj", None, x)
        ref = x @ params["units"][0]["proj"]["w"][r] + \
            params["units"][0]["proj"]["b"][r]
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert rel < 0.05, rel


def test_scan_stacked_repeat_count_mismatch_rejected():
    """A plan covering fewer repeats than the model's stack must not bind:
    out-of-range jnp.take inside the scan would produce NaN silently."""
    rng = np.random.default_rng(13)
    a = np.array(([0] * 3 + [1]) * 16)
    art, params, R, K = _stacked_artifact(rng, [a] * 2)   # plan: 2 repeats
    # model: 3 repeats
    params = {"units": ({"proj": {
        "w": jnp.asarray(rng.normal(size=(3, K, len(a))) * 0.25, jnp.float32),
        "b": jnp.asarray(rng.normal(size=(3, len(a))) * 0.1, jnp.float32)}},)}
    plan = lower(art, params=params)
    with pytest.raises(ExecutionError, match="2 repeats.*carries 3"):
        PlannedBackend(plan, params)


# --------------------------------------------------------------------------
# conv lowering: im2col onto the planned dense kernels
# --------------------------------------------------------------------------

def _conv_prep(rng, kh, kw, ci, co, kernel=KERNEL_FP, bits=(8, 16)):
    assign = {KERNEL_FP: [1] * co, KERNEL_SPLIT:
              [0] * (co // 2) + [1] * (co - co // 2)}[kernel]
    counts = [assign.count(0), assign.count(1)]
    lp = LayerPlan(name="c", kernel=kernel, c_in=kh * kw * ci, c_out=co,
                   perm=np.argsort(np.asarray(assign), kind="stable"),
                   counts=counts, boundaries=list(np.cumsum(counts)),
                   aligned_boundaries=[128, 128], w_log_scales=None,
                   act_log_scale=None)
    w = jnp.asarray(rng.normal(size=(kh, kw, ci, co)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.normal(size=(co,)) * 0.1, jnp.float32)
    return prepare_layer(lp, w, b, domain_bits=list(bits)), w, b


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID")])
def test_conv_im2col_matches_lax_conv(stride, padding):
    """fp-kernel conv execution through im2col == lax.conv_general_dilated
    (same SAME/VALID semantics, bias applied)."""
    rng = np.random.default_rng(21)
    prep, w, b = _conv_prep(rng, 3, 3, 5, 8)
    x = jnp.asarray(rng.normal(size=(2, 12, 12, 5)), jnp.float32)
    y = execute_conv_layer(prep, x, stride=stride, padding=padding)
    ref_y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref_y),
                               rtol=2e-5, atol=2e-5)


def test_planned_conv_through_managed_backend_jit():
    """A CNN-style artifact binds conv weights and `managed.conv2d` executes
    them through the planned split kernel under jax.jit, within quant
    tolerance of the fp conv."""
    from repro.models import managed as mg
    rng = np.random.default_rng(22)
    ci, co = 4, 16
    assign = [0] * 11 + [1] * 5
    doc = {
        "schema_version": 2, "model": "convtest",
        "domains": [{"name": "int8", "weight_bits": 8, "act_bits": 8},
                    {"name": "bf16", "weight_bits": 16, "act_bits": 16}],
        "layers": [{"name": "c", "searchable": True, "assignment": assign,
                    "counts": [11, 5]}],
    }
    params = {"c": {"w": jnp.asarray(rng.normal(size=(3, 3, ci, co)) * 0.3,
                                     jnp.float32),
                    "b": jnp.zeros((co,), jnp.float32)}}
    plan = lower(doc, params=params)
    assert plan["c"].kernel == KERNEL_SPLIT
    backend = PlannedBackend(plan, params, interpret=True)
    assert backend.bound == ["c"]
    x = jnp.asarray(rng.normal(size=(2, 8, 8, ci)), jnp.float32)
    fwd = jax.jit(lambda p, xx: mg.conv2d(p["c"], xx, name="c"))
    with mg.matmul_backend(backend):
        y_planned = fwd(params, x)
    y_fp = mg.conv2d(params["c"], x)
    rel = float(jnp.linalg.norm(y_planned - y_fp) /
                jnp.maximum(jnp.linalg.norm(y_fp), 1e-9))
    assert rel < 0.1, rel
    # dense-style call on a conv-bound name is a loud mismatch
    with pytest.raises(ExecutionError, match="conv weight"):
        backend("c", params["c"], x.reshape(2, -1))


def test_grouped_conv_declines_with_reason():
    """Depthwise/grouped convs have no im2col lowering: the backend declines
    at trace time and records why (surfaced by serve's coverage check)."""
    from repro.models import managed as mg
    rng = np.random.default_rng(23)
    c = 8
    doc = {
        "schema_version": 2, "model": "dw",
        "domains": [{"name": "int8", "weight_bits": 8, "act_bits": 8}],
        "layers": [{"name": "dw", "searchable": False,
                    "assignment": [0] * c, "counts": [c]}],
    }
    params = {"dw": {"w": jnp.asarray(rng.normal(size=(3, 3, 1, c)),
                                      jnp.float32),
                     "b": jnp.zeros((c,), jnp.float32)}}
    backend = PlannedBackend(lower(doc, params=params), params,
                             interpret=True)
    x = jnp.asarray(rng.normal(size=(1, 6, 6, c)), jnp.float32)
    with mg.matmul_backend(backend):
        y = mg.conv2d(params["dw"], x, groups=c, name="dw")  # default path
    assert np.isfinite(np.asarray(y)).all()
    assert "dw" in backend.runtime_declines
    assert "grouped conv" in backend.runtime_declines["dw"]


# --------------------------------------------------------------------------
# per-domain per-column quant scales (multi-quantized-domain plans)
# --------------------------------------------------------------------------

def test_prepare_layer_per_domain_column_steps():
    """Each active quantized domain's columns carry THAT domain's dequant
    step — not a uniform step from quantized[0] (wrong for plans with
    several quantized domains, e.g. 3-domain gap9_like)."""
    from repro.core import quant
    rng = np.random.default_rng(31)
    n0, n1, n2 = 10, 6, 4            # int8 | ternary | fp16 (gap9-like)
    N = n0 + n1 + n2
    ls = [0.3, -0.9, 0.0]
    lp = LayerPlan(name="g", kernel=KERNEL_QUANT, c_in=8, c_out=N,
                   perm=np.arange(N), counts=[n0, n1, n2],
                   boundaries=[n0, n0 + n1, N],
                   aligned_boundaries=[128, 128, 128],
                   w_log_scales=ls, act_log_scale=None)
    w = jnp.asarray(rng.normal(size=(8, N)) * 0.5, jnp.float32)
    prep = prepare_layer(lp, w, domain_bits=[8, 2, 16])
    sw = np.asarray(prep.sw)
    step0 = np.exp(ls[0]) / quant.qlevels(8)
    step1 = np.exp(ls[1]) / quant.qlevels(2)
    np.testing.assert_allclose(sw[:n0], step0, rtol=1e-6)
    np.testing.assert_allclose(sw[n0:n0 + n1], step1, rtol=1e-6)
    # identity-domain columns inherit the DRIVING quantized domain's step
    # (they execute in int8 only as conservative block padding)
    np.testing.assert_allclose(sw[n0 + n1:], step0, rtol=1e-6)
    # codes * step reconstruct each domain's columns with ITS scale
    deq = np.asarray(prep.w_q, np.float32) * sw[None, :]
    wf = np.asarray(w)
    for cols, bits, s in [(slice(0, n0), 8, ls[0]),
                          (slice(n0, n0 + n1), 2, ls[1])]:
        expect = np.asarray(quant.fake_quant(jnp.asarray(wf[:, cols]),
                                             jnp.asarray(s), bits))
        np.testing.assert_allclose(deq[:, cols], expect, atol=1e-6)


# --------------------------------------------------------------------------
# serve coverage gate
# --------------------------------------------------------------------------

def test_serve_require_full_coverage_exits_nonzero():
    from repro.launch import serve

    class FakeBackend:
        unbound = ["l1"]
        runtime_declines = {}
    with pytest.raises(SystemExit) as ei:
        serve.check_coverage("serve", FakeBackend(), True)
    assert ei.value.code == 2

    class Declined:
        unbound = []
        runtime_declines = {"dw": "grouped conv"}
    with pytest.raises(SystemExit):
        serve.check_coverage("serve", Declined(), True)

    class Full:
        unbound = []
        runtime_declines = {}
    serve.check_coverage("serve", Full(), True)   # no exit


# --------------------------------------------------------------------------
# (c) lowering validation
# --------------------------------------------------------------------------

def test_lowering_rejects_shape_mismatched_artifact():
    art, params = _toy_artifact()
    bad = {"l0": {"w": jnp.zeros((32, 60), jnp.float32)},
           "l1": params["l1"]}
    with pytest.raises(LoweringError,
                       match="assigns 64 output channels.*60 channels"):
        lower(art, params=bad)
    # inconsistent stored counts are rejected too
    doc = art.to_dict()
    doc["layers"][0]["counts"] = [1, 63]
    with pytest.raises(LoweringError, match="disagree"):
        lower(doc, params=params)
    # out-of-range domain indices are rejected
    doc = art.to_dict()
    doc["layers"][0]["assignment"][0] = 7
    with pytest.raises(LoweringError, match="references domain"):
        lower(doc, params=params)
    # a layer name that resolves nowhere means the wrong model was given
    with pytest.raises(LoweringError, match="no param node"):
        lower(art, params={"l1": params["l1"]})


# --------------------------------------------------------------------------
# serve fallback: searchable-only majority vote
# --------------------------------------------------------------------------

def test_apply_mapping_artifact_counts_searchable_votes_only():
    from repro.configs import base as cfgbase
    from repro.launch import serve
    cfgbase.load_all()
    cfg = cfgbase.reduce_for_smoke(cfgbase.get("yi-9b"))
    spec = Platform.get("tpu_v5e").spec()
    # a wide PINNED layer on int8 (domain 0) vs a small searchable layer
    # whose channels chose bf16: only the searchable layer may vote
    a_pinned = np.zeros(512, np.int64)
    a_search = np.ones(32, np.int64)
    art = MappingArtifact.from_search(
        "vote", spec, [("pinned", None, False), ("chosen", None, True)],
        [a_pinned, a_search],
        BL.counts_from_assignments([a_pinned, a_search], 2))
    new_cfg, dom = serve.apply_mapping_artifact(cfg, art)
    assert dom["name"] == "bf16"
    assert new_cfg.serve_weight_dtype == cfg.serve_weight_dtype  # unchanged
    # with no searchable layers at all, every layer votes (fallback)
    art_all_pinned = MappingArtifact.from_search(
        "vote2", spec, [("pinned", None, False)], [a_pinned],
        BL.counts_from_assignments([a_pinned], 2))
    _, dom = serve.apply_mapping_artifact(cfg, art_all_pinned)
    assert dom["name"] == "int8"


# --------------------------------------------------------------------------
# pipeline stage checkpointing
# --------------------------------------------------------------------------

def test_pipeline_checkpoint_resume_restarts_at_search(tmp_path):
    handle = mlp_handle(in_dim=48, widths=(24,), n_classes=10)
    data_fn = _data_fn()
    full = SearchPipeline(handle, "tpu_v5e", config=TINY, data_fn=data_fn,
                          checkpoint_dir=str(tmp_path / "ck")).run()
    resumed = SearchPipeline(handle, "tpu_v5e", config=TINY, data_fn=data_fn,
                             resume_from=str(tmp_path / "ck")).run()
    # the resumed run skipped Pretrain...
    assert "pretrain" not in resumed.history and "pretrain" in full.history
    # ...and is bit-identical from DNASSearch onward
    assert resumed.accuracy == full.accuracy
    assert resumed.latency == full.latency
    for a, b in zip(resumed.assignments, full.assignments):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(
        np.asarray(resumed.history["search"]),
        np.asarray(full.history["search"]))
    with pytest.raises(FileNotFoundError, match="no committed"):
        SearchPipeline(handle, "tpu_v5e", config=TINY, data_fn=data_fn,
                       resume_from=str(tmp_path / "nope")).run()


# --------------------------------------------------------------------------
# gap9_like: third registered platform, 3 domains
# --------------------------------------------------------------------------

def test_gap9_platform_three_domains():
    plat = Platform.get("gap9_like")
    assert [d.name for d in plat.domains] == ["ne16", "analog",
                                              "cluster_fp16"]
    assert [d.weight_bits for d in plat.domains] == [8, 2, 16]
    spec = plat.spec()
    assert spec.n_domains == 3 and spec.act_bits == 7
    cm = plat.cost_model()
    from repro.core.cost_models import LayerGeometry
    lat = cm.latency(LayerGeometry(c_in=16, c_out=30),
                     jnp.asarray([10.0, 10.0, 10.0]))
    assert lat.shape == (3,)
    assert float(lat[1]) < float(lat[0]) < float(lat[2])  # analog fastest


def test_gap9_search_and_lowering():
    handle = mlp_handle(in_dim=48, widths=(24,), n_classes=10)
    res = SearchPipeline(handle, "gap9_like", config=TINY,
                         data_fn=_data_fn()).run()
    assert all(len(c) == 3 for c in res.counts)
    assert len(res.artifact.domains) == 3
    # lowering handles 3-domain layers: single-domain ones get their kernel,
    # >2-active ones record the fp fallback reason
    plan = lower(res.artifact, params=res.params, handle=handle)
    for lp in plan.layers:
        assert lp.kernel in (KERNEL_QUANT, KERNEL_TERNARY, KERNEL_SPLIT,
                             KERNEL_SPLIT_TERNARY, KERNEL_FP)
        if len(lp.active_domains()) > 2:
            assert lp.kernel == KERNEL_FP and lp.note


# --------------------------------------------------------------------------
# grouped/depthwise conv im2col lowering (block-diagonal zero-embedding)
# --------------------------------------------------------------------------

def test_grouped_conv_planned_matches_lax_conv():
    """A plan with ``groups`` executes a depthwise conv through the im2col
    kernels via block-diagonal weight expansion — close to the exact
    lax grouped conv (quantization tolerance), jit included."""
    from repro.models import managed as mg
    rng = np.random.default_rng(29)
    c, g = 12, 4                      # 4 groups x 3 in-ch x 3 out-ch
    doc = {
        "schema_version": 2, "model": "gc",
        "domains": [{"name": "int8", "weight_bits": 8, "act_bits": 8}],
        "layers": [{"name": "gc", "searchable": False, "groups": g,
                    "assignment": [0] * c, "counts": [c]}],
    }
    params = {"gc": {"w": jnp.asarray(rng.normal(size=(3, 3, c // g, c)) * 0.4,
                                      jnp.float32),
                     "b": jnp.asarray(rng.normal(size=(c,)) * 0.1,
                                      jnp.float32)}}
    plan = lower(doc, params=params)
    assert plan["gc"].groups == g
    backend = PlannedBackend(plan, params, interpret=True)
    assert backend.fully_covered
    x = jnp.asarray(rng.normal(size=(2, 6, 6, c)), jnp.float32)
    fwd = jax.jit(lambda p, xx: mg.conv2d_linear(p["gc"], xx, groups=g,
                                                 name="gc"))
    with mg.matmul_backend(backend):
        y = fwd(params, x)
    assert not backend.runtime_declines
    y_ref = mg.conv2d_linear(params["gc"], x, groups=g)
    rel = float(jnp.linalg.norm(y - y_ref) /
                jnp.maximum(jnp.linalg.norm(y_ref), 1e-9))
    assert rel < 0.1, rel
    # group-count mismatch at the call site is a loud error, not silent fp
    with pytest.raises(ExecutionError, match="groups"):
        backend("gc", params["gc"], x,
                conv={"stride": 1, "padding": "SAME", "groups": 2})


def test_grouped_conv_expansion_is_block_diagonal():
    """`_expand_grouped`: input-channel block i only feeds output block i;
    off-diagonal entries are exactly zero (they quantize to code 0)."""
    from repro.runtime.execute import _expand_grouped
    rng = np.random.default_rng(31)
    w = jnp.asarray(rng.normal(size=(1, 1, 2, 6)), jnp.float32)   # g=3
    full = np.asarray(_expand_grouped(w, 3))[0, 0]                # (6, 6)
    for gi in range(3):
        blk = full[gi * 2:(gi + 1) * 2, gi * 2:(gi + 1) * 2]
        np.testing.assert_array_equal(blk, np.asarray(w)[0, 0][:,
                                      gi * 2:(gi + 1) * 2])
        off = np.delete(full[gi * 2:(gi + 1) * 2], np.s_[gi * 2:(gi + 1) * 2],
                        axis=1)
        assert (off == 0).all()


@pytest.mark.slow
def test_mbv1_artifact_full_coverage():
    """ROADMAP open item: mbv1's own emitted artifact (depthwise convs
    included) lowers and binds with FULL coverage — no trace-time declines,
    no unbound layers."""
    from repro.launch.train import emit_static_mapping
    from repro.models import cnn as C
    from repro.models import managed as mg
    cfg = C.get_config("mobilenetv1_tiny")
    init_fn, apply_fn, plan_fn = C.get_model(cfg)
    params = init_fn(jax.random.PRNGKey(0), cfg, None)
    hints = {n: (g, s) for (n, g, s) in plan_fn(cfg)}
    import tempfile
    from pathlib import Path
    with tempfile.TemporaryDirectory() as td:
        art = emit_static_mapping(params, cfg, "diana",
                                  Path(td) / "m.json", plan_hints=hints)
    dw_layers = [l for l in art.layers if l.get("groups", 1) > 1]
    assert len(dw_layers) == 13          # every depthwise block emitted
    assert all(not l["searchable"] for l in dw_layers)  # pinned (paper rule)
    plan = lower(art, params=params)
    backend = PlannedBackend(plan, params, interpret=True)
    assert backend.fully_covered, backend.unbound
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.img_hw, cfg.in_ch),
                          jnp.float32)
    fwd = jax.jit(lambda p, xb: apply_fn(p, xb, cfg, None, "fp", 1.0))
    with mg.matmul_backend(backend):
        y = jax.block_until_ready(fwd(params, x))
    assert not backend.runtime_declines, backend.runtime_declines
    assert np.isfinite(np.asarray(y)).all()


def test_grouped_conv_plan_json_roundtrip():
    lp = LayerPlan(name="dw", kernel=KERNEL_QUANT, c_in=9 * 4, c_out=4,
                   perm=np.arange(4), counts=[4], boundaries=[4],
                   aligned_boundaries=[128], w_log_scales=[0.1],
                   act_log_scale=None, groups=4)
    plan = ExecutionPlan(model="m", domains=[{"name": "d", "weight_bits": 8,
                                              "act_bits": 8}], layers=[lp])
    back = ExecutionPlan.from_json(plan.to_json())
    assert back["dw"].groups == 4


# --------------------------------------------------------------------------
# gpu_tc_like: GPU tensor-core platform (int8 + fp16 pair)
# --------------------------------------------------------------------------

def test_gpu_tc_platform_registered_and_fuses_split_precision():
    plat = Platform.get("gpu_tc_like")
    assert [d.name for d in plat.domains] == ["tc_int8", "tc_fp16"]
    assert [d.weight_bits for d in plat.domains] == [8, 16]
    caps = plat.kernel_capabilities()
    # the mixed pairing fuses (int8 ordered first), no fallback note
    kernel, note = caps[("tc_int8", "tc_fp16")]
    assert kernel == KERNEL_SPLIT and not note
    from repro.core.cost_models import LayerGeometry
    lat = plat.cost_model().latency(LayerGeometry(c_in=16, c_out=32),
                                    jnp.asarray([8.0, 8.0]))
    assert lat.shape == (2,)
    assert float(lat[0]) < float(lat[1])     # int8 MMA @2x throughput


def test_gpu_tc_search_lowers_executably():
    handle = mlp_handle(in_dim=48, widths=(24,), n_classes=10)
    res = SearchPipeline(handle, "gpu_tc_like", config=TINY,
                         data_fn=_data_fn()).run()
    plan = lower(res.artifact, params=res.params, handle=handle)
    for lp in plan.layers:
        assert lp.kernel in (KERNEL_QUANT, KERNEL_SPLIT, KERNEL_FP)
        assert not lp.note                   # every pairing has a kernel
