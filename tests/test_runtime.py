"""Mapping-execution runtime tests (`repro.runtime`): artifact -> plan ->
artifact round trips, per-layer planned execution parity against the fp
reference (interpret mode), lowering validation, kernel capability
selection, the serve fallback vote, pipeline stage checkpointing, and the
3-domain gap9_like platform."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (MappingArtifact, Platform, SearchConfig,
                       SearchPipeline, lower, mlp_handle)
from repro.core import baselines as BL
from repro.data.pipeline import ImageTaskConfig, image_batch
from repro.runtime import (ExecutionPlan, KERNEL_FP, KERNEL_QUANT,
                           KERNEL_SPLIT, KERNEL_TERNARY, LayerPlan,
                           LoweringError, PlannedBackend, execute_layer,
                           prepare_layer, reference_layer)
from repro.runtime.lower import select_kernel

TINY = SearchConfig(lam=1e-6, objective="latency", pretrain_steps=3,
                    search_steps=5, finetune_steps=2, batch=8, eval_batches=2)


def _data_fn(n_classes=10, img_hw=(4, 4)):
    task = ImageTaskConfig(n_classes=n_classes, img_hw=img_hw)
    return lambda step, batch: image_batch(task, step, batch)


def _toy_artifact(rng=None):
    """2-layer TPU-domain artifact + matching concrete params."""
    rng = rng or np.random.default_rng(0)
    spec = Platform.get("tpu_v5e").spec()
    a0 = np.array(([0] * 3 + [1]) * 16)            # 64 cols, mixed
    a1 = np.zeros(48, dtype=np.int64)              # all int8
    assigns = [a0, a1]
    counts = BL.counts_from_assignments(assigns, 2)
    plan_list = [("l0", None, True), ("l1", None, False)]
    art = MappingArtifact.from_search("toy", spec, plan_list, assigns,
                                      counts, platform="tpu_v5e")
    params = {
        "l0": {"w": jnp.asarray(rng.normal(size=(32, 64)) * 0.3, jnp.float32),
               "b": jnp.asarray(rng.normal(size=(64,)) * 0.1, jnp.float32)},
        "l1": {"w": jnp.asarray(rng.normal(size=(64, 48)) * 0.2,
                                jnp.float32)},
    }
    return art, params


# --------------------------------------------------------------------------
# (a) artifact -> plan -> artifact round trip
# --------------------------------------------------------------------------

def test_artifact_plan_artifact_roundtrip(tmp_path):
    art, params = _toy_artifact()
    plan = lower(art, params=params)
    assert [lp.name for lp in plan.layers] == ["l0", "l1"]
    for lp, a in zip(plan.layers, art.assignments()):
        # the permutation groups channels by domain, stably
        sorted_assign = a[lp.perm]
        assert (np.diff(sorted_assign) >= 0).all()
        np.testing.assert_array_equal(lp.perm, np.argsort(a, kind="stable"))
        # boundaries are the counts' cumulative sums; aligned ones are
        # block-multiples covering them
        np.testing.assert_array_equal(lp.boundaries, np.cumsum(lp.counts))
        for raw, al in zip(lp.boundaries, lp.aligned_boundaries):
            assert al % plan.block_n == 0 or al >= lp.c_out
            assert al >= min(raw, al)
        # plan -> artifact: counts and assignment are recoverable
        assert lp.counts == [int((a == i).sum()) for i in range(2)]
        rebuilt = np.empty_like(a)
        rebuilt[lp.perm] = sorted_assign
        np.testing.assert_array_equal(rebuilt, a)
    # searchability survives lowering
    assert plan["l0"].searchable and not plan["l1"].searchable
    # JSON round trip, to disk and back
    p = plan.save(tmp_path / "plan.json")
    loaded = ExecutionPlan.load(p)
    assert loaded.to_dict() == plan.to_dict()
    assert loaded.summary() == plan.summary()
    # future plan schemas are rejected, not misread
    doc = json.loads(p.read_text())
    doc["schema_version"] = 99
    with pytest.raises(ValueError, match="newer"):
        ExecutionPlan.from_dict(doc)


def test_v1_artifact_lowers_without_scales():
    """Migration: v1 documents (no scales) load and lower; executors fall
    back to max-abs scales of the bound weights."""
    art, params = _toy_artifact()
    doc = art.to_dict()
    doc["schema_version"] = 1
    for l in doc["layers"]:
        l.pop("scales", None)
    v1 = MappingArtifact.from_dict(doc)
    assert v1.schema_version == 1
    plan = lower(v1, params=params)
    lp = plan["l0"]
    w = params["l0"]["w"]
    assert lp.act_log_scale is None
    assert lp.w_log_scales == pytest.approx(
        [float(np.log(np.max(np.abs(np.asarray(w)))))] * 2)
    backend = PlannedBackend(plan, params)
    x = jnp.ones((4, 32), jnp.float32)
    assert backend(params["l0"], x).shape == (4, 64)


# --------------------------------------------------------------------------
# kernel capability selection
# --------------------------------------------------------------------------

def test_select_kernel_capability_matrix():
    bits2 = [8, 16]
    assert select_kernel([10, 0], bits2) == (KERNEL_QUANT, "")
    assert select_kernel([0, 10], bits2) == (KERNEL_FP, "")
    assert select_kernel([5, 5], bits2) == (KERNEL_SPLIT, "")
    assert select_kernel([4, 0], [2, 16]) == (KERNEL_TERNARY, "")
    # ternary + int8 (DIANA mixed layer): no fused kernel -> fp, with reason
    k, note = select_kernel([5, 5], [8, 2])
    assert k == KERNEL_FP and "no fused kernel" in note
    # quant domain ordered after the identity domain: split layout impossible
    k, note = select_kernel([5, 5], [16, 8])
    assert k == KERNEL_FP and "ordered before" in note
    # three active domains exceed the fused kernels
    k, note = select_kernel([3, 3, 3], [8, 2, 16])
    assert k == KERNEL_FP and "3 active domains" in note


def test_strict_lowering_rejects_capability_fallbacks():
    spec = Platform.get("diana").spec()   # digital int8 + ternary AIMC
    a = np.array([0, 1] * 8)
    art = MappingArtifact.from_search(
        "mixed", spec, [("l", None, True)], [a],
        BL.counts_from_assignments([a], 2))
    plan = lower(art)                     # non-strict: fp fallback + note
    assert plan["l"].kernel == KERNEL_FP and plan["l"].note
    with pytest.raises(LoweringError, match="no fused kernel"):
        lower(art, strict=True)


# --------------------------------------------------------------------------
# (b) planned execution parity (interpret mode)
# --------------------------------------------------------------------------

def _split_prepared(rng, m=16, k=64, n=256, boundary=128):
    assign = np.array([0] * boundary + [1] * (n - boundary))
    lp = LayerPlan(
        name="l", kernel=KERNEL_SPLIT, c_in=k, c_out=n,
        perm=np.arange(n), counts=[boundary, n - boundary],
        boundaries=[boundary, n], aligned_boundaries=[128, 256],
        w_log_scales=None, act_log_scale=None)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.25, jnp.float32)
    b = jnp.asarray(rng.normal(size=(n,)) * 0.1, jnp.float32)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    return prepare_layer(lp, w, b, domain_bits=[8, 16]), x


def test_planned_execution_matches_quantized_reference():
    """Pallas (interpret) vs the pure-jnp oracle: bit-tolerance parity on a
    layer wide enough that BOTH split domains execute."""
    prep, x = _split_prepared(np.random.default_rng(1))
    y_kernel = execute_layer(prep, x, interpret=True)
    y_oracle = execute_layer(prep, x, reference=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                               rtol=1e-4, atol=1e-4)


def test_block_n_agrees_between_plan_and_execution():
    """Plans lowered with a non-default block_n align boundaries with the
    SAME effective N-block the ops execute with (the ops clamp bn to
    min(bn, max(128, n)))."""
    art, params = _toy_artifact()
    for bn, expect_eff in ((256, 128), (128, 128)):   # c_out = 64 -> eff 128
        plan = lower(art, params=params, block_n=bn)
        lp = plan["l0"]
        assert lp.aligned_boundaries == [128, 128]
        backend = PlannedBackend(plan, params)
        prep = next(iter(backend._by_id.values()))
        assert prep.block_n == bn
        x = jnp.asarray(np.random.default_rng(3).normal(size=(4, 32)),
                        jnp.float32)
        y_kernel = execute_layer(prep, x, interpret=True)
        y_oracle = execute_layer(prep, x, reference=True)
        np.testing.assert_allclose(np.asarray(y_kernel),
                                   np.asarray(y_oracle),
                                   rtol=1e-4, atol=1e-4)


def test_planned_execution_vs_fp_reference_within_quant_tolerance():
    prep, x = _split_prepared(np.random.default_rng(2))
    y = np.asarray(execute_layer(prep, x, interpret=True), np.float64)
    y_fp = np.asarray(reference_layer(prep, x), np.float64)
    rel = np.linalg.norm(y - y_fp) / np.linalg.norm(y_fp)
    assert rel < 0.05, rel
    # the bf16 (identity) half must be much tighter than int8 quant error
    rel_hi = (np.linalg.norm(y[:, 128:] - y_fp[:, 128:])
              / np.linalg.norm(y_fp[:, 128:]))
    assert rel_hi < 0.01, rel_hi


def test_planned_model_execution_parity_mlp():
    """End-to-end deploy mode: a fixed-mapping search artifact lowered and
    executed through the façade's pluggable backend stays within quant
    tolerance of the fp forward pass."""
    handle = mlp_handle(in_dim=48, widths=(160, 144), n_classes=10)
    data_fn = _data_fn()
    assigns = [np.array([0] * 96 + [1] * 64),
               np.array([0] * 80 + [1] * 64),
               np.zeros(10, np.int64)]
    res = SearchPipeline.fixed_mapping(handle, assigns, "tpu_v5e",
                                       train_steps=2, config=TINY,
                                       data_fn=data_fn).run()
    art = res.artifact
    assert art.schema_version == 2
    assert art.layers[0]["scales"]["w_log_scales"] is not None
    plan = lower(art, params=res.params, handle=handle)
    assert plan.kernel_histogram() == {KERNEL_SPLIT: 2, KERNEL_QUANT: 1}
    backend = PlannedBackend(plan, res.params, handle=handle)
    assert backend.bound == [lp.name for lp in plan.layers]

    from repro.models import facades
    spec = Platform.get("tpu_v5e").spec()
    x, _ = data_fn(0, 8)
    y_dep = facades.mlp_apply(res.params, x, handle.config, spec,
                              mode="deploy", backend=backend)
    y_fp = facades.mlp_apply(res.params, x, handle.config, spec, mode="fp")
    rel = float(jnp.linalg.norm(y_dep - y_fp) / jnp.linalg.norm(y_fp))
    assert rel < 0.15, rel
    # without a backend the deploy mode still runs (discretized fallback)
    y_fb = facades.mlp_apply(res.params, x, handle.config, spec,
                             mode="deploy")
    assert np.isfinite(np.asarray(y_fb)).all()


def test_backend_declines_uncovered_layers():
    art, params = _toy_artifact()
    plan = lower(art, params=params)
    backend = PlannedBackend(plan, params)
    other = {"w": jnp.ones((32, 64), jnp.float32)}
    assert backend(other, jnp.ones((2, 32))) is None
    from repro.models import layers as L
    from repro.models.managed import matmul_backend
    with matmul_backend(backend):
        y = L.dense(other, jnp.ones((2, 32), jnp.float32))  # default path
    np.testing.assert_allclose(np.asarray(y), 32.0)


# --------------------------------------------------------------------------
# (c) lowering validation
# --------------------------------------------------------------------------

def test_lowering_rejects_shape_mismatched_artifact():
    art, params = _toy_artifact()
    bad = {"l0": {"w": jnp.zeros((32, 60), jnp.float32)},
           "l1": params["l1"]}
    with pytest.raises(LoweringError,
                       match="assigns 64 output channels.*60 channels"):
        lower(art, params=bad)
    # inconsistent stored counts are rejected too
    doc = art.to_dict()
    doc["layers"][0]["counts"] = [1, 63]
    with pytest.raises(LoweringError, match="disagree"):
        lower(doc, params=params)
    # out-of-range domain indices are rejected
    doc = art.to_dict()
    doc["layers"][0]["assignment"][0] = 7
    with pytest.raises(LoweringError, match="references domain"):
        lower(doc, params=params)
    # a layer name that resolves nowhere means the wrong model was given
    with pytest.raises(LoweringError, match="no param node"):
        lower(art, params={"l1": params["l1"]})


# --------------------------------------------------------------------------
# serve fallback: searchable-only majority vote
# --------------------------------------------------------------------------

def test_apply_mapping_artifact_counts_searchable_votes_only():
    from repro.configs import base as cfgbase
    from repro.launch import serve
    cfgbase.load_all()
    cfg = cfgbase.reduce_for_smoke(cfgbase.get("yi-9b"))
    spec = Platform.get("tpu_v5e").spec()
    # a wide PINNED layer on int8 (domain 0) vs a small searchable layer
    # whose channels chose bf16: only the searchable layer may vote
    a_pinned = np.zeros(512, np.int64)
    a_search = np.ones(32, np.int64)
    art = MappingArtifact.from_search(
        "vote", spec, [("pinned", None, False), ("chosen", None, True)],
        [a_pinned, a_search],
        BL.counts_from_assignments([a_pinned, a_search], 2))
    new_cfg, dom = serve.apply_mapping_artifact(cfg, art)
    assert dom["name"] == "bf16"
    assert new_cfg.serve_weight_dtype == cfg.serve_weight_dtype  # unchanged
    # with no searchable layers at all, every layer votes (fallback)
    art_all_pinned = MappingArtifact.from_search(
        "vote2", spec, [("pinned", None, False)], [a_pinned],
        BL.counts_from_assignments([a_pinned], 2))
    _, dom = serve.apply_mapping_artifact(cfg, art_all_pinned)
    assert dom["name"] == "int8"


# --------------------------------------------------------------------------
# pipeline stage checkpointing
# --------------------------------------------------------------------------

def test_pipeline_checkpoint_resume_restarts_at_search(tmp_path):
    handle = mlp_handle(in_dim=48, widths=(24,), n_classes=10)
    data_fn = _data_fn()
    full = SearchPipeline(handle, "tpu_v5e", config=TINY, data_fn=data_fn,
                          checkpoint_dir=str(tmp_path / "ck")).run()
    resumed = SearchPipeline(handle, "tpu_v5e", config=TINY, data_fn=data_fn,
                             resume_from=str(tmp_path / "ck")).run()
    # the resumed run skipped Pretrain...
    assert "pretrain" not in resumed.history and "pretrain" in full.history
    # ...and is bit-identical from DNASSearch onward
    assert resumed.accuracy == full.accuracy
    assert resumed.latency == full.latency
    for a, b in zip(resumed.assignments, full.assignments):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(
        np.asarray(resumed.history["search"]),
        np.asarray(full.history["search"]))
    with pytest.raises(FileNotFoundError, match="no committed"):
        SearchPipeline(handle, "tpu_v5e", config=TINY, data_fn=data_fn,
                       resume_from=str(tmp_path / "nope")).run()


# --------------------------------------------------------------------------
# gap9_like: third registered platform, 3 domains
# --------------------------------------------------------------------------

def test_gap9_platform_three_domains():
    plat = Platform.get("gap9_like")
    assert [d.name for d in plat.domains] == ["ne16", "analog",
                                              "cluster_fp16"]
    assert [d.weight_bits for d in plat.domains] == [8, 2, 16]
    spec = plat.spec()
    assert spec.n_domains == 3 and spec.act_bits == 7
    cm = plat.cost_model()
    from repro.core.cost_models import LayerGeometry
    lat = cm.latency(LayerGeometry(c_in=16, c_out=30),
                     jnp.asarray([10.0, 10.0, 10.0]))
    assert lat.shape == (3,)
    assert float(lat[1]) < float(lat[0]) < float(lat[2])  # analog fastest


def test_gap9_search_and_lowering():
    handle = mlp_handle(in_dim=48, widths=(24,), n_classes=10)
    res = SearchPipeline(handle, "gap9_like", config=TINY,
                         data_fn=_data_fn()).run()
    assert all(len(c) == 3 for c in res.counts)
    assert len(res.artifact.domains) == 3
    # lowering handles 3-domain layers: single-domain ones get their kernel,
    # >2-active ones record the fp fallback reason
    plan = lower(res.artifact, params=res.params, handle=handle)
    for lp in plan.layers:
        assert lp.kernel in (KERNEL_QUANT, KERNEL_TERNARY, KERNEL_SPLIT,
                             KERNEL_FP)
        if len(lp.active_domains()) > 2:
            assert lp.kernel == KERNEL_FP and lp.note
