"""2-bit-packed ternary kernel: pack/unpack roundtrip + allclose vs the
unpacked ternary oracle across shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ternary_packed import (pack_ternary, ternary_packed_matmul,
                                          unpack_ternary)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), k=st.sampled_from([16, 64, 256]),
       n=st.sampled_from([8, 128]))
def test_pack_unpack_roundtrip(seed, k, n):
    wt = jax.random.randint(jax.random.PRNGKey(seed), (k, n), -1, 2, jnp.int8)
    np.testing.assert_array_equal(np.asarray(unpack_ternary(pack_ternary(wt))),
                                  np.asarray(wt))


def test_packed_is_4x_smaller():
    wt = jnp.zeros((512, 128), jnp.int8)
    assert pack_ternary(wt).size == wt.size // 4


@pytest.mark.parametrize("m,k,n", [(128, 512, 128), (128, 1024, 256)])
def test_packed_matmul_matches_oracle(m, k, n):
    key = jax.random.PRNGKey(m + k)
    xq = jax.random.randint(key, (m, k), -127, 128, jnp.int8)
    wt = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -1, 2,
                            jnp.int8)
    sx = jnp.asarray(0.05, jnp.float32)
    sw = jax.random.uniform(jax.random.fold_in(key, 2), (n,), jnp.float32)
    out = ternary_packed_matmul(xq, pack_ternary(wt), sx, sw, interpret=True)
    expect = ref.ternary_matmul_ref(xq, wt, sx, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)
