"""Continuous-batching serving engine tests (`repro.serving`).

Covers: scheduler admission/retirement mechanics (no model), engine-vs-
legacy-loop greedy token parity on same-length prompts (with and without a
planned mapping backend), the ISSUE acceptance criterion — engine tokens
identical to per-request `serve_batch` on a MIXED-length prompt set with a
fully covered diana plan (zero fp fallbacks) — and a masked-decode
regression pinning per-slot cache lengths against single-request decode.
"""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgbase
from repro.models import transformer as T
from repro.models.managed import matmul_backend
from repro.serving import (BatchState, Engine, Request, RequestQueue,
                           Scheduler, load_trace, save_trace,
                           synthetic_trace)


@pytest.fixture(scope="module", autouse=True)
def _load():
    cfgbase.load_all()


def _reduced(arch):
    return cfgbase.reduce_for_smoke(cfgbase.get(arch))


def _legacy_serve_batch(cfg, params, prompts, gen_len, backend=None):
    """The pre-engine fixed-shape serve loop (scalar cache_index), kept
    verbatim as the migration parity oracle for `serve_batch`."""
    B, P = prompts.shape
    caches = T.init_cache(cfg, B, P + gen_len)
    prefill = jax.jit(lambda p, t, c: T.prefill(p, cfg, t, c))
    decode = jax.jit(lambda p, t, c, i: T.decode_step(p, cfg, t, c, i))
    ctx = (matmul_backend(backend) if backend is not None
           else contextlib.nullcontext())
    with ctx:
        logits, caches = prefill(params, prompts, caches)
        tok = jnp.argmax(logits, -1)
        out = [tok]
        for i in range(gen_len - 1):
            logits, caches = decode(params, tok, caches, P + i)
            tok = jnp.argmax(logits, -1)
            out.append(tok)
    return np.asarray(jnp.stack(out, axis=1))


def _diana_artifact(cfg, params, tmp_path, act_log_scale=2.0):
    """Static min-cost diana artifact with STATIC activation scales (the
    engine's per-request reproducibility precondition)."""
    from repro.launch.train import emit_static_mapping
    return emit_static_mapping(params, cfg, "diana",
                               tmp_path / "mapping.json",
                               act_log_scale=act_log_scale)


# --------------------------------------------------------------------------
# scheduler / queue / batch-state mechanics (no model)
# --------------------------------------------------------------------------

def _req(rid, plen=4, new=4, arrival=0):
    return Request(rid=rid, prompt=np.arange(plen) % 7, max_new_tokens=new,
                   arrival_step=arrival)


def test_queue_arrival_visibility_and_fcfs():
    q = RequestQueue()
    for r in (_req("a"), _req("b", arrival=3), _req("c")):
        q.push(r)
    assert len(q) == 3 and q.ready(0) == 2 and q.ready(3) == 3
    assert q.next_arrival() == 0
    got = q.pop_ready(0, 5)
    assert [r.rid for r in got] == ["a", "c"]     # FCFS among visible
    assert [r.rid for r in q] == ["b"]
    assert q.pop_ready(0, 5) == [] and q.next_arrival() == 3


def test_scheduler_continuous_fills_free_slots():
    q = RequestQueue()
    for i in range(3):
        q.push(_req(i))
    adm = Scheduler("continuous").admissions(q, free_slots=[0, 2],
                                             n_active=2, step=0)
    assert [(s, r.rid) for s, r in adm] == [(0, 0), (2, 1)]
    assert len(q) == 1


def test_scheduler_static_waits_for_drain():
    q = RequestQueue()
    q.push(_req("x"))
    sched = Scheduler("static")
    assert sched.admissions(q, free_slots=[1], n_active=1, step=0) == []
    assert len(q) == 1                       # nothing popped while active
    adm = sched.admissions(q, free_slots=[0, 1], n_active=0, step=0)
    assert [(s, r.rid) for s, r in adm] == [(0, "x")]


def test_scheduler_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        Scheduler("round_robin")


def test_batchstate_slot_lifecycle():
    bs = BatchState(2, caches=None)
    assert bs.free_slots() == [0, 1] and not bs.any_active()
    st = bs.assign(0, _req("a", plen=3), first_token=5, t_ready=0.0,
                   t_first=0.1, step=0)
    assert bs.active[0] and bs.lengths[0] == 3 and bs.last_tok[0] == 5
    assert st.tokens == [5] and bs.free_slots() == [1]
    with pytest.raises(RuntimeError, match="active"):
        bs.assign(0, _req("b"), 1, 0.0, 0.0, 0)
    assert bs.retire(0).request.rid == "a"
    assert bs.free_slots() == [0, 1]
    with pytest.raises(RuntimeError, match="not occupied"):
        bs.retire(0)


def test_request_validation():
    with pytest.raises(ValueError, match="empty prompt"):
        Request(rid=0, prompt=np.zeros(0), max_new_tokens=2)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(rid=0, prompt=np.zeros(3), max_new_tokens=0)


def test_trace_roundtrip_and_determinism(tmp_path):
    t1 = synthetic_trace(5, vocab=64, seed=3, arrival_every=2)
    t2 = synthetic_trace(5, vocab=64, seed=3, arrival_every=2)
    assert all(np.array_equal(a.prompt, b.prompt) and
               a.max_new_tokens == b.max_new_tokens and
               a.arrival_step == b.arrival_step for a, b in zip(t1, t2))
    p = save_trace(tmp_path / "t.jsonl", t1)
    t3 = load_trace(p)
    assert all(np.array_equal(a.prompt, b.prompt) and a.rid == b.rid
               for a, b in zip(t1, t3))


# --------------------------------------------------------------------------
# engine vs the legacy fixed-shape loop (serve_batch migration parity)
# --------------------------------------------------------------------------

def test_serve_batch_matches_legacy_loop():
    """`serve_batch` (now an engine wrapper) is token-identical to the old
    fixed-shape prefill/decode loop on a same-length batch."""
    from repro.launch.serve import serve_batch
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, cfg.vocab)
    gen, stats = serve_batch(cfg, params, prompts, gen_len=5)
    legacy = _legacy_serve_batch(cfg, params, prompts, gen_len=5)
    np.testing.assert_array_equal(np.asarray(gen), legacy)
    assert stats["tok_per_s"] > 0


@pytest.mark.slow
def test_serve_batch_matches_legacy_loop_planned(tmp_path):
    """Same-length parity WITH the planned diana backend bound: the engine
    route and the legacy loop execute identical planned kernels."""
    from repro.launch.serve import plan_mapping_execution, serve_batch
    cfg = _reduced("zamba2-1.2b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    art = _diana_artifact(cfg, params, tmp_path)
    plan, backend = plan_mapping_execution(params, art)
    assert "fp" not in plan.kernel_histogram()
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    gen, _ = serve_batch(cfg, params, prompts, gen_len=4, backend=backend)
    legacy = _legacy_serve_batch(cfg, params, prompts, gen_len=4,
                                 backend=backend)
    np.testing.assert_array_equal(np.asarray(gen), legacy)
    assert not backend.unbound and not backend.runtime_declines


# --------------------------------------------------------------------------
# acceptance: mixed-length engine == per-request serve_batch, planned diana
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_mixed_length_per_request_parity_planned(tmp_path):
    """ISSUE acceptance criterion: on a mixed-length prompt set with the
    planned backend bound (diana, zero fp fallbacks), the continuous-
    batching engine produces token-identical greedy outputs vs per-request
    `serve_batch`."""
    from repro.launch.serve import plan_mapping_execution, serve_batch
    cfg = _reduced("zamba2-1.2b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    art = _diana_artifact(cfg, params, tmp_path)
    plan, backend = plan_mapping_execution(params, art)
    assert "fp" not in plan.kernel_histogram(), plan.kernel_histogram()

    rng = np.random.default_rng(5)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=plen),
                    max_new_tokens=new)
            for i, (plen, new) in enumerate([(7, 4), (3, 5), (8, 3),
                                             (5, 4)])]
    eng = Engine(cfg, params, max_batch=2, max_len=16, backend=backend)
    results = eng.run(reqs)
    assert backend.fully_covered and not backend.runtime_declines

    for r, res in zip(reqs, results):
        gen, _ = serve_batch(cfg, params, jnp.asarray(r.prompt)[None],
                             gen_len=r.max_new_tokens, backend=backend)
        assert res.tokens == list(np.asarray(gen)[0]), \
            (r.rid, res.tokens, np.asarray(gen)[0])


def test_engine_mixed_length_per_request_parity_fp():
    """Mixed-length engine-vs-per-request parity without a mapping (pure
    bf16/f32 path), yi-9b reduced — the cheap always-on version of the
    acceptance test."""
    from repro.launch.serve import serve_batch
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=plen),
                    max_new_tokens=new)
            for i, (plen, new) in enumerate([(6, 3), (2, 6), (9, 2),
                                             (4, 4), (3, 3)])]
    eng = Engine(cfg, params, max_batch=2, max_len=16)
    results = eng.run(reqs)
    for r, res in zip(reqs, results):
        gen, _ = serve_batch(cfg, params, jnp.asarray(r.prompt)[None],
                             gen_len=r.max_new_tokens)
        assert res.tokens == list(np.asarray(gen)[0]), (r.rid,)


# --------------------------------------------------------------------------
# slot retirement / admission through the engine
# --------------------------------------------------------------------------

def test_engine_retirement_and_admission():
    """Slots retire on max_new_tokens/eos/length_cap and are refilled
    mid-flight; every request completes with the right finish reason."""
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    mk = lambda i, plen, new, **kw: Request(
        rid=i, prompt=rng.integers(0, cfg.vocab, size=plen),
        max_new_tokens=new, **kw)
    # learn a token to use as EOS for request 1
    probe = Engine(cfg, params, max_batch=1, max_len=16)
    r1 = mk(1, 5, 6)
    probe_tok = probe.run([Request(rid="p", prompt=r1.prompt,
                                   max_new_tokens=2)])[0].tokens
    reqs = [
        mk(0, 4, 1),                                   # retires at admission
        Request(rid=1, prompt=r1.prompt, max_new_tokens=6,
                eos_id=int(probe_tok[1])),             # retires on EOS
        mk(2, 14, 8),                                  # hits the length cap
        mk(3, 3, 4),                                   # fills a freed slot
        mk(4, 3, 3, arrival_step=2),                   # late arrival
    ]
    eng = Engine(cfg, params, max_batch=2, max_len=16)
    res = {r.rid: r for r in eng.run(reqs)}
    assert res[0].finish_reason == "max_new_tokens" and res[0].n_tokens == 1
    assert res[0].finished_step == res[0].admitted_step   # no decode needed
    assert res[1].finish_reason == "eos" and res[1].n_tokens == 2
    assert res[2].finish_reason == "length_cap"
    assert res[2].prompt_len + res[2].n_tokens - 1 == 16  # pool exhausted
    assert res[3].finish_reason == "max_new_tokens" and res[3].n_tokens == 4
    assert res[4].n_tokens == 3 and res[4].admitted_step >= 2
    assert all(r.ttft_s >= 0 and r.finish_s >= r.ttft_s
               for r in res.values())


def test_engine_rejects_oversized_prompt():
    """Dense keeps the old hard max_len bound; paged admits anything that
    fits in ``pages_per_slot * page_size`` tokens and only refuses (with a
    warning naming the request and its page requirement) beyond that."""
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_batch=1, max_len=8, kv_layout="dense")
    with pytest.raises(ValueError, match="max_len"):
        eng.run([Request(rid=0, prompt=np.zeros(8, np.int32),
                         max_new_tokens=2)])
    # paged: the same prompt fits (slot capacity = ceil(8/4)*4 = 8 tokens
    # of pages, prompt 8 needs all of them and decode budget spills past —
    # still admitted, generation just stops at the slot capacity)
    eng = Engine(cfg, params, max_batch=1, max_len=8, kv_layout="paged",
                 page_size=4)
    res = eng.run([Request(rid=0, prompt=np.zeros(6, np.int32),
                           max_new_tokens=2)])
    assert len(res[0].tokens) == 2
    # ... but a prompt beyond the whole slot's page capacity is unservable
    with pytest.warns(UserWarning, match="unservable request 'big'"):
        with pytest.raises(ValueError, match="pages"):
            eng.run([Request(rid="big", prompt=np.zeros(9, np.int32),
                             max_new_tokens=2)])


def test_engine_static_policy_same_tokens_more_steps():
    """The static gang-batching baseline produces the same greedy tokens but
    cannot overlap mixed-length requests (>= decode steps, ttft no
    better)."""
    cfg = _reduced("yi-9b")
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    trace = synthetic_trace(6, vocab=cfg.vocab, min_prompt=3, max_prompt=10,
                            min_new=2, max_new=8, seed=2)
    cont = Engine(cfg, params, max_batch=2, max_len=20)
    res_c = cont.run(trace)
    stat = Engine(cfg, params, max_batch=2, max_len=20,
                  scheduler=Scheduler("static"))
    res_s = stat.run(trace)
    assert [r.tokens for r in res_c] == [r.tokens for r in res_s]
    assert stat.stats["decode_steps"] >= cont.stats["decode_steps"]


# --------------------------------------------------------------------------
# masked-decode regression: per-slot cache lengths
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi-9b", "zamba2-1.2b"])
def test_masked_decode_per_slot_cache_lengths(arch):
    """Per-slot decode (index (B,), per-slot kv masking) must match scalar
    single-request decode for every slot, with slots parked at DIFFERENT
    cache lengths and garbage KV beyond each slot's length (the ragged-
    prefill contract)."""
    cfg = _reduced(arch)
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    S_max, P_pad = 16, 8
    lens = [6, 8, 2]
    B = len(lens)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, size=l) for l in lens]
    padded = jnp.asarray(np.stack(
        [np.pad(p, (0, P_pad - len(p))) for p in prompts]).astype(np.int32))
    caches = T.init_cache(cfg, B, S_max)
    lengths = jnp.asarray(lens, jnp.int32)
    logits, caches = T.prefill(params, cfg, padded, caches, lengths=lengths)
    tok = jnp.argmax(logits, -1)
    seqs = [tok]
    for step in range(3):
        logits, caches = T.decode_step(params, cfg, tok, caches,
                                       lengths + step,
                                       active=jnp.ones((B,), bool))
        tok = jnp.argmax(logits, -1)
        seqs.append(tok)
    got = np.asarray(jnp.stack(seqs, axis=1))            # (B, 4)
    # reference: each slot alone, scalar index, exact-length cache
    for b in range(B):
        c1 = T.init_cache(cfg, 1, lens[b] + 4)
        lg, c1 = T.prefill(params, cfg, jnp.asarray(prompts[b])[None], c1)
        t1 = jnp.argmax(lg, -1)
        ref = [int(t1[0])]
        for s in range(3):
            lg, c1 = T.decode_step(params, cfg, t1, c1, lens[b] + s)
            t1 = jnp.argmax(lg, -1)
            ref.append(int(t1[0]))
        assert list(got[b]) == ref, (arch, b, list(got[b]), ref)


def test_scatter_cache_roundtrip():
    """`scatter_cache` writes a k-request cache into the right slots of the
    pool and leaves other slots untouched."""
    cfg = _reduced("zamba2-1.2b")
    pool = T.init_cache(cfg, 3, 8)
    pool = jax.tree.map(lambda l: jnp.ones_like(l), pool)
    sub = T.init_cache(cfg, 2, 8)
    sub = jax.tree.map(lambda l: jnp.full_like(l, 2), sub)
    out = T.scatter_cache(pool, sub, jnp.asarray([2, 0]))
    axes = T.cache_batch_axes(pool)

    def check(leaf, ax):
        leaf = np.asarray(leaf, np.float32)
        idx = [slice(None)] * leaf.ndim
        for slot, val in ((0, 2.0), (1, 1.0), (2, 2.0)):
            idx[ax] = slot
            assert (leaf[tuple(idx)] == val).all()
    jax.tree.map(check, out, axes)
