"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle,
sweeping shapes and dtypes (hypothesis for the matmuls)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional test dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.split_precision import split_precision_matmul
from repro.kernels.ternary_matmul import ternary_matmul


def _rand_int8(key, shape, lo=-127, hi=128):
    return jax.random.randint(key, shape, lo, hi, dtype=jnp.int8)


# ------------------------------------------------------------ quant_matmul
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 512, 128, 128, 128, 512),
    (256, 1024, 256, 128, 128, 512),
    (8, 512, 128, 8, 128, 512),
    (128, 512, 384, 128, 128, 256),
])
def test_quant_matmul_blocks(m, k, n, bm, bn, bk):
    key = jax.random.PRNGKey(m + k + n)
    xq = _rand_int8(key, (m, k))
    wq = _rand_int8(jax.random.fold_in(key, 1), (k, n))
    sx = jnp.asarray(0.013, jnp.float32)
    sw = jax.random.uniform(jax.random.fold_in(key, 2), (n,), jnp.float32)
    out = quant_matmul(xq, wq, sx, sw, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.quant_matmul_ref(xq, wq, sx, sw)),
                               rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(m=st.sampled_from([16, 100, 128]), k=st.sampled_from([96, 512]),
       n=st.sampled_from([130, 256]), seed=st.integers(0, 100))
def test_quant_matmul_op_padding(m, k, n, seed):
    """ops.py wrapper handles non-block-aligned shapes via padding."""
    key = jax.random.PRNGKey(seed)
    xq = _rand_int8(key, (m, k))
    wq = _rand_int8(jax.random.fold_in(key, 1), (k, n))
    sx = jnp.asarray(0.07, jnp.float32)
    sw = jax.random.uniform(jax.random.fold_in(key, 2), (n,), jnp.float32)
    out = ops.quant_matmul_op(xq, wq, sx, sw, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.quant_matmul_ref(xq, wq, sx, sw)),
                               rtol=1e-5)


# ---------------------------------------------------------- ternary_matmul
def test_ternary_matmul():
    key = jax.random.PRNGKey(0)
    m, k, n = 128, 512, 256
    xq = _rand_int8(key, (m, k))
    wt = _rand_int8(jax.random.fold_in(key, 1), (k, n), -1, 2)
    sx = jnp.asarray(0.02, jnp.float32)
    sw = jnp.full((n,), 0.5, jnp.float32)
    out = ternary_matmul(xq, wt, sx, sw, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.ternary_matmul_ref(xq, wt, sx, sw)),
        rtol=1e-6)
    assert set(np.unique(np.asarray(wt))) <= {-1, 0, 1}


# --------------------------------------------------------- split precision
@pytest.mark.parametrize("boundary_frac", [0.0, 0.25, 0.5, 1.0])
def test_split_precision_matmul(boundary_frac):
    key = jax.random.PRNGKey(3)
    m, k, n = 128, 512, 512
    bn = 128
    boundary = int(n * boundary_frac) // bn * bn
    x = jax.random.normal(key, (m, k), jnp.bfloat16)
    xq = _rand_int8(jax.random.fold_in(key, 1), (m, k))
    wb = jax.random.normal(jax.random.fold_in(key, 2), (k, n), jnp.bfloat16)
    wq = _rand_int8(jax.random.fold_in(key, 3), (k, n))
    sx = jnp.asarray(0.01, jnp.float32)
    sw = jax.random.uniform(jax.random.fold_in(key, 4), (n,), jnp.float32)
    out = split_precision_matmul(x, xq, sx, wb, wq, sw, boundary,
                                 interpret=True)
    expect = ref.split_precision_matmul_ref(x, xq, sx, wb, wq, sw, boundary)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-2, atol=2e-2)


def test_odimo_deployed_dense_matches_fake_quant():
    """Deployment path == search-time discretized fake-quant semantics."""
    from repro.core import quant
    key = jax.random.PRNGKey(7)
    m, k, n = 64, 256, 256
    x = jax.random.normal(key, (m, k))
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
    assign = np.asarray(jax.random.bernoulli(
        jax.random.fold_in(key, 2), 0.5, (n,)).astype(np.int64))
    wls = quant.init_log_scale(w)
    xls = quant.init_log_scale(x)
    out = ops.odimo_deployed_dense(x, w, assign, wls, xls, interpret=True)
    # oracle: int8-domain columns use fake-quant x and w; bf16 columns plain
    xq = quant.fake_quant(x, xls, 8)
    wq8 = quant.fake_quant(w, wls, 8)
    lo = (xq @ wq8)
    hi = (x.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16)).astype(jnp.float32)
    expect = jnp.where(jnp.asarray(assign)[None, :] == 0, lo, hi)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=0.05, atol=0.12)


# --------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,H,KVH,Sq,Sk,D,causal", [
    (1, 4, 4, 256, 256, 64, True),
    (2, 8, 2, 256, 512, 64, True),     # GQA G=4
    (1, 4, 1, 512, 512, 128, True),    # MQA
    (1, 2, 2, 256, 256, 64, False),
])
def test_flash_attention(B, H, KVH, Sq, Sk, D, causal):
    key = jax.random.PRNGKey(B * H + Sq)
    q = jax.random.normal(key, (B, H, Sq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, KVH, Sk, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, KVH, Sk, D), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=128, bk=128,
                          interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_dtype_bf16():
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (1, 4, 256, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 256, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 4, 256, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=128, bk=128, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)
