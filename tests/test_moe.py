"""MoE unit tests: routing mass conservation, capacity dropping, shared
experts, load-balance loss, group-heuristic behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as M


def _cfg(**over):
    base = dict(n_experts=8, top_k=2, d_ff=16, capacity_factor=8.0)
    base.update(over)
    return M.MoEConfig(**base)


def _run(cfg, B=2, S=16, d=8, seed=0):
    key = jax.random.PRNGKey(seed)
    p = M.init_moe(key, d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d))
    y, aux = M.moe_ffn(p, x, cfg)
    return p, x, y, aux


def test_output_shape_and_finite():
    cfg = _cfg()
    _, x, y, aux = _run(cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux["load_balance"]))


def test_high_capacity_matches_exact_topk_computation():
    """With no drops, the grouped dense dispatch equals a direct per-token
    top-k expert evaluation."""
    cfg = _cfg(capacity_factor=50.0, n_shared=0)
    p, x, y, _ = _run(cfg)
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt @ np.asarray(p["router"]["w"])
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    out = np.zeros_like(np.asarray(xt))
    for t in range(xt.shape[0]):
        for j in range(cfg.top_k):
            e = int(topi[t, j])
            h = jax.nn.silu(xt[t] @ p["gate"][e]) * (xt[t] @ p["up"][e])
            out[t] += float(topv[t, j]) * np.asarray(h @ p["down"][e])
    np.testing.assert_allclose(np.asarray(y).reshape(-1, D), out,
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens():
    """Tiny capacity forces drops -> output differs from no-drop config."""
    cfg_lo = _cfg(capacity_factor=0.25)
    cfg_hi = _cfg(capacity_factor=50.0)
    p, x, y_lo, _ = _run(cfg_lo, seed=3)
    y_hi, _ = M.moe_ffn(p, x, cfg_hi)
    assert not np.allclose(np.asarray(y_lo), np.asarray(y_hi))


def test_shared_expert_always_contributes():
    cfg = _cfg(n_shared=1)
    p, x, y, _ = _run(cfg)
    y_no_shared, _ = M.moe_ffn({k: v for k, v in p.items() if k != "shared"},
                               x, dataclasses.replace(cfg, n_shared=0))
    assert not np.allclose(np.asarray(y), np.asarray(y_no_shared))


def test_load_balance_penalizes_collapse():
    """A router collapsed onto one expert scores worse than uniform."""
    cfg = _cfg()
    d = 8
    p = M.init_moe(jax.random.PRNGKey(0), d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, d))
    _, aux_uniform = M.moe_ffn(p, x, cfg)
    p_collapsed = dict(p)
    w = np.zeros((d, cfg.n_experts), np.float32)
    w[:, 0] = 10.0
    p_collapsed["router"] = {"w": jnp.asarray(w)}
    _, aux_collapsed = M.moe_ffn(p_collapsed, x, cfg)
    assert float(aux_collapsed["load_balance"]) > \
        float(aux_uniform["load_balance"])


def test_group_heuristic():
    """Decode-sized T collapses to one group; training T gets many."""
    cfg = _cfg()
    d = 8
    p = M.init_moe(jax.random.PRNGKey(0), d, cfg, jnp.float32)
    # T = 8 (decode-ish): G = max(1, min(256, 8 // 4096)) = 1 -> works
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, d))
    y, _ = M.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    # explicit n_groups still respected
    x2 = jax.random.normal(jax.random.PRNGKey(2), (4, 8, d))
    y2, _ = M.moe_ffn(p, x2, cfg, n_groups=2)
    assert y2.shape == x2.shape
